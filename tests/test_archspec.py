"""ArchSpec + executor coverage: serialization, match types, encodings."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (ArchSpec, CamType, Metric, OptimizationTarget,
                        PAPER_BASE_ARCH)
from repro.core.arch import AccessMode
from repro.kernels import ops, ref


def test_archspec_json_roundtrip():
    a = ArchSpec(rows=64, cols=128, cam_type=CamType.ACAM,
                 banks=8).with_target("power+density")
    b = ArchSpec.from_json(a.to_json())
    assert a == b


def test_archspec_validation():
    with pytest.raises(ValueError):
        ArchSpec(cam_type="nvram")
    with pytest.raises(ValueError):
        ArchSpec(target="speed")
    with pytest.raises(ValueError):
        ArchSpec(access={"bank": "parallel", "mat": "parallel",
                         "array": "diagonal", "subarray": "parallel"})


def test_metric_all_covers_engine_metrics():
    """Metric.ALL is the single source of truth for metric names: every
    metric the engine/IR accept (cos included — it was missing) is
    listed, Metric.validate pins construction-time rejection, and the
    IR builders actually consult it."""
    assert Metric.ALL == ("hamming", "eucl", "dot", "cos")
    assert Metric.COSINE == "cos" and Metric.COSINE in Metric.ALL
    for name in Metric.ALL:
        assert Metric.validate(name) == name
        # every listed metric must be executable by the oracle layer
        ref.distances(jnp.zeros((2, 8)), jnp.zeros((3, 8)), name)
    with pytest.raises(ValueError):
        Metric.validate("manhattan")

    from repro.core import Module, TensorType
    from repro.core.cim_dialect import make_similarity

    mod = Module("m", [TensorType((2, 8)), TensorType((4, 8))])
    with pytest.raises(ValueError):
        make_similarity(mod.body, mod.arguments[0], mod.arguments[1],
                        metric="manhattan", k=1, largest=False)
    make_similarity(mod.body, mod.arguments[0], mod.arguments[1],
                    metric="cos", k=1, largest=True)


def test_with_target_knobs():
    base = PAPER_BASE_ARCH
    p = base.with_target(OptimizationTarget.POWER)
    assert p.max_active_subarrays == 1 and not p.selective_search
    d = base.with_target(OptimizationTarget.DENSITY)
    assert d.selective_search and d.max_active_subarrays == 0
    pd = base.with_target(OptimizationTarget.POWER_DENSITY)
    assert pd.selective_search and pd.max_active_subarrays == 1


def test_capacity_accounting():
    a = ArchSpec(rows=32, cols=32, subarrays_per_array=8, arrays_per_mat=4,
                 mats_per_bank=4)
    assert a.subarrays_per_bank == 128
    assert a.cells_per_bank == 128 * 1024
    assert a.banks_needed(10, 8192) == 2      # 256 tiles over 128/bank


def test_exact_match_semantics(rng):
    """EX match: only identical rows fire (paper match-type EX)."""
    p = (rng.random((20, 48)) > 0.5).astype(np.float32)
    q = p[[4, 9]].copy()
    ex = np.asarray(ref.cam_exact(jnp.asarray(q), jnp.asarray(p)))
    assert ex[0].sum() >= 1 and ex[0, 4]
    assert ex[1, 9]


def test_threshold_match_monotone(rng):
    """TH match: match set grows monotonically with the threshold."""
    q = (rng.random((3, 64)) > 0.5).astype(np.float32)
    p = (rng.random((50, 64)) > 0.5).astype(np.float32)
    sizes = []
    for th in (0, 8, 16, 32, 64):
        m = np.asarray(ref.cam_range(jnp.asarray(q), jnp.asarray(p),
                                     float(th)))
        sizes.append(m.sum())
    assert all(b >= a for a, b in zip(sizes, sizes[1:]))
    assert sizes[-1] == 3 * 50                 # threshold D matches all


def test_sequential_access_mode_raises_latency():
    from repro.core import compile_fn

    def k(inp, w):
        mm = inp.matmul(w.transpose(-2, -1))
        return mm.topk(1, largest=False)

    seq = ArchSpec(rows=32, cols=32,
                   access={"bank": AccessMode.PARALLEL,
                           "mat": AccessMode.PARALLEL,
                           "array": AccessMode.SEQUENTIAL,
                           "subarray": AccessMode.PARALLEL})
    par = ArchSpec(rows=32, cols=32)
    rs = compile_fn(k, [(100, 4096), (10, 4096)], seq,
                    unroll_limit=0).cost_report()
    rp = compile_fn(k, [(100, 4096), (10, 4096)], par,
                    unroll_limit=0).cost_report()
    assert rs.latency_ns > rp.latency_ns
