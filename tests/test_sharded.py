"""Multi-device sharded search plans: parity with the single-device plan.

Device count is fixed at jax import time, so the multi-device checks run
in a child process launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; this file doubles
as that child (``python tests/test_sharded.py --child``).  The in-process
tests cover the single-device degradation path (shard requests clamp to
the host's device count).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

DEVICES = 8


# ---------------------------------------------------------------------------
# child: runs under 8 forced host devices
# ---------------------------------------------------------------------------


def _child_main() -> int:
    import jax

    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))
    from repro.core import ArchSpec, clear_plan_cache, compile_fn, get_plan
    from repro.core.executor import execute_module

    assert jax.device_count() == DEVICES, jax.device_count()
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from test_engine import _data, _sim_module

    rng = np.random.default_rng(7)
    arch = ArchSpec(rows=16, cols=32)

    # metrics x gallery sizes; 137 and 23 are not divisible by 8 shards
    # (23 < 8 * tile_rows even leaves some shards fully padded), 64 is
    # aligned, and n=5 < k exposes the losing-slot sentinels
    for metric, largest in (("hamming", False), ("dot", False),
                            ("cos", True), ("eucl", False)):
        for n in (137, 64, 23, 5):
            m, dim, k = 9, 100, 6
            mod = _sim_module(metric, k, largest, m, n, dim, arch)
            single = get_plan(mod, shards=1)
            sharded = get_plan(mod, shards=DEVICES)
            assert sharded is not None and sharded.shards == DEVICES
            assert single is not sharded, "shard count must split the key"
            q, p = _data(rng, metric, m, n, dim)
            sv, si = single.execute(q, p)
            mv, mi = sharded.execute(q, p)
            np.testing.assert_array_equal(
                np.asarray(si), np.asarray(mi),
                err_msg=f"indices diverged: {metric} n={n}")
            if metric in ("hamming", "dot"):   # integer metrics: bit-exact
                np.testing.assert_array_equal(
                    np.asarray(sv), np.asarray(mv),
                    err_msg=f"values diverged: {metric} n={n}")
            else:
                np.testing.assert_allclose(np.asarray(sv), np.asarray(mv),
                                           atol=1e-4)
            # the interpreter stays the semantic oracle for the sharded
            # path too
            iv, ii = execute_module(mod, q, p)
            np.testing.assert_array_equal(np.asarray(mi), np.asarray(ii))

    # shard requests beyond the host clamp (and share the clamped key)
    mod = _sim_module("eucl", 3, False, 8, 40, 64, arch)
    clear_plan_cache()
    p16 = get_plan(mod, shards=16)
    p8 = get_plan(mod, shards=DEVICES)
    assert p16.shards == DEVICES and p16 is p8

    # compile_fn front door: shards land on the program's plan
    def knn(q, g):
        diff = q.unsqueeze(1).sub(g)
        return diff.norm(p=2, dim=-1).topk(5, largest=False)

    q = rng.standard_normal((12, 96)).astype(np.float32)
    g = rng.standard_normal((137, 96)).astype(np.float32)
    prog1 = compile_fn(knn, [q, g], arch)
    prog8 = compile_fn(knn, [q, g], arch, shards=DEVICES)
    assert prog8.shards == DEVICES and prog8.engine_plan.shards == DEVICES
    v1, i1 = prog1(q, g)
    v8, i8 = prog8(q, g)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i8))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v8), atol=1e-4)

    # pallas backend cannot shard: explicit error, not silent fallback
    try:
        get_plan(mod, backend="pallas", shards=DEVICES)
    except ValueError:
        pass
    else:
        raise AssertionError("pallas + shards>1 should raise")

    print("SHARDED-OK")
    return 0


# ---------------------------------------------------------------------------
# pytest entry points
# ---------------------------------------------------------------------------


def test_sharded_plan_parity_multi_device():
    """Full multi-device parity matrix under 8 forced host devices."""
    from repro.launch.mesh import forced_host_devices_env

    env = forced_host_devices_env(DEVICES)
    env.pop("REPRO_ENGINE_MAX_CHUNK", None)
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0 and "SHARDED-OK" in out.stdout, (
        f"sharded child failed (rc={out.returncode}):\n"
        f"{out.stdout[-3000:]}\n{out.stderr[-3000:]}")


def test_shards_clamp_to_single_device():
    """On a 1-device host a shard request degrades to the unsharded plan
    (same cache entry as shards=1) and still computes correctly."""
    import jax

    from repro.core import clear_plan_cache, get_plan, ArchSpec
    from repro.core.executor import execute_module
    from test_engine import _data, _sim_module

    if jax.device_count() != 1:
        pytest.skip("host already multi-device")
    rng = np.random.default_rng(3)
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("dot", 3, False, 6, 30, 64, arch)
    clear_plan_cache()
    # the pallas refusal is host-invariant: it fires on the *requested*
    # shard count even though this 1-device host would clamp to 1
    with pytest.raises(ValueError):
        get_plan(mod, backend="pallas", shards=8)
    plan = get_plan(mod, shards=8)
    assert plan.shards == 1
    assert plan is get_plan(mod, shards=1) and plan is get_plan(mod)
    q, p = _data(rng, "dot", 6, 30, 64)
    v, i = plan.execute(q, p)
    iv, ii = execute_module(mod, q, p)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ii))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(iv))


if __name__ == "__main__":
    if "--child" in sys.argv:
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={DEVICES}")
        raise SystemExit(_child_main())
    raise SystemExit(pytest.main([__file__, "-v"]))
