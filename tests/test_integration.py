"""End-to-end integration: training loop (loss goes down, resume-exact),
failure recovery mid-training, serving loop, C4CAM-in-the-loop MoE."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.launch.train import TrainLoop
from repro.launch.serve import Request, Server
from repro.models import model


def test_training_loss_decreases(tmp_path):
    cfg = get_smoke_config("xlstm-125m")
    loop = TrainLoop(cfg, batch=8, seq=64, steps=30, lr=3e-3,
                     ckpt_dir=str(tmp_path))
    out = loop.run()
    first = np.mean([h["loss"] for h in loop.history[:5]])
    last = np.mean([h["loss"] for h in loop.history[-5:]])
    assert last < first - 0.2, f"loss {first:.3f} -> {last:.3f}"


def test_failure_injection_recovers_and_resumes(tmp_path):
    cfg = get_smoke_config("chatglm3-6b")
    loop = TrainLoop(cfg, batch=4, seq=32, steps=12, ckpt_dir=str(tmp_path),
                     ckpt_every=4, fail_at=6)
    out = loop.run()
    assert out["restarts"] == 1
    assert np.isfinite(out["final"]["loss"])


def test_resume_bit_exact(tmp_path):
    """Training N steps straight == training k, restoring, training N-k."""
    cfg = get_smoke_config("qwen2.5-14b")

    loop_a = TrainLoop(cfg, batch=4, seq=32, steps=8,
                       ckpt_dir=str(tmp_path / "a"), ckpt_every=4, seed=3)
    out_a = loop_a.run()

    loop_b = TrainLoop(cfg, batch=4, seq=32, steps=4,
                       ckpt_dir=str(tmp_path / "b"), ckpt_every=4, seed=3)
    loop_b.run()
    loop_c = TrainLoop(cfg, batch=4, seq=32, steps=8,
                       ckpt_dir=str(tmp_path / "b"), ckpt_every=4, seed=3)
    state, step = loop_c.supervisor.restore(loop_c.state)
    loop_c.state = state
    loop_c.loader.step = step
    out_c = loop_c.run()

    pa = jax.tree.leaves(loop_a.state.params)
    pc = jax.tree.leaves(loop_c.state.params)
    for a, c in zip(pa, pc):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_elastic_restore_into_new_state(tmp_path):
    """Checkpoints store logical content: restore into a freshly-built
    (differently-placed) state works and matches."""
    cfg = get_smoke_config("xlstm-125m")
    loop = TrainLoop(cfg, batch=4, seq=32, steps=4,
                     ckpt_dir=str(tmp_path), ckpt_every=2, seed=9)
    loop.run()
    fresh = TrainLoop(cfg, batch=4, seq=32, steps=4,
                      ckpt_dir=str(tmp_path), ckpt_every=2, seed=99)
    state, step = fresh.supervisor.restore(fresh.state)
    a = jax.tree.leaves(loop.state.params)
    b = jax.tree.leaves(state.params)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_gradient_compression_trains(tmp_path):
    cfg = get_smoke_config("xlstm-125m")
    loop = TrainLoop(cfg, batch=8, seq=64, steps=20, lr=3e-3,
                     ckpt_dir=str(tmp_path), compression="int8")
    loop.run()
    first = np.mean([h["loss"] for h in loop.history[:5]])
    last = np.mean([h["loss"] for h in loop.history[-5:]])
    assert last < first - 0.1


def test_serving_loop_completes_requests():
    cfg = get_smoke_config("chatglm3-6b")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, params, batch=2, max_len=40)
    rng = np.random.default_rng(0)
    for r in range(4):
        srv.submit(Request(rid=r, prompt=rng.integers(1, cfg.vocab, 8),
                           max_new=6))
    out = srv.run()
    assert out["completed"] == 4
    assert out["tokens"] >= 4 * 5


def test_moe_cam_offload_end_to_end(tmp_path):
    """deepseek-style MoE with the router running through the C4CAM
    primitive — the paper's technique inside the LM framework."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("deepseek-moe-16b"),
                              router_offload="cam")
    loop = TrainLoop(cfg, batch=4, seq=32, steps=6, ckpt_dir=str(tmp_path))
    out = loop.run()
    assert np.isfinite(out["final"]["loss"])
