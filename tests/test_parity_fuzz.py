"""Randomized engine/interpreter/oracle parity fuzz harness.

Generates random programs across **both plan families** — similarity
(metric x k x n<k x packed/unpacked x ternary care masks x tile
geometry x unrolled/loop-structured IR) and range (threshold across
metrics/polarity + aCAM interval) — and asserts that the compiled
engine plan, the IR interpreter, and the tiled reference oracles agree:
indices and boolean matches bit-exactly everywhere, values bit-exactly
for the integer metrics and to float tolerance for the analog ones.

A **hierarchical** sweep rides along: random two-stage plans
(clusters x nprobe x metric x polarity x packed/unpacked) must be
bit-identical to their flat equivalent at ``nprobe == clusters`` and
monotone in recall as ``nprobe`` grows (the probed cluster sets are
nested per query).

A third axis rides on every case: a **fault model** (absent / null /
real).  A null model (all probabilities zero) must be bit-identical to
running with no model at all on every backend and layout; a real model
must equal the clean plan run on pre-corrupted stored operands (faults
are a pure source transformation), reproduce bit-exactly across calls,
and agree between the packed and unpacked encodings.

Two drivers share one case generator:

* a deterministic numpy-seeded sweep (``REPRO_FUZZ_CASES``, default
  200 cases — the local profile the acceptance gate counts; set it
  lower for a bounded CI profile) that always runs,
* ``hypothesis`` property wrappers (via ``tests/_hypothesis_compat``)
  that explore the same space adversarially when the dependency is
  installed and skip cleanly when it is not.

Every failure message carries the full case tuple so any mismatch is
reproducible with ``_run_sim_case``/``_run_range_case`` directly.
"""

import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st
from repro.core import ArchSpec, clear_plan_cache, get_plan
from repro.core.engine import get_hierarchical_plan
from repro.core.envcfg import env_int
from repro.core.executor import execute_module
from repro.faults import FaultModel
from repro.kernels import ref as kref

from test_engine import _sim_module
from test_range import _range_module

FUZZ_CASES = env_int("REPRO_FUZZ_CASES", 200, min_value=1)
#: similarity cases get the larger share (more axes to cross);
#: hierarchical cases are the most expensive (k-means per nprobe plan)
HIER_CASES = max(1, FUZZ_CASES // 10)
SIM_CASES = ((FUZZ_CASES - HIER_CASES) * 3) // 5
RANGE_CASES = FUZZ_CASES - HIER_CASES - SIM_CASES

#: discrete axes — small enough that geometry keys repeat (plan-cache
#: hits keep the sweep fast), rich enough to cross every semantics axis
_METRICS = ("hamming", "dot", "eucl", "cos")
_RANGE_METRICS = ("hamming", "dot", "eucl")
_MS = (1, 2, 7, 9)
_NS = (2, 5, 16, 21, 40)                   # includes n < k cases
_KS = (1, 3, 6)
_DIMS = (8, 17, 32, 64)
_ROWS = (4, 8, 16)
_COLS = (8, 16, 32)
_UNROLL = (64, 0)                          # explicit tile ops vs loops


def _draw_sim_case(rng: np.random.Generator) -> dict:
    metric = _METRICS[rng.integers(len(_METRICS))]
    case = {
        "family": "sim",
        "metric": metric,
        "largest": bool(rng.integers(2)) if metric in ("dot", "cos")
        else False,
        "m": int(_MS[rng.integers(len(_MS))]),
        "n": int(_NS[rng.integers(len(_NS))]),
        "k": int(_KS[rng.integers(len(_KS))]),
        "dim": int(_DIMS[rng.integers(len(_DIMS))]),
        "rows": int(_ROWS[rng.integers(len(_ROWS))]),
        "cols": int(_COLS[rng.integers(len(_COLS))]),
        "unroll": int(_UNROLL[rng.integers(len(_UNROLL))]),
        # None = auto-pack (packs hamming/dot/cos); False = float path
        "pack": None if rng.integers(2) else False,
        "care": bool(metric == "hamming" and rng.integers(10) < 3),
        "faults": _draw_faults(rng, analog=metric == "eucl"),
    }
    return case


def _draw_faults(rng: np.random.Generator, *, analog: bool):
    """Fault axis: absent / null (p=0, must be bit-identical to clean) /
    real (stuck + flips, plus sigma noise on analog cells)."""
    r = int(rng.integers(3))
    if r == 0:
        return None
    if r == 1:
        return {"seed": int(rng.integers(1 << 16))}        # null model
    return {"seed": int(rng.integers(1 << 16)),
            "p_stuck": float(rng.uniform(0.01, 0.05)),
            "p_flip": float(rng.uniform(0.0, 0.02)),
            "sigma": float(rng.uniform(0.0, 0.05)) if analog else 0.0}


def _draw_range_case(rng: np.random.Generator) -> dict:
    interval = bool(rng.integers(4) == 0)
    metric = _RANGE_METRICS[rng.integers(len(_RANGE_METRICS))]
    return {
        "family": "range",
        "interval": interval,
        "metric": metric,
        "below": bool(rng.integers(2)),
        "quantile": float(rng.uniform(0.15, 0.85)),
        "m": int(_MS[rng.integers(len(_MS))]),
        "n": int(_NS[rng.integers(len(_NS))]),
        "dim": int(_DIMS[rng.integers(len(_DIMS))]),
        "rows": int(_ROWS[rng.integers(len(_ROWS))]),
        "cols": int(_COLS[rng.integers(len(_COLS))]),
        "pack": None if rng.integers(2) else False,
        "faults": _draw_faults(rng, analog=interval or metric == "eucl"),
    }


def _data_for(rng, metric, m, n, dim):
    """Metric-appropriate operands.

    ``dot``/``cos`` draw bipolar ±1 cells — the CAM stores *bits*
    (``_encode`` binarises via ``x > 0``), so only bipolar data makes
    the logical dot (``dim - 2 * hamming``) equal the arithmetic dot
    the oracles compute; that identity is exactly what the fuzz pins.
    """
    if metric == "hamming":
        return ((rng.random((m, dim)) > 0.5).astype(np.float32),
                (rng.random((n, dim)) > 0.5).astype(np.float32))
    if metric in ("dot", "cos"):
        return (np.where(rng.random((m, dim)) < 0.5, -1.0, 1.0
                         ).astype(np.float32),
                np.where(rng.random((n, dim)) < 0.5, -1.0, 1.0
                         ).astype(np.float32))
    return (rng.standard_normal((m, dim)).astype(np.float32),
            rng.standard_normal((n, dim)).astype(np.float32))


def _run_sim_case(case: dict, rng: np.random.Generator) -> None:
    m, n, dim, k = case["m"], case["n"], case["dim"], case["k"]
    metric, largest = case["metric"], case["largest"]
    arch = ArchSpec(rows=case["rows"], cols=case["cols"])
    q, p = _data_for(rng, metric, m, n, dim)
    care = None
    if case["care"]:
        care = (rng.random((n, dim)) > 0.3).astype(np.float32)
        care[rng.integers(n)] = 0.0        # an all-wildcard row

    if care is None:
        mod = _sim_module(metric, k, largest, m, n, dim, arch,
                          unroll_limit=case["unroll"])
        inputs = (q, p)
    else:
        mod = _ternary_module(m, n, dim, k, arch)
        inputs = (q, p, care)
    plan = get_plan(mod, pack=case["pack"])
    assert plan is not None, f"no plan for {case}"

    ev, ei = (np.asarray(x) for x in plan.execute(*inputs))
    iv, ii = (np.asarray(x) for x in execute_module(mod, *inputs))
    np.testing.assert_array_equal(ei, ii, err_msg=f"engine!=interp {case}")
    if metric in ("hamming", "dot"):
        np.testing.assert_array_equal(ev, iv,
                                      err_msg=f"engine!=interp {case}")
    else:
        np.testing.assert_allclose(ev, iv, atol=1e-4,
                                   err_msg=f"engine!=interp {case}")

    # tiled ref oracle at the plan's actual geometry.  On bipolar data
    # cos is dot up to a positive per-pair-constant norm, and the
    # engine reports the dot value for both — so the dot oracle pins
    # cos bit-exactly too (same integers, same stable ties).
    tr, dpt = plan.spec.tile_rows, plan.spec.dims_per_tile
    oracle_metric = "dot" if metric == "cos" else metric
    rv, ri = (np.asarray(x) for x in kref.cam_topk_tiled(
        jnp.asarray(q), jnp.asarray(p), metric=oracle_metric, k=k,
        largest=largest, tile_rows=tr, dims_per_tile=dpt,
        care=None if care is None else jnp.asarray(care)))
    np.testing.assert_array_equal(ei, ri, err_msg=f"engine!=oracle {case}")
    if metric == "eucl":
        np.testing.assert_allclose(ev, rv, atol=1e-4,
                                   err_msg=f"engine!=oracle {case}")
    else:
        np.testing.assert_array_equal(ev, rv,
                                      err_msg=f"engine!=oracle {case}")

    _check_sim_faults(case, plan, mod, inputs, ev, ei)


def _check_sim_faults(case, plan, mod, inputs, ev, ei):
    """Fault axis for a similarity case (see module docstring)."""
    if case["faults"] is None:
        return
    fm = FaultModel(**case["faults"])
    fv, fi = (np.asarray(x) for x in plan.execute(*inputs, faults=fm))
    if fm.is_null:
        np.testing.assert_array_equal(fi, ei,
                                      err_msg=f"null-faults!=clean {case}")
        np.testing.assert_array_equal(fv, ev,
                                      err_msg=f"null-faults!=clean {case}")
        return
    # faults == a pure transformation of the stored operands
    corr = fm.corrupt_stored(tuple(np.asarray(s) for s in inputs[1:]),
                             plan.spec)
    wv, wi = (np.asarray(x) for x in plan.execute(inputs[0], *corr))
    np.testing.assert_array_equal(fi, wi,
                                  err_msg=f"faults!=corrupted-src {case}")
    np.testing.assert_array_equal(fv, wv,
                                  err_msg=f"faults!=corrupted-src {case}")
    # seeded injection reproduces bit-exactly across calls
    fv2, fi2 = (np.asarray(x) for x in plan.execute(
        *inputs, faults=FaultModel(**case["faults"])))
    np.testing.assert_array_equal(fi, fi2,
                                  err_msg=f"faults not reproducible {case}")
    np.testing.assert_array_equal(fv, fv2,
                                  err_msg=f"faults not reproducible {case}")
    # ... and across the packed / unpacked encodings
    if plan.packed:
        uv, ui = (np.asarray(x) for x in get_plan(mod, pack=False)
                  .execute(*inputs, faults=fm))
        np.testing.assert_array_equal(fi, ui,
                                      err_msg=f"packed!=unpacked {case}")
        if case["metric"] in ("hamming", "dot"):
            np.testing.assert_array_equal(
                fv, uv, err_msg=f"packed!=unpacked {case}")
        else:
            np.testing.assert_allclose(
                fv, uv, atol=1e-4, err_msg=f"packed!=unpacked {case}")


def _check_range_faults(case, plan, inputs, em):
    """Fault axis for a range case."""
    if case["faults"] is None:
        return
    fm = FaultModel(**case["faults"])
    f = np.asarray(plan.execute(*inputs, faults=fm))
    if fm.is_null:
        np.testing.assert_array_equal(f, em,
                                      err_msg=f"null-faults!=clean {case}")
        return
    corr = fm.corrupt_stored(tuple(np.asarray(s) for s in inputs[1:]),
                             plan.spec)
    w = np.asarray(plan.execute(inputs[0], *corr))
    np.testing.assert_array_equal(f, w,
                                  err_msg=f"faults!=corrupted-src {case}")
    f2 = np.asarray(plan.execute(
        *inputs, faults=FaultModel(**case["faults"])))
    np.testing.assert_array_equal(f, f2,
                                  err_msg=f"faults not reproducible {case}")


def _run_range_case(case: dict, rng: np.random.Generator) -> None:
    m, n, dim = case["m"], case["n"], case["dim"]
    arch = ArchSpec(rows=case["rows"], cols=case["cols"])
    if case["interval"]:
        q = rng.standard_normal((m, dim)).astype(np.float32)
        lo = np.full((n, dim), -np.inf, np.float32)
        hi = np.full((n, dim), np.inf, np.float32)
        sel = rng.random((n, dim)) < 0.2
        lo[sel] = (rng.standard_normal(sel.sum()) - 1.5).astype(np.float32)
        hi[sel] = lo[sel] + rng.uniform(0.5, 4.0)
        mod = _range_module(m, n, dim, arch, interval=True)
        plan = get_plan(mod, pack=case["pack"])
        assert plan is not None, f"no plan for {case}"
        em = np.asarray(plan.execute(q, lo, hi))
        im = np.asarray(execute_module(mod, q, lo, hi)[0])
        rm = np.asarray(kref.acam_match(jnp.asarray(q), jnp.asarray(lo),
                                        jnp.asarray(hi)))
        np.testing.assert_array_equal(em, im,
                                      err_msg=f"engine!=interp {case}")
        np.testing.assert_array_equal(em, rm,
                                      err_msg=f"engine!=oracle {case}")
        _check_range_faults(case, plan, (q, lo, hi), em)
        return

    metric = case["metric"]
    q, p = _data_for(rng, metric, m, n, dim)
    mod0 = _range_module(m, n, dim, arch, metric=metric, tau=0.0)
    probe = get_plan(mod0)
    tr, dpt = probe.spec.tile_rows, probe.spec.dims_per_tile
    d = np.asarray(kref.tiled_distances(jnp.asarray(q), jnp.asarray(p),
                                        metric=metric, tile_rows=tr,
                                        dims_per_tile=dpt))
    tau = float(np.quantile(d, case["quantile"]))
    mod = _range_module(m, n, dim, arch, metric=metric, tau=tau,
                        below=case["below"])
    plan = get_plan(mod, pack=case["pack"])
    assert plan is not None, f"no plan for {case}"
    em = np.asarray(plan.execute(q, p))
    im = np.asarray(execute_module(mod, q, p)[0])
    rm = (d <= tau) if case["below"] else (d >= tau)
    np.testing.assert_array_equal(em, im, err_msg=f"engine!=interp {case}")
    np.testing.assert_array_equal(em, rm, err_msg=f"engine!=oracle {case}")
    _check_range_faults(case, plan, (q, p), em)


def _ternary_module(m, n, dim, k, arch):
    from repro.core.cim_dialect import (make_acquire, make_execute,
                                       make_release, make_similarity,
                                       make_yield)
    from repro.core.ir import Builder, Module, PassManager, TensorType
    from repro.core.passes import CompulsoryPartition

    mod = Module("fuzz_tern", [TensorType((m, dim)), TensorType((n, dim)),
                               TensorType((n, dim))])
    q_a, p_a, c_a = mod.arguments
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, [q_a, p_a, c_a],
                       [TensorType((m, k)), TensorType((m, k), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, q_a, p_a, metric="hamming", k=k,
                          largest=False, care=c_a)
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition())
    return pm.run(mod, {"arch": arch})


# ---------------------------------------------------------------------------
# hierarchical axis: two-stage plans vs their flat equivalent
# ---------------------------------------------------------------------------

#: hierarchical galleries need n >= k (the strict-identity contract:
#: with n < k the flat tournament and the probing stage fill the dead
#: slots with different — equally losing — filler indices)
_NS_HIER = (48, 64, 97, 130)
_CLUSTERS = (2, 4, 6, 8)


def _draw_hier_case(rng: np.random.Generator) -> dict:
    metric = _METRICS[rng.integers(len(_METRICS))]
    return {
        "family": "hier",
        "metric": metric,
        "largest": bool(rng.integers(2)) if metric in ("dot", "cos")
        else False,
        "m": int(_MS[rng.integers(len(_MS))]),
        "n": int(_NS_HIER[rng.integers(len(_NS_HIER))]),
        "k": int(_KS[rng.integers(len(_KS))]),
        "dim": int(_DIMS[rng.integers(len(_DIMS))]),
        "rows": int(_ROWS[rng.integers(len(_ROWS))]),
        "cols": int(_COLS[rng.integers(len(_COLS))]),
        "unroll": int(_UNROLL[rng.integers(len(_UNROLL))]),
        "pack": None if rng.integers(2) else False,
        "clusters": int(_CLUSTERS[rng.integers(len(_CLUSTERS))]),
    }


def _run_hier_case(case: dict, rng: np.random.Generator) -> None:
    m, n, dim, k = case["m"], case["n"], case["dim"], case["k"]
    metric, largest, c = case["metric"], case["largest"], case["clusters"]
    arch = ArchSpec(rows=case["rows"], cols=case["cols"])
    q, p = _data_for(rng, metric, m, n, dim)
    mod = _sim_module(metric, k, largest, m, n, dim, arch,
                      unroll_limit=case["unroll"])
    flat = get_plan(mod, pack=case["pack"])
    fv, fi = (np.asarray(x) for x in flat.execute(q, p))

    # nprobe == clusters: every tile probed -> bit-identical to flat
    full = get_hierarchical_plan(mod, clusters=c, nprobe=c,
                                 pack=case["pack"])
    hv, hi = (np.asarray(x) for x in full.execute(q, p))
    np.testing.assert_array_equal(hi, fi, err_msg=f"hier!=flat {case}")
    if metric in ("hamming", "dot"):
        np.testing.assert_array_equal(hv, fv, err_msg=f"hier!=flat {case}")
    else:
        np.testing.assert_allclose(hv, fv, atol=1e-4,
                                   err_msg=f"hier!=flat {case}")

    # recall vs the flat oracle is monotone in nprobe: the coarse
    # ranking is fixed per query, so the probed cluster sets are nested
    # and a flat winner, once a candidate, always survives selection
    flat_sets = [set(map(int, row)) for row in fi]
    recalls = []
    for nprobe in sorted({1, max(1, c // 2), c}):
        hp = get_hierarchical_plan(mod, clusters=c, nprobe=nprobe,
                                   pack=case["pack"])
        _, pi = hp.execute(q, p)
        pi = np.asarray(pi)
        recalls.append(np.mean([
            len(set(map(int, row)) & fs) / max(len(fs), 1)
            for row, fs in zip(pi, flat_sets)]))
    assert all(a <= b + 1e-9 for a, b in zip(recalls, recalls[1:])), \
        f"recall not monotone in nprobe: {recalls} {case}"
    assert recalls[-1] > 1.0 - 1e-9, f"nprobe=all recall {recalls[-1]} {case}"


def test_fuzz_hierarchical_family():
    master = np.random.default_rng(40817)
    for i in range(HIER_CASES):
        rng = np.random.default_rng(np.random.SeedSequence([40817, i]))
        _run_hier_case(_draw_hier_case(master), rng)


# ---------------------------------------------------------------------------
# deterministic sweep (always runs; REPRO_FUZZ_CASES bounds it)
# ---------------------------------------------------------------------------


def test_fuzz_similarity_family():
    clear_plan_cache()
    master = np.random.default_rng(20260729)
    for i in range(SIM_CASES):
        rng = np.random.default_rng(np.random.SeedSequence([20260729, i]))
        _run_sim_case(_draw_sim_case(master), rng)


def test_fuzz_range_family():
    master = np.random.default_rng(733)
    for i in range(RANGE_CASES):
        rng = np.random.default_rng(np.random.SeedSequence([733, i]))
        _run_range_case(_draw_range_case(master), rng)


# ---------------------------------------------------------------------------
# hypothesis property wrappers (skip cleanly without the dependency)
# ---------------------------------------------------------------------------


@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_fuzz_similarity_property(seed):
    rng = np.random.default_rng(seed)
    _run_sim_case(_draw_sim_case(rng), rng)


@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_fuzz_range_property(seed):
    rng = np.random.default_rng(seed)
    _run_range_case(_draw_range_case(rng), rng)
