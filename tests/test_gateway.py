"""Multi-tenant gateway: registry, admission, failover, healing, RYW."""

import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import ArchSpec, compile_fn
from repro.serving import (AdmissionError, CamServingGateway,
                           TenantUnavailable)
from repro.serving.tenant import _PendingQueue, _TokenBucket

N, DIM, K = 96, 16, 3


def _knn(q, gallery):
    d = q.unsqueeze(1).sub(gallery).norm(p=2, dim=-1)
    return d.topk(K, largest=False)


@pytest.fixture(scope="module")
def compiled():
    rng = np.random.default_rng(5)
    gal = rng.standard_normal((N, DIM)).astype(np.float32)
    prog = compile_fn(_knn, [np.zeros((8, DIM), np.float32), gal],
                      ArchSpec(rows=32, cols=DIM))
    assert prog.engine_plan is not None
    return prog, gal


@pytest.fixture()
def gw():
    g = CamServingGateway(maint_ms=0.0)     # no background thread: tests
    yield g                                 # drive maintenance explicitly
    g.stop()


# -- admission primitives ---------------------------------------------------

class TestAdmissionPrimitives:
    def test_token_bucket_limits_and_refills(self):
        b = _TokenBucket(rate=100.0, burst=10)
        assert b.try_acquire(10)
        assert not b.try_acquire(1)
        time.sleep(0.05)                    # ~5 tokens back
        assert b.try_acquire(2)

    def test_token_bucket_unlimited_when_rate_zero(self):
        b = _TokenBucket(rate=0.0, burst=1)
        assert all(b.try_acquire(1000) for _ in range(100))

    def test_pending_queue_sheds_lowest_priority_newest(self):
        q = _PendingQueue(limit=2)
        assert q.push(1, "a") is None
        assert q.push(1, "b") is None
        # full; incoming priority 0 ranks below everything -> bounced
        assert q.push(0, "c") == "c"
        # incoming priority 2 evicts the NEWEST of the priority-1 pair
        assert q.push(2, "d") == "b"
        assert q.pop() == "d" and q.pop() == "a" and q.pop() is None

    def test_pending_queue_fifo_within_priority(self):
        q = _PendingQueue(limit=4)
        for item in ["a", "b", "c"]:
            q.push(0, item)
        assert [q.pop() for _ in range(3)] == ["a", "b", "c"]


# -- registry ---------------------------------------------------------------

class TestRegistry:
    def test_duplicate_name_rejected(self, compiled, gw):
        prog, gal = compiled
        gw.register_tenant("a", prog, gal)
        with pytest.raises(ValueError, match="already registered"):
            gw.register_tenant("a", prog, gal)

    def test_share_with_unknown_peer(self, compiled, gw):
        with pytest.raises(KeyError, match="unknown tenant"):
            gw.register_tenant("a", share_with="ghost")

    def test_share_with_excludes_gallery(self, compiled, gw):
        prog, gal = compiled
        gw.register_tenant("a", prog, gal)
        with pytest.raises(ValueError, match="share_with"):
            gw.register_tenant("b", gallery=gal, share_with="a")

    def test_register_needs_program_and_gallery(self, gw):
        with pytest.raises(ValueError, match="program"):
            gw.register_tenant("a")

    def test_shared_tenants_share_one_replica_set(self, compiled, gw):
        prog, gal = compiled
        gw.register_tenant("a", prog, gal, replicas=2)
        gw.register_tenant("b", share_with="a")
        ta, tb = gw._tenant("a"), gw._tenant("b")
        assert ta.rset is tb.rset and ta.rset.refs == 2
        assert gw.tenants == ["a", "b"]

    def test_unknown_tenant_submit(self, compiled, gw):
        with pytest.raises(KeyError, match="unknown tenant"):
            gw.submit("ghost", np.zeros((1, DIM), np.float32))


# -- serving parity + replicas ----------------------------------------------

class TestServing:
    def test_search_bit_identical_to_plan(self, compiled, gw, rng):
        prog, gal = compiled
        gw.register_tenant("t", prog, gal, replicas=2)
        q = rng.standard_normal((5, DIM)).astype(np.float32)
        v, i = gw.search("t", q)
        ev, ei = prog.engine_plan.execute(q, gal)
        np.testing.assert_array_equal(i, np.asarray(ei))
        np.testing.assert_array_equal(v, np.asarray(ev))

    def test_replicas_share_one_pattern_memo(self, compiled, gw, rng):
        prog, gal = compiled
        plan = prog.engine_plan
        before = plan.counters()["pattern_misses"]
        gw.register_tenant("t", prog, gal, replicas=3)
        q = rng.standard_normal((2, DIM)).astype(np.float32)
        for _ in range(6):                  # bounce across replicas
            gw.search("t", q)
        after = plan.counters()["pattern_misses"]
        # one warm() prepare for the whole 3-replica fleet
        assert after - before <= 1

    def test_result_carries_device_group(self, compiled, gw, rng):
        prog, gal = compiled
        gw.register_tenant("t", prog, gal, replicas=2,
                           device_groups=["dg-A", "dg-B"])
        q = rng.standard_normal((2, DIM)).astype(np.float32)
        seen = {gw.submit("t", q).wait(10).replica for _ in range(12)}
        assert seen <= {"dg-A", "dg-B"} and seen

    def test_read_your_writes_across_shared_set(self, compiled, gw, rng):
        prog, gal = compiled
        plan = prog.engine_plan
        gw.register_tenant("w", prog, gal, replicas=2)
        gw.register_tenant("r", share_with="w")
        q = rng.standard_normal((4, DIM)).astype(np.float32)
        cur = gal.copy()
        for step in range(4):
            rows = rng.standard_normal((3, DIM)).astype(np.float32)
            idx = rng.choice(N, 3, replace=False)
            assert gw.update_gallery("w", idx, rows) == 3
            cur[idx] = rows
            _, got = gw.search("r", q)      # immediately after the write
            _, want = plan.execute(q, cur)
            np.testing.assert_array_equal(got, np.asarray(want))


# -- admission control ------------------------------------------------------

class TestAdmission:
    def test_rate_limit_rejects(self, compiled, gw, rng):
        prog, gal = compiled
        gw.register_tenant("t", prog, gal, rate=1.0, burst=2)
        q = rng.standard_normal((1, DIM)).astype(np.float32)
        gw.search("t", q)                   # burst token 1
        gw.search("t", q)                   # burst token 2
        with pytest.raises(AdmissionError, match="rate limit"):
            gw.submit("t", q)
        st = gw.health()["tenants"]["t"]["stats"]
        assert st["rejected_rate"] == 1 and st["completed"] == 2

    def test_queue_full_rejects_submitter(self, compiled, gw, rng):
        prog, gal = compiled
        # 1 outstanding slot + 1 queued; block the slot with a fault
        # injector that stalls dispatch
        gate = threading.Event()
        gw.register_tenant("t", prog, gal, queue_limit=1,
                           max_outstanding=1,
                           fault_injectors=[lambda lvl: gate.wait(10)],
                           server_kwargs={"max_wait_ms": 0.0})
        q = rng.standard_normal((1, DIM)).astype(np.float32)
        h1 = gw.submit("t", q)              # occupies the slot
        h2 = gw.submit("t", q)              # queued
        with pytest.raises(AdmissionError, match="queue full"):
            gw.submit("t", q)
        gate.set()
        assert h1.wait(10).error is None
        assert h2.wait(10).error is None

    def test_shed_prefers_low_priority(self, compiled, gw, rng):
        prog, gal = compiled
        gate = threading.Event()
        gw.register_tenant("t", prog, gal, queue_limit=1,
                           max_outstanding=1,
                           fault_injectors=[lambda lvl: gate.wait(10)],
                           server_kwargs={"max_wait_ms": 0.0})
        q = rng.standard_normal((1, DIM)).astype(np.float32)
        h1 = gw.submit("t", q)              # slot
        low = gw.submit("t", q, priority=0)  # queued
        high = gw.submit("t", q, priority=5)  # evicts low
        res = low.wait(10)
        assert isinstance(res.error, AdmissionError)
        gate.set()
        assert h1.wait(10).error is None
        assert high.wait(10).error is None
        assert gw.health()["tenants"]["t"]["stats"]["shed"] == 1

    def test_per_tenant_budgets_are_independent(self, compiled, gw, rng):
        prog, gal = compiled
        gw.register_tenant("free", prog, gal)
        gw.register_tenant("capped", share_with="free", rate=1.0, burst=1)
        q = rng.standard_normal((1, DIM)).astype(np.float32)
        gw.search("capped", q)
        with pytest.raises(AdmissionError):
            gw.submit("capped", q)
        for _ in range(5):                  # the peer is untouched
            gw.search("free", q)


# -- failover / health ------------------------------------------------------

class TestFailover:
    def test_failover_to_healthy_replica(self, compiled, gw, rng):
        prog, gal = compiled
        plan = prog.engine_plan
        gw.register_tenant("t", prog, gal, replicas=2, unhealthy_k=3)
        q = rng.standard_normal((3, DIM)).astype(np.float32)
        _, want = plan.execute(q, gal)
        gw.kill_replica("t", 0)
        for _ in range(8):                  # all served by replica 1
            _, got = gw.search("t", q)
            np.testing.assert_array_equal(got, np.asarray(want))
        h = gw.health()["tenants"]["t"]
        assert h["stats"]["failovers"] > 0
        assert h["stats"]["completed"] >= 8

    def test_kill_drain_heal_readmit(self, compiled, gw, rng):
        prog, gal = compiled
        gw.register_tenant("t", prog, gal, replicas=2, unhealthy_k=2)
        q = rng.standard_normal((2, DIM)).astype(np.float32)
        gw.kill_replica("t", 0)
        for _ in range(4):
            gw.search("t", q)               # failures drain replica 0
        rep0 = gw._tenant("t").rset.replicas[0]
        assert rep0.state == "draining"
        report = gw.check_tenant("t")
        assert [h["mode"] for h in report["healed"]] == ["rebuild"]
        assert rep0.state == "serving" and rep0.generation == 1
        assert rep0.rebuilds == 1 and not rep0._killed
        # the rebuilt replica serves again, bit-identically
        v1, i1 = gw.search("t", q)
        _, want = prog.engine_plan.execute(q, gal)
        np.testing.assert_array_equal(i1, np.asarray(want))

    def test_all_replicas_down_is_unavailable(self, compiled, gw, rng):
        prog, gal = compiled
        gw.register_tenant("t", prog, gal, replicas=1, unhealthy_k=1,
                           breaker_threshold=0)
        q = rng.standard_normal((1, DIM)).astype(np.float32)
        gw.kill_replica("t", 0)
        h = gw.submit("t", q)
        assert isinstance(h.wait(10).error, TenantUnavailable)

    def test_breaker_opens_after_unavailability(self, compiled, gw, rng):
        prog, gal = compiled
        gw.register_tenant("t", prog, gal, replicas=1, unhealthy_k=1,
                           breaker_threshold=1,
                           breaker_cooldown_ms=60_000.0)
        q = rng.standard_normal((1, DIM)).astype(np.float32)
        gw.kill_replica("t", 0)
        assert isinstance(gw.submit("t", q).wait(10).error,
                          TenantUnavailable)
        with pytest.raises(TenantUnavailable, match="breaker"):
            gw.submit("t", q)
        h = gw.health()
        assert h["status"] == "degraded"
        assert h["tenants"]["t"]["breaker"]["state"] == "open"
        assert h["tenants"]["t"]["stats"]["rejected_breaker"] == 1

    def test_divergence_detected_and_resynced(self, compiled, gw, rng):
        prog, gal = compiled
        import jax.numpy as jnp
        gw.register_tenant("t", prog, gal, replicas=2)
        rset = gw._tenant("t").rset
        # sabotage replica 1's served copy behind the gateway's back
        wrong = gal.copy()
        wrong[:5] += 1.0
        rset.replicas[1].server.adopt_gallery(jnp.asarray(wrong))
        report = gw.check_tenant("t")
        resynced = {e["replica"]: e["rows_resynced"]
                    for e in report["checked"]}
        assert resynced[1] == 5 and resynced[0] == 0
        # both replicas serve canonical content again
        q = rng.standard_normal((2, DIM)).astype(np.float32)
        _, want = prog.engine_plan.execute(q, gal)
        for _ in range(6):
            _, got = gw.search("t", q)
            np.testing.assert_array_equal(got, np.asarray(want))

    def test_fault_degraded_replica_is_drained_and_scrubbed(
            self, compiled, gw, rng):
        from repro.faults import FaultModel
        prog, gal = compiled
        # drift-only aging: a rewrite restarts drift from t=0, so the
        # heal mode must be "scrub", not "rebuild"
        fm = FaultModel(seed=99, drift=0.05, t=50)
        gw.register_tenant("t", prog, gal, replicas=2,
                           fault_models=[fm, None], max_fault_rows=0)
        rset = gw._tenant("t").rset
        report = gw.check_tenant("t")
        drained = [e for e in report["checked"] if e.get("drained")]
        assert [e["replica"] for e in drained] == [0]
        healed = {h["replica"]: h["mode"] for h in report["healed"]}
        assert healed.get(0) == "scrub"
        r0 = rset.replicas[0]
        assert r0.state == "serving" and r0.generation == 0
        assert r0.fault_model is not None and r0.fault_model.epoch == 1

    def test_maintenance_thread_heals(self, compiled, rng):
        prog, gal = compiled
        g = CamServingGateway(maint_ms=5.0)
        try:
            g.register_tenant("t", prog, gal, replicas=2, unhealthy_k=1)
            q = rng.standard_normal((1, DIM)).astype(np.float32)
            g.kill_replica("t", 0)
            g.search("t", q)                # drains replica 0
            deadline = time.perf_counter() + 10
            r0 = g._tenant("t").rset.replicas[0]
            while time.perf_counter() < deadline:
                if r0.state == "serving" and r0.rebuilds > 0:
                    break
                time.sleep(0.01)
            assert r0.state == "serving" and r0.rebuilds == 1
        finally:
            g.stop()


# -- lifecycle --------------------------------------------------------------

class TestLifecycle:
    def test_stop_settles_everything(self, compiled, rng):
        prog, gal = compiled
        gate = threading.Event()
        g = CamServingGateway(maint_ms=0.0)
        g.register_tenant("t", prog, gal, max_outstanding=1,
                          queue_limit=8,
                          fault_injectors=[lambda lvl: gate.wait(10)],
                          server_kwargs={"max_wait_ms": 0.0,
                                         "max_retries": 0,
                                         "breaker_threshold": 0})
        q = rng.standard_normal((1, DIM)).astype(np.float32)
        handles = [g.submit("t", q) for _ in range(5)]
        stopper = threading.Thread(target=g.stop)
        stopper.start()
        gate.set()
        stopper.join(15)
        assert not stopper.is_alive()
        for h in handles:
            h.wait(10)                      # every future resolves
        with pytest.raises(RuntimeError, match="stopped"):
            g.submit("t", q)

    def test_context_manager(self, compiled, rng):
        prog, gal = compiled
        q = rng.standard_normal((1, DIM)).astype(np.float32)
        with CamServingGateway(maint_ms=0.0) as g:
            g.register_tenant("t", prog, gal)
            g.search("t", q)


# -- telemetry --------------------------------------------------------------

class TestHealth:
    def test_health_shape_and_ok_status(self, compiled, gw, rng):
        prog, gal = compiled
        gw.register_tenant("t", prog, gal, replicas=2)
        q = rng.standard_normal((2, DIM)).astype(np.float32)
        for _ in range(3):
            gw.search("t", q)
        h = gw.health()
        assert h["status"] == "ok" and h["accepting"]
        e = h["tenants"]["t"]
        assert e["stats"]["completed"] == 3
        assert e["stats"]["queries"] == 6
        assert "p95_ms" in e["latency"]
        assert e["replicas"]["serving"] == 2
        assert {r["state"] for r in e["replicas"]["replicas"]} \
            == {"serving"}
        assert e["admission"]["queue_limit"] >= 1

    def test_snapshot_includes_server_snapshots(self, compiled, gw, rng):
        prog, gal = compiled
        gw.register_tenant("t", prog, gal, replicas=2)
        gw.search("t", rng.standard_normal((1, DIM)).astype(np.float32))
        snap = gw.snapshot()
        servers = snap["tenants"]["t"]["servers"]
        assert len(servers) == 2
        assert all(s is None or "plan" in s for s in servers)


def test_example_multitenant_serve_runs():
    out = subprocess.run(
        [sys.executable, "examples/multitenant_serve.py"],
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTITENANT-OK" in out.stdout
