"""Model-substrate tests: all 10 reduced architectures + layer semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import layers, model, steps
from repro.models.config import ModelConfig
from repro.optim import warmup_cosine


def _batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            key, (b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """Assignment requirement: reduced config, one forward + train step on
    CPU, output shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    batch = _batch(cfg, key)
    params = model.init_params(key, cfg)
    logits = model.forward(params, cfg, batch, train=False)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    state = steps.init_train_state(key, cfg)
    fn = jax.jit(steps.make_train_step(cfg, warmup_cosine(1e-3, 5, 50)))
    state, m = fn(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    """Decode path correctness: prefill(t[:n]) + decode steps must produce
    the same logits as the teacher-forced forward pass.

    Run in f32 so this checks the *algorithm* (cache indexing, chunked-scan
    vs recurrent state equivalence) rather than bf16 noise — the hybrid's
    exponential-state recurrences amplify bf16 rounding between the two
    mathematically-equivalent execution orders.  MoE capacity is raised so
    no tokens drop: Switch-style capacity dropping is batch-context
    dependent by design, so teacher-forcing and incremental decode only
    agree in the drop-free regime."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config(arch),
                              param_dtype="float32",
                              compute_dtype="float32",
                              capacity_factor=64.0)
    key = jax.random.PRNGKey(1)
    b, s = 2, 12
    batch = _batch(cfg, key, b, s)
    params = model.init_params(key, cfg)

    full = model.forward(params, cfg, batch, train=False).astype(jnp.float32)

    n_prefill = 8
    pre_batch = dict(batch, tokens=batch["tokens"][:, :n_prefill])
    cache = model.init_decode_cache(cfg, b, s + 2)
    lg, cache = model.prefill(params, cfg, pre_batch, cache)
    outs = [lg.astype(jnp.float32)]
    for t in range(n_prefill, s):
        lg, cache = model.decode_step(params, cfg, batch["tokens"][:, t:t + 1],
                                      cache)
        outs.append(lg.astype(jnp.float32))
    stitched = jnp.concatenate(outs, axis=1)            # pos n_prefill-1 .. s-1
    want = full[:, n_prefill - 1:s]
    np.testing.assert_allclose(np.asarray(stitched), np.asarray(want),
                               atol=0.75, rtol=0.2)
    # demand argmax agreement everywhere except genuine near-ties, which
    # reordered-reduction rounding may legitimately flip (observed top-2
    # gaps < 2e-4 on some arch/seed combinations)
    agree = np.asarray(stitched.argmax(-1) == want.argmax(-1))
    w = np.asarray(want)
    at_stitched = np.take_along_axis(
        w, np.asarray(stitched.argmax(-1))[..., None], axis=-1)[..., 0]
    near_tie = (w.max(-1) - at_stitched) < 5e-3
    bad = ~(agree | near_tie)
    assert not bad.any(), f"argmax mismatch beyond near-ties at {np.argwhere(bad)}"


def test_param_axes_tree_matches_params():
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        params = jax.eval_shape(lambda k, c=cfg: model.init_params(k, c),
                                jax.random.PRNGKey(0))
        axes = model.param_axes(cfg)
        ps = jax.tree_util.tree_structure(params)
        ass = jax.tree_util.tree_structure(
            axes, is_leaf=lambda x: isinstance(x, tuple))
        assert ps == ass, f"{arch}: axes tree != params tree"
        flat_p = jax.tree_util.tree_leaves(params)
        flat_a = jax.tree_util.tree_leaves(
            axes, is_leaf=lambda x: isinstance(x, tuple))
        for p, a in zip(flat_p, flat_a):
            assert len(p.shape) == len(a), f"{arch}: rank mismatch {p.shape} {a}"


def test_cache_axes_tree_matches_cache():
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        cache = jax.eval_shape(lambda c=cfg: model.init_decode_cache(c, 2, 8))
        axes = model.cache_axes(cfg)
        assert jax.tree_util.tree_structure(cache) == \
            jax.tree_util.tree_structure(
                axes, is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# attention semantics
# ---------------------------------------------------------------------------


def _mini_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab=64)
    base.update(kw)
    return ModelConfig(**base)


def test_chunked_attention_equals_full():
    cfg = _mini_cfg()
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 64, 4, 16), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 2, 16))
    full = layers.attn_core(q, k, v, causal=True, q_chunk=64)
    chunked = layers.attn_core(q, k, v, causal=True, q_chunk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-5)


def test_prefix_lm_mask():
    """With a prefix, early tokens attend bidirectionally into the prefix."""
    q = jnp.ones((1, 8, 1, 4))
    k = jnp.ones((1, 8, 1, 4))
    v = jnp.arange(8, dtype=jnp.float32)[None, :, None, None] * jnp.ones(
        (1, 8, 1, 4))
    causal = layers.attn_core(q, k, v, causal=True, q_chunk=8)
    prefix = layers.attn_core(q, k, v, causal=True, prefix_len=4, q_chunk=8)
    # token 0 under pure causal sees only v0 (=0); with prefix sees v0..v3
    assert float(causal[0, 0, 0]) == 0.0
    assert abs(float(prefix[0, 0, 0]) - 1.5) < 1e-5


def test_gqa_cache_decode_matches_nocache():
    cfg = _mini_cfg()
    key = jax.random.PRNGKey(3)
    p = layers.init_attention(key, cfg)
    x = jax.random.normal(key, (2, 10, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(10)[None], (2, 10))
    full, _ = layers.attention(p, x, cfg, positions=pos)
    cache = layers.init_cache(cfg, 2, 12, dtype=jnp.float32)
    outs = []
    for t in range(10):
        o, cache = layers.attention(p, x[:, t:t + 1], cfg,
                                    positions=pos[:, t:t + 1], cache=cache)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-2,
                               rtol=1e-2)


def test_rope_relative_shift_invariance():
    """RoPE scores depend on relative distance: shifting all positions by a
    constant must not change attention outputs."""
    cfg = _mini_cfg()
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (1, 8, 4, 16), jnp.float32)
    p0 = jnp.arange(8)[None]
    p1 = p0 + 100
    r0 = layers.apply_rope(x, p0, cfg)
    r1 = layers.apply_rope(x, p1, cfg)
    s0 = jnp.einsum("bqhd,bkhd->bhqk", r0, r0)
    s1 = jnp.einsum("bqhd,bkhd->bhqk", r1, r1)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-3)


# ---------------------------------------------------------------------------
# MoE: CAM-offloaded router == dense router (the paper-technique hook)
# ---------------------------------------------------------------------------


def test_moe_cam_router_matches_dense_router():
    from repro.models import moe as moe_mod
    cfg_d = _mini_cfg(family="moe", n_experts=8, moe_top_k=2,
                      d_expert=32, first_dense_layers=0,
                      router_offload="dense")
    key = jax.random.PRNGKey(5)
    xt = jax.random.normal(key, (32, 64), jnp.float32)
    rw = jax.random.normal(jax.random.fold_in(key, 1), (64, 8), jnp.float32)
    vd, idd = moe_mod.router_topk(xt, rw, 2, "dense")
    vc, idc = moe_mod.router_topk(xt, rw, 2, "cam")
    np.testing.assert_array_equal(np.asarray(idd), np.asarray(idc))
    np.testing.assert_allclose(np.asarray(vd), np.asarray(vc), atol=1e-4)


def test_moe_ffn_cam_equals_dense_output():
    from repro.models import moe as moe_mod
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (2, 8, 64), jnp.float32)
    cfg_d = _mini_cfg(family="moe", n_experts=8, moe_top_k=2, d_expert=32,
                      n_shared_experts=1, router_offload="dense")
    cfg_c = _mini_cfg(family="moe", n_experts=8, moe_top_k=2, d_expert=32,
                      n_shared_experts=1, router_offload="cam")
    p = moe_mod.init_moe(key, cfg_d)
    yd = moe_mod.moe_ffn(p, x, cfg_d)
    yc = moe_mod.moe_ffn(p, x, cfg_c)
    np.testing.assert_allclose(np.asarray(yd, np.float32),
                               np.asarray(yc, np.float32), atol=1e-2)


# ---------------------------------------------------------------------------
# sharding rules (pure logic — no devices needed)
# ---------------------------------------------------------------------------


class _FakeMesh:
    def __init__(self, **shape):
        self.shape = shape


def test_sharding_divisibility_fallbacks():
    from repro.models.sharding import ShardingRules
    rules = ShardingRules(mesh=_FakeMesh(data=16, model=16))
    # 40 heads don't divide 16 -> replicated
    assert rules.mesh_axes(("heads",), (40,)) == (None,)
    assert rules.mesh_axes(("heads",), (96,)) == ("model",)
    # whisper's odd vocab falls back to replicated
    assert rules.mesh_axes(("vocab",), (51865,)) == (None,)
    assert rules.mesh_axes(("vocab",), (152064,)) == ("model",)
    # kv=8 cache: kv replicated, head_dim picks up the model axis
    axes = rules.mesh_axes(
        ("layers", "cache_batch", "cache_seq", "cache_kv", "cache_dim"),
        (88, 128, 32768, 8, 128))
    assert axes[3] is None and axes[4] == "model"
    # ...but kv=32 takes model and head_dim must NOT reuse it
    axes = rules.mesh_axes(
        ("layers", "cache_batch", "cache_seq", "cache_kv", "cache_dim"),
        (54, 1, 1024, 32, 80))
    assert axes[3] == "model" and axes[4] is None


def test_sharding_multipod_batch_axes():
    from repro.models.sharding import ShardingRules
    rules = ShardingRules(mesh=_FakeMesh(pod=2, data=16, model=16))
    assert rules.batch_axes == ("pod", "data")
    assert rules.mesh_axes(("batch", None), (256, 4096))[0] == ("pod", "data")
    # batch=1 (long_500k): replicated
    assert rules.mesh_axes(("batch", None), (1, 4096))[0] is None
