"""HDC subsystem: hypervector algebra oracles, the fused encode kernel,
item/level memories, the classifier's engine/interpreter/oracle parity,
perceptron retraining through ``update_rows`` / ``update_gallery``, and
the end-to-end example (which also covers 8-device sharding)."""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.arch import ArchSpec
from repro.hdc import HdcClassifier, ItemMemory
from repro.hdc.encoding import level_hypervectors, random_hypervectors
from repro.kernels import ops, ref
from repro.serving import CamSearchServer


def _bipolar(rng, *shape):
    return np.where(rng.random(shape) < 0.5, -1.0, 1.0).astype(np.float32)


# ---------------------------------------------------------------------------
# hypervector algebra
# ---------------------------------------------------------------------------


def test_bind_is_xor_in_sign_domain(rng):
    a, b = _bipolar(rng, 4, 64), _bipolar(rng, 4, 64)
    bound = np.asarray(ref.hdc_bind(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(bound, a * b)
    # binding with itself is the identity hypervector (+1 everywhere)
    np.testing.assert_array_equal(
        np.asarray(ref.hdc_bind(jnp.asarray(a), jnp.asarray(a))),
        np.ones_like(a))
    # bind preserves distance: d(a*c, b*c) == d(a, b)
    c = _bipolar(rng, 4, 64)
    np.testing.assert_array_equal((a * c != b * c).sum(-1),
                                  (a != b).sum(-1))


def test_bundle_majority_and_tie_contract(rng):
    a, b, c = (_bipolar(rng, 1, 32)[0] for _ in range(3))
    maj = np.asarray(ref.hdc_bundle(jnp.asarray(np.stack([a, b, c]))))
    np.testing.assert_array_equal(maj, np.where(a + b + c >= 0, 1, -1))
    # even stack, perfect tie -> +1 (the pinned deterministic tie-break)
    tie = np.asarray(ref.hdc_bundle(jnp.asarray(np.stack([a, -a]))))
    np.testing.assert_array_equal(tie, np.ones_like(a))


def test_permute_rolls_and_inverts(rng):
    a = _bipolar(rng, 3, 40)
    r = np.asarray(ref.hdc_permute(jnp.asarray(a), 7))
    np.testing.assert_array_equal(r, np.roll(a, 7, axis=-1))
    back = np.asarray(ref.hdc_permute(jnp.asarray(r), -7))
    np.testing.assert_array_equal(back, a)


def test_encode_kernel_matches_oracle(rng):
    """The fused Pallas encode kernel, the one-hot matmul decomposition,
    and the dense oracle are bit-identical (integer sums, tie -> +1)."""
    from repro.hdc.encoding import _encode_matmul

    M, F, H, L = 9, 37, 70, 8
    q = rng.integers(0, L, size=(M, F)).astype(np.int32)
    keys = _bipolar(rng, F, H)
    levels = _bipolar(rng, L, H)
    want = np.asarray(ref.hdc_encode(jnp.asarray(q), jnp.asarray(keys),
                                     jnp.asarray(levels)))
    got_pl = np.asarray(ops.hdc_encode(jnp.asarray(q), jnp.asarray(keys),
                                       jnp.asarray(levels)))
    got_mm = np.asarray(_encode_matmul(jnp.asarray(q), jnp.asarray(keys),
                                       jnp.asarray(levels), n_levels=L))
    np.testing.assert_array_equal(want, got_pl)
    np.testing.assert_array_equal(want, got_mm)
    assert set(np.unique(want)) <= {-1.0, 1.0}


# ---------------------------------------------------------------------------
# item / level memories
# ---------------------------------------------------------------------------


def test_level_hypervectors_thermometer(rng):
    L, H = 9, 512
    lv = level_hypervectors(rng, L, H)
    d0 = [(lv[0] != lv[i]).sum() for i in range(L)]
    # distance to level 0 grows monotonically, in equal segments
    assert d0 == sorted(d0)
    seg = H // (2 * (L - 1))
    assert d0[1] == seg and d0[-1] == seg * (L - 1)


def test_item_memory_quantize_and_determinism():
    im = ItemMemory(8, dim=256, n_levels=4, lo=0.0, hi=1.0, seed=3)
    x = np.array([[0.0, 0.1, 0.26, 0.5, 0.74, 0.99, 1.0, -5.0]], np.float32)
    np.testing.assert_array_equal(im.quantize(x)[0],
                                  [0, 0, 1, 2, 2, 3, 3, 0])
    im2 = ItemMemory(8, dim=256, n_levels=4, seed=3)
    np.testing.assert_array_equal(im.keys, im2.keys)
    np.testing.assert_array_equal(im.levels, im2.levels)
    with pytest.raises(ValueError):
        im.quantize(np.zeros((2, 5), np.float32))     # wrong feature count


def test_item_memory_encode_paths_agree(rng):
    im = ItemMemory(12, dim=192, n_levels=5, seed=1)
    x = rng.random((7, 12)).astype(np.float32)
    e_mm = im.encode(x, kernel="matmul")
    np.testing.assert_array_equal(e_mm, im.encode(x, kernel="ref"))
    np.testing.assert_array_equal(e_mm, im.encode(x, kernel="pallas"))


# ---------------------------------------------------------------------------
# classifier
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_problem():
    rng = np.random.default_rng(7)
    C, F = 5, 24
    templates = rng.random((C, F)).astype(np.float32)

    def draw(n):
        y = rng.integers(0, C, n).astype(np.int32)
        x = np.clip(templates[y] + rng.normal(0, 0.3, (n, F)), 0, 1)
        return x.astype(np.float32), y

    return draw(160), draw(80), C, F


def test_classifier_parity_and_retraining(small_problem):
    (xtr, ytr), (xte, yte), C, F = small_problem
    clf = HdcClassifier(F, C, dim=512, n_levels=8, seed=0)
    clf.fit(xtr, ytr).compile(ArchSpec(rows=8, cols=64), batch_hint=64)
    assert clf.plan.packed                 # bipolar dot rides packed lanes

    pred = clf.predict(xte)
    np.testing.assert_array_equal(pred, clf.predict_interpreted(xte))
    np.testing.assert_array_equal(pred, clf.predict_reference(xte))
    acc0 = (pred == yte).mean()
    assert acc0 > 1.5 / C                  # far better than chance

    enc_tr = clf.encode(xtr)
    for _ in range(4):
        clf.retrain_epoch(xtr, ytr, encoded=enc_tr)
    assert clf.plan.row_update_fallbacks == 0
    # parity survives the incremental AM updates
    predN = clf.predict(xte)
    np.testing.assert_array_equal(predN, clf.predict_reference(xte))
    np.testing.assert_array_equal(predN, clf.predict_interpreted(xte))
    assert (clf.retrain_epoch(xtr, ytr, encoded=enc_tr)[0]
            >= (clf.predict(encoded=enc_tr) == ytr).mean() - 1e-9)


def test_retrain_step_moves_mass_between_touched_classes(small_problem):
    (xtr, ytr), _, C, F = small_problem
    clf = HdcClassifier(F, C, dim=256, n_levels=8, seed=0).fit(xtr, ytr)
    sums0 = clf.class_sums.copy()
    enc = clf.encode(xtr[:4])
    y = np.array([0, 1, 2, 3])
    preds = np.array([0, 1, 3, 2])         # two misclassified
    changed = clf.retrain_step(enc, y, preds)
    np.testing.assert_array_equal(changed, [2, 3])
    np.testing.assert_array_equal(clf.class_sums[[0, 1, 4]],
                                  sums0[[0, 1, 4]])  # untouched classes
    np.testing.assert_array_equal(
        clf.class_sums[2], sums0[2] + enc[2].astype(np.int64)
        - enc[3].astype(np.int64))
    # a perfect batch is a no-op
    assert clf.retrain_step(enc, y, y).size == 0


def test_classifier_served_retraining_matches_offline(small_problem):
    (xtr, ytr), (xte, _), C, F = small_problem
    offline = HdcClassifier(F, C, dim=512, n_levels=8, seed=0)
    offline.fit(xtr, ytr).compile(ArchSpec(rows=8, cols=64), batch_hint=64)
    served = HdcClassifier(F, C, dim=512, n_levels=8, seed=0)
    served.fit(xtr, ytr).compile(ArchSpec(rows=8, cols=64), batch_hint=64)

    enc_tr = offline.encode(xtr)
    for _ in range(3):
        offline.retrain_epoch(xtr, ytr, encoded=enc_tr)
    with CamSearchServer(served.plan, served.gallery,
                         max_wait_ms=1.0) as srv:
        for _ in range(3):
            served.retrain_epoch(xtr, ytr, encoded=enc_tr, server=srv)
        _, idx = srv.search(served.encode(xte))
        snap = srv.snapshot()
    # same deterministic update trajectory -> identical AMs/predictions
    np.testing.assert_array_equal(served.class_sums, offline.class_sums)
    np.testing.assert_array_equal(np.asarray(idx)[:, 0].astype(np.int32),
                                  offline.predict(xte))
    assert snap["plan"]["row_update_fallbacks"] == 0
    if snap["gallery_updates"]:
        assert snap["rows_updated"] > 0


def test_classifier_requires_compile():
    clf = HdcClassifier(8, 3, dim=64, n_levels=4)
    with pytest.raises(RuntimeError, match="compile"):
        clf.predict(np.zeros((1, 8), np.float32))


def test_hdc_example_end_to_end():
    """The acceptance pin: examples/hdc_mnist.py encodes, trains,
    retrains online through CamSearchServer.update_gallery under live
    traffic, and proves single-device / sharded (8 forced host devices)
    / served predictions bit-identical.  Runs in a subprocess because
    the example forces the device count."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, os.path.join(root, "examples", "hdc_mnist.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0 and "HDC-OK" in out.stdout, (
        f"hdc example failed (rc={out.returncode}):\n"
        f"{out.stdout[-3000:]}\n{out.stderr[-3000:]}")
