"""Continuous-batching CAM search server: coalescing, concurrency,
result parity, error fan-out, and lifecycle."""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArchSpec, compile_fn
from repro.serving import CamSearchServer


def _knn(q, gallery):
    diff = q.unsqueeze(1).sub(gallery)
    d = diff.norm(p=2, dim=-1)
    return d.topk(4, largest=False)


@pytest.fixture(scope="module")
def compiled():
    rng = np.random.default_rng(11)
    gallery = rng.standard_normal((300, 64)).astype(np.float32)
    example_q = rng.standard_normal((32, 64)).astype(np.float32)
    prog = compile_fn(_knn, [example_q, gallery], ArchSpec(rows=32, cols=64))
    assert prog.engine_plan is not None
    return prog, gallery


def test_search_matches_plan_directly(compiled, rng):
    prog, gallery = compiled
    q = rng.standard_normal((7, 64)).astype(np.float32)
    with CamSearchServer(prog, gallery) as srv:
        v, i = srv.search(q)
    dv, di = prog.engine_plan.execute(q, gallery)
    np.testing.assert_array_equal(i, np.asarray(di))
    np.testing.assert_array_equal(v, np.asarray(dv))


def test_concurrent_clients_coalesce_and_scatter(compiled, rng):
    """Many small concurrent requests share micro-batches, and every
    client gets exactly its own rows back."""
    prog, gallery = compiled
    plan = prog.engine_plan
    n_clients, reps = 6, 5
    queries = {c: [rng.standard_normal((1 + c % 3, 64)).astype(np.float32)
                   for _ in range(reps)] for c in range(n_clients)}
    results = {c: [] for c in range(n_clients)}
    errs = []

    with CamSearchServer(prog, gallery, max_wait_ms=5.0) as srv:
        def client(c):
            try:
                for q in queries[c]:
                    results[c].append(srv.search(q, timeout=60))
            except Exception as e:             # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        snap = srv.snapshot()

    assert not errs, errs[:1]
    for c in range(n_clients):
        for q, (v, i) in zip(queries[c], results[c]):
            dv, di = plan.execute(q, gallery)
            np.testing.assert_array_equal(i, np.asarray(di))
            np.testing.assert_array_equal(v, np.asarray(dv))
    total_rows = sum(q.shape[0] for qs in queries.values() for q in qs)
    assert snap["queries"] == total_rows
    assert snap["requests"] == n_clients * reps
    # coalescing must have packed multiple requests per launched batch
    assert snap["batches"] < snap["requests"]
    assert snap["avg_batch_fill"] > 1.0
    assert snap["p50_ms"] > 0


def test_oversized_request_spans_chunks(compiled, rng):
    """A request bigger than the plan micro-batch still comes back whole
    (plan-side chunking is invisible to the client)."""
    prog, gallery = compiled
    plan = prog.engine_plan
    q = rng.standard_normal((plan.batch * 2 + 3, 64)).astype(np.float32)
    with CamSearchServer(prog, gallery) as srv:
        v, i = srv.search(q)
    assert v.shape == (q.shape[0], 4) and i.shape == (q.shape[0], 4)
    dv, di = plan.execute(q, gallery)
    np.testing.assert_array_equal(i, np.asarray(di))


def test_submit_returns_waitable_future(compiled, rng):
    prog, gallery = compiled
    q = rng.standard_normal((3, 64)).astype(np.float32)
    with CamSearchServer(prog, gallery) as srv:
        reqs = [srv.submit(q) for _ in range(4)]
        for r in reqs:
            res = r.wait(timeout=60)
            assert res.error is None
            assert res.values.shape == (3, 4)
            assert res.latency_s >= 0


def test_bad_request_rejected_at_submit(compiled, rng):
    """Malformed blocks fail synchronously in submit() — they must never
    reach a batch where they would poison coalesced innocent requests."""
    prog, gallery = compiled
    with CamSearchServer(prog, gallery) as srv:
        with pytest.raises(ValueError):
            srv.submit(rng.standard_normal((2, 2, 64)))  # 3-D: rejected
        with pytest.raises(ValueError):
            srv.submit(np.ones((2, 17), np.float32))     # wrong feature dim
        # the server stays healthy for well-formed traffic
        q = rng.standard_normal((2, 64)).astype(np.float32)
        v, i = srv.search(q, timeout=60)
        assert v.shape == (2, 4)
        assert srv.snapshot()["errors"] == 0


def test_runtime_error_fans_out_to_batch_only(compiled, rng):
    """Execution failures surface through SearchResult.error and leave
    the batcher/completer alive for later traffic."""
    prog, gallery = compiled
    srv = CamSearchServer(prog, gallery)
    srv.gallery = np.ones((3,), np.float32)   # sabotage: execution raises
    with srv:
        req = srv.submit(rng.standard_normal((2, 64)).astype(np.float32))
        res = req.wait(timeout=60)
        assert res.error is not None
        assert srv.snapshot()["errors"] >= 1
        srv.gallery = jnp.asarray(gallery)
        v, _ = srv.search(rng.standard_normal((2, 64)).astype(np.float32),
                          timeout=60)
        assert v.shape == (2, 4)


def test_server_accepts_bare_search_plan(rng):
    """The server works over a bare SearchPlan (not just a compiled
    program), and results stay row-aligned when the coalesced batch
    happens to match the plan's traced query count exactly."""
    from repro.core import get_plan
    from test_engine import _data, _sim_module

    m, n, dim, k = 6, 40, 64, 4          # coalesced rows will equal m
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("eucl", k, False, m, n, dim, arch)
    plan = get_plan(mod)
    q, p = _data(rng, "eucl", m, n, dim)
    want_v, want_i = plan.execute(q, p)

    outs = {}
    with CamSearchServer(plan, p, max_wait_ms=50.0) as srv:
        def client(c):   # 3 clients x 2 rows coalesce to exactly m=6 rows
            outs[c] = srv.search(q[2 * c:2 * c + 2], timeout=60)

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    got_i = np.concatenate([outs[c][1] for c in range(3)])
    got_v = np.concatenate([outs[c][0] for c in range(3)])
    assert got_i.shape == (m, k)
    np.testing.assert_array_equal(got_i, np.asarray(want_i))
    np.testing.assert_allclose(got_v, np.asarray(want_v), atol=1e-4)


def test_stop_drains_pending_requests(compiled, rng):
    prog, gallery = compiled
    srv = CamSearchServer(prog, gallery).start()
    q = rng.standard_normal((2, 64)).astype(np.float32)
    srv.search(q)
    srv.stop()
    with pytest.raises(RuntimeError):
        srv.submit(q)
    # restartable
    srv2 = CamSearchServer(prog, gallery).start()
    try:
        v, _ = srv2.search(q)
        assert v.shape == (2, 4)
    finally:
        srv2.stop()


def test_server_requires_similarity_program():
    prog = compile_fn(lambda a, b: a.add(b), [(8, 8), (8, 8)],
                      ArchSpec(rows=16, cols=16))
    with pytest.raises(ValueError):
        CamSearchServer(prog, np.ones((8, 8), np.float32))
    with pytest.raises(TypeError):
        CamSearchServer(object(), np.ones((8, 8), np.float32))


def test_linger_launches_partial_batches(compiled, rng):
    """A lone request must not wait for a full batch — the max_wait
    linger bounds its latency."""
    prog, gallery = compiled
    q = rng.standard_normal((1, 64)).astype(np.float32)
    with CamSearchServer(prog, gallery, max_wait_ms=1.0) as srv:
        t0 = time.perf_counter()
        srv.search(q, timeout=60)
        assert time.perf_counter() - t0 < 30   # bounded, not starved
        assert srv.snapshot()["batches"] >= 1


# ---------------------------------------------------------------------------
# failure paths: shutdown with in-flight traffic, double shutdown,
# update_gallery racing concurrent searches
# ---------------------------------------------------------------------------


def test_stop_with_inflight_requests_completes_every_future(compiled, rng):
    """Shutdown under live traffic: every outstanding future completes
    (result or 'server stopped' error — never a hang), worker threads
    join, and both server threads are gone afterwards."""
    prog, gallery = compiled
    srv = CamSearchServer(prog, gallery, max_wait_ms=1.0).start()
    q = rng.standard_normal((2, 64)).astype(np.float32)
    outcomes = []

    def client():
        try:
            while True:
                v, _ = srv.search(q, timeout=60)
                outcomes.append(("ok", v.shape))
        except RuntimeError as e:          # stopped mid-traffic
            outcomes.append(("stopped", str(e)))
        except Exception as e:             # noqa: BLE001
            outcomes.append(("unexpected", e))

    threads = [threading.Thread(target=client) for _ in range(6)]
    for t in threads:
        t.start()
    deadline = time.perf_counter() + 30
    while not any(o[0] == "ok" for o in outcomes):
        assert time.perf_counter() < deadline, "no traffic before stop"
        time.sleep(0.001)
    srv.stop()                             # front door closes mid-flight
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "client deadlocked across shutdown"
    assert srv._thread is None and srv._completer is None
    assert all(kind in ("ok", "stopped") for kind, _ in outcomes), outcomes
    assert sum(1 for kind, _ in outcomes if kind == "stopped") == 6


def test_double_stop_is_idempotent(compiled, rng):
    prog, gallery = compiled
    srv = CamSearchServer(prog, gallery)
    srv.stop()                             # stop before start: no-op
    srv.start()
    srv.search(rng.standard_normal((1, 64)).astype(np.float32), timeout=60)
    srv.stop()
    srv.stop()                             # second stop: no-op, no error
    assert srv._thread is None and srv._completer is None
    with pytest.raises(RuntimeError):
        srv.submit(np.zeros((1, 64), np.float32))


def test_update_gallery_racing_searches_stays_consistent(compiled, rng):
    """Concurrent searches racing update_gallery under the writer-
    priority lock: every response must match ONE gallery version
    exactly — a batch must never see a half-applied update."""
    prog, gallery = compiled
    plan = prog.engine_plan
    n, dim = gallery.shape
    g_a = gallery
    g_b = np.ascontiguousarray(gallery[::-1])   # distinguishable version
    q = rng.standard_normal((3, dim)).astype(np.float32)
    va, ia = (np.asarray(x) for x in plan.execute(q, g_a))
    vb, ib = (np.asarray(x) for x in plan.execute(q, g_b))
    assert not np.array_equal(ia, ib)           # versions distinguishable

    results, errs = [], []
    stop = threading.Event()

    def searcher():
        try:
            while not stop.is_set():
                results.append(srv.search(q, timeout=60))
        except Exception as e:                  # noqa: BLE001
            errs.append(e)

    with CamSearchServer(prog, gallery, max_wait_ms=0.5) as srv:
        threads = [threading.Thread(target=searcher) for _ in range(4)]
        for t in threads:
            t.start()
        rows = np.arange(n)
        for flip in range(40):                  # hammer full-gallery swaps
            srv.update_gallery(rows, g_b if flip % 2 == 0 else g_a)
        stop.set()
        for t in threads:
            t.join(timeout=120)
        snap = srv.snapshot()
    assert not errs, errs[:1]
    assert snap["gallery_updates"] == 40
    assert results, "no searches completed during the race"
    for v, i in results:
        matches_a = np.array_equal(i, ia) and np.array_equal(v, va)
        matches_b = np.array_equal(i, ib) and np.array_equal(v, vb)
        assert matches_a or matches_b, \
            "response matches neither gallery version (torn update)"


def test_update_gallery_validates_synchronously(compiled, rng):
    prog, gallery = compiled
    n, dim = gallery.shape
    with CamSearchServer(prog, gallery) as srv:
        with pytest.raises(ValueError):
            srv.update_gallery([n], rng.standard_normal(
                (1, dim)).astype(np.float32))          # out of range
        with pytest.raises(ValueError):
            srv.update_gallery([0], rng.standard_normal(
                (2, dim)).astype(np.float32))          # row-count mismatch
        # server stays healthy for good traffic and good updates
        srv.update_gallery([0, 1], rng.standard_normal(
            (2, dim)).astype(np.float32))
        v, _ = srv.search(rng.standard_normal((2, dim)).astype(np.float32),
                          timeout=60)
        assert v.shape == (2, 4)
        assert srv.snapshot()["gallery_updates"] == 1


def test_update_gallery_interval_range_server(rng):
    """Range-plan servers mutate (lo, hi) rows as a pair."""
    from repro.core import get_plan
    from test_range import _interval_data, _range_module
    from repro.core.arch import ArchSpec

    m, n, dim = 4, 30, 32
    mod = _range_module(m, n, dim, ArchSpec(rows=8, cols=16), interval=True)
    plan = get_plan(mod)
    q, lo, hi = _interval_data(rng, m, n, dim)
    with CamSearchServer(plan, (lo, hi), max_wait_ms=1.0) as srv:
        before = srv.match(q, timeout=60)
        with pytest.raises(ValueError, match="lo_rows"):
            srv.update_gallery([0], lo[:1])    # must be a (lo, hi) pair
        srv.update_gallery([0, n - 1],
                           (lo[[0, n - 1]] - 10.0, hi[[0, n - 1]] + 10.0))
        after = srv.match(q, timeout=60)
    assert before.shape == after.shape == (m, n)
    # widened intervals can only add matches on the touched rows
    assert (after[:, [0, n - 1]] >= before[:, [0, n - 1]]).all()
    untouched = [c for c in range(n) if c not in (0, n - 1)]
    np.testing.assert_array_equal(after[:, untouched], before[:, untouched])


# ---------------------------------------------------------------------------
# resilience: deadlines, retries, circuit breaker, degraded mode,
# fault models, and shutdown with a wedged completion pipeline
# ---------------------------------------------------------------------------


def test_deadline_miss_is_timeout_not_batch_failure(compiled, rng):
    """An expired deadline costs that request a TimeoutError; requests
    coalesced alongside it still complete."""
    prog, gallery = compiled
    with CamSearchServer(prog, gallery, max_wait_ms=20.0) as srv:
        dead = srv.submit(rng.standard_normal((2, 64)).astype(np.float32),
                          deadline_ms=0.001)
        live = srv.submit(rng.standard_normal((2, 64)).astype(np.float32))
        res_d = dead.wait(timeout=60)
        res_l = live.wait(timeout=60)
        snap = srv.health()
    assert isinstance(res_d.error, TimeoutError)
    assert res_l.error is None and res_l.values.shape == (2, 4)
    assert snap["deadline_misses"] >= 1
    assert snap["deadline_miss_rate"] > 0


def test_retry_heals_transient_backend_fault(compiled, rng):
    """A transient dispatch failure is retried on the same level with
    backoff — no degradation, no client-visible error."""
    prog, gallery = compiled
    fails = {"primary": 1}

    def injector(level):
        if fails.get(level, 0) > 0:
            fails[level] -= 1
            raise RuntimeError("transient")

    with CamSearchServer(prog, gallery, max_retries=2,
                         retry_backoff_ms=1.0,
                         fault_injector=injector) as srv:
        v, i = srv.search(rng.standard_normal((2, 64)).astype(np.float32),
                          timeout=60)
        h = srv.health()
    assert v.shape == (2, 4)
    assert h["retries"] >= 1
    assert h["degraded_batches"] == 0
    assert h["status"] == "ok"


def test_breaker_trips_degrades_and_recovers(compiled, rng):
    """K consecutive primary failures open the breaker (requests served
    degraded, primary skipped); after the cooldown a probe closes it."""
    prog, gallery = compiled
    plan = prog.engine_plan
    q = rng.standard_normal((2, 64)).astype(np.float32)
    want_v, want_i = (np.asarray(x) for x in plan.execute(q, gallery))
    fails = {"primary": 2}

    def injector(level):
        if fails.get(level, 0) > 0:
            fails[level] -= 1
            raise RuntimeError("injected outage")

    with CamSearchServer(prog, gallery, max_retries=0,
                         breaker_threshold=2, breaker_cooldown_ms=50.0,
                         fault_injector=injector) as srv:
        outs = [srv.search(q, timeout=60) for _ in range(3)]
        mid = srv.health()
        time.sleep(0.12)                   # past the cooldown: probe
        outs.append(srv.search(q, timeout=60))
        after = srv.health()
    for v, i in outs:                      # degraded results stay exact
        np.testing.assert_array_equal(i, want_i)
        np.testing.assert_array_equal(v, want_v)
    assert mid["breaker"]["trips"] >= 1
    assert mid["status"] == "degraded"
    assert mid["degraded_batches"] >= 1
    assert after["breaker"]["state"] == "closed"
    assert after["breaker"]["recoveries"] >= 1


def test_interpreter_fallback_serves_when_all_backends_fail(compiled, rng):
    """With every compiled level permanently failing, the IR
    interpreter still serves exact results (last-resort degraded mode)."""
    prog, gallery = compiled
    plan = prog.engine_plan
    q = rng.standard_normal((3, 64)).astype(np.float32)
    want_v, want_i = (np.asarray(x) for x in plan.execute(q, gallery))

    def injector(level):
        if level != "interpreter":
            raise RuntimeError(f"dead backend {level}")

    with CamSearchServer(prog, gallery, max_retries=0, breaker_threshold=1,
                         fault_injector=injector) as srv:
        v, i = srv.search(q, timeout=120)
        h = srv.health()
    np.testing.assert_array_equal(i, want_i)
    np.testing.assert_allclose(v, want_v, atol=1e-4)
    assert h["status"] == "degraded"
    assert h["fallback_levels"][-1] == "interpreter"


def test_server_fault_model_matches_plan_execute(compiled, rng):
    """A server-level fault model corrupts exactly like plan.execute
    with the same model, and health() surfaces the realised counts."""
    from repro.faults import FaultModel

    prog, gallery = compiled
    plan = prog.engine_plan
    q = rng.standard_normal((2, 64)).astype(np.float32)
    fm = FaultModel(seed=5, p_stuck=0.01, sigma=0.02)
    with CamSearchServer(prog, gallery, fault_model=fm) as srv:
        v, i = srv.search(q, timeout=60)
        h = srv.health()
    want_v, want_i = plan.execute(q, gallery, faults=fm)
    np.testing.assert_array_equal(i, np.asarray(want_i))
    np.testing.assert_array_equal(v, np.asarray(want_v))
    counts = h["fault_model"]["cells"]
    assert counts["stuck0"] + counts["stuck1"] > 0


def test_server_rejects_garbage_fault_model(compiled):
    prog, gallery = compiled
    with pytest.raises(TypeError):
        CamSearchServer(prog, gallery, fault_model="p=0.1")


def test_null_fault_model_is_clean(compiled, rng):
    from repro.faults import FaultModel

    prog, gallery = compiled
    plan = prog.engine_plan
    q = rng.standard_normal((2, 64)).astype(np.float32)
    with CamSearchServer(prog, gallery, fault_model=FaultModel()) as srv:
        v, i = srv.search(q, timeout=60)
        h = srv.health()
    want_v, want_i = plan.execute(q, gallery)
    np.testing.assert_array_equal(i, np.asarray(want_i))
    np.testing.assert_array_equal(v, np.asarray(want_v))
    assert "fault_model" not in h          # normalised away


def test_stop_does_not_hang_with_dead_completer_and_full_queue(
        compiled, rng):
    """Shutdown regression: completer dead, completion queue full
    (bounded, max_inflight=1), batcher wedged mid-hand-off, and an
    update_gallery writer pending — stop() must return promptly and
    every outstanding future must resolve with an error."""
    prog, gallery = compiled
    n, dim = gallery.shape
    srv = CamSearchServer(prog, gallery, max_inflight=1,
                          max_wait_ms=1.0).start()
    # kill the completion thread out from under the server
    srv._completions.put(None)
    deadline = time.perf_counter() + 10
    while srv._completer_alive and time.perf_counter() < deadline:
        time.sleep(0.001)
    assert not srv._completer_alive

    q = rng.standard_normal((2, dim)).astype(np.float32)
    reqs = [srv.submit(q) for _ in range(4)]   # wedge the hand-off

    upd_err = []

    def writer():                              # pending gallery update
        try:
            srv.update_gallery([0], rng.standard_normal(
                (1, dim)).astype(np.float32))
        except Exception as e:                 # noqa: BLE001
            upd_err.append(e)

    w = threading.Thread(target=writer)
    w.start()
    time.sleep(0.2)                            # let everything wedge

    t0 = time.perf_counter()
    srv.stop()
    assert time.perf_counter() - t0 < 10, "stop() hung"
    w.join(timeout=10)
    assert not w.is_alive(), "update_gallery writer deadlocked"
    for r in reqs:
        res = r.wait(timeout=10)
        assert res.error is not None           # failed, never stranded
    assert srv._thread is None and srv._completer is None


# -- stats consistency (the snapshot/health atomicity regression) -----------

class TestStatsConsistency:
    """``snapshot()``/``health()`` must read a *consistent* view: every
    related counter group lands atomically, so no reader can observe a
    half-applied update (the historical bug: each ``stats[k] += 1``
    took its own lock acquisition)."""

    def test_serverstats_multi_key_bump_is_atomic(self):
        from repro.serving import ServerStats
        stats = ServerStats("a", "b", window=64)
        stop = threading.Event()
        torn = []

        def writer():
            while not stop.is_set():
                stats.bump(a=1, b=2)       # invariant: b == 2a, always

        def reader():
            while not stop.is_set():
                c, _ = stats.view()
                if c["b"] != 2 * c["a"]:
                    torn.append(dict(c))
                    return

        writers = [threading.Thread(target=writer) for _ in range(4)]
        readers = [threading.Thread(target=reader) for _ in range(2)]
        for t in writers + readers:
            t.start()
        time.sleep(0.4)
        stop.set()
        for t in writers + readers:
            t.join(10)
        assert not torn, f"torn read observed: {torn[:3]}"
        c, lat = stats.view()
        assert c["b"] == 2 * c["a"] and c["a"] > 0

    def test_serverstats_rejects_unknown_counter(self):
        from repro.serving import ServerStats
        stats = ServerStats("a")
        with pytest.raises(KeyError, match="typo"):
            stats.bump(typo=1)
        assert stats.view()[0] == {"a": 0}

    def test_live_snapshot_invariants_under_concurrency(self, compiled,
                                                        rng):
        """Hammer a live server from worker threads while snapshotting:
        every snapshot must satisfy the cross-counter invariants (a
        request is never visible without its rows, the latency window
        never exceeds delivered requests)."""
        prog, gallery = compiled
        q = rng.standard_normal((3, 64)).astype(np.float32)
        violations = []
        stop = threading.Event()

        with CamSearchServer(prog, gallery, max_wait_ms=0.5) as srv:
            def client():
                while not stop.is_set():
                    srv.search(q)

            def observer():
                while not stop.is_set():
                    counts, lat = srv._stats.view()
                    snap = srv.snapshot()
                    for src in (counts, snap):
                        if src["queries"] != 3 * src["requests"]:
                            violations.append(
                                ("rows", src["requests"], src["queries"]))
                    if len(lat) > counts["requests"]:
                        violations.append(
                            ("latency", len(lat), counts["requests"]))
                    if counts["batched_rows"] < \
                            counts["queries"] - 3 * 64:
                        # batched rows may run AHEAD of delivered
                        # queries, never meaningfully behind
                        violations.append(
                            ("batch", counts["batched_rows"],
                             counts["queries"]))

            clients = [threading.Thread(target=client) for _ in range(4)]
            obs = [threading.Thread(target=observer) for _ in range(2)]
            for t in clients + obs:
                t.start()
            time.sleep(0.8)
            stop.set()
            for t in clients + obs:
                t.join(10)
            final = srv.stats
        assert not violations, violations[:5]
        assert final["requests"] > 0
        assert final["queries"] == 3 * final["requests"]
