"""Plan-cache key disjointness regression pins.

The plan-cache key has been widened three times (shards in PR 2,
packed + operand dtypes in PR 3, the RangeSpec family + threshold in
PR 4).  Each widening happened because two *different* executables
could silently share a cache slot.  This file exhaustively crosses the
spec axes and asserts that no two distinct configurations produce equal
cache keys — so the next axis added to the engine fails loudly here
instead of colliding silently.
"""

import itertools

from repro.core import (ArchSpec, HierarchicalSpec, RangeSpec,
                        SimilaritySpec, clear_plan_cache,
                        get_hierarchical_plan, get_plan)

from test_engine import _sim_module
from test_range import _range_module


def _sim_specs():
    """SimilaritySpec instances across every key-relevant axis."""
    specs = []
    for metric, k, largest, care_arg, dtypes, n, dim in itertools.product(
            ("hamming", "dot", "eucl"), (1, 4), (False, True),
            (None, 2), (("f32", "f32"), ("u32", "u32")),
            (16, 33), (32, 64)):
        if care_arg is not None and metric != "hamming":
            continue                       # ternary is hamming-only
        in_dtypes = dtypes if care_arg is None else dtypes + (dtypes[0],)
        specs.append(SimilaritySpec(
            metric=metric, k=k, largest=largest, tile_rows=16,
            dims_per_tile=32, grid_rows=-(-n // 16), grid_cols=-(-dim // 32),
            m=8, n=n, dim=dim, query_arg=0, pattern_arg=1,
            out_v_shape=(8, k), out_i_shape=(8, k),
            care_arg=care_arg, in_dtypes=in_dtypes))
    return specs


def _range_specs():
    """RangeSpec instances across mode/metric/threshold/polarity axes."""
    specs = []
    for mode, metric, tau, below, n, dim in itertools.product(
            ("threshold", "interval"), ("hamming", "dot", "eucl"),
            (0.0, 1.5), (True, False), (16, 33), (32, 64)):
        if mode == "interval":
            if metric != "hamming" or tau != 0.0 or not below:
                continue                   # interval has no such axes
            metric_eff, pattern_args, dtypes = \
                "interval", (1, 2), ("f32", "f32", "f32")
        else:
            metric_eff, pattern_args, dtypes = \
                metric, (1,), ("f32", "f32")
        specs.append(RangeSpec(
            mode=mode, metric=metric_eff, threshold=tau, below=below,
            tile_rows=16, dims_per_tile=32, grid_rows=-(-n // 16),
            grid_cols=-(-dim // 32), m=8, n=n, dim=dim, query_arg=0,
            pattern_args=pattern_args, out_shape=(8, n),
            in_dtypes=dtypes))
    return specs


def _hier_specs():
    """HierarchicalSpec instances across the clustering axes (the fine
    spec sweep is covered by ``_sim_specs``; here a few fine specs cross
    clusters / nprobe / kmeans_iters / seed)."""
    specs = []
    for fine in _sim_specs()[:4]:
        for clusters, nprobe, iters, seed in itertools.product(
                (4, 8), (1, 4), (4, 8), (0, 7)):
            if nprobe > clusters:
                continue
            specs.append(HierarchicalSpec(
                fine=fine, clusters=clusters, nprobe=nprobe,
                kmeans_iters=iters, seed=seed))
    return specs


def test_cache_keys_disjoint_across_all_axes():
    """Exhaustive cross: (spec, backend, batch, shards, packed) keys are
    pairwise distinct for every distinct configuration."""
    specs = _sim_specs() + _range_specs() + _hier_specs()
    keys = []
    for spec in specs:
        for backend, batch, shards, packed in itertools.product(
                ("jnp", "pallas"), (8, 64), (1, 4), (False, True)):
            keys.append((spec, backend, batch, shards, packed))
    assert len(keys) == len(set(keys)), (
        f"{len(keys) - len(set(keys))} plan-cache key collisions across "
        f"{len(specs)} specs")
    # hashability sanity: every key actually lands in a dict slot
    assert len({k: None for k in keys}) == len(keys)


def test_similarity_and_range_specs_never_compare_equal():
    """The plan families share the cache dict; a frozen-dataclass
    type split is what keeps their keys disjoint — pin it."""
    for s in _sim_specs():
        for r in _range_specs():
            assert s != r and r != s
    # even with maximally-aligned field values
    s = _sim_specs()[0]
    r = _range_specs()[0]
    assert hash((s,)) != hash((r,)) or s != r


def test_hierarchical_specs_never_equal_their_fine_spec():
    """A composite wrapping a fine spec must not collide with the flat
    plan compiled for that same fine spec — the wrapper *type* splits
    the key even when every delegated field agrees."""
    for h in _hier_specs():
        assert h != h.fine and h.fine != h
    # ... and nprobe / clusters / seed / kmeans_iters all join the key
    fine = _sim_specs()[0]
    base = HierarchicalSpec(fine=fine, clusters=8, nprobe=2)
    for other in (HierarchicalSpec(fine=fine, clusters=8, nprobe=3),
                  HierarchicalSpec(fine=fine, clusters=4, nprobe=2),
                  HierarchicalSpec(fine=fine, clusters=8, nprobe=2,
                                   kmeans_iters=9),
                  HierarchicalSpec(fine=fine, clusters=8, nprobe=2,
                                   seed=1)):
        assert base != other


def test_get_plan_returns_distinct_plans_per_axis():
    """End-to-end: axes that must split the cache do split it."""
    clear_plan_cache()
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("hamming", 3, False, 6, 24, 32, arch)

    packed = get_plan(mod)                 # auto-pack for hamming
    unpacked = get_plan(mod, pack=False)
    assert packed is not unpacked and packed.packed and not unpacked.packed

    jnp_plan = get_plan(mod, backend="jnp")
    pallas_plan = get_plan(mod, backend="pallas")
    assert jnp_plan is not pallas_plan

    b8 = get_plan(mod, batch=8)
    b16 = get_plan(mod, batch=16)
    assert b8 is not b16

    # threshold joins the RangeSpec key: same program shape, different
    # tau/polarity -> different plans
    r1 = get_plan(_range_module(4, 20, 32, arch, metric="hamming", tau=4.0))
    r2 = get_plan(_range_module(4, 20, 32, arch, metric="hamming", tau=5.0))
    r3 = get_plan(_range_module(4, 20, 32, arch, metric="hamming", tau=4.0,
                                below=False))
    assert r1 is not r2 and r1 is not r3 and r2 is not r3

    # a range program can never hit a similarity plan's slot
    sim_like = get_plan(_sim_module("hamming", 1, False, 4, 20, 32, arch))
    assert sim_like is not None and sim_like is not r1


def test_hierarchical_plans_share_the_cache():
    """get_hierarchical_plan is an ordinary plan-cache citizen: same
    clustering config -> the same object, any axis change -> a new one,
    and the flat plan for the same module keeps its own slot."""
    clear_plan_cache()
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("hamming", 3, False, 6, 64, 32, arch)
    flat = get_plan(mod)
    h1 = get_hierarchical_plan(mod, clusters=4, nprobe=2)
    h2 = get_hierarchical_plan(mod, clusters=4, nprobe=2)
    h3 = get_hierarchical_plan(mod, clusters=4, nprobe=4)
    h4 = get_hierarchical_plan(mod, clusters=4, nprobe=2, seed=1)
    assert h1 is h2
    assert h1 is not h3 and h1 is not h4 and h3 is not h4
    assert all(h is not flat for h in (h1, h3, h4))


def test_spec_equality_is_value_based():
    """Equal configurations must share a plan (the cache-hit side)."""
    a, b = _sim_specs()[0], _sim_specs()[0]
    assert a == b and hash(a) == hash(b)
    clear_plan_cache()
    arch = ArchSpec(rows=16, cols=32)
    p1 = get_plan(_sim_module("dot", 2, False, 4, 16, 32, arch))
    p2 = get_plan(_sim_module("dot", 2, False, 4, 16, 32, arch))
    assert p1 is p2
