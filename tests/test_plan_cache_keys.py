"""Plan-cache key disjointness regression pins.

The plan-cache key has been widened three times (shards in PR 2,
packed + operand dtypes in PR 3, the RangeSpec family + threshold in
PR 4).  Each widening happened because two *different* executables
could silently share a cache slot.  This file exhaustively crosses the
spec axes and asserts that no two distinct configurations produce equal
cache keys — so the next axis added to the engine fails loudly here
instead of colliding silently.
"""

import itertools

from repro.core import (ArchSpec, HierarchicalSpec, RangeSpec,
                        SimilaritySpec, clear_plan_cache,
                        get_hierarchical_plan, get_plan)

from test_engine import _sim_module
from test_range import _range_module


def _sim_specs():
    """SimilaritySpec instances across every key-relevant axis."""
    specs = []
    for metric, k, largest, care_arg, dtypes, n, dim in itertools.product(
            ("hamming", "dot", "eucl"), (1, 4), (False, True),
            (None, 2), (("f32", "f32"), ("u32", "u32")),
            (16, 33), (32, 64)):
        if care_arg is not None and metric != "hamming":
            continue                       # ternary is hamming-only
        in_dtypes = dtypes if care_arg is None else dtypes + (dtypes[0],)
        specs.append(SimilaritySpec(
            metric=metric, k=k, largest=largest, tile_rows=16,
            dims_per_tile=32, grid_rows=-(-n // 16), grid_cols=-(-dim // 32),
            m=8, n=n, dim=dim, query_arg=0, pattern_arg=1,
            out_v_shape=(8, k), out_i_shape=(8, k),
            care_arg=care_arg, in_dtypes=in_dtypes))
    return specs


def _range_specs():
    """RangeSpec instances across mode/metric/threshold/polarity axes."""
    specs = []
    for mode, metric, tau, below, n, dim in itertools.product(
            ("threshold", "interval"), ("hamming", "dot", "eucl"),
            (0.0, 1.5), (True, False), (16, 33), (32, 64)):
        if mode == "interval":
            if metric != "hamming" or tau != 0.0 or not below:
                continue                   # interval has no such axes
            metric_eff, pattern_args, dtypes = \
                "interval", (1, 2), ("f32", "f32", "f32")
        else:
            metric_eff, pattern_args, dtypes = \
                metric, (1,), ("f32", "f32")
        specs.append(RangeSpec(
            mode=mode, metric=metric_eff, threshold=tau, below=below,
            tile_rows=16, dims_per_tile=32, grid_rows=-(-n // 16),
            grid_cols=-(-dim // 32), m=8, n=n, dim=dim, query_arg=0,
            pattern_args=pattern_args, out_shape=(8, n),
            in_dtypes=dtypes))
    return specs


def _hier_specs():
    """HierarchicalSpec instances across the clustering axes (the fine
    spec sweep is covered by ``_sim_specs``; here a few fine specs cross
    clusters / nprobe / kmeans_iters / seed)."""
    specs = []
    for fine in _sim_specs()[:4]:
        for clusters, nprobe, iters, seed in itertools.product(
                (4, 8), (1, 4), (4, 8), (0, 7)):
            if nprobe > clusters:
                continue
            specs.append(HierarchicalSpec(
                fine=fine, clusters=clusters, nprobe=nprobe,
                kmeans_iters=iters, seed=seed))
    return specs


def test_cache_keys_disjoint_across_all_axes():
    """Exhaustive cross: (spec, backend, batch, shards, packed, unroll)
    keys are pairwise distinct for every distinct configuration."""
    specs = _sim_specs() + _range_specs() + _hier_specs()
    keys = []
    for spec in specs:
        for backend, batch, shards, packed, unroll in itertools.product(
                ("jnp", "pallas"), (8, 64), (1, 4), (False, True), (1, 2)):
            keys.append((spec, backend, batch, shards, packed, unroll))
    assert len(keys) == len(set(keys)), (
        f"{len(keys) - len(set(keys))} plan-cache key collisions across "
        f"{len(specs)} specs")
    # hashability sanity: every key actually lands in a dict slot
    assert len({k: None for k in keys}) == len(keys)


def test_similarity_and_range_specs_never_compare_equal():
    """The plan families share the cache dict; a frozen-dataclass
    type split is what keeps their keys disjoint — pin it."""
    for s in _sim_specs():
        for r in _range_specs():
            assert s != r and r != s
    # even with maximally-aligned field values
    s = _sim_specs()[0]
    r = _range_specs()[0]
    assert hash((s,)) != hash((r,)) or s != r


def test_hierarchical_specs_never_equal_their_fine_spec():
    """A composite wrapping a fine spec must not collide with the flat
    plan compiled for that same fine spec — the wrapper *type* splits
    the key even when every delegated field agrees."""
    for h in _hier_specs():
        assert h != h.fine and h.fine != h
    # ... and nprobe / clusters / seed / kmeans_iters all join the key
    fine = _sim_specs()[0]
    base = HierarchicalSpec(fine=fine, clusters=8, nprobe=2)
    for other in (HierarchicalSpec(fine=fine, clusters=8, nprobe=3),
                  HierarchicalSpec(fine=fine, clusters=4, nprobe=2),
                  HierarchicalSpec(fine=fine, clusters=8, nprobe=2,
                                   kmeans_iters=9),
                  HierarchicalSpec(fine=fine, clusters=8, nprobe=2,
                                   seed=1)):
        assert base != other


def test_get_plan_returns_distinct_plans_per_axis():
    """End-to-end: axes that must split the cache do split it."""
    clear_plan_cache()
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("hamming", 3, False, 6, 24, 32, arch)

    packed = get_plan(mod)                 # auto-pack for hamming
    unpacked = get_plan(mod, pack=False)
    assert packed is not unpacked and packed.packed and not unpacked.packed

    jnp_plan = get_plan(mod, backend="jnp")
    pallas_plan = get_plan(mod, backend="pallas")
    assert jnp_plan is not pallas_plan

    b8 = get_plan(mod, batch=8)
    b16 = get_plan(mod, batch=16)
    assert b8 is not b16

    # unroll is scheduling-only (identical arithmetic) but still a
    # different executable -> its own cache slot; pallas ignores it
    u1 = get_plan(mod, unroll=1)
    u4 = get_plan(mod, unroll=4)
    assert u1 is not u4 and u1.unroll == 1 and u4.unroll == 4
    assert get_plan(mod, backend="pallas", unroll=4) is \
        get_plan(mod, backend="pallas")

    # threshold joins the RangeSpec key: same program shape, different
    # tau/polarity -> different plans
    r1 = get_plan(_range_module(4, 20, 32, arch, metric="hamming", tau=4.0))
    r2 = get_plan(_range_module(4, 20, 32, arch, metric="hamming", tau=5.0))
    r3 = get_plan(_range_module(4, 20, 32, arch, metric="hamming", tau=4.0,
                                below=False))
    assert r1 is not r2 and r1 is not r3 and r2 is not r3

    # a range program can never hit a similarity plan's slot
    sim_like = get_plan(_sim_module("hamming", 1, False, 4, 20, 32, arch))
    assert sim_like is not None and sim_like is not r1


def test_hierarchical_plans_share_the_cache():
    """get_hierarchical_plan is an ordinary plan-cache citizen: same
    clustering config -> the same object, any axis change -> a new one,
    and the flat plan for the same module keeps its own slot."""
    clear_plan_cache()
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("hamming", 3, False, 6, 64, 32, arch)
    flat = get_plan(mod)
    h1 = get_hierarchical_plan(mod, clusters=4, nprobe=2)
    h2 = get_hierarchical_plan(mod, clusters=4, nprobe=2)
    h3 = get_hierarchical_plan(mod, clusters=4, nprobe=4)
    h4 = get_hierarchical_plan(mod, clusters=4, nprobe=2, seed=1)
    assert h1 is h2
    assert h1 is not h3 and h1 is not h4 and h3 is not h4
    assert all(h is not flat for h in (h1, h3, h4))


class TestRetirementAccounting:
    """Evicted-plan counter folding (the PR-10 accounting fix).

    ``_retire_plan`` used to fold a plan's full pattern counters into
    the retained ``_STATS`` on every eviction *without* remembering it
    had done so — a retired plan still driven by a live server (the
    normal serving topology: the server holds the plan, the LRU
    evicts it) would double-fold on re-insert + re-evict, and
    ``plan_cache_stats`` would jump discontinuously.  These tests pin
    the fixed contract: retirement is idempotent, live plan counters
    are never zeroed, and the aggregate is monotonic across
    evict / re-insert / evict cycles.
    """

    def _plan_with_traffic(self, arch, n):
        import numpy as np
        from test_engine import _data
        mod = _sim_module("hamming", 2, False, 4, n, 32, arch)
        plan = get_plan(mod, pack=False)
        rng = np.random.default_rng(0)
        q, p = _data(rng, "hamming", 4, n, 32)
        (jp,) = plan.warm(p)                   # prepare miss
        plan.execute(q, jp)                    # same object -> memo hit
        plan.execute(q, np.array(p))           # distinct object -> miss
        return plan

    def test_retire_is_idempotent_and_never_zeroes_live_counters(self):
        from repro.core.engine.cache import _retire_plan, plan_cache_stats
        clear_plan_cache()
        arch = ArchSpec(rows=16, cols=32)
        plan = self._plan_with_traffic(arch, 40)
        live_before = (plan.pattern_hits, plan.pattern_misses,
                       plan.pattern_evictions)
        assert sum(live_before) > 0
        agg_before = plan_cache_stats()

        _retire_plan(plan)
        # the plan's own telemetry is untouched: a server reading
        # plan.counters() must never see a counter go backwards
        assert (plan.pattern_hits, plan.pattern_misses,
                plan.pattern_evictions) == live_before
        agg_once = plan_cache_stats()
        _retire_plan(plan)                    # second retire: no-op fold
        agg_twice = plan_cache_stats()
        for k in ("pattern_hits", "pattern_misses", "pattern_evictions"):
            assert agg_twice[k] == agg_once[k], k
            # while the plan is still cached, stats count it net of its
            # retired bases -> retiring a cached plan changes nothing
            assert agg_once[k] == agg_before[k], k

    def test_reinserted_retired_plan_is_not_double_counted(self):
        import numpy as np
        from test_engine import _data
        from repro.core.engine.cache import (_MAX_PLANS, _retire_plan,
                                             plan_cache_stats)
        clear_plan_cache()
        arch = ArchSpec(rows=16, cols=32)
        plan = self._plan_with_traffic(arch, 40)
        stats0 = plan_cache_stats()

        # flood the LRU so `plan` is genuinely evicted (and retired)
        for n in range(41, 41 + _MAX_PLANS):
            get_plan(_sim_module("dot", 2, False, 4, n, 32, arch))
        stats1 = plan_cache_stats()
        for k in ("pattern_hits", "pattern_misses", "pattern_evictions"):
            assert stats1[k] == stats0[k], f"{k} changed across eviction"

        # the evicted plan keeps serving, then gets re-planned (cache
        # miss -> same key rebuilt is a *new* plan; simulate the nastier
        # path of the same object re-entering via _cache_insert)
        rng = np.random.default_rng(1)
        q, p = _data(rng, "hamming", 4, 40, 32)
        plan.execute(q, p)                    # post-retirement traffic
        from repro.core.engine.cache import _cache_insert
        _cache_insert(("reinserted-sentinel",), plan)
        stats2 = plan_cache_stats()
        # aggregate grew by exactly the post-retirement delta, not by
        # the plan's full lifetime counters again
        grew = sum(stats2[k] - stats1[k] for k in
                   ("pattern_hits", "pattern_misses", "pattern_evictions"))
        live_total = (plan.pattern_hits + plan.pattern_misses +
                      plan.pattern_evictions)
        retired_total = (plan._retired_hits + plan._retired_misses +
                         plan._retired_evictions)
        assert grew == live_total - retired_total
        # ... and a second eviction folds only that same delta once
        _retire_plan(plan)
        stats3 = plan_cache_stats()
        for k in ("pattern_hits", "pattern_misses", "pattern_evictions"):
            assert stats3[k] == stats2[k], k

    def test_stats_monotonic_across_many_cycles(self):
        from repro.core.engine.cache import _retire_plan, plan_cache_stats
        clear_plan_cache()
        arch = ArchSpec(rows=16, cols=32)
        plan = self._plan_with_traffic(arch, 48)
        last = plan_cache_stats()
        for _ in range(5):
            _retire_plan(plan)
            cur = plan_cache_stats()
            for k in ("pattern_hits", "pattern_misses",
                      "pattern_evictions"):
                assert cur[k] >= last[k], f"{k} went backwards"
            last = cur


class TestSpecFloatCanonicalization:
    """Float fields in frozen specs are cache keys — -0.0/0.0 and NaN
    must not split or poison slots (the PR-10 hashing audit)."""

    def _rspec(self, tau):
        return RangeSpec(
            mode="threshold", metric="eucl", threshold=tau, below=True,
            tile_rows=16, dims_per_tile=32, grid_rows=2, grid_cols=1,
            m=8, n=20, dim=32, query_arg=0, pattern_args=(1,),
            out_shape=(8, 20), in_dtypes=("f32", "f32"))

    def test_negative_zero_threshold_is_canonicalized(self):
        a, b = self._rspec(0.0), self._rspec(-0.0)
        assert a == b and hash(a) == hash(b)
        assert repr(b.threshold) == "0.0"     # stored canonical, not -0.0
        from repro.core import spec_digest
        assert spec_digest(a) == spec_digest(b)

    def test_nan_threshold_raises(self):
        import pytest
        with pytest.raises(ValueError, match="NaN"):
            self._rspec(float("nan"))

    def test_digest_is_stable_and_threshold_sensitive(self):
        from repro.core import spec_digest, workload_digest
        a, b = self._rspec(1.5), self._rspec(2.5)
        assert spec_digest(a) != spec_digest(b)
        # workload digest ignores tile geometry but keeps the threshold
        assert workload_digest(a) != workload_digest(b)
        import dataclasses
        retiled = dataclasses.replace(a, tile_rows=8, grid_rows=3)
        assert workload_digest(a) == workload_digest(retiled)
        assert spec_digest(a) != spec_digest(retiled)
        # pinned hex: the digest is the on-disk plan-store key — a
        # representation change silently orphans every stored plan,
        # so make it loud instead
        assert spec_digest(a) == spec_digest(self._rspec(1.5))
        assert len(spec_digest(a)) == 64 and int(spec_digest(a), 16) >= 0


def test_spec_equality_is_value_based():
    """Equal configurations must share a plan (the cache-hit side)."""
    a, b = _sim_specs()[0], _sim_specs()[0]
    assert a == b and hash(a) == hash(b)
    clear_plan_cache()
    arch = ArchSpec(rows=16, cols=32)
    p1 = get_plan(_sim_module("dot", 2, False, 4, 16, 32, arch))
    p2 = get_plan(_sim_module("dot", 2, False, 4, 16, 32, arch))
    assert p1 is p2
