"""aCAM range-search subsystem: oracle contracts, Pallas kernels, the
engine's ``RangePlan`` family (threshold + interval modes, packed /
pallas / sharded / served), and the IR interpreter as semantic oracle.

Device count is fixed at jax import time, so the multi-device parity
matrix runs in a child process under 8 forced host devices (this file
doubles as that child: ``python tests/test_range.py --child``).
"""

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (ArchSpec, Builder, Module, PassManager, RangePlan,
                        RangeSpec, SearchPlan, TensorType, clear_plan_cache,
                        get_plan)
from repro.core.cim_dialect import (make_acquire, make_execute,
                                    make_range_search, make_release,
                                    make_similarity, make_yield)
from repro.core.executor import execute_module
from repro.core.ir import IRError
from repro.core.passes import CompulsoryPartition
from repro.kernels import ops, ref

DEVICES = 8


def _range_module(m, n, dim, arch, *, interval=False, metric="hamming",
                  tau=0.0, below=True, value_bits=1):
    """Hand-built range program through the partition pass."""
    args = [TensorType((m, dim))] + \
        [TensorType((n, dim))] * (2 if interval else 1)
    mod = Module("rng", args)
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, list(mod.arguments),
                       [TensorType((m, n), "i1")])
    blk = exe.region().block()
    if interval:
        rs = make_range_search(blk, mod.arguments[0], lo=mod.arguments[1],
                               hi=mod.arguments[2],
                               extra_attrs={"value_bits": value_bits})
    else:
        rs = make_range_search(blk, mod.arguments[0],
                               patterns=mod.arguments[1], metric=metric,
                               threshold=tau, below=below,
                               extra_attrs={"value_bits": value_bits})
    make_yield(blk, rs.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition())
    return pm.run(mod, {"arch": arch})


def _interval_data(rng, m, n, dim, constrained=0.08):
    """Queries + (lo, hi) with wildcards and a non-trivial match rate."""
    q = rng.standard_normal((m, dim)).astype(np.float32)
    lo = np.full((n, dim), -np.inf, np.float32)
    hi = np.full((n, dim), np.inf, np.float32)
    sel = rng.random((n, dim)) < constrained
    lo[sel] = (rng.standard_normal(sel.sum()) - 2).astype(np.float32)
    hi[sel] = lo[sel] + 3.5
    return q, lo, hi


# ---------------------------------------------------------------------------
# ref oracles: cam_range promoted to a tested contract; acam_match
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric,tau", [("hamming", 28.0), ("dot", 3.0),
                                        ("cos", 0.1), ("eucl", 130.0)])
def test_cam_range_contract_all_metrics(metric, tau, rng):
    """``cam_range`` is exactly ``distances <= threshold``, with a
    non-trivial (neither empty nor full) match set at the tested tau."""
    if metric == "hamming":
        q = (rng.random((7, 64)) > 0.5).astype(np.float32)
        p = (rng.random((40, 64)) > 0.5).astype(np.float32)
    else:
        q = rng.standard_normal((7, 64)).astype(np.float32)
        p = rng.standard_normal((40, 64)).astype(np.float32)
    m = np.asarray(ref.cam_range(jnp.asarray(q), jnp.asarray(p), tau,
                                 metric=metric))
    d = np.asarray(ref.distances(jnp.asarray(q), jnp.asarray(p), metric))
    assert m.dtype == np.bool_ and m.shape == (7, 40)
    np.testing.assert_array_equal(m, d <= tau)
    assert 0 < m.sum() < m.size


def test_cam_range_threshold_ties_inclusive(rng):
    """A row at exactly the threshold distance matches (TH sensing
    latches on the reference level)."""
    q = (rng.random((1, 32)) > 0.5).astype(np.float32)
    p = np.repeat(q, 4, axis=0)
    p[1, :5] = 1 - p[1, :5]            # distance exactly 5
    p[2, :6] = 1 - p[2, :6]            # distance 6
    p[3, :] = 1 - p[3, :]              # distance 32
    m = np.asarray(ref.cam_range(jnp.asarray(q), jnp.asarray(p), 5.0))
    np.testing.assert_array_equal(m[0], [True, True, False, False])


def test_cam_range_empty_match_rows(rng):
    """Rows with no match at all stay all-False (and stay well-formed
    through the kernel wrapper too)."""
    q = (rng.random((3, 48)) > 0.5).astype(np.float32)
    p = 1.0 - np.repeat(q[[0]], 10, axis=0)     # distance 48 from q[0]
    m = np.asarray(ref.cam_range(jnp.asarray(q[[0]]), jnp.asarray(p), 4.0))
    assert m.sum() == 0
    k = np.asarray(ops.cam_range_match(jnp.asarray(q[[0]]), jnp.asarray(p),
                                       metric="hamming", threshold=4.0))
    np.testing.assert_array_equal(m, k)


def test_acam_match_oracle_semantics():
    """Closed-interval contract, wildcards, inclusive bounds."""
    q = np.array([[0.5, -1.0], [2.0, 0.0]], np.float32)
    lo = np.array([[0.5, -np.inf], [0.6, -np.inf], [-np.inf, 0.0]],
                  np.float32)
    hi = np.array([[0.5, np.inf], [1.0, np.inf], [np.inf, np.inf]],
                  np.float32)
    m = np.asarray(ref.acam_match(jnp.asarray(q), jnp.asarray(lo),
                                  jnp.asarray(hi)))
    # q0: row0 matches (0.5 in [0.5, 0.5] — inclusive both ends),
    #     row1 fails (0.5 < 0.6), row2 fails (-1.0 < 0.0)
    np.testing.assert_array_equal(m[0], [True, False, False])
    # q1: row0/row1 fail on dim0 upper bound, row2 matches (wildcard dim0)
    np.testing.assert_array_equal(m[1], [False, False, True])


def test_acam_kernel_matches_oracle(rng):
    """Pallas interval kernel == oracle on ragged, wildcard-heavy data."""
    q, lo, hi = _interval_data(rng, 23, 137, 70)
    r = np.asarray(ref.acam_match(jnp.asarray(q), jnp.asarray(lo),
                                  jnp.asarray(hi)))
    k = np.asarray(ops.acam_match(jnp.asarray(q), jnp.asarray(lo),
                                  jnp.asarray(hi)))
    assert 0 < r.sum() < r.size
    np.testing.assert_array_equal(r, k)


@pytest.mark.parametrize("metric,tau", [("hamming", 28.0), ("dot", 3.0),
                                        ("eucl", 130.0)])
def test_range_match_kernel_parity(metric, tau, rng):
    """Fused thresholded kernel == cam_range oracle (physical metrics)."""
    if metric == "hamming":
        q = (rng.random((9, 70)) > 0.5).astype(np.float32)
        p = (rng.random((37, 70)) > 0.5).astype(np.float32)
    else:
        q = rng.standard_normal((9, 70)).astype(np.float32)
        p = rng.standard_normal((37, 70)).astype(np.float32)
    r = np.asarray(ref.cam_range(jnp.asarray(q), jnp.asarray(p), tau,
                                 metric=metric))
    k = np.asarray(ops.cam_range_match(jnp.asarray(q), jnp.asarray(p),
                                       metric=metric, threshold=tau))
    np.testing.assert_array_equal(r, k)


# ---------------------------------------------------------------------------
# engine RangePlan: parity with the interpreter oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric,tau,below", [
    ("hamming", 40.0, True), ("dot", 4.0, False), ("cos", -2.0, False),
    ("eucl", 180.0, True)])
@pytest.mark.parametrize("n", [37, 64, 5])
def test_range_plan_matches_interpreter(metric, tau, below, n, rng):
    m, dim = 9, 100
    arch = ArchSpec(rows=16, cols=32)
    mod = _range_module(m, n, dim, arch, metric=metric, tau=tau, below=below)
    plan = get_plan(mod)
    assert isinstance(plan, RangePlan) and isinstance(plan.spec, RangeSpec)
    if metric == "hamming":
        q = (rng.random((m, dim)) > 0.5).astype(np.float32)
        p = (rng.random((n, dim)) > 0.5).astype(np.float32)
    else:
        q = rng.standard_normal((m, dim)).astype(np.float32)
        p = rng.standard_normal((n, dim)).astype(np.float32)
    ev = np.asarray(plan.execute(q, p))
    iv = np.asarray(execute_module(mod, q, p)[0])
    np.testing.assert_array_equal(ev, iv)


@pytest.mark.parametrize("n", [137, 64, 23, 5])
def test_interval_plan_matches_interpreter(n, rng):
    m, dim = 9, 100
    arch = ArchSpec(rows=16, cols=32)
    mod = _range_module(m, n, dim, arch, interval=True)
    plan = get_plan(mod)
    assert isinstance(plan, RangePlan)
    assert not plan.packed            # interval cells are analog floats
    q, lo, hi = _interval_data(rng, m, n, dim)
    ev = np.asarray(plan.execute(q, lo, hi))
    iv = np.asarray(execute_module(mod, q, lo, hi)[0])
    assert 0 < ev.sum() < ev.size
    np.testing.assert_array_equal(ev, iv)


def test_range_plan_packed_equals_unpacked(rng):
    """Packed XOR+popcount threshold path == float path, bit for bit."""
    m, n, dim = 9, 64, 96
    arch = ArchSpec(rows=16, cols=32)
    mod = _range_module(m, n, dim, arch, metric="hamming", tau=40.0)
    packed = get_plan(mod, pack=True)
    unpacked = get_plan(mod, pack=False)
    assert packed.packed and not unpacked.packed and packed is not unpacked
    q = (rng.random((m, dim)) > 0.5).astype(np.float32)
    p = (rng.random((n, dim)) > 0.5).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(packed.execute(q, p)),
                                  np.asarray(unpacked.execute(q, p)))


def test_range_plan_pallas_backend(rng):
    """Pallas range executables (both modes) match the interpreter."""
    m, n, dim = 9, 37, 70
    arch = ArchSpec(rows=16, cols=32)
    mod = _range_module(m, n, dim, arch, metric="eucl", tau=150.0)
    plan = get_plan(mod, backend="pallas")
    assert isinstance(plan, RangePlan) and not plan.packed
    q = rng.standard_normal((m, dim)).astype(np.float32)
    p = rng.standard_normal((n, dim)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(plan.execute(q, p)),
                                  np.asarray(execute_module(mod, q, p)[0]))

    modi = _range_module(m, n, dim, arch, interval=True)
    plani = get_plan(modi, backend="pallas")
    q, lo, hi = _interval_data(rng, m, n, dim)
    np.testing.assert_array_equal(
        np.asarray(plani.execute(q, lo, hi)),
        np.asarray(execute_module(modi, q, lo, hi)[0]))
    # packed pallas range is refused explicitly, not silently unpacked
    with pytest.raises(ValueError):
        get_plan(_range_module(m, n, dim, arch, metric="hamming", tau=9.0),
                 backend="pallas", pack=True)


def test_range_plan_cache_keys(rng):
    """Range plans live in the shared cache; threshold and mode join the
    key; a range plan never collides with a similarity plan of the same
    geometry."""
    clear_plan_cache()
    m, n, dim = 8, 32, 64
    arch = ArchSpec(rows=16, cols=32)
    mod_a = _range_module(m, n, dim, arch, metric="hamming", tau=10.0)
    mod_b = _range_module(m, n, dim, arch, metric="hamming", tau=10.0)
    mod_c = _range_module(m, n, dim, arch, metric="hamming", tau=11.0)
    pa, pb, pc = get_plan(mod_a), get_plan(mod_b), get_plan(mod_c)
    assert pa is pb                       # same program shape: cache hit
    assert pa is not pc                   # threshold is part of the key

    simmod = Module("sim", [TensorType((m, dim)), TensorType((n, dim))])
    b = Builder(simmod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, list(simmod.arguments),
                       [TensorType((m, 3)), TensorType((m, 3), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, simmod.arguments[0], simmod.arguments[1],
                          metric="hamming", k=3, largest=False,
                          extra_attrs={"value_bits": 1})
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition())
    ps = get_plan(pm.run(simmod, {"arch": arch}))
    assert isinstance(ps, SearchPlan) and not isinstance(ps, RangePlan)
    assert ps is not pa


def test_range_plan_microbatch_and_memo(rng):
    """Runtime M beyond the traced batch streams in chunks; a jax-array
    gallery hits the pattern memo on the second execute."""
    m, n, dim = 8, 40, 64
    arch = ArchSpec(rows=16, cols=32)
    mod = _range_module(m, n, dim, arch, interval=True)
    plan = get_plan(mod)
    q, lo, hi = _interval_data(rng, 61, n, dim)
    loj, hij = jnp.asarray(lo), jnp.asarray(hi)
    h0, m0 = plan.pattern_hits, plan.pattern_misses
    ev = np.asarray(plan.execute(q, loj, hij))
    assert ev.shape == (61, n)
    assert plan.pattern_misses == m0 + 1
    np.asarray(plan.execute(q, loj, hij))
    assert plan.pattern_hits == h0 + 1
    big = _range_module(61, n, dim, arch, interval=True)
    np.testing.assert_array_equal(
        ev, np.asarray(execute_module(big, q, lo, hi)[0]))


def test_range_plan_served(rng):
    """CamSearchServer serves a range plan: concurrent clients get the
    same matches the plan computes directly; search() refuses."""
    import threading

    from repro.serving import CamSearchServer

    m, n, dim = 16, 48, 64
    arch = ArchSpec(rows=16, cols=32)
    mod = _range_module(m, n, dim, arch, interval=True)
    plan = get_plan(mod)
    q, lo, hi = _interval_data(rng, 64, n, dim)
    direct = np.asarray(plan.execute(q, lo, hi))
    got = {}
    with CamSearchServer(plan, (lo, hi), max_wait_ms=1.0) as srv:
        with pytest.raises(TypeError):
            srv.search(q[:2])
        parts = np.array_split(np.arange(64), 4)
        def client(c):
            got[c] = srv.match(q[parts[c]])
        ts = [threading.Thread(target=client, args=(c,)) for c in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        snap = srv.snapshot()
    served = np.concatenate([got[c] for c in range(4)])
    np.testing.assert_array_equal(served, direct)
    assert snap["plan"]["family"] == "range"
    assert snap["plan"]["mode"] == "interval"
    # geometry validation up front
    with pytest.raises(ValueError):
        CamSearchServer(plan, (lo[:, :-1], hi[:, :-1]))
    with pytest.raises(ValueError):
        CamSearchServer(plan, lo)          # interval plan needs (lo, hi)


def test_range_search_ir_validation():
    mod = Module("bad", [TensorType((4, 8)), TensorType((6, 8)),
                         TensorType((6, 8))])
    blk = mod.body
    q, lo, hi = mod.arguments
    with pytest.raises(IRError):
        make_range_search(blk, q, lo=lo)               # hi missing
    with pytest.raises(IRError):
        make_range_search(blk, q, patterns=lo, metric="hamming")  # no tau
    with pytest.raises(ValueError):
        make_range_search(blk, q, patterns=lo, metric="manhattan",
                          threshold=1.0)               # unknown metric
    with pytest.raises(IRError):
        make_range_search(blk, q, lo=lo, hi=hi, metric="hamming",
                          threshold=1.0)               # mixed forms


# ---------------------------------------------------------------------------
# sharded parity (child process under 8 forced host devices)
# ---------------------------------------------------------------------------


def _child_main() -> int:
    import jax

    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))

    assert jax.device_count() == DEVICES, jax.device_count()
    rng = np.random.default_rng(7)
    arch = ArchSpec(rows=16, cols=32)

    # threshold (packed hamming + float eucl) and interval modes over
    # aligned / ragged / sub-shard / tiny galleries
    for n in (137, 64, 23, 5):
        m, dim = 9, 100
        q = (rng.random((m, dim)) > 0.5).astype(np.float32)
        p = (rng.random((n, dim)) > 0.5).astype(np.float32)
        mod = _range_module(m, n, dim, arch, metric="hamming", tau=40.0)
        single = get_plan(mod, shards=1)
        sharded = get_plan(mod, shards=DEVICES)
        assert sharded.shards == DEVICES and single is not sharded
        sv = np.asarray(single.execute(q, p))
        mv = np.asarray(sharded.execute(q, p))
        iv = np.asarray(execute_module(mod, q, p)[0])
        np.testing.assert_array_equal(sv, mv, err_msg=f"hamming n={n}")
        np.testing.assert_array_equal(sv, iv, err_msg=f"hamming n={n}")

        qf = rng.standard_normal((m, dim)).astype(np.float32)
        pf = rng.standard_normal((n, dim)).astype(np.float32)
        emod = _range_module(m, n, dim, arch, metric="eucl", tau=170.0)
        es, em = get_plan(emod, shards=1), get_plan(emod, shards=DEVICES)
        np.testing.assert_array_equal(np.asarray(es.execute(qf, pf)),
                                      np.asarray(em.execute(qf, pf)),
                                      err_msg=f"eucl n={n}")

        imod = _range_module(m, n, dim, arch, interval=True)
        i1, i8 = get_plan(imod, shards=1), get_plan(imod, shards=DEVICES)
        q2, lo, hi = _interval_data(rng, m, n, dim)
        a = np.asarray(i1.execute(q2, lo, hi))
        b = np.asarray(i8.execute(q2, lo, hi))
        c = np.asarray(execute_module(imod, q2, lo, hi)[0])
        np.testing.assert_array_equal(a, b, err_msg=f"interval n={n}")
        np.testing.assert_array_equal(a, c, err_msg=f"interval n={n}")

    print("RANGE-SHARDED-OK")
    return 0


def test_sharded_range_parity_multi_device():
    """Sharded RangePlan parity matrix under 8 forced host devices."""
    from repro.launch.mesh import forced_host_devices_env

    env = forced_host_devices_env(DEVICES)
    env.pop("REPRO_ENGINE_MAX_CHUNK", None)
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0 and "RANGE-SHARDED-OK" in out.stdout, (
        f"range sharded child failed (rc={out.returncode}):\n"
        f"{out.stdout[-3000:]}\n{out.stderr[-3000:]}")


if __name__ == "__main__":
    if "--child" in sys.argv:
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={DEVICES}")
        raise SystemExit(_child_main())
    raise SystemExit(pytest.main([__file__, "-v"]))
