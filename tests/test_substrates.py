"""Substrate tests: optimizer, schedules, data, checkpoint, compression,
straggler monitor, recovery supervisor."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st  # skips cleanly without hypothesis

from repro.checkpoint import (AsyncCheckpointer, latest_step, restore_pytree,
                              save_pytree)
from repro.data import ShardedLoader, TokenStream, hdc_dataset, knn_dataset
from repro.distributed import (ErrorFeedbackInt8, ErrorFeedbackTopK,
                               RecoveryConfig, SimulatedFailure,
                               StragglerMonitor, Supervisor)
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, warmup_cosine, warmup_linear)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0]), "scale": jnp.asarray([2.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=100.0)
    lr = jnp.asarray(0.1)
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2)
                         + jnp.sum((p["scale"] - 1.0) ** 2))(params)
        params, state, m = adamw_update(grads, state, params, lr, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert abs(float(params["scale"][0]) - 1.0) < 1e-2


def test_adamw_no_decay_on_norm_leaves():
    params = {"w": jnp.ones((4,)), "norm_scale": jnp.ones((4,))}
    state = adamw_init(params)
    grads = {"w": jnp.zeros((4,)), "norm_scale": jnp.zeros((4,))}
    cfg = AdamWConfig(weight_decay=0.5)
    params2, _, _ = adamw_update(grads, state, params, jnp.asarray(0.1), cfg)
    assert float(params2["w"][0]) < 1.0            # decayed
    assert float(params2["norm_scale"][0]) == 1.0  # excluded


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(gn) > 30.0


def test_bf16_params_master_accumulates_small_updates():
    """bf16 params alone would lose 1e-3-scale updates; the fp32 master
    must accumulate them."""
    params = {"w": jnp.ones((4,), jnp.bfloat16) * 100.0}
    state = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0)
    g = {"w": jnp.ones((4,), jnp.float32)}
    for _ in range(100):
        params, state, _ = adamw_update(g, state, params, jnp.asarray(1e-3),
                                        cfg)
    # 100 steps x ~1e-3 -> master moved ~0.1 even though bf16 eps(100)=0.5
    assert float(state.master["w"][0]) < 99.95


def test_schedules_monotone_warmup():
    s = warmup_cosine(1e-3, 10, 100)
    vals = [float(s(jnp.asarray(i))) for i in range(15)]
    assert vals[0] > 0                    # first step is not dead
    assert all(b >= a for a, b in zip(vals[:9], vals[1:10]))
    assert abs(vals[9] - 1e-3) < 1e-9
    lin = warmup_linear(1e-3, 10, 100)
    assert float(lin(jnp.asarray(99))) < 2e-5 + 1e-9


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_tokenstream_deterministic_and_resumable():
    a = TokenStream(vocab=1000, seq_len=64, global_batch=4, seed=7)
    b = TokenStream(vocab=1000, seq_len=64, global_batch=4, seed=7)
    np.testing.assert_array_equal(a.batch(5)["tokens"], b.batch(5)["tokens"])
    assert not np.array_equal(a.batch(5)["tokens"], a.batch(6)["tokens"])
    assert a.batch(0)["tokens"].max() < 1000
    # loader state round-trip
    ld = ShardedLoader(a)
    ld.next(), ld.next()
    st_ = ld.state_dict()
    x3 = ld.next()["tokens"]
    ld2 = ShardedLoader(b)
    ld2.load_state_dict(st_)
    np.testing.assert_array_equal(ld2.next()["tokens"], x3)


def test_hdc_dataset_recall_structure():
    classes, queries, labels = hdc_dataset(n_classes=10, dim=1024,
                                           n_queries=200, noise=0.1)
    d = (queries[:, None] != classes[None]).sum(-1)
    assert (d.argmin(-1) == labels).mean() > 0.99


def test_knn_dataset_separable():
    g, gl, q, ql = knn_dataset(n_gallery=2000, dim=64, n_queries=50)
    d = ((q[:, None] - g[None]) ** 2).sum(-1)
    nn = gl[d.argmin(-1)]
    assert (nn == ql).mean() > 0.9


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nest": {"b": jnp.arange(6, dtype=jnp.int32)},
            "t": (jnp.ones(3), jnp.zeros(2))}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_pytree(tree, str(tmp_path), 7)
    assert latest_step(str(tmp_path)) == 7
    out = restore_pytree(jax.tree.map(jnp.zeros_like, tree), str(tmp_path))
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), tree, out)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_pytree({"a": jnp.ones((4,))}, str(tmp_path), 1)
    with pytest.raises(ValueError):
        restore_pytree({"a": jnp.ones((5,))}, str(tmp_path))


def test_checkpoint_atomicity_partial_write_invisible(tmp_path):
    tree = _tree()
    save_pytree(tree, str(tmp_path), 3)
    # simulate a crashed writer: stale tmp dir must be ignored
    os.makedirs(tmp_path / "step_000000009.tmp.0" )
    assert latest_step(str(tmp_path)) == 3


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(_tree(s), s)
    ck.wait()
    steps_left = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps_left == [3, 4]


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comp", [ErrorFeedbackInt8(),
                                  ErrorFeedbackTopK(density=0.25)])
def test_error_feedback_is_unbiased_over_time(comp):
    """sum(compressed) -> sum(true grads): the residual stays bounded."""
    params = {"w": jnp.zeros((64,))}
    state = comp.init(params)
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
    total = jnp.zeros((64,))
    for _ in range(50):
        c, state = comp(g_true, state)
        total = total + c["w"]
    np.testing.assert_allclose(np.asarray(total) / 50,
                               np.asarray(g_true["w"]), atol=0.1)


def test_topk_compression_sparsity():
    comp = ErrorFeedbackTopK(density=0.1)
    params = {"w": jnp.zeros((100,))}
    state = comp.init(params)
    g = {"w": jnp.asarray(np.random.default_rng(1).standard_normal(100),
                          jnp.float32)}
    c, state = comp(g, state)
    assert int((np.asarray(c["w"]) != 0).sum()) <= 10


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------


def test_straggler_detection_flags_outlier():
    mon = StragglerMonitor(window=16, z_threshold=4.0)
    for _ in range(16):
        mon.record(0.100 + np.random.default_rng(0).normal(0, 0.001))
    assert mon.record(0.5) is True
    assert mon.record(0.101) is False


def test_straggler_rebalance_suggestion():
    mon = StragglerMonitor(window=16)
    for _ in range(16):
        mon.record(0.1)
    for _ in range(8):
        mon.record(0.3)                # persistent slowdown
    assert mon.suggest_rebalance() < 1.0


# ---------------------------------------------------------------------------
# recovery supervisor
# ---------------------------------------------------------------------------


def test_supervisor_recovers_from_injected_failure(tmp_path):
    sup = Supervisor(RecoveryConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                                    max_restarts=2))
    calls = {"fails": 0}

    def step_fn(state, step):
        if step == 5 and calls["fails"] == 0:
            calls["fails"] += 1
            raise SimulatedFailure("boom")
        return {"x": state["x"] + 1}, {"loss": 1.0 / (step + 1)}

    final, metrics = sup.run({"x": jnp.zeros(())}, 8, step_fn)
    assert sup.restarts == 1
    assert float(final["x"]) == 8          # replayed correctly from ckpt
    assert any("restored_to" in e for e in sup.log)


def test_supervisor_nan_loss_triggers_restore(tmp_path):
    sup = Supervisor(RecoveryConfig(ckpt_dir=str(tmp_path), ckpt_every=1,
                                    max_restarts=3))
    hit = {"n": 0}

    def step_fn(state, step):
        loss = float("nan") if step == 3 and hit["n"] == 0 else 0.5
        if step == 3 and hit["n"] == 0:
            hit["n"] = 1
        return {"x": state["x"] + 1}, {"loss": loss}

    final, _ = sup.run({"x": jnp.zeros(())}, 5, step_fn)
    assert sup.restarts == 1


def test_supervisor_retry_budget_exhausts(tmp_path):
    sup = Supervisor(RecoveryConfig(ckpt_dir=str(tmp_path), ckpt_every=1,
                                    max_restarts=1))

    def step_fn(state, step):
        if step == 2:
            raise SimulatedFailure("always")
        return state, {"loss": 1.0}

    with pytest.raises(RuntimeError, match="retry budget"):
        sup.run({"x": jnp.zeros(())}, 5, step_fn)
