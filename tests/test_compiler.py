"""C4CAM compiler pipeline tests: tracing, Algorithm 1, partitioning,
lowering, functional execution vs the dense oracle, cost-model trends."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st  # skips cleanly without hypothesis

from repro.core import (ArchSpec, CamType, IRError, OptimizationTarget,
                        PAPER_BASE_ARCH, compile_fn, trace, verify)
from repro.core.arch import kazemi_arch
from repro.core.passes.partition import tile_grid
from repro.core.passes.cam_map import derive_plan
from repro.camsim import CostModel
from repro.kernels import ref as kref


# ---------------------------------------------------------------------------
# frontend / IR
# ---------------------------------------------------------------------------


def _dot_sim(inp, weight):
    others = weight.transpose(-2, -1)
    mm = inp.matmul(others)
    return mm.topk(1, largest=False)


def _eucl_sim(inp, weight):
    diff = inp.unsqueeze(1).sub(weight)      # (M,1,D) - (N,D) -> (M,N,D)
    n = diff.norm(p=2, dim=-1)
    return n.topk(3, largest=False)


def _cos_sim(inp, weight):
    qn = inp.norm(dim=-1, keepdim=True)
    wn = weight.norm(dim=-1, keepdim=True)
    mm = inp.matmul(weight.transpose(-2, -1))
    sim = mm / wn.transpose(-2, -1) / qn
    return sim.topk(1, largest=True)


def test_trace_produces_torch_dialect():
    m = trace(_dot_sim, [(10, 64), (16, 64)])
    names = [op.name for op in m.ops()]
    assert names[:3] == ["torch.transpose", "torch.matmul", "torch.topk"]
    assert names[-1] == "func.return"
    verify(m)
    assert "torch.matmul" in m.dump()


def test_trace_rejects_bad_matmul():
    with pytest.raises(IRError):
        trace(lambda a, b: a.matmul(b), [(4, 8), (4, 8)])


@pytest.mark.parametrize("fn,pattern", [
    (_dot_sim, "DotProdSimPattern"),
    (_eucl_sim, "EuclNormPattern"),
    (_cos_sim, "CosSimPattern"),
])
def test_algorithm1_matches_all_three_patterns(fn, pattern):
    prog = compile_fn(fn, [(10, 256), (32, 256)], PAPER_BASE_ARCH)
    assert prog.matched_patterns == [pattern]
    fused = prog.stages["cim_fused"].dump()
    assert "cim.similarity" in fused


def test_non_similarity_code_not_matched():
    prog = compile_fn(lambda a, b: a.add(b), [(8, 8), (8, 8)],
                      PAPER_BASE_ARCH)
    assert prog.matched_patterns == []


# ---------------------------------------------------------------------------
# partitioning invariants (property-based)
# ---------------------------------------------------------------------------


@given(rows=st.sampled_from([16, 32, 64, 128, 256]),
       cols=st.sampled_from([16, 32, 64, 128, 256]),
       n=st.integers(1, 2000), dim=st.integers(1, 9000),
       bits=st.sampled_from([1, 8]))
@settings(max_examples=60, deadline=None)
def test_tile_grid_covers_workload(rows, cols, n, dim, bits):
    arch = ArchSpec(rows=rows, cols=cols)
    gr, gc, cpv, dpt = tile_grid(arch, n, dim, value_bits=bits)
    # full coverage
    assert gr * rows >= n and (gr - 1) * rows < n
    assert gc * dpt >= dim
    # no tile exceeds the physical columns
    assert dpt * cpv <= cols or dpt == 1


@given(rows=st.sampled_from([16, 32, 64]), n=st.integers(1, 512),
       m=st.integers(1, 64), dim=st.integers(1, 2048),
       target=st.sampled_from(list(OptimizationTarget.ALL)))
@settings(max_examples=40, deadline=None)
def test_mapping_plan_invariants(rows, n, m, dim, target):
    arch = ArchSpec(rows=rows, cols=rows).with_target(target)
    gr, gc, cpv, dpt = tile_grid(arch, n, dim, value_bits=1)
    part = dict(m=m, n=n, dim=dim, grid_rows=gr, grid_cols=gc,
                dims_per_tile=dpt, cells_per_value=cpv, value_bits=1,
                metric="dot", k=1, largest=True)
    plan = derive_plan(arch, part)
    assert plan.physical_subarrays <= plan.logical_tiles
    assert plan.physical_subarrays * plan.stack >= plan.logical_tiles
    assert plan.searches == m * plan.logical_tiles
    assert plan.search_cycles >= m * plan.stack  # at least one cycle/query
    if target in (OptimizationTarget.DENSITY,
                  OptimizationTarget.POWER_DENSITY):
        assert plan.stack >= 1
    else:
        assert plan.stack == 1


# ---------------------------------------------------------------------------
# functional execution == dense oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", [kazemi_arch(16), kazemi_arch(32),
                                  PAPER_BASE_ARCH,
                                  ArchSpec(rows=64, cols=128)])
def test_compiled_hdc_equals_dense_reference(arch, rng):
    q = rng.standard_normal((12, 512)).astype(np.float32)
    w = rng.standard_normal((10, 512)).astype(np.float32)
    prog = compile_fn(_dot_sim, [q, w], arch)
    v, i = prog(q, w)
    # dense bipolar oracle
    qb = np.where(q > 0, 1.0, -1.0)
    wb = np.where(w > 0, 1.0, -1.0)
    ref_idx = np.argmin(qb @ wb.T, axis=-1)
    assert np.array_equal(np.asarray(i).ravel(), ref_idx)


def test_compiled_eucl_matches_reference(rng):
    q = rng.standard_normal((6, 64)).astype(np.float32)
    w = rng.standard_normal((40, 64)).astype(np.float32)
    prog = compile_fn(_eucl_sim, [q, w], ArchSpec(rows=16, cols=32),
                      cam_type=CamType.ACAM)
    v, i = prog(q, w)
    d = ((q[:, None, :] - w[None]) ** 2).sum(-1)
    ref_i = np.argsort(d, axis=-1, kind="stable")[:, :3]
    assert np.array_equal(np.asarray(i), ref_i)


def test_all_optimization_targets_same_results(rng):
    q = rng.standard_normal((5, 256)).astype(np.float32)
    w = rng.standard_normal((64, 256)).astype(np.float32)
    outs = []
    for target in OptimizationTarget.ALL:
        prog = compile_fn(_dot_sim, [q, w], PAPER_BASE_ARCH, target=target)
        outs.append(np.asarray(prog(q, w)[1]))
    for o in outs[1:]:
        assert np.array_equal(outs[0], o)


# ---------------------------------------------------------------------------
# cost model: the paper's qualitative trends
# ---------------------------------------------------------------------------


def _report(target, size=32, m=100, n=640, dim=8192):
    arch = ArchSpec(rows=size, cols=size).with_target(target)
    q_shape, w_shape = (m, dim), (n, dim)
    prog = compile_fn(_dot_sim, [q_shape, w_shape], arch, unroll_limit=0)
    return prog.cost_report()


def test_power_mode_reduces_power_increases_latency():
    base = _report(OptimizationTarget.LATENCY)
    power = _report(OptimizationTarget.POWER)
    assert power.power_w < base.power_w
    assert power.latency_ns > base.latency_ns
    # energy approximately conserved (paper: "overall energy ... the same")
    assert abs(power.energy_fj - base.energy_fj) / base.energy_fj < 0.05


def test_density_mode_uses_fewer_subarrays():
    arch_b = ArchSpec(rows=256, cols=256).with_target("latency")
    arch_d = ArchSpec(rows=256, cols=256).with_target("density")
    from repro.core.compiler import compile_fn as cf
    pb = cf(_dot_sim, [(10, 8192), (10, 8192)], arch_b, unroll_limit=0)
    pd = cf(_dot_sim, [(10, 8192), (10, 8192)], arch_d, unroll_limit=0)
    sb = pb.plans[0].physical_subarrays
    sd = pd.plans[0].physical_subarrays
    assert sd < sb          # Table I: density packs tiles into fewer arrays
    assert pd.cost_report().latency_ns > pb.cost_report().latency_ns


def test_search_latency_grows_with_columns():
    cm16 = CostModel(ArchSpec(rows=16, cols=16))
    cm256 = CostModel(ArchSpec(rows=256, cols=256))
    t16 = cm16.tech.t_search_ns(16)
    t256 = cm256.tech.t_search_ns(256)
    assert abs(t16 - 0.86) < 0.02           # paper anchor
    assert abs(t256 - 7.5) < 0.6            # paper anchor
