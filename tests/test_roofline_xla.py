"""Cross-check the HLO roofline parser against XLA's own cost analysis
on a real compiled module (single device, no collectives).

Pins the empirical fact the §Roofline methodology rests on: XLA's
``cost_analysis()`` counts a ``while`` (scan) body ONCE, while the parser
re-weights by the trip count.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import roofline as rl

L, M, K = 6, 32, 64


def _compiled():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), 0
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((M, K), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, K, K), jnp.float32)
    return jax.jit(f).lower(x, ws).compile()


def test_parser_reweights_scan_bodies():
    compiled = _compiled()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):      # older jax: one dict per partition
        ca = ca[0]
    rep = rl.analyze_compiled(compiled, n_devices=1)

    per_iter = 2 * M * K * K
    # XLA counts the body once...
    assert abs(ca["flops"] - per_iter) / per_iter < 0.05, ca["flops"]
    # ...the parser counts it L times
    assert rep.while_trip_counts == [L]
    np.testing.assert_allclose(rep.flops, per_iter * L, rtol=0.05)
    assert rep.dot_count == L


def test_parser_hbm_within_sane_bounds():
    """HBM estimate covers at least the unavoidable traffic (weights read
    once, activations per step) and is within a small factor of it."""
    compiled = _compiled()
    rep = rl.analyze_compiled(compiled, n_devices=1)
    lower = 4 * (L * K * K + L * M * K)      # weights + per-iter x in/out
    assert rep.hbm_bytes >= lower
    assert rep.hbm_bytes < 20 * lower
