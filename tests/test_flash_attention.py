"""Pallas flash-attention kernel vs the pure-jnp chunked oracle
(`models/layers.attn_core`), interpret mode on CPU."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st  # skips cleanly without hypothesis

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.layers import attn_core


def _data(rng, b, s, t, h, kvh, dh, dtype=np.float32):
    q = jnp.asarray(rng.standard_normal((b, s, h, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((b, t, kvh, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((b, t, kvh, dh)), dtype)
    return q, k, v


@pytest.mark.parametrize("shape", [
    (2, 64, 64, 4, 2, 32), (1, 100, 100, 8, 8, 16),
    (2, 32, 96, 4, 1, 64), (1, 257, 257, 2, 2, 128),
    (1, 16, 512, 4, 4, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(shape, causal, rng):
    b, s, t, h, kvh, dh = shape
    q, k, v = _data(rng, *shape)
    o1 = flash_attention_pallas(q, k, v, causal=causal,
                                block_q=32, block_k=64)
    o2 = attn_core(q, k, v, causal=causal).reshape(b, s, h, dh)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-3)


def test_flash_prefix_lm(rng):
    q, k, v = _data(rng, 1, 32, 32, 2, 2, 16)
    o1 = flash_attention_pallas(q, k, v, causal=True, prefix_len=8,
                                block_q=16, block_k=16)
    o2 = attn_core(q, k, v, causal=True, prefix_len=8).reshape(1, 32, 2, 16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-3)


def test_flash_kv_len_masking(rng):
    """Cache-style: only the first kv_len rows are valid."""
    q, k, v = _data(rng, 1, 8, 64, 2, 2, 16)
    o1 = flash_attention_pallas(q, k, v, causal=False, kv_len=40,
                                block_q=8, block_k=16)
    o2 = attn_core(q, k[:, :40], v[:, :40], causal=False
                   ).reshape(1, 8, 2, 16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-3)


def test_flash_bf16(rng):
    q, k, v = _data(rng, 1, 64, 64, 4, 2, 32, np.float32)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    o1 = flash_attention_pallas(qb, kb, vb, causal=True)
    o2 = attn_core(q, k, v, causal=True).reshape(1, 64, 4, 32)
    np.testing.assert_allclose(np.asarray(o1, np.float32), np.asarray(o2),
                               atol=0.05)


@given(s=st.integers(1, 80), t=st.integers(1, 80),
       g=st.sampled_from([1, 2, 4]), dh=st.sampled_from([8, 16, 32]),
       bq=st.sampled_from([8, 16, 32]), bk=st.sampled_from([8, 16, 32]))
@settings(max_examples=12, deadline=None)
def test_flash_block_shape_invariance(s, t, g, dh, bq, bk):
    """Property: results are independent of the VMEM tiling."""
    rng = np.random.default_rng(s * 100 + t)
    kvh = 2
    q, k, v = _data(rng, 1, s, t, kvh * g, kvh, dh)
    o1 = flash_attention_pallas(q, k, v, causal=True, block_q=bq, block_k=bk)
    o2 = attn_core(q, k, v, causal=True).reshape(1, s, kvh * g, dh)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-3)
