"""Autotuner + persistent plan store (``repro.tune``).

Covers the search itself (verified winners, trial bounds), the on-disk
store round-trip (configs + AOT executables), the serving warm-start
hook, and — the load-bearing one — the cross-process cold-start
contract: a fresh process serving a previously-tuned workload runs
**zero** tune trials, compiles **zero** XLA executables (pinned via the
store's adoption counters), and produces bit-identical output.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import PAPER_BASE_ARCH, ArchSpec, clear_plan_cache, get_plan
from repro.tune import (active_store, plan_for_config, plan_store_stats,
                        reset_plan_store_stats, reset_tune_stats, tune_plan,
                        tune_stats, warm_start_plan)

from test_engine import _data, _sim_module

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mod_and_data(metric="hamming", k=4, m=16, n=256, dim=64,
                  arch=PAPER_BASE_ARCH, seed=0):
    rng = np.random.default_rng(seed)
    mod = _sim_module(metric, k, metric != "eucl", m, n, dim, arch)
    q, p = _data(rng, metric, m, n, dim)
    return mod, q, p


# ---------------------------------------------------------------------------
# the search
# ---------------------------------------------------------------------------

class TestTuner:
    def test_winner_matches_baseline_output(self):
        clear_plan_cache()
        reset_tune_stats()
        mod, q, p = _mod_and_data()
        res = tune_plan(mod, q, p, trials=6, reps=1)
        assert not res.from_store and res.trials <= 6
        base = get_plan(mod)
        bv, bi = (np.asarray(x) for x in base.execute(q, p))
        tv, ti = (np.asarray(x) for x in res.plan.execute(q, p))
        # hamming is an integer count: the tuned plan is bit-identical
        assert (bv == tv).all() and (bi == ti).all()
        # the incumbent only ever loses to a faster verified candidate
        assert res.best_s <= res.base_s

    def test_trial_bound_is_respected(self):
        clear_plan_cache()
        reset_tune_stats()
        mod, q, p = _mod_and_data(n=128)
        res = tune_plan(mod, q, p, trials=2, reps=1)
        assert res.trials <= 2
        assert tune_stats()["trials"] <= 2

    def test_float_metric_winner_is_tolerance_verified(self):
        clear_plan_cache()
        mod, q, p = _mod_and_data(metric="eucl", n=128)
        res = tune_plan(mod, q, p, trials=4, reps=1)
        base = get_plan(mod)
        bv, _ = (np.asarray(x) for x in base.execute(q, p))
        tv, _ = (np.asarray(x) for x in res.plan.execute(q, p))
        np.testing.assert_allclose(bv, tv, rtol=1e-4, atol=1e-4)

    def test_interpreter_only_module_is_rejected(self):
        from repro.core import Builder, Module, TensorType
        mod = Module("empty", [TensorType((4, 8))])
        Builder(mod.body).ret(list(mod.arguments))
        with pytest.raises(ValueError, match="similarity/range"):
            tune_plan(mod, np.zeros((4, 8), np.float32))

    def test_history_records_rejections_and_errors_without_raising(self):
        clear_plan_cache()
        mod, q, p = _mod_and_data(n=128)
        res = tune_plan(mod, q, p, trials=8, reps=1)
        assert res.history[0]["baseline"] is True
        assert all("wall_s" in h for h in res.history)


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

class TestPlanStore:
    def test_active_store_unset_is_none(self, monkeypatch):
        monkeypatch.delenv("REPRO_PLAN_STORE", raising=False)
        assert active_store() is None

    def test_active_store_blank_raises(self, monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_STORE", "   ")
        with pytest.raises(ValueError, match="REPRO_PLAN_STORE"):
            active_store()

    def test_config_roundtrip_and_store_hit(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_STORE", str(tmp_path))
        clear_plan_cache()
        reset_plan_store_stats()
        mod, q, p = _mod_and_data(n=128)
        first = tune_plan(mod, q, p, trials=4, reps=1)
        assert not first.from_store
        assert any(f.startswith("cfg-jnp-") for f in os.listdir(tmp_path))
        second = tune_plan(mod, q, p, trials=4, reps=1)
        assert second.from_store and second.trials == 0
        assert second.config["tile_rows"] == first.config["tile_rows"]
        assert plan_store_stats()["config_hits"] >= 1
        fv, fi = (np.asarray(x) for x in first.plan.execute(q, p))
        sv, si = (np.asarray(x) for x in second.plan.execute(q, p))
        assert (fv == sv).all() and (fi == si).all()

    def test_aot_record_written_for_eligible_plan(self, tmp_path,
                                                  monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_STORE", str(tmp_path))
        clear_plan_cache()
        reset_plan_store_stats()
        # non-tiny: n*dim must clear REPRO_ENGINE_TINY_CELLS (32768)
        mod, q, p = _mod_and_data(m=32, n=768, dim=64)
        tune_plan(mod, q, p, trials=3, reps=1)
        assert any(f.startswith("aot-") and f.endswith(".pkl")
                   for f in os.listdir(tmp_path))
        assert plan_store_stats()["exec_saves"] >= 1

    def test_fresh_plan_adopts_stored_executables(self, tmp_path,
                                                  monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_STORE", str(tmp_path))
        clear_plan_cache()
        reset_plan_store_stats()
        mod, q, p = _mod_and_data(m=32, n=768, dim=64)
        res = tune_plan(mod, q, p, trials=3, reps=1)
        want = (np.asarray(x) for x in res.plan.execute(q, p))
        # evict everything: the next get_plan builds fresh and must
        # adopt the serialized executables instead of re-jitting
        clear_plan_cache()
        reset_plan_store_stats()
        plan = plan_for_config(res.plan.spec, res.config)
        stats = plan_store_stats()
        assert stats["exec_hits"] == 2        # prepare + chunk adopted
        got = (np.asarray(x) for x in plan.execute(q, p))
        for w, g in zip(want, got):
            assert (w == g).all()
        assert plan_store_stats()["exec_fallbacks"] == 0

    def test_tiny_plans_are_config_only(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_STORE", str(tmp_path))
        clear_plan_cache()
        reset_plan_store_stats()
        # grid_cols == 1 and few cells -> the shape-polymorphic tiny
        # fast path, which must never be AOT-frozen at one query count
        arch = ArchSpec(rows=16, cols=256)   # one column tile for dim=32
        mod, q, p = _mod_and_data(n=64, dim=32, arch=arch)
        plan = get_plan(mod)
        assert plan.tiny
        store = active_store()
        assert store.persist_executables(plan, plan.warm(p)) is False
        assert plan_store_stats()["exec_skips"] == 1
        assert not any(f.startswith("aot-") for f in os.listdir(tmp_path))
        assert store.adopt_executables(plan) is False


# ---------------------------------------------------------------------------
# serving warm start
# ---------------------------------------------------------------------------

class TestServingWarmStart:
    def test_warm_start_plan_noop_without_store(self, monkeypatch):
        monkeypatch.delenv("REPRO_PLAN_STORE", raising=False)
        clear_plan_cache()
        mod, q, p = _mod_and_data(n=128)
        plan = get_plan(mod)
        assert warm_start_plan(plan) is plan

    def test_server_construction_picks_tuned_plan(self, tmp_path,
                                                  monkeypatch):
        from repro.serving import CamSearchServer
        monkeypatch.setenv("REPRO_PLAN_STORE", str(tmp_path))
        clear_plan_cache()
        # heuristic geometry from a deliberately small arch...
        arch = ArchSpec(rows=16, cols=32)
        mod, q, p = _mod_and_data(n=256, dim=64, arch=arch)
        res = tune_plan(mod, q, p, trials=5, reps=1)
        heuristic = get_plan(mod)
        with CamSearchServer(heuristic, p) as srv:
            # ...swapped for the stored winner at construction
            assert srv.plan.spec.tile_rows == res.config["tile_rows"]
            assert srv.plan.batch == res.config["batch"]
            v, i = srv.search(q)
            bv, bi = (np.asarray(x) for x in res.plan.execute(q, p))
            np.testing.assert_array_equal(np.asarray(v), bv)
            np.testing.assert_array_equal(np.asarray(i), bi)
        with CamSearchServer(heuristic, p, tuned=False) as srv:
            assert srv.plan is heuristic

    def test_env_kill_switch(self, tmp_path, monkeypatch):
        from repro.serving.server import _resolve_plan
        monkeypatch.setenv("REPRO_PLAN_STORE", str(tmp_path))
        clear_plan_cache()
        arch = ArchSpec(rows=16, cols=32)
        mod, q, p = _mod_and_data(n=256, dim=64, arch=arch)
        tune_plan(mod, q, p, trials=4, reps=1)
        plan = get_plan(mod)
        monkeypatch.setenv("REPRO_TUNE_SERVE", "0")
        assert _resolve_plan(plan) is plan


# ---------------------------------------------------------------------------
# cross-process cold start (the contract the store exists for)
# ---------------------------------------------------------------------------

_CHILD = r'''
import json, sys, os
import numpy as np
sys.path.insert(0, os.path.join(%(root)r, "tests"))
from test_engine import _sim_module, _data
from repro.core import PAPER_BASE_ARCH
from repro.tune import tune_plan, plan_store_stats, tune_stats
rng = np.random.default_rng(7)
mod = _sim_module("hamming", 8, True, 32, 768, 64, PAPER_BASE_ARCH)
q, p = _data(rng, "hamming", 32, 768, 64)
res = tune_plan(mod, q, p, trials=4, reps=1)
v, i = (np.asarray(x) for x in res.plan.execute(q, p))
print(json.dumps({
    "trials": res.trials, "from_store": res.from_store,
    "store": plan_store_stats(), "tune": tune_stats(),
    "config": {k: res.config[k] for k in
               ("tile_rows", "dims_per_tile", "batch", "pack", "unroll")},
    "v": v.tolist(), "i": i.tolist()}))
'''


class TestColdStartAcrossProcesses:
    def test_second_process_skips_tuning_and_compilation(self, tmp_path):
        """Process A tunes + persists; process B must warm-start: zero
        trials, both executables adopted (== zero XLA compiles: the
        python-jitted originals are never invoked when
        ``exec_fallbacks == 0``), bit-identical results."""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(ROOT, "src"),
                   REPRO_PLAN_STORE=str(tmp_path))
        env.pop("REPRO_TUNE_TRIALS", None)

        def run():
            proc = subprocess.run(
                [sys.executable, "-c", _CHILD % {"root": ROOT}],
                env=env, capture_output=True, text=True, timeout=600)
            assert proc.returncode == 0, proc.stderr[-2000:]
            return json.loads(proc.stdout.splitlines()[-1])

        cold = run()
        assert cold["trials"] > 0 and not cold["from_store"]
        assert cold["store"]["config_saves"] == 1
        assert cold["store"]["exec_saves"] == 1

        warm = run()
        assert warm["from_store"] and warm["trials"] == 0
        assert warm["tune"]["trials"] == 0
        assert warm["store"]["config_hits"] == 1
        assert warm["store"]["exec_hits"] == 2, \
            "stored executables were not adopted (XLA recompiled)"
        assert warm["store"]["exec_fallbacks"] == 0, \
            "adopted executables fell back to the lazy jit path"
        assert warm["store"]["exec_misses"] == 0
        assert warm["config"] == cold["config"]
        assert warm["v"] == cold["v"] and warm["i"] == cold["i"], \
            "warm-started plan is not bit-identical to the tuned one"
