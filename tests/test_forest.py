"""Forest-to-CAM compiler: interval encoding, vote semantics, engine /
interpreter / traversal parity, camsim aCAM costing, sklearn adapter,
and the end-to-end example (which also covers 8-device sharding)."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.arch import ArchSpec, CamType
from repro.forest import (CamForestClassifier, TreeArrays,
                          forest_to_intervals, random_forest,
                          traverse_matches, tree_to_intervals, vote)


def _stump(feature, thr, left_cls, right_cls):
    """depth-1 tree: x[feature] <= thr -> left_cls else right_cls."""
    return TreeArrays(feature=[feature, -1, -1], threshold=[thr, 0, 0],
                      left=[1, -1, -1], right=[2, -1, -1],
                      leaf_class=[0, left_cls, right_cls])


def test_tree_to_intervals_encoding():
    """Left tightens hi to thr; right tightens lo to nextafter(thr);
    untested features stay full-range wildcards."""
    lo, hi, cls = tree_to_intervals(_stump(1, 0.25, 3, 7), dim=4)
    assert lo.shape == hi.shape == (2, 4)
    by_cls = {int(c): i for i, c in enumerate(cls)}
    l3, l7 = by_cls[3], by_cls[7]
    thr = np.float32(0.25)
    assert hi[l3, 1] == thr and lo[l3, 1] == -np.inf
    assert lo[l7, 1] == np.nextafter(thr, np.float32(np.inf))
    assert hi[l7, 1] == np.inf
    # wildcard dims on both rows
    for d in (0, 2, 3):
        assert lo[:, d].tolist() == [-np.inf] * 2
        assert hi[:, d].tolist() == [np.inf] * 2


def test_boundary_sample_routes_like_traversal():
    """x exactly at a threshold goes left (<=) in both encodings — the
    nextafter trick keeps the closed-interval match bit-identical."""
    trees = [_stump(0, 0.5, 1, 2)]
    clf = CamForestClassifier(trees, dim=2).compile(
        ArchSpec(rows=8, cols=8, cam_type=CamType.ACAM))
    x = np.array([[0.5, 0.0],                          # exactly at thr
                  [np.nextafter(np.float32(0.5), np.float32(1)), 0.0]],
                 np.float32)
    pred = clf.predict(x)
    np.testing.assert_array_equal(pred, [1, 2])
    np.testing.assert_array_equal(pred, clf.predict_reference(x))


def test_vote_majority_and_ties():
    leaf_class = np.array([0, 1, 1, 2], np.int32)
    matches = np.array([[True, True, True, False],     # 1 beats 0
                        [True, False, False, True],    # 0-2 tie -> 0
                        [False, False, False, True]],  # only 2
                       bool)
    np.testing.assert_array_equal(vote(matches, leaf_class, 3), [1, 0, 2])


@pytest.mark.parametrize("shape", [(16, 4, 24), (7, 3, 10)])
def test_forest_parity_engine_interpreter_traversal(shape, rng):
    n_trees, depth, dim = shape
    trees = random_forest(rng, n_trees=n_trees, dim=dim, depth=depth,
                          n_classes=5, feature_frac=0.5)
    clf = CamForestClassifier(trees, dim=dim).compile(
        ArchSpec(rows=32, cols=32, cam_type=CamType.ACAM), batch_hint=32)
    assert clf.intervals.wildcard_frac > 0       # wildcard dims exercised
    x = rng.standard_normal((57, dim)).astype(np.float32)
    pred = clf.predict(x)
    np.testing.assert_array_equal(pred, clf.predict_interpreted(x))
    np.testing.assert_array_equal(pred, clf.predict_reference(x))
    # one matched leaf per tree, matches equal the traversal's
    m = clf.matches(x)
    assert (m.sum(axis=1) == n_trees).all()
    np.testing.assert_array_equal(
        m, traverse_matches(trees, clf.intervals, x))


def test_interval_lowering_requires_acam(rng):
    trees = random_forest(rng, n_trees=2, dim=8, depth=2, n_classes=2)
    with pytest.raises(ValueError, match="acam"):
        CamForestClassifier(trees, dim=8).compile(
            ArchSpec(rows=16, cols=16, cam_type=CamType.TCAM))


def test_forest_cost_report_prices_acam(rng):
    """camsim report covers the forest mapping; ACAM sensing costs more
    than the same mapping priced as plain TCAM sensing would."""
    from repro.camsim import CostModel
    from dataclasses import replace

    trees = random_forest(rng, n_trees=8, dim=16, depth=3, n_classes=3)
    clf = CamForestClassifier(trees, dim=16).compile(
        ArchSpec(rows=32, cols=32, cam_type=CamType.ACAM))
    rep = clf.cost_report()
    assert rep.latency_ns > 0 and rep.energy_fj > 0
    plan = clf.mapping_plans[0]
    assert plan.search_type == "range" and plan.k == 0
    assert plan.n_rows == clf.intervals.n_rows
    tcam_arch = replace(clf.arch, cam_type=CamType.TCAM)
    tcam_plan = replace(plan, arch=tcam_arch)
    assert rep.energy_fj > CostModel(tcam_arch).plan_report(tcam_plan).energy_fj


def test_from_sklearn_adapter(rng):
    sklearn = pytest.importorskip("sklearn")           # noqa: F841
    from sklearn.ensemble import RandomForestClassifier

    from repro.forest import from_sklearn

    X = rng.standard_normal((300, 12)).astype(np.float32)
    y = (X[:, 0] + X[:, 1] ** 2 > 0.5).astype(int)
    sk = RandomForestClassifier(n_estimators=10, max_depth=4,
                                random_state=0).fit(X, y)
    trees = from_sklearn(sk)
    assert len(trees) == 10
    clf = CamForestClassifier(trees, dim=12).compile(
        ArchSpec(rows=32, cols=32, cam_type=CamType.ACAM))
    Xq = rng.standard_normal((128, 12)).astype(np.float32)
    pred = clf.predict(Xq)
    # bit-identical to OUR traversal of the converted trees (the pinned
    # contract); close to sklearn's probability-averaged predict
    np.testing.assert_array_equal(pred, clf.predict_reference(Xq))
    assert (pred == sk.predict(Xq)).mean() > 0.8


def test_forest_intervals_row_bookkeeping(rng):
    trees = random_forest(rng, n_trees=4, dim=8, depth=3, n_classes=3)
    iv = forest_to_intervals(trees, 8)
    assert iv.n_rows == sum(t.n_leaves for t in trees)
    assert iv.tree_id.tolist() == sorted(iv.tree_id.tolist())
    assert iv.n_trees == 4 and 0 < iv.wildcard_frac < 1


def test_forest_example_end_to_end():
    """The acceptance pin: examples/forest_inference.py runs a 64-tree
    ensemble through the RangePlan path — single-device, sharded over 8
    forced host devices, and served — with bit-identical predictions.
    Runs in a subprocess because the example forces the device count."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, os.path.join(root, "examples",
                                      "forest_inference.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0 and "FOREST-OK" in out.stdout, (
        f"forest example failed (rc={out.returncode}):\n"
        f"{out.stdout[-3000:]}\n{out.stderr[-3000:]}")
