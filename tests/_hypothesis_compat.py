"""Optional-``hypothesis`` shim for the test suite.

The seed suite hard-imported ``hypothesis`` from four test modules, so a
missing dev dependency aborted collection of the *entire* suite under
``pytest -x``.  Importing ``given``/``settings``/``st`` from here instead
keeps every non-property test runnable: when ``hypothesis`` is installed
the real decorators are re-exported, otherwise ``@given`` turns the test
into a clean per-test skip (the moral equivalent of
``pytest.importorskip("hypothesis")`` without sacrificing the rest of the
module).  ``hypothesis`` itself is listed in ``requirements-dev.txt``.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the dep
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning None (the strategies are never drawn from)."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass  # pragma: no cover

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
