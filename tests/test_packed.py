"""Bit-packed TCAM fast path: pack/unpack round-trips, popcount parity,
packed-vs-unpacked plan bit-parity (incl. n < k sentinels and forced
8-host-device sharding), ternary wildcard semantics, plan-key isolation
(packing axis + operand dtype), pattern-memo LRU counters, and serving
with care masks.

This file doubles as the multi-device child (``--child``), mirroring
``test_sharded.py``: device count is fixed at jax import, so the sharded
packed parity matrix runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st  # skips cleanly without hypothesis

DEVICES = 8


def _ternary_module(m, n, dim, k, arch, care_dtype="i8"):
    """Hand-built TCAM wildcard program: cim.similarity with a care-mask
    operand, run through the partition pass (mirrors test_engine._sim_module)."""
    from repro.core import Builder, Module, PassManager, TensorType
    from repro.core.cim_dialect import (make_acquire, make_execute,
                                        make_release, make_similarity,
                                        make_yield)
    from repro.core.passes import CompulsoryPartition

    mod = Module("tcam", [TensorType((m, dim)), TensorType((n, dim)),
                          TensorType((n, dim), care_dtype)])
    q, p, c = mod.arguments
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, [q, p, c],
                       [TensorType((m, k)), TensorType((m, k), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, q, p, metric="hamming", k=k, largest=False,
                          care=c)
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition())
    return pm.run(mod, {"arch": arch})


def _ternary_data(rng, m, n, dim, care_p=0.3):
    q = (rng.random((m, dim)) > 0.5).astype(np.float32)
    p = (rng.random((n, dim)) > 0.5).astype(np.float32)
    care = (rng.random((n, dim)) > care_p).astype(np.int8)
    return q, p, care


# ---------------------------------------------------------------------------
# packing primitives
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dim", [1, 31, 32, 33, 64, 100, 257])
def test_pack_roundtrip(dim, rng):
    """unpack(pack(x)) == x, including dim % 32 != 0 tail lanes."""
    from repro.kernels import packing as kpack

    b = (rng.random((5, dim)) > 0.5).astype(np.float32)
    packed = kpack.pack_bits(b)
    assert packed.shape == (5, kpack.lanes(dim))
    assert packed.dtype == jnp.uint32
    assert np.array_equal(np.asarray(kpack.unpack_bits(packed, dim)),
                          b.astype(np.uint8))


def test_pack_tail_bits_are_zero(rng):
    """Bits past dim in the last lane must be zero — both operands pad
    identically, so padding can never contribute a mismatch."""
    from repro.kernels import packing as kpack

    dim = 40                    # 8 tail bits used in lane 1
    b = np.ones((3, dim), np.float32)
    packed = np.asarray(kpack.pack_bits(b))
    assert np.all(packed[:, 1] == np.uint32(0xFF))      # only 8 low bits set


@given(bits=st.lists(st.booleans(), min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_pack_roundtrip_property(bits):
    from repro.kernels import packing as kpack

    b = np.asarray(bits, dtype=bool)[None, :]
    rt = np.asarray(kpack.unpack_bits(kpack.pack_bits(b), b.shape[-1]))
    assert np.array_equal(rt.astype(bool), b)


def test_popcount_swar_lut_python_agree(rng):
    from repro.kernels import packing as kpack

    x = rng.integers(0, 2 ** 32, size=(2048,), dtype=np.uint32)
    x[:4] = [0, 1, 2 ** 32 - 1, 0x80000000]             # edge words
    want = np.array([bin(int(v)).count("1") for v in x], dtype=np.int32)
    assert np.array_equal(np.asarray(kpack.popcount32(x)), want)
    assert np.array_equal(np.asarray(kpack.popcount32_lut(x)), want)


def test_pack_bipolar_matches_float_encoding(rng):
    """Sign packing thresholds at > 0, exactly like the engine's float
    encoding for dot/cos — any real input produces the same cells."""
    from repro.kernels import packing as kpack

    x = rng.standard_normal((4, 70)).astype(np.float32)
    x[0, :3] = [0.0, -0.0, 1e-30]
    bits = np.asarray(kpack.unpack_bits(kpack.pack_bipolar(x), 70))
    assert np.array_equal(bits, (x > 0).astype(np.uint8))


# ---------------------------------------------------------------------------
# packed reference kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dim", [33, 100])
def test_packed_distances_match_unpacked(dim, rng):
    from repro.kernels import packing as kpack
    from repro.kernels import ref as kref

    q = (rng.random((7, dim)) > 0.5).astype(np.float32)
    p = (rng.random((23, dim)) > 0.5).astype(np.float32)
    dp = np.asarray(kref.packed_distances(kpack.pack_bits(q),
                                          kpack.pack_bits(p)))
    assert np.array_equal(dp, np.asarray(kref.distances(q, p, "hamming")))


def test_ternary_distances_wildcards(rng):
    from repro.kernels import packing as kpack
    from repro.kernels import ref as kref

    q, p, care = _ternary_data(rng, 6, 19, 77)
    dt = np.asarray(kref.ternary_distances(q, p, care))
    # oracle by hand
    want = ((q[:, None, :] != p[None, :, :]) & (care[None] != 0)).sum(-1)
    assert np.array_equal(dt, want.astype(np.float32))
    # full care mask degenerates to plain hamming
    full = np.asarray(kref.ternary_distances(q, p, np.ones_like(care)))
    assert np.array_equal(full, np.asarray(kref.distances(q, p, "hamming")))
    # packed ternary == unpacked ternary
    dtp = np.asarray(kref.packed_distances(
        kpack.pack_bits(q), kpack.pack_bits(p), kpack.pack_bits(care)))
    assert np.array_equal(dtp, dt)


def test_ops_cam_topk_packed_matches_float_path(rng):
    """Packed Pallas kernel == float Pallas kernel == dense oracle,
    including k > N sentinel padding."""
    from repro.kernels import ops as kops
    from repro.kernels import packing as kpack
    from repro.kernels import ref as kref

    q = (rng.random((7, 100)) > 0.5).astype(np.float32)
    p = (rng.random((23, 100)) > 0.5).astype(np.float32)
    qb, pb = kpack.pack_bits(q), kpack.pack_bits(p)
    for k, n in ((5, 23), (6, 3)):      # n=3 < k exposes sentinels
        fv, fi = kops.cam_topk(q, p[:n], metric="hamming", k=k,
                               largest=False, tile_rows=8, dims_per_tile=64)
        pv, pi = kops.cam_topk_packed(qb, pb[:n], k=k, largest=False,
                                      tile_rows=8, lanes_per_tile=2)
        np.testing.assert_array_equal(np.asarray(fv), np.asarray(pv))
        np.testing.assert_array_equal(np.asarray(fi), np.asarray(pi))


# ---------------------------------------------------------------------------
# engine: packed plans == unpacked plans == interpreter
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric,largest,n", [("hamming", False, 37),
                                              ("dot", False, 5),
                                              ("cos", True, 64)])
def test_packed_plan_matches_unpacked_and_interpreter(metric, largest, n, rng):
    from repro.core import ArchSpec, get_plan
    from repro.core.executor import execute_module
    from test_engine import _data, _sim_module

    m, dim, k = 9, 100, 6                   # n=5 < k exposes sentinel slots
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module(metric, k, largest, m, n, dim, arch)
    packed = get_plan(mod)                  # auto-pack for binary metrics
    unpacked = get_plan(mod, pack=False)
    assert packed is not None and packed.packed
    assert unpacked is not None and not unpacked.packed
    assert packed is not unpacked, "packing must split the plan key"
    q, p = _data(rng, metric, m, n, dim)
    pv, pi = packed.execute(q, p)
    uv, ui = unpacked.execute(q, p)
    iv, ii = execute_module(mod, q, p)
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(ui))
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(uv))
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(ii))
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(iv))


@pytest.mark.parametrize("k", [1, 4, 11])
def test_packed_parity_across_k(k, rng):
    from repro.core import ArchSpec, get_plan
    from test_engine import _data, _sim_module

    m, n, dim = 5, 29, 64
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("hamming", k, False, m, n, dim, arch)
    q, p = _data(rng, "hamming", m, n, dim)
    pv, pi = get_plan(mod).execute(q, p)
    uv, ui = get_plan(mod, pack=False).execute(q, p)
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(uv))
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(ui))


def test_eucl_never_packs_and_explicit_pack_raises():
    from repro.core import ArchSpec, get_plan
    from test_engine import _sim_module

    mod = _sim_module("eucl", 3, False, 5, 20, 32, ArchSpec(rows=16, cols=32))
    assert not get_plan(mod).packed
    with pytest.raises(ValueError):
        get_plan(mod, pack=True)


def test_packed_hamming_rejects_non_binary_data(rng):
    """The unpacked path counts mismatches over any alphabet; the packed
    path only sees bits.  Rather than silently collapse {-1,+1} or
    multi-bit cells to all-match, the packed hamming plan rejects
    non-binary operands (pack=False keeps the general float path)."""
    from repro.core import ArchSpec, get_plan
    from repro.core.executor import execute_module
    from test_engine import _data, _sim_module

    m, n, dim, k = 5, 20, 64, 3
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("hamming", k, False, m, n, dim, arch)
    plan = get_plan(mod)
    bipolar_q = np.sign(rng.standard_normal((m, dim))).astype(np.float32)
    bipolar_p = np.sign(rng.standard_normal((n, dim))).astype(np.float32)
    with pytest.raises(ValueError, match="binary"):
        plan.execute(bipolar_q, bipolar_p)
    binary_q, _ = _data(rng, "hamming", m, n, dim)
    with pytest.raises(ValueError, match="binary"):
        plan.execute(binary_q, bipolar_p)       # gallery alone non-binary
    # pack=False still handles the richer alphabet, matching the oracle
    unpacked = get_plan(mod, pack=False)
    v, i = unpacked.execute(bipolar_q, bipolar_p)
    iv, ii = execute_module(mod, bipolar_q, bipolar_p)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ii))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(iv))
    # boolean operands are inside the contract
    bq, bp = _data(rng, "hamming", m, n, dim)
    plan.execute(bq.astype(bool), bp.astype(bool))


def test_pack_env_kill_switch(monkeypatch):
    from repro.core import ArchSpec, clear_plan_cache, get_plan
    from test_engine import _sim_module

    clear_plan_cache()
    mod = _sim_module("hamming", 3, False, 5, 20, 32, ArchSpec(rows=16, cols=32))
    monkeypatch.setenv("REPRO_ENGINE_PACK", "off")
    assert not get_plan(mod).packed
    monkeypatch.delenv("REPRO_ENGINE_PACK")
    assert get_plan(mod).packed


def test_operand_dtype_splits_plan_key():
    """Regression (packed uint32 operands make this a correctness
    requirement): same geometry, different operand dtype -> different
    spec -> different plan."""
    from repro.core import (ArchSpec, Builder, Module, PassManager,
                            TensorType, clear_plan_cache, get_plan)
    from repro.core.cim_dialect import (make_acquire, make_execute,
                                        make_release, make_similarity,
                                        make_yield)
    from repro.core.passes import CompulsoryPartition

    def build(dtype):
        mod = Module("sim", [TensorType((4, 64), dtype),
                             TensorType((16, 64), dtype)])
        q, p = mod.arguments
        b = Builder(mod.body)
        dev = make_acquire(b)
        exe = make_execute(b, dev.result, [q, p],
                           [TensorType((4, 2), dtype),
                            TensorType((4, 2), "i32")])
        blk = exe.region().block()
        sim = make_similarity(blk, q, p, metric="hamming", k=2, largest=False)
        make_yield(blk, sim.results)
        make_release(b, dev.result)
        b.ret(exe.results)
        pm = PassManager()
        pm.add(CompulsoryPartition())
        return pm.run(mod, {"arch": ArchSpec(rows=16, cols=32)})

    clear_plan_cache()
    p_f32 = get_plan(build("f32"))
    p_u32 = get_plan(build("u32"))
    assert p_f32.spec.in_dtypes == ("f32", "f32")
    assert p_u32.spec.in_dtypes == ("u32", "u32")
    assert p_f32 is not p_u32, "operand dtype must split the plan key"


# ---------------------------------------------------------------------------
# pattern-prep memo: LRU bound + counters
# ---------------------------------------------------------------------------


def test_pattern_memo_lru_and_counters(monkeypatch, rng):
    from repro.core import ArchSpec, clear_plan_cache, get_plan, \
        plan_cache_stats
    from test_engine import _data, _sim_module

    monkeypatch.setenv("REPRO_ENGINE_PATTERN_SLOTS", "2")
    clear_plan_cache()
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("dot", 2, False, 4, 20, 32, arch)
    plan = get_plan(mod)
    q, _ = _data(rng, "dot", 4, 20, 32)
    gals = [jnp.asarray(rng.standard_normal((20, 32)).astype(np.float32))
            for _ in range(3)]
    for g in gals:
        plan.execute(q, g)
    assert plan.pattern_misses == 3
    assert plan.pattern_evictions == 1          # 3 galleries, 2 slots
    assert len(plan._pattern_cache) == 2
    plan.execute(q, gals[-1])                   # most-recent: still resident
    assert plan.pattern_hits == 1
    plan.execute(q, gals[0])                    # evicted: re-prepared
    assert plan.pattern_misses == 4
    # numpy galleries are never memoised, but every re-prepare still
    # counts as a miss — the telemetry must not read "fully cached"
    plan.execute(q, np.asarray(gals[0]))
    assert plan.pattern_misses == 5
    stats = plan_cache_stats()                  # surfaced process-wide
    assert stats["pattern_hits"] >= 1
    assert stats["pattern_misses"] >= 5
    assert stats["pattern_evictions"] >= 2


def test_pattern_counters_survive_plan_eviction(rng):
    """Evicting a plan from the 64-slot plan LRU folds its pattern
    counters into the retained stats — plan_cache_stats() stays
    monotonic across evictions."""
    from repro.core import ArchSpec, clear_plan_cache, get_plan, \
        plan_cache_stats
    from repro.core.engine import _MAX_PLANS
    from test_engine import _data, _sim_module

    clear_plan_cache()
    arch = ArchSpec(rows=16, cols=32)
    plan = get_plan(_sim_module("dot", 2, False, 4, 20, 32, arch))
    q, _ = _data(rng, "dot", 4, 20, 32)
    plan.execute(q, jnp.asarray(rng.standard_normal((20, 32))
                                .astype(np.float32)))
    before = plan_cache_stats()
    assert before["pattern_misses"] >= 1
    # plan construction is lazy (no jit compile until execute), so
    # flooding the LRU with distinct geometries is cheap
    for n in range(21, 21 + _MAX_PLANS):
        get_plan(_sim_module("eucl", 2, False, 4, n, 32, arch))
    after = plan_cache_stats()
    assert after["plans"] <= _MAX_PLANS
    assert after["pattern_misses"] >= before["pattern_misses"]
    assert after["pattern_hits"] >= before["pattern_hits"]


# ---------------------------------------------------------------------------
# ternary (TCAM wildcard) search
# ---------------------------------------------------------------------------


def test_ternary_plan_packed_unpacked_interpreter_dense(rng):
    from repro.core import ArchSpec, get_plan
    from repro.core.executor import execute_module
    from repro.kernels import ref as kref

    m, n, dim, k = 7, 37, 100, 5
    arch = ArchSpec(rows=16, cols=32)
    mod = _ternary_module(m, n, dim, k, arch)
    q, p, care = _ternary_data(rng, m, n, dim)
    packed = get_plan(mod)
    unpacked = get_plan(mod, pack=False)
    assert packed.packed and packed.spec.care_arg == 2
    pv, pi = packed.execute(q, p, care)
    for v, i in (unpacked.execute(q, p, care),
                 execute_module(mod, q, p, care),
                 kref.cam_topk_ternary(q, p, care, k=k)):
        np.testing.assert_array_equal(np.asarray(pi), np.asarray(i))
        np.testing.assert_array_equal(np.asarray(pv), np.asarray(v))


def test_ternary_wildcards_never_mismatch(rng):
    """An all-wildcard mask matches everything at distance 0; flipping a
    pattern only in wildcarded cells leaves its distance unchanged."""
    from repro.core import ArchSpec, get_plan

    m, n, dim, k = 4, 20, 64, 3
    mod = _ternary_module(m, n, dim, k, ArchSpec(rows=16, cols=32))
    plan = get_plan(mod)
    q, p, care = _ternary_data(rng, m, n, dim)
    v0, _ = plan.execute(q, p, care)
    flipped = np.where(care == 0, 1.0 - p, p).astype(np.float32)
    v1, i1 = plan.execute(q, flipped, care)
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    zv, zi = plan.execute(q, p, np.zeros_like(care))
    assert np.all(np.asarray(zv) == 0)          # everything matches exactly
    np.testing.assert_array_equal(np.asarray(zi),
                                  np.tile(np.arange(k), (m, 1)))


def test_ternary_pallas_backend_parity(rng):
    from repro.core import ArchSpec, get_plan

    m, n, dim, k = 7, 37, 100, 5
    arch = ArchSpec(rows=16, cols=32)
    mod = _ternary_module(m, n, dim, k, arch)
    q, p, care = _ternary_data(rng, m, n, dim)
    jv, ji = get_plan(mod).execute(q, p, care)
    pv, pi = get_plan(mod, backend="pallas").execute(q, p, care)
    np.testing.assert_array_equal(np.asarray(jv), np.asarray(pv))
    np.testing.assert_array_equal(np.asarray(ji), np.asarray(pi))
    # unpacked pallas has no masked kernel: explicit refusal
    with pytest.raises(ValueError):
        get_plan(mod, backend="pallas", pack=False)


def test_ternary_memo_keys_on_care_too(rng):
    """Same gallery with a different care mask must not hit the memo."""
    from repro.core import ArchSpec, get_plan

    m, n, dim, k = 4, 20, 64, 3
    mod = _ternary_module(m, n, dim, k, ArchSpec(rows=16, cols=32))
    plan = get_plan(mod)
    q, p, care = _ternary_data(rng, m, n, dim)
    pj = jnp.asarray(p)
    c1 = jnp.asarray(care)
    c2 = jnp.asarray(np.ones_like(care))
    _, i1 = plan.execute(q, pj, c1)
    misses = plan.pattern_misses
    plan.execute(q, pj, c2)
    assert plan.pattern_misses == misses + 1
    _, i1b = plan.execute(q, pj, c1)            # original pair: memo hit
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i1b))


# ---------------------------------------------------------------------------
# serving: ternary as a first-class served workload
# ---------------------------------------------------------------------------


def test_server_serves_ternary_with_care_mask(rng):
    from repro.core import ArchSpec, get_plan
    from repro.serving import CamSearchServer

    m, n, dim, k = 6, 37, 100, 5
    mod = _ternary_module(m, n, dim, k, ArchSpec(rows=16, cols=32))
    plan = get_plan(mod)
    q, p, care = _ternary_data(rng, m, n, dim)
    want_v, want_i = plan.execute(q, p, care)
    with CamSearchServer(plan, p, care_mask=care, max_wait_ms=1.0) as srv:
        v, i = srv.search(q)
        snap = srv.snapshot()
    np.testing.assert_array_equal(np.asarray(v),
                                  np.asarray(want_v).reshape(m, k))
    np.testing.assert_array_equal(np.asarray(i),
                                  np.asarray(want_i).reshape(m, k))
    assert snap["plan"]["ternary"] and snap["plan"]["packed"]


def test_server_care_mask_validation(rng):
    from repro.core import ArchSpec, get_plan
    from repro.serving import CamSearchServer
    from test_engine import _data, _sim_module

    arch = ArchSpec(rows=16, cols=32)
    tmod = _ternary_module(4, 20, 64, 3, arch)
    q, p, care = _ternary_data(rng, 4, 20, 64)
    with pytest.raises(ValueError):             # ternary plan, no mask
        CamSearchServer(get_plan(tmod), p)
    with pytest.raises(ValueError):             # wrong mask geometry
        CamSearchServer(get_plan(tmod), p, care_mask=care[:-1])
    bmod = _sim_module("dot", 2, False, 4, 20, 32, arch)
    _, g = _data(rng, "dot", 4, 20, 32)
    with pytest.raises(ValueError):             # mask on a binary plan
        CamSearchServer(get_plan(bmod), g, care_mask=np.ones((20, 32)))


# ---------------------------------------------------------------------------
# property test: packed == unpacked across random geometry
# ---------------------------------------------------------------------------


@given(m=st.integers(1, 8), n=st.integers(1, 40), dim=st.integers(1, 80),
       k=st.integers(1, 8))
@settings(max_examples=15, deadline=None)
def test_packed_unpacked_property(m, n, dim, k):
    from repro.kernels import ops as kops
    from repro.kernels import packing as kpack
    from repro.kernels import ref as kref

    rng = np.random.default_rng(m * 1000 + n * 10 + dim + k)
    q = (rng.random((m, dim)) > 0.5).astype(np.float32)
    p = (rng.random((n, dim)) > 0.5).astype(np.float32)
    rv, ri = kref.pad_candidates(
        *kref.cam_topk(q, p, metric="hamming", k=min(k, n), largest=False),
        k, False)
    pv, pi = kops.cam_topk_packed(kpack.pack_bits(q), kpack.pack_bits(p),
                                  k=k, largest=False, tile_rows=16,
                                  lanes_per_tile=1)
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(pv))
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(pi))


# ---------------------------------------------------------------------------
# multi-device: packed sharded tournament (child process, 8 devices)
# ---------------------------------------------------------------------------


def _child_main() -> int:
    import jax

    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))
    from repro.core import ArchSpec, get_plan

    assert jax.device_count() == DEVICES, jax.device_count()
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from test_engine import _data, _sim_module

    rng = np.random.default_rng(11)
    arch = ArchSpec(rows=16, cols=32)

    # 137 is not divisible by 8 shards (padding tiles), 5 < k exposes the
    # losing-slot sentinels through the cross-shard merge
    for n in (137, 5):
        m, dim, k = 9, 100, 6
        mod = _sim_module("hamming", k, False, m, n, dim, arch)
        single = get_plan(mod, shards=1)
        sharded = get_plan(mod, shards=DEVICES)
        unpacked = get_plan(mod, shards=DEVICES, pack=False)
        assert single.packed and sharded.packed and not unpacked.packed
        assert sharded.shards == DEVICES
        q, p = _data(rng, "hamming", m, n, dim)
        sv, si = single.execute(q, p)
        mv, mi = sharded.execute(q, p)
        uv, ui = unpacked.execute(q, p)
        np.testing.assert_array_equal(np.asarray(si), np.asarray(mi),
                                      err_msg=f"packed sharded idx n={n}")
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(mv),
                                      err_msg=f"packed sharded val n={n}")
        np.testing.assert_array_equal(np.asarray(ui), np.asarray(mi),
                                      err_msg=f"unpacked-vs-packed idx n={n}")
        np.testing.assert_array_equal(np.asarray(uv), np.asarray(mv),
                                      err_msg=f"unpacked-vs-packed val n={n}")

    # ternary sharded: care mask sharded alongside the gallery
    m, n, dim, k = 6, 53, 80, 4
    tmod = _ternary_module(m, n, dim, k, arch)
    q, p, care = _ternary_data(rng, m, n, dim)
    t1 = get_plan(tmod, shards=1)
    t8 = get_plan(tmod, shards=DEVICES)
    assert t1.packed and t8.packed and t8.shards == DEVICES
    v1, i1 = t1.execute(q, p, care)
    v8, i8 = t8.execute(q, p, care)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v8))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i8))

    print("PACKED-SHARDED-OK")
    return 0


def test_sharded_packed_parity_multi_device():
    """Packed sharded tournament == packed single-device == unpacked
    sharded, under 8 forced host devices (subprocess)."""
    from repro.launch.mesh import forced_host_devices_env

    env = forced_host_devices_env(DEVICES)
    env.pop("REPRO_ENGINE_MAX_CHUNK", None)
    env.pop("REPRO_ENGINE_PACK", None)
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0 and "PACKED-SHARDED-OK" in out.stdout, (
        f"packed sharded child failed (rc={out.returncode}):\n"
        f"{out.stdout[-3000:]}\n{out.stderr[-3000:]}")


if __name__ == "__main__":
    if "--child" in sys.argv:
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={DEVICES}")
        raise SystemExit(_child_main())
    raise SystemExit(pytest.main([__file__, "-v"]))
