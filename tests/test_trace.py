"""Execution tracing (``repro.obs``): recorder semantics, Chrome
export validity, and end-to-end followability.

The load-bearing pins:

* the disabled path allocates nothing — ``trace_span`` returns one
  shared singleton and ``trace_begin`` returns ``None``;
* the export is always Perfetto-loadable — every ``B`` has an ``E``
  (synthesised at the horizon for spans still open), orphan ``E``
  whose ``B`` was ring-evicted are dropped, timestamps are monotonic;
* the ring is bounded — capacity evicts oldest, never grows;
* one multi-tenant request is followable across the gateway, batcher
  and engine threads: the gateway ``gw.route`` instant links the
  gateway rid to the serving rid, and both request tracks plus the
  engine spans land in the same export.
"""

import json
import threading

import numpy as np
import pytest

from repro.core import ArchSpec, compile_fn
from repro.obs import trace as obs
from repro.serving import CamSearchServer, CamServingGateway

N, DIM, K = 96, 16, 3


def _knn(q, gallery):
    d = q.unsqueeze(1).sub(gallery).norm(p=2, dim=-1)
    return d.topk(K, largest=False)


@pytest.fixture(scope="module")
def compiled():
    rng = np.random.default_rng(5)
    gal = rng.standard_normal((N, DIM)).astype(np.float32)
    prog = compile_fn(_knn, [np.zeros((8, DIM), np.float32), gal],
                      ArchSpec(rows=32, cols=DIM))
    assert prog.engine_plan is not None
    return prog, gal


@pytest.fixture()
def clean_tracer():
    """Tracing off and empty before and after; capacity restored."""
    cap, clock = obs.tracer.capacity, obs.tracer.clock
    obs.stop()
    obs.tracer.clear()
    yield obs.tracer
    obs.stop()
    obs.tracer.clear()
    obs.enable(cap, clock)
    obs.stop()


def _events(doc, ph=None, pid=None, name=None):
    pids = {e["args"]["name"]: e["pid"] for e in doc["traceEvents"]
            if e["ph"] == "M" and e["name"] == "process_name"}
    out = []
    for e in doc["traceEvents"]:
        if e["ph"] == "M":
            continue
        if ph is not None and e["ph"] != ph:
            continue
        if pid is not None and e["pid"] != pids.get(pid):
            continue
        if name is not None and e["name"] != name:
            continue
        out.append(e)
    return out


def _assert_valid_chrome(doc):
    """Every B has an E (per pid/tid, LIFO), timestamps monotonic."""
    json.dumps(doc)                         # serialisable
    assert doc["displayTimeUnit"] == "ms"
    stacks = {}
    last_ts = -1.0
    for e in doc["traceEvents"]:
        if e["ph"] == "M":
            continue
        assert e["ts"] >= 0
        if e["ph"] == "B":
            stacks.setdefault((e["pid"], e["tid"]), []).append(e)
        elif e["ph"] == "E":
            stack = stacks.get((e["pid"], e["tid"]))
            assert stack, f"E without open B: {e}"
            stack.pop()
        last_ts = max(last_ts, e["ts"])
    for key, stack in stacks.items():
        assert not stack, f"unterminated B on {key}: {stack}"


class TestDisabledPath:
    def test_span_is_shared_singleton(self, clean_tracer):
        s1 = obs.trace_span("a")
        s2 = obs.trace_span("b", "serving", args={"x": 1})
        assert s1 is s2                     # no allocation when off
        with s1:
            pass
        assert len(clean_tracer) == 0

    def test_begin_and_instant_are_noops(self, clean_tracer):
        assert obs.trace_begin("r") is None
        obs.instant("i", "gateway", {"reason": "x"})
        assert len(clean_tracer) == 0


class TestRecorder:
    def test_nesting_and_pairing(self, clean_tracer):
        obs.enable()
        with obs.trace_span("outer"):
            with obs.trace_span("inner"):
                pass
        obs.stop()
        doc = obs.to_chrome()
        _assert_valid_chrome(doc)
        names = [(e["name"], e["ph"]) for e in doc["traceEvents"]
                 if e["ph"] in "BE"]
        assert names == [("outer", "B"), ("inner", "B"),
                         ("inner", "E"), ("outer", "E")]

    def test_unterminated_b_closed_at_horizon(self, clean_tracer):
        obs.enable()
        clean_tracer.emit("B", "never_closed", "engine",
                          clean_tracer.now())
        with obs.trace_span("ok"):
            pass
        obs.stop()
        _assert_valid_chrome(obs.to_chrome())

    def test_orphan_e_from_eviction_dropped(self, clean_tracer):
        obs.enable(capacity=8)
        for _ in range(50):                 # Bs evicted, tail Es orphan
            with obs.trace_span("s"):
                pass
        obs.stop()
        assert len(clean_tracer) == 8       # bounded
        _assert_valid_chrome(obs.to_chrome())

    def test_capacity_grows_and_shrinks_preserving_events(
            self, clean_tracer):
        obs.enable(capacity=4)
        with obs.trace_span("keep"):
            pass
        obs.enable(capacity=16)
        assert len(clean_tracer) == 2
        assert clean_tracer.capacity == 16

    def test_cross_thread_handle_pins_origin_tid(self, clean_tracer):
        obs.enable()
        h = obs.trace_begin("request", "serving", {"rid": 1})
        origin = threading.get_ident()

        def worker():
            h.lap("request.queue_wait")
            h.end()

        t = threading.Thread(target=worker, name="completer")
        t.start()
        t.join()
        obs.stop()
        xs = _events(obs.to_chrome(), ph="X")
        assert len(xs) == 2
        assert all(e["tid"] == origin for e in xs)
        whole = next(e for e in xs if e["name"] == "request")
        assert whole["args"]["rid"] == 1
        assert whole["dur"] >= next(
            e for e in xs if e["name"] == "request.queue_wait")["dur"]

    def test_thread_and_process_names_exported(self, clean_tracer):
        obs.enable()

        def worker():
            with obs.trace_span("w", "serving"):
                pass

        t = threading.Thread(target=worker, name="batcher-0")
        t.start()
        t.join()
        obs.stop()
        doc = obs.to_chrome()
        procs = {e["args"]["name"] for e in doc["traceEvents"]
                 if e["ph"] == "M" and e["name"] == "process_name"}
        threads = {e["args"]["name"] for e in doc["traceEvents"]
                   if e["ph"] == "M" and e["name"] == "thread_name"}
        assert "serving" in procs
        assert "batcher-0" in threads

    def test_span_stats_aggregates(self, clean_tracer):
        obs.enable()
        for _ in range(3):
            with obs.trace_span("k"):
                pass
        h = obs.trace_begin("r", "serving")
        h.end()
        obs.stop()
        st = obs.span_stats()
        assert st["k"]["count"] == 3
        assert st["k"]["total_ms"] >= st["k"]["mean_ms"]
        assert "r" in st


class TestServedWorkloadTrace:
    def test_concurrent_serving_emits_followable_spans(
            self, compiled, clean_tracer, rng, tmp_path):
        """Batcher/completer spans nest correctly under concurrency and
        every request's queue-wait + service windows land on its own
        submitter thread track."""
        prog, gal = compiled
        obs.enable()
        with CamSearchServer(prog, gal, max_wait_ms=2.0) as srv:
            errs = []

            def client(c):
                try:
                    for _ in range(3):
                        q = rng.standard_normal((2, DIM)) \
                            .astype(np.float32)
                        srv.search(q, timeout=60)
                except Exception as e:      # noqa: BLE001
                    errs.append(e)

            threads = [threading.Thread(target=client, args=(c,))
                       for c in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errs, errs[:1]
            path = srv.dump_trace(str(tmp_path / "serve.json"))
        obs.stop()
        with open(path) as f:
            doc = json.load(f)
        _assert_valid_chrome(doc)
        # per-batch pipeline spans from the serving threads
        # (batch.fill is a window handle -> X; the others nest -> B/E)
        assert _events(doc, ph="X", pid="serving", name="batch.fill")
        for span in ("batch.dispatch", "batch.finalize"):
            assert _events(doc, ph="B", pid="serving", name=span)
        # engine spans landed in the same export, on the engine pid
        assert _events(doc, ph="B", pid="engine", name="plan.dispatch")
        # every delivered request has its lifetime + both windows
        reqs = _events(doc, ph="X", pid="serving", name="request")
        waits = _events(doc, ph="X", pid="serving",
                        name="request.queue_wait")
        servs = _events(doc, ph="X", pid="serving",
                        name="request.service")
        assert len(reqs) == 12 and len(waits) == 12 and len(servs) == 12
        # request tracks are pinned to their submitter threads
        assert len({e["tid"] for e in reqs}) == 4
        for r in reqs:
            rid = r["args"]["rid"]
            w = [e for e in waits if e["tid"] == r["tid"]
                 and r["ts"] <= e["ts"] <= r["ts"] + r["dur"]]
            assert w, f"request {rid} has no queue-wait inside its span"

    def test_queue_wait_vs_service_split_in_snapshot(
            self, compiled, rng):
        prog, gal = compiled
        with CamSearchServer(prog, gal) as srv:
            q = rng.standard_normal((4, DIM)).astype(np.float32)
            for _ in range(3):
                srv.search(q, timeout=60)
            snap = srv.snapshot()
            health = srv.health()
        for key in ("queue_wait_p50_ms", "queue_wait_p95_ms",
                    "service_p50_ms", "service_p95_ms"):
            assert key in snap
            assert key in health["latency"]
        assert snap["service_p50_ms"] > 0
        # each component is pointwise <= the end-to-end latency, so its
        # p50 cannot exceed the blended p50
        assert snap["queue_wait_p50_ms"] <= snap["p50_ms"] + 1e-9
        assert snap["service_p50_ms"] <= snap["p50_ms"] + 1e-9


class TestGatewayFollowability:
    def test_multitenant_request_followable_across_components(
            self, compiled, clean_tracer, rng, tmp_path):
        """THE acceptance pin: a traced multi-tenant run produces a
        Perfetto-loadable export in which one request is followable
        gateway -> serving -> engine via the ``gw.route`` link."""
        prog, gal = compiled
        obs.enable()
        gw = CamServingGateway(maint_ms=0.0)
        try:
            gw.register_tenant("alpha", prog, gal)
            gw.register_tenant("beta", prog, gal)
            for tenant in ("alpha", "beta"):
                for _ in range(2):
                    q = rng.standard_normal((2, DIM)).astype(np.float32)
                    gw.search(tenant, q, timeout=60)
            path = gw.dump_trace(str(tmp_path / "gateway.json"))
        finally:
            gw.stop()
            obs.stop()
        with open(path) as f:
            doc = json.load(f)
        _assert_valid_chrome(doc)

        gw_reqs = _events(doc, ph="X", pid="gateway", name="request")
        routes = _events(doc, ph="i", pid="gateway", name="gw.route")
        srv_reqs = _events(doc, ph="X", pid="serving", name="request")
        assert len(gw_reqs) == 4 and len(routes) == 4
        assert {e["args"]["tenant"] for e in gw_reqs} == {"alpha", "beta"}
        for g in gw_reqs:
            # gateway request -> its route hop -> the serving request
            route = next(r for r in routes
                         if r["args"]["rid"] == g["args"]["rid"])
            server_rid = route["args"]["server_rid"]
            s = [e for e in srv_reqs
                 if e["args"]["rid"] == server_rid]
            assert len(s) == 1, \
                f"gateway rid {g['args']['rid']} not followable"
            # the admission window sits on the gateway track
        assert _events(doc, ph="X", pid="gateway", name="gw.admission")
        # and the engine's dispatch spans are in the same export
        assert _events(doc, ph="B", pid="engine", name="plan.dispatch")

    def test_reject_instants_carry_reason(self, compiled, clean_tracer):
        prog, gal = compiled
        obs.enable()
        gw = CamServingGateway(maint_ms=0.0)
        try:
            gw.register_tenant("limited", prog, gal,
                               rate=1.0, burst=2)
            q = np.zeros((2, DIM), np.float32)
            gw.search("limited", q, timeout=60)     # drains the burst
            with pytest.raises(Exception):
                gw.submit("limited", q)             # over rate
        finally:
            gw.stop()
            obs.stop()
        rejects = _events(obs.to_chrome(), ph="i", pid="gateway",
                          name="gw.reject")
        assert any(e["args"]["reason"] == "rate" for e in rejects)


class TestEnvDrivenTracing:
    def test_repro_trace_enables_and_sets_dump_path(
            self, clean_tracer, monkeypatch, tmp_path):
        p = str(tmp_path / "t.json")
        monkeypatch.setenv("REPRO_TRACE", p)
        monkeypatch.setenv("REPRO_TRACE_EVENTS", "128")
        monkeypatch.setenv("REPRO_TRACE_CLOCK", "mono")
        assert obs.configure_from_env() == p
        assert obs.tracer.enabled
        assert obs.tracer.capacity == 128
        assert obs.tracer.clock == "mono"
        assert obs.tracer._atexit_path == p
        monkeypatch.delenv("REPRO_TRACE")
        assert obs.configure_from_env() is None
        assert obs.tracer._atexit_path is None
