"""Launch-layer logic tests (no multi-device mesh needed: ShardingRules
only reads ``mesh.shape``)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import (SHAPES, batch_struct, default_microbatches,
                                input_specs, skip_reason, state_sharding,
                                train_state_struct)
from repro.models.sharding import ShardingRules
from repro.optim import AdamWConfig


class _FakeMesh:
    def __init__(self, **shape):
        self.shape = shape


RULES = ShardingRules(mesh=_FakeMesh(data=16, model=16))


def test_shapes_table_matches_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524_288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_skips_exactly_the_full_attention_archs():
    runs = {a for a in ARCH_IDS
            if skip_reason(get_config(a), "long_500k") is None}
    assert runs == {"zamba2-2.7b", "xlstm-125m"}
    for a in ARCH_IDS:
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert skip_reason(get_config(a), shape) is None


def test_input_specs_cover_all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if skip_reason(cfg, shape):
                continue
            kind, specs = input_specs(cfg, shape)
            assert kind in ("train", "prefill", "decode")
            # every leaf is an abstract ShapeDtypeStruct (no allocation)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct), leaf


def test_microbatch_heuristic_scales_with_model():
    assert default_microbatches(get_config("mistral-large-123b"),
                                "train_4k", RULES) >= 4
    assert default_microbatches(get_config("xlstm-125m"),
                                "train_4k", RULES) == 1
    # serve shapes never microbatch
    assert default_microbatches(get_config("mistral-large-123b"),
                                "decode_32k", RULES) == 1


def test_state_sharding_tree_matches_state_struct():
    for factored in (False, True):
        opt = AdamWConfig(factored_nu=factored)
        cfg = get_config("chatglm3-6b")
        struct = train_state_struct(cfg, opt)
        spec = state_sharding(cfg, RULES, opt)
        assert jax.tree_util.tree_structure(struct) == \
            jax.tree_util.tree_structure(spec)


def test_vlm_audio_frontends_are_stub_inputs():
    vlm = batch_struct(get_config("paligemma-3b"), 4, 16)
    assert vlm["vision"].shape == (4, 256, 2048)
    audio = batch_struct(get_config("whisper-medium"), 4, 16)
    assert audio["frames"].shape == (4, 1500, 1024)
