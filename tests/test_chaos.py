"""Randomized multi-tenant chaos soak.

Concurrent clients x tenants x live ``update_gallery`` flips x replica
kills x maintenance healing x ``stop()`` — the invariants:

* every submitted future resolves (no hang, no leak);
* every *successful* result is bit-identical to one of the two clean
  single-plan oracles (the gallery only ever holds version A or B, and
  a request spans exactly one version — never a mix);
* every failure is one of the allowed shapes (admission rejection,
  tenant unavailability, deadline, stopped gateway).

Case count is CI-bounded via ``REPRO_CHAOS_CASES`` (0 skips).
"""

import threading
import time

import numpy as np
import pytest

from repro.core import ArchSpec, compile_fn
from repro.core.envcfg import env_int
from repro.serving import (AdmissionError, CamServingGateway,
                           TenantUnavailable)

N, DIM, K = 64, 16, 3
CASES = env_int("REPRO_CHAOS_CASES", 3, min_value=0)


def _knn(q, gallery):
    d = q.unsqueeze(1).sub(gallery).norm(p=2, dim=-1)
    return d.topk(K, largest=False)


@pytest.fixture(scope="module")
def compiled():
    gal = np.zeros((N, DIM), np.float32)
    prog = compile_fn(_knn, [np.zeros((4, DIM), np.float32), gal],
                      ArchSpec(rows=32, cols=DIM))
    return prog


ALLOWED = (AdmissionError, TenantUnavailable, TimeoutError)


@pytest.mark.skipif(CASES == 0, reason="REPRO_CHAOS_CASES=0")
@pytest.mark.parametrize("case", range(CASES))
def test_chaos_soak(compiled, case):
    prog = compiled
    plan = prog.engine_plan
    rng = np.random.default_rng(1000 + case)
    gal_a = rng.standard_normal((N, DIM)).astype(np.float32)
    gal_b = rng.standard_normal((N, DIM)).astype(np.float32)
    queries = {t: rng.standard_normal((4, DIM)).astype(np.float32)
               for t in ("t0", "t1")}
    # clean oracles: the gallery is only ever wholly A or wholly B
    oracle = {t: {v: np.asarray(plan.execute(queries[t], g)[1])
                  for v, g in (("a", gal_a), ("b", gal_b))}
              for t in ("t0", "t1")}

    gw = CamServingGateway(maint_ms=5.0)
    for t in ("t0", "t1"):
        gw.register_tenant(t, prog, gal_a.copy(), replicas=2,
                           unhealthy_k=2, queue_limit=64,
                           max_outstanding=4)

    stop_evt = threading.Event()
    handles = []
    handles_lock = threading.Lock()
    failures = []

    def client(tenant):
        while not stop_evt.is_set():
            try:
                h = gw.submit(tenant, queries[tenant])
            except ALLOWED:
                continue
            except RuntimeError as e:
                if "stopped" in str(e):
                    return
                failures.append(repr(e))
                return
            with handles_lock:
                handles.append((tenant, h))

    def updater(tenant):
        flip = False
        idx = np.arange(N)
        while not stop_evt.is_set():
            src = gal_b if flip else gal_a
            try:
                gw.update_gallery(tenant, idx, src)
            except Exception as e:          # noqa: BLE001 — recorded
                failures.append(f"update: {e!r}")
                return
            flip = not flip
            time.sleep(0.01)

    def chaos():
        k = 0
        while not stop_evt.is_set():
            time.sleep(0.15)
            try:
                gw.kill_replica("t0" if k % 2 else "t1", k % 2)
            except Exception as e:          # noqa: BLE001 — recorded
                failures.append(f"kill: {e!r}")
                return
            k += 1

    threads = [threading.Thread(target=client, args=(t,))
               for t in ("t0", "t1") for _ in range(2)]
    threads += [threading.Thread(target=updater, args=(t,))
                for t in ("t0", "t1")]
    threads.append(threading.Thread(target=chaos))
    for th in threads:
        th.start()
    time.sleep(1.2)
    stop_evt.set()
    stuck = []
    for th in threads:
        th.join(30)
        if th.is_alive():
            stuck.append(th.name)
    if stuck:
        import faulthandler
        faulthandler.dump_traceback()       # name the wedged thread
        raise AssertionError(f"chaos workers failed to stop: {stuck}")

    assert not failures, failures[:5]

    mismatches = 0
    resolved = 0
    for tenant, h in handles:
        res = h.wait(60)                    # every future must resolve
        resolved += 1
        if res.error is None:
            ok = any(np.array_equal(np.asarray(res.indices), want)
                     for want in oracle[tenant].values())
            if not ok:
                mismatches += 1
        else:
            assert isinstance(res.error, ALLOWED + (RuntimeError,)), \
                repr(res.error)
    assert mismatches == 0, \
        f"{mismatches}/{resolved} successful results match no clean oracle"
    assert resolved > 0
    gw.stop()
