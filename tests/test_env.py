"""Strict ``REPRO_*`` environment parsing (``repro.core.envcfg``).

The contract pinned here: garbage in any recognised variable raises a
``ValueError`` that names the variable, the offending value, and what
would have been accepted — it never silently becomes a default (the
historical failure mode: ``REPRO_ENGINE_PACK=offf`` meant *on*).
"""

import json
import math
import os

import pytest

from repro.core.envcfg import (env_choice, env_flag, env_float, env_gate,
                               env_int, env_path)


class TestEnvFlag:
    def test_unset_means_default(self, monkeypatch):
        monkeypatch.delenv("X_FLAG", raising=False)
        assert env_flag("X_FLAG", True) is True
        assert env_flag("X_FLAG", False) is False

    @pytest.mark.parametrize("raw,want", [
        ("1", True), ("true", True), ("ON", True), ("yes", True),
        ("0", False), ("False", False), ("off", False), ("NO", False),
    ])
    def test_spellings(self, monkeypatch, raw, want):
        monkeypatch.setenv("X_FLAG", raw)
        assert env_flag("X_FLAG", not want) is want

    def test_auto_means_default(self, monkeypatch):
        monkeypatch.setenv("X_FLAG", "auto")
        assert env_flag("X_FLAG", True) is True
        assert env_flag("X_FLAG", False) is False

    def test_garbage_raises_naming_the_variable(self, monkeypatch):
        monkeypatch.setenv("X_FLAG", "offf")
        with pytest.raises(ValueError, match="X_FLAG.*offf"):
            env_flag("X_FLAG", True)

    def test_auto_rejected_when_disallowed(self, monkeypatch):
        monkeypatch.setenv("X_FLAG", "auto")
        with pytest.raises(ValueError, match="X_FLAG"):
            env_flag("X_FLAG", True, auto_means_default=False)


class TestEnvInt:
    def test_parse_and_bounds(self, monkeypatch):
        monkeypatch.setenv("X_INT", " 42 ")
        assert env_int("X_INT", 7) == 42
        monkeypatch.delenv("X_INT")
        assert env_int("X_INT", 7) == 7

    @pytest.mark.parametrize("raw", ["1k", "3.5", "", "NaN"])
    def test_garbage_raises(self, monkeypatch, raw):
        monkeypatch.setenv("X_INT", raw)
        with pytest.raises(ValueError, match="X_INT"):
            env_int("X_INT", 7)

    def test_min_max_enforced(self, monkeypatch):
        monkeypatch.setenv("X_INT", "0")
        with pytest.raises(ValueError, match="X_INT.*>= 1"):
            env_int("X_INT", 7, min_value=1)
        monkeypatch.setenv("X_INT", "9")
        with pytest.raises(ValueError, match="X_INT.*<= 8"):
            env_int("X_INT", 7, max_value=8)


class TestEnvFloat:
    def test_parse(self, monkeypatch):
        monkeypatch.setenv("X_F", "2.5")
        assert env_float("X_F", 1.0) == 2.5

    def test_nan_rejected(self, monkeypatch):
        monkeypatch.setenv("X_F", "nan")
        with pytest.raises(ValueError, match="X_F"):
            env_float("X_F", 1.0)

    def test_min_enforced(self, monkeypatch):
        monkeypatch.setenv("X_F", "-1")
        with pytest.raises(ValueError, match="X_F.*>= 0"):
            env_float("X_F", 1.0, min_value=0.0)


class TestEnvChoice:
    def test_choice(self, monkeypatch):
        monkeypatch.setenv("X_C", "Ref")
        assert env_choice("X_C", "auto", ("auto", "ref")) == "ref"
        monkeypatch.setenv("X_C", "nope")
        with pytest.raises(ValueError, match="X_C.*auto/ref"):
            env_choice("X_C", "auto", ("auto", "ref"))


class TestEnvPath:
    def test_unset_means_default(self, monkeypatch):
        monkeypatch.delenv("X_P", raising=False)
        assert env_path("X_P") is None
        assert env_path("X_P", "/tmp/d.json") == "/tmp/d.json"

    def test_value_passes_through(self, monkeypatch):
        monkeypatch.setenv("X_P", "/tmp/trace.json")
        assert env_path("X_P") == "/tmp/trace.json"

    @pytest.mark.parametrize("raw", ["", "   "])
    def test_blank_is_a_quoting_accident_not_a_path(self, monkeypatch,
                                                    raw):
        monkeypatch.setenv("X_P", raw)
        with pytest.raises(ValueError, match="X_P"):
            env_path("X_P")


class TestEnvGate:
    def test_auto_off_and_value(self, monkeypatch):
        monkeypatch.delenv("X_G", raising=False)
        assert env_gate("X_G", 3.0) == 3.0
        monkeypatch.setenv("X_G", "auto")
        assert env_gate("X_G", 3.0) == 3.0
        monkeypatch.setenv("X_G", "off")
        assert env_gate("X_G", 3.0) == 0.0
        monkeypatch.setenv("X_G", "1.5")
        assert env_gate("X_G", 3.0) == 1.5
        monkeypatch.setenv("X_G", "fast")
        with pytest.raises(ValueError, match="X_G"):
            env_gate("X_G", 3.0)
        assert not math.isnan(env_gate("X_G2", 2.0))


class TestEngineKnobsAreStrict:
    """The engine's own knobs go through the strict parsers."""

    def test_max_chunk_garbage_raises(self, monkeypatch):
        from repro.core.engine import _pick_batch
        monkeypatch.setenv("REPRO_ENGINE_MAX_CHUNK", "1k")
        with pytest.raises(ValueError, match="REPRO_ENGINE_MAX_CHUNK"):
            _pick_batch(64)

    def test_pack_typo_raises_not_silently_on(self, monkeypatch):
        from types import SimpleNamespace

        from repro.core.engine import _resolve_pack
        monkeypatch.setenv("REPRO_ENGINE_PACK", "offf")
        with pytest.raises(ValueError, match="REPRO_ENGINE_PACK"):
            _resolve_pack(SimpleNamespace(metric="hamming"), None)

    def test_update_flag_garbage_raises(self, monkeypatch):
        from repro.core.engine import _update_enabled
        monkeypatch.setenv("REPRO_ENGINE_UPDATE", "2")
        with pytest.raises(ValueError, match="REPRO_ENGINE_UPDATE"):
            _update_enabled()

    def test_pattern_slots_must_be_positive(self, monkeypatch):
        from repro.core.engine import SearchPlan
        monkeypatch.setenv("REPRO_ENGINE_PATTERN_SLOTS", "0")
        with pytest.raises(ValueError,
                           match="REPRO_ENGINE_PATTERN_SLOTS"):
            SearchPlan._pattern_cache_slots()

    def test_hdc_kernel_garbage_raises(self, monkeypatch):
        from repro.hdc.encoding import _kernel_choice
        monkeypatch.setenv("REPRO_HDC_KERNEL", "fastest")
        with pytest.raises(ValueError, match="REPRO_HDC_KERNEL"):
            _kernel_choice()

    def test_serve_deadline_garbage_fails_at_construction(
            self, monkeypatch, rng):
        from repro.core import ArchSpec, get_plan
        from repro.serving import CamSearchServer
        from test_engine import _data, _sim_module

        mod = _sim_module("dot", 2, True, 4, 16, 16,
                          ArchSpec(rows=8, cols=16))
        plan = get_plan(mod)
        _, p = _data(rng, "dot", 4, 16, 16)
        monkeypatch.setenv("REPRO_SERVE_DEADLINE_MS", "soon")
        with pytest.raises(ValueError, match="REPRO_SERVE_DEADLINE_MS"):
            CamSearchServer(plan, p)

    def test_tenant_knobs_garbage_fails_at_registration(self, monkeypatch):
        from repro.serving import CamServingGateway
        gw = CamServingGateway(maint_ms=0.0)
        monkeypatch.setenv("REPRO_TENANT_RATE", "plenty")
        with pytest.raises(ValueError, match="REPRO_TENANT_RATE"):
            gw.register_tenant("t", object(), object())
        monkeypatch.delenv("REPRO_TENANT_RATE")
        monkeypatch.setenv("REPRO_TENANT_QUEUE", "0")
        with pytest.raises(ValueError, match="REPRO_TENANT_QUEUE"):
            gw.register_tenant("t", object(), object())

    def test_replica_knobs_garbage_fails_at_registration(
            self, monkeypatch, rng):
        from repro.core import ArchSpec, get_plan
        from repro.serving import CamServingGateway
        from test_engine import _data, _sim_module

        mod = _sim_module("dot", 2, True, 4, 16, 16,
                          ArchSpec(rows=8, cols=16))
        plan = get_plan(mod)
        _, p = _data(rng, "dot", 4, 16, 16)
        gw = CamServingGateway(maint_ms=0.0)
        monkeypatch.setenv("REPRO_SERVE_REPLICAS", "many")
        with pytest.raises(ValueError, match="REPRO_SERVE_REPLICAS"):
            gw.register_tenant("t", plan, p)
        monkeypatch.delenv("REPRO_SERVE_REPLICAS")
        monkeypatch.setenv("REPRO_SERVE_UNHEALTHY_K", "0")
        with pytest.raises(ValueError, match="REPRO_SERVE_UNHEALTHY_K"):
            gw.register_tenant("t", plan, p)

    def test_gateway_maint_garbage_fails_at_construction(self, monkeypatch):
        from repro.serving import CamServingGateway
        monkeypatch.setenv("REPRO_SERVE_MAINT_MS", "often")
        with pytest.raises(ValueError, match="REPRO_SERVE_MAINT_MS"):
            CamServingGateway()

    def test_tiny_cells_garbage_raises(self, monkeypatch):
        from repro.core.engine.cache import _tiny_plan
        from test_plan_cache_keys import _sim_specs
        monkeypatch.setenv("REPRO_ENGINE_TINY_CELLS", "lots")
        with pytest.raises(ValueError, match="REPRO_ENGINE_TINY_CELLS"):
            _tiny_plan(_sim_specs()[0], "jnp", 1)

    def test_trace_knobs_garbage_raises(self, monkeypatch):
        from repro.obs import trace as obs
        monkeypatch.setenv("REPRO_TRACE_EVENTS", "lots")
        with pytest.raises(ValueError, match="REPRO_TRACE_EVENTS"):
            obs.configure_from_env()
        monkeypatch.setenv("REPRO_TRACE_EVENTS", "0")
        with pytest.raises(ValueError, match="REPRO_TRACE_EVENTS.*>= 1"):
            obs.configure_from_env()
        monkeypatch.delenv("REPRO_TRACE_EVENTS")
        monkeypatch.setenv("REPRO_TRACE_CLOCK", "wall")
        with pytest.raises(ValueError,
                           match="REPRO_TRACE_CLOCK.*perf/mono"):
            obs.configure_from_env()
        monkeypatch.delenv("REPRO_TRACE_CLOCK")
        # an empty REPRO_TRACE is a shell quoting accident, not "off"
        monkeypatch.setenv("REPRO_TRACE", "")
        with pytest.raises(ValueError, match="REPRO_TRACE"):
            obs.configure_from_env()

    def test_hier_nprobe_strict_and_applied(self, monkeypatch):
        from repro.core import ArchSpec, clear_plan_cache
        from repro.core.engine import get_hierarchical_plan
        from test_engine import _sim_module

        mod = _sim_module("hamming", 2, False, 4, 64, 16,
                          ArchSpec(rows=8, cols=16))
        monkeypatch.setenv("REPRO_HIER_NPROBE", "some")
        with pytest.raises(ValueError, match="REPRO_HIER_NPROBE"):
            get_hierarchical_plan(mod, clusters=8)
        monkeypatch.setenv("REPRO_HIER_NPROBE", "-1")
        with pytest.raises(ValueError, match="REPRO_HIER_NPROBE"):
            get_hierarchical_plan(mod, clusters=8)
        clear_plan_cache()
        monkeypatch.setenv("REPRO_HIER_NPROBE", "3")
        plan = get_hierarchical_plan(mod, clusters=8)
        assert plan.spec.nprobe == 3
        # an explicit nprobe argument beats the environment default
        plan = get_hierarchical_plan(mod, clusters=8, nprobe=5)
        assert plan.spec.nprobe == 5


class TestTuneKnobsAreStrict:
    """Autotuner + plan-store knobs parse strictly at the call site."""

    def _mod(self):
        from repro.core import ArchSpec
        from test_engine import _sim_module
        return _sim_module("hamming", 2, False, 4, 32, 16,
                           ArchSpec(rows=8, cols=16))

    def test_tune_trials_strict(self, monkeypatch):
        from repro.tune import tune_plan
        import numpy as np
        q = np.zeros((4, 16), np.float32)
        p = np.zeros((32, 16), np.float32)
        monkeypatch.setenv("REPRO_TUNE_TRIALS", "many")
        with pytest.raises(ValueError, match="REPRO_TUNE_TRIALS"):
            tune_plan(self._mod(), q, p)
        monkeypatch.setenv("REPRO_TUNE_TRIALS", "0")
        with pytest.raises(ValueError, match="REPRO_TUNE_TRIALS"):
            tune_plan(self._mod(), q, p)

    def test_tune_reps_and_budget_strict(self, monkeypatch):
        from repro.tune import tune_plan
        import numpy as np
        q = np.zeros((4, 16), np.float32)
        p = np.zeros((32, 16), np.float32)
        monkeypatch.setenv("REPRO_TUNE_REPS", "thrice")
        with pytest.raises(ValueError, match="REPRO_TUNE_REPS"):
            tune_plan(self._mod(), q, p)
        monkeypatch.delenv("REPRO_TUNE_REPS")
        for bad in ("forever", "nan", "-1"):
            monkeypatch.setenv("REPRO_TUNE_BUDGET_S", bad)
            with pytest.raises(ValueError, match="REPRO_TUNE_BUDGET_S"):
                tune_plan(self._mod(), q, p)

    def test_tune_serve_flag_strict(self, monkeypatch):
        from repro.core import get_plan
        from repro.serving.server import _resolve_plan
        plan = get_plan(self._mod())
        monkeypatch.setenv("REPRO_TUNE_SERVE", "maybe")
        with pytest.raises(ValueError, match="REPRO_TUNE_SERVE"):
            _resolve_plan(plan)

    def test_plan_store_blank_raises(self, monkeypatch):
        from repro.tune import active_store
        monkeypatch.setenv("REPRO_PLAN_STORE", "")
        with pytest.raises(ValueError, match="REPRO_PLAN_STORE"):
            active_store()


class TestBenchSmokeDirRouting:
    """``save_bench_json`` smoke routing (the PR-10 path-handling fix):
    ``*_smoke`` records never land at the repo root, an unset dir falls
    back under the system temp dir, a relative dir is anchored there
    too (not under whatever cwd the bench runs from), and a blank dir
    raises instead of writing into ``""``."""

    def _common(self, monkeypatch):
        import importlib
        import pathlib
        root = str(pathlib.Path(__file__).resolve().parent.parent)
        monkeypatch.syspath_prepend(root)
        return importlib.import_module("benchmarks.common")

    def test_unset_routes_under_tempdir(self, monkeypatch):
        import tempfile
        common = self._common(monkeypatch)
        monkeypatch.delenv("REPRO_BENCH_SMOKE_DIR", raising=False)
        path = common.save_bench_json("routing_smoke", {"ok": 1})
        try:
            assert path.startswith(tempfile.gettempdir())
            assert not os.path.exists(
                os.path.join(common.ROOT, "BENCH_routing_smoke.json"))
        finally:
            os.unlink(path)

    def test_explicit_absolute_dir_is_used(self, monkeypatch, tmp_path):
        common = self._common(monkeypatch)
        monkeypatch.setenv("REPRO_BENCH_SMOKE_DIR", str(tmp_path))
        path = common.save_bench_json("routing_smoke", {"ok": 2})
        assert path == str(tmp_path / "BENCH_routing_smoke.json")
        with open(path) as f:
            assert json.load(f) == {"ok": 2}

    def test_relative_dir_is_anchored_under_tempdir(self, monkeypatch):
        import tempfile
        common = self._common(monkeypatch)
        monkeypatch.setenv("REPRO_BENCH_SMOKE_DIR", "rel-smoke-dir")
        path = common.save_bench_json("routing_smoke", {"ok": 3})
        try:
            assert path == os.path.join(tempfile.gettempdir(),
                                        "rel-smoke-dir",
                                        "BENCH_routing_smoke.json")
            assert not os.path.exists(
                os.path.join(os.getcwd(), "rel-smoke-dir"))
        finally:
            os.unlink(path)

    def test_blank_dir_raises(self, monkeypatch):
        common = self._common(monkeypatch)
        monkeypatch.setenv("REPRO_BENCH_SMOKE_DIR", "  ")
        with pytest.raises(ValueError, match="REPRO_BENCH_SMOKE_DIR"):
            common.save_bench_json("routing_smoke", {"ok": 4})

    def test_non_smoke_records_still_land_at_root(self, monkeypatch):
        common = self._common(monkeypatch)
        # don't actually write BENCH_x.json at the real repo root
        monkeypatch.setattr(common, "ROOT", str(
            __import__("tempfile").mkdtemp()))
        path = common.save_bench_json("baseline_record", {"ok": 5})
        assert os.path.dirname(path) == common.ROOT


class TestBenchGatesUseEnvcfg:
    """Every benchmark acceptance gate parses through ``env_gate`` —
    ``auto``/``off``/float semantics with strict errors, no ad-hoc
    ``os.environ`` parsing left behind."""

    @pytest.mark.parametrize("var,loader,auto", [
        ("REPRO_FOREST_GATE", "benchmarks.bench_forest", 2.0),
        ("REPRO_PACKED_GATE", "benchmarks.bench_packed", 4.0),
        ("REPRO_HDC_GATE", "benchmarks.bench_hdc", 3.0),
        ("REPRO_MULTITENANT_GATE", "benchmarks.bench_multitenant", 2.0),
        ("REPRO_TRACE_GATE", "benchmarks.bench_trace", 1.0),
        ("REPRO_TUNE_GATE", "benchmarks.bench_tune", 1.2),
    ])
    def test_gate_semantics(self, monkeypatch, var, loader, auto):
        import importlib
        import pathlib
        import sys
        root = str(pathlib.Path(__file__).resolve().parent.parent)
        monkeypatch.syspath_prepend(root)
        bench = importlib.import_module(loader)
        monkeypatch.delenv(var, raising=False)
        assert bench._gate() == auto
        monkeypatch.setenv(var, "off")
        assert bench._gate() == 0.0
        monkeypatch.setenv(var, "1.25")
        assert bench._gate() == 1.25
        monkeypatch.setenv(var, "fast")
        with pytest.raises(ValueError, match=var):
            bench._gate()

    def test_hier_wide_gate_semantics(self, monkeypatch):
        import importlib
        import pathlib
        root = str(pathlib.Path(__file__).resolve().parent.parent)
        monkeypatch.syspath_prepend(root)
        bench = importlib.import_module("benchmarks.bench_hier")
        monkeypatch.delenv("REPRO_HIER_WIDE_GATE", raising=False)
        assert bench._wide_gate() == 1.0
        monkeypatch.setenv("REPRO_HIER_WIDE_GATE", "off")
        assert bench._wide_gate() == 0.0
        monkeypatch.setenv("REPRO_HIER_WIDE_GATE", "slow")
        with pytest.raises(ValueError, match="REPRO_HIER_WIDE_GATE"):
            bench._wide_gate()

    def test_tune_warm_gate_semantics(self, monkeypatch):
        import importlib
        import pathlib
        root = str(pathlib.Path(__file__).resolve().parent.parent)
        monkeypatch.syspath_prepend(root)
        bench = importlib.import_module("benchmarks.bench_tune")
        monkeypatch.delenv("REPRO_TUNE_WARM_GATE", raising=False)
        assert bench._warm_gate() == 3.0
        monkeypatch.setenv("REPRO_TUNE_WARM_GATE", "off")
        assert bench._warm_gate() == 0.0
        monkeypatch.setenv("REPRO_TUNE_WARM_GATE", "cold")
        with pytest.raises(ValueError, match="REPRO_TUNE_WARM_GATE"):
            bench._warm_gate()
