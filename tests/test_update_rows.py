"""Engine gallery mutation: ``update_rows`` on both plan families.

The pinned contract: an incrementally updated gallery's results are
bit-identical to re-preparing the mutated gallery from scratch, on
every backend (jnp / pallas / sharded), packed and unpacked, and the
memoised prepared layout is reused (no full re-prepare).  The sharded
leg runs in a child process under 8 forced host devices
(``python tests/test_update_rows.py --child``).
"""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArchSpec, clear_plan_cache, get_plan
from repro.core.engine import _update_enabled

from test_engine import _data, _sim_module
from test_range import _interval_data, _range_module

DEVICES = 8


def _fresh_oracle(mod, q, gallery, **kw):
    """Full re-prepare oracle: a fresh plan on the mutated gallery."""
    clear_plan_cache()
    plan = get_plan(mod, **kw)
    out = plan.execute(q, *(gallery if isinstance(gallery, tuple)
                            else (gallery,)))
    clear_plan_cache()
    return out


@pytest.mark.parametrize("metric,largest", [("hamming", False),
                                            ("dot", True), ("eucl", False)])
def test_update_rows_matches_full_reprepare(metric, largest, rng):
    m, n, dim, k = 6, 37, 64, 4
    mod = _sim_module(metric, k, largest, m, n, dim, ArchSpec(rows=16,
                                                              cols=32))
    plan = get_plan(mod)
    q, p = _data(rng, metric, m, n, dim)
    pj = jnp.asarray(p)
    plan.execute(q, pj)

    idx = np.array([0, 17, 36])            # first, middle, ragged-last rows
    new = _data(rng, metric, 3, n, dim)[0]
    pj2 = plan.update_rows(pj, idx, new)
    assert isinstance(pj2, jnp.ndarray)
    np.testing.assert_array_equal(np.asarray(pj2)[idx], new)

    hits0, fb0 = plan.pattern_hits, plan.row_update_fallbacks
    v1, i1 = plan.execute(q, pj2)
    assert plan.pattern_hits == hits0 + 1, "updated layout not memo-seeded"
    assert plan.row_update_fallbacks == fb0
    assert plan.row_updates >= 1 and plan.rows_updated >= 3

    v2, i2 = _fresh_oracle(mod, q, np.asarray(pj2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_update_rows_pallas_backend(rng):
    mod = _sim_module("dot", 3, False, 6, 40, 64, ArchSpec(rows=16, cols=32))
    plan = get_plan(mod, backend="pallas")
    q, p = _data(rng, "dot", 6, 40, 64)
    pj = jnp.asarray(p)
    plan.execute(q, pj)
    idx = np.array([5, 39])
    pj2 = plan.update_rows(pj, idx, _data(rng, "dot", 2, 40, 64)[0])
    hits0 = plan.pattern_hits
    v1, i1 = plan.execute(q, pj2)
    assert plan.pattern_hits == hits0 + 1
    v2, i2 = _fresh_oracle(mod, q, np.asarray(pj2), backend="pallas")
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_update_rows_unpacked_float_path(rng):
    """pack=False keeps the float tile layout; updates rewrite it too."""
    mod = _sim_module("hamming", 3, False, 5, 29, 48, ArchSpec(rows=8,
                                                               cols=16))
    plan = get_plan(mod, pack=False)
    assert not plan.packed
    q, p = _data(rng, "hamming", 5, 29, 48)
    pj = jnp.asarray(p)
    plan.execute(q, pj)
    idx = np.array([2, 28])
    pj2 = plan.update_rows(pj, idx, _data(rng, "hamming", 2, 29, 48)[0])
    hits0 = plan.pattern_hits
    v1, i1 = plan.execute(q, pj2)
    assert plan.pattern_hits == hits0 + 1
    v2, i2 = _fresh_oracle(mod, q, np.asarray(pj2), pack=False)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_update_rows_range_threshold_and_interval(rng):
    m, n, dim = 4, 29, 48
    arch = ArchSpec(rows=8, cols=16)
    idx = np.array([3, 28])

    mod = _range_module(m, n, dim, arch, metric="hamming", tau=20.0)
    plan = get_plan(mod)
    q = (rng.random((m, dim)) > .5).astype(np.float32)
    p = (rng.random((n, dim)) > .5).astype(np.float32)
    pj = jnp.asarray(p)
    plan.execute(q, pj)
    pj2 = plan.update_rows(pj, idx, (rng.random((2, dim)) > .5
                                     ).astype(np.float32))
    hits0 = plan.pattern_hits
    m1 = np.asarray(plan.execute(q, pj2))
    assert plan.pattern_hits == hits0 + 1
    np.testing.assert_array_equal(m1, np.asarray(
        _fresh_oracle(mod, q, np.asarray(pj2))))

    mod = _range_module(m, n, dim, arch, interval=True)
    plan = get_plan(mod)
    q, lo, hi = _interval_data(rng, m, n, dim)
    loj, hij = jnp.asarray(lo), jnp.asarray(hi)
    plan.execute(q, loj, hij)
    loj2, hij2 = plan.update_rows((loj, hij), idx,
                                  (lo[idx] - 1.0, hi[idx] + 1.0))
    hits0 = plan.pattern_hits
    m1 = np.asarray(plan.execute(q, loj2, hij2))
    assert plan.pattern_hits == hits0 + 1
    np.testing.assert_array_equal(m1, np.asarray(
        _fresh_oracle(mod, q, (np.asarray(loj2), np.asarray(hij2)))))


def test_update_rows_ternary_keys_on_gallery_care_pair(rng):
    """Ternary plans memo on (gallery, care); updating gallery rows keeps
    serving the same wildcard mask and stays bit-exact."""
    from repro.core.cim_dialect import (make_acquire, make_execute,
                                        make_release, make_similarity,
                                        make_yield)
    from repro.core.ir import Builder, Module, PassManager, TensorType
    from repro.core.passes import CompulsoryPartition

    m, n, dim, k = 4, 21, 40, 3
    mod = Module("tern", [TensorType((m, dim)), TensorType((n, dim)),
                          TensorType((n, dim))])
    q_a, p_a, c_a = mod.arguments
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, [q_a, p_a, c_a],
                       [TensorType((m, k)), TensorType((m, k), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, q_a, p_a, metric="hamming", k=k,
                          largest=False, care=c_a)
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition())
    part = pm.run(mod, {"arch": ArchSpec(rows=8, cols=16)})

    plan = get_plan(part)
    q = (rng.random((m, dim)) > .5).astype(np.float32)
    p = (rng.random((n, dim)) > .5).astype(np.float32)
    care = (rng.random((n, dim)) > .3).astype(np.float32)
    pj, cj = jnp.asarray(p), jnp.asarray(care)
    plan.execute(q, pj, cj)

    with pytest.raises(ValueError, match="care"):
        plan.update_rows(pj, [0], (rng.random((1, dim)) > .5
                                   ).astype(np.float32))
    idx = np.array([0, 20])
    pj2 = plan.update_rows(pj, idx, (rng.random((2, dim)) > .5
                                     ).astype(np.float32), care=cj)
    hits0 = plan.pattern_hits
    v1, i1 = plan.execute(q, pj2, cj)
    assert plan.pattern_hits == hits0 + 1
    clear_plan_cache()
    v2, i2 = get_plan(part).execute(q, np.asarray(pj2), care)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_update_rows_validation(rng):
    mod = _sim_module("dot", 2, False, 4, 16, 32, ArchSpec(rows=8, cols=16))
    plan = get_plan(mod)
    q, p = _data(rng, "dot", 4, 16, 32)
    pj = jnp.asarray(p)
    good = _data(rng, "dot", 2, 16, 32)[0]
    with pytest.raises(ValueError, match="out of range"):
        plan.update_rows(pj, [0, 16], good)
    with pytest.raises(ValueError, match="duplicate"):
        plan.update_rows(pj, [3, 3], good)
    with pytest.raises(ValueError, match="shape"):
        plan.update_rows(pj, [3], good)            # 2 rows for 1 index
    # empty update is a no-op returning the gallery unchanged
    assert plan.update_rows(pj, np.empty(0, np.int64),
                            np.empty((0, 32), np.float32)) is pj


def test_update_rows_fallback_paths(rng, monkeypatch):
    """Numpy galleries, never-prepared galleries, and the kill switch
    all fall back (counted) — and stay correct via full re-prepare."""
    mod = _sim_module("hamming", 2, False, 4, 20, 32, ArchSpec(rows=8,
                                                               cols=16))
    plan = get_plan(mod)
    q, p = _data(rng, "hamming", 4, 20, 32)
    new = _data(rng, "hamming", 1, 20, 32)[0]

    # numpy gallery: never memoised -> fallback, still correct
    fb0 = plan.row_update_fallbacks
    p2 = plan.update_rows(p, [5], new)
    assert plan.row_update_fallbacks == fb0 + 1
    v1, i1 = plan.execute(q, p2)
    v2, i2 = _fresh_oracle(mod, q, np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    # jax gallery that was never dispatched -> memo miss -> fallback
    pj = jnp.asarray(p)
    fb0 = plan.row_update_fallbacks
    plan.update_rows(pj, [5], new)
    assert plan.row_update_fallbacks == fb0 + 1

    # kill switch: mutation still applied, memo rewrite skipped
    monkeypatch.setenv("REPRO_ENGINE_UPDATE", "off")
    assert not _update_enabled()
    plan.execute(q, pj)
    misses0, fb0 = plan.pattern_misses, plan.row_update_fallbacks
    pj2 = plan.update_rows(pj, [5], new)
    assert plan.row_update_fallbacks == fb0 + 1
    v1, i1 = plan.execute(q, pj2)          # full re-prepare (counted miss)
    assert plan.pattern_misses == misses0 + 1
    monkeypatch.delenv("REPRO_ENGINE_UPDATE")
    v2, i2 = _fresh_oracle(mod, q, np.asarray(pj2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_update_rows_packed_enforces_binary_contract(rng):
    mod = _sim_module("hamming", 2, False, 4, 16, 32, ArchSpec(rows=8,
                                                               cols=16))
    plan = get_plan(mod)
    assert plan.packed
    q, p = _data(rng, "hamming", 4, 16, 32)
    pj = jnp.asarray(p)
    plan.execute(q, pj)
    with pytest.raises(ValueError, match="binary"):
        plan.update_rows(pj, [0], np.full((1, 32), 2.0, np.float32))


def test_repeated_updates_chain_incrementally(rng):
    """Each update seeds the memo for the next: a retraining loop of K
    updates performs zero full re-prepares after the first dispatch."""
    mod = _sim_module("dot", 2, True, 4, 24, 32, ArchSpec(rows=8, cols=16))
    plan = get_plan(mod)
    q, p = _data(rng, "dot", 4, 24, 32)
    g = jnp.asarray(p)
    plan.execute(q, g)
    misses0 = plan.pattern_misses
    for step in range(5):
        g = plan.update_rows(g, [step, 23 - step],
                             _data(rng, "dot", 2, 24, 32)[0])
        plan.execute(q, g)
    assert plan.pattern_misses == misses0
    assert plan.row_update_fallbacks == 0
    v1, i1 = plan.execute(q, g)
    v2, i2 = _fresh_oracle(mod, q, np.asarray(g))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


# ---------------------------------------------------------------------------
# sharded: child process under 8 forced host devices
# ---------------------------------------------------------------------------


def _child() -> None:
    import jax

    assert jax.device_count() == DEVICES, jax.device_count()
    rng = np.random.default_rng(5)
    m, n, dim, k = 5, 77, 64, 4
    mod = _sim_module("hamming", k, False, m, n, dim, ArchSpec(rows=8,
                                                               cols=32))
    plan = get_plan(mod, shards=DEVICES)
    assert plan.shards == DEVICES
    q, p = _data(rng, "hamming", m, n, dim)
    pj = jnp.asarray(p)
    plan.execute(q, pj)
    idx = np.array([0, 40, 76])
    pj2 = plan.update_rows(pj, idx, _data(rng, "hamming", 3, n, dim)[0])
    hits0 = plan.pattern_hits
    v1, i1 = plan.execute(q, pj2)
    assert plan.pattern_hits == hits0 + 1, "sharded update not memo-seeded"
    assert plan.row_update_fallbacks == 0
    v2, i2 = get_plan(mod, shards=1).execute(q, np.asarray(pj2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))

    # range plan, sharded, interval mode
    mod = _range_module(4, 50, 32, ArchSpec(rows=8, cols=16), interval=True)
    plan = get_plan(mod, shards=DEVICES)
    q, lo, hi = _interval_data(rng, 4, 50, 32)
    loj, hij = jnp.asarray(lo), jnp.asarray(hi)
    plan.execute(q, loj, hij)
    loj2, hij2 = plan.update_rows((loj, hij), [0, 49],
                                  (lo[[0, 49]] - 1, hi[[0, 49]] + 1))
    hits0 = plan.pattern_hits
    m1 = np.asarray(plan.execute(q, loj2, hij2))
    assert plan.pattern_hits == hits0 + 1
    m2 = np.asarray(get_plan(mod, shards=1).execute(
        q, np.asarray(loj2), np.asarray(hij2)))
    np.testing.assert_array_equal(m1, m2)
    print("UPDATE-SHARDED-OK")


def test_update_rows_sharded_eight_devices():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={DEVICES}")
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child"],
        env=env, capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.abspath(__file__)))
    assert out.returncode == 0 and "UPDATE-SHARDED-OK" in out.stdout, (
        f"sharded update child failed (rc={out.returncode}):\n"
        f"{out.stdout[-3000:]}\n{out.stderr[-3000:]}")


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        raise SystemExit("run under pytest, or with --child")
