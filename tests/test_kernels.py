"""Pallas CAM-search kernels vs the pure-jnp oracle.

Sweeps shapes / dtypes / metrics / k and asserts bit-exact indices and
allclose values (interpret=True executes the kernel body on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st  # skips cleanly without hypothesis

from repro.kernels import ops, ref


def _data(rng, metric, m, n, d, dtype=np.float32):
    if metric == "hamming":
        q = (rng.random((m, d)) > 0.5).astype(dtype)
        p = (rng.random((n, d)) > 0.5).astype(dtype)
    else:
        q = rng.standard_normal((m, d)).astype(dtype)
        p = rng.standard_normal((n, d)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(p)


SHAPES = [(1, 8, 16, 1), (10, 100, 64, 5), (7, 33, 130, 3),
          (128, 512, 256, 8), (3, 1000, 48, 10), (65, 129, 257, 4)]


@pytest.mark.parametrize("metric", ["hamming", "dot", "eucl"])
@pytest.mark.parametrize("m,n,d,k", SHAPES)
def test_pallas_topk_matches_oracle(metric, m, n, d, k, rng):
    q, p = _data(rng, metric, m, n, d)
    largest = metric == "dot"
    v1, i1 = ops.cam_topk(q, p, metric=metric, k=k, largest=largest,
                          tile_rows=32, dims_per_tile=64)
    v2, i2 = ref.cam_topk(q, p, metric=metric, k=k, largest=largest)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float16, np.int8])
def test_pallas_topk_dtypes(dtype, rng):
    q = (rng.random((6, 96)) > 0.5).astype(dtype)
    p = (rng.random((50, 96)) > 0.5).astype(dtype)
    v1, i1 = ops.cam_topk(jnp.asarray(q), jnp.asarray(p), metric="hamming",
                          k=3, largest=False)
    v2, i2 = ref.cam_topk(jnp.asarray(q, jnp.float32),
                          jnp.asarray(p, jnp.float32),
                          metric="hamming", k=3, largest=False)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("block", [(8, 16), (16, 128), (128, 512)])
def test_pallas_block_shape_invariance(block, rng):
    """Different CAM subarray geometries must give identical results."""
    q, p = _data(rng, "eucl", 9, 77, 120)
    tr, dpt = block
    v1, i1 = ops.cam_topk(q, p, metric="eucl", k=5, largest=False,
                          tile_rows=tr, dims_per_tile=dpt)
    v2, i2 = ref.cam_topk(q, p, metric="eucl", k=5, largest=False)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-3)


def test_exact_and_range_match(rng):
    p = (rng.random((40, 64)) > 0.5).astype(np.float32)
    q = p[[3, 17, 25]].copy()
    q[2, :5] = 1 - q[2, :5]            # 5 mismatches in the third query
    ex = np.asarray(ops.cam_exact(jnp.asarray(q), jnp.asarray(p)))
    assert ex[0, 3] and ex[1, 17] and not ex[2].any()
    rg = np.asarray(ops.cam_range(jnp.asarray(q), jnp.asarray(p), 5.0))
    assert rg[2, 25]
    ex_ref = np.asarray(ref.cam_exact(jnp.asarray(q), jnp.asarray(p)))
    np.testing.assert_array_equal(ex, ex_ref)


@given(m=st.integers(1, 17), n=st.integers(1, 80), d=st.integers(1, 100),
       k=st.integers(1, 12), metric=st.sampled_from(["hamming", "dot", "eucl"]))
@settings(max_examples=25, deadline=None)
def test_tiled_reference_equals_dense(m, n, d, k, metric):
    """Property: the partitioned execution semantics == whole-array search."""
    rng = np.random.default_rng(m * 1000 + n * 10 + d)
    q, p = _data(rng, metric, m, n, d)
    largest = metric == "dot"
    v1, i1 = ref.cam_topk_tiled(q, p, metric=metric, k=k, largest=largest,
                                tile_rows=16, dims_per_tile=32)
    kk = min(k, n)
    v2, i2 = ref.cam_topk(q, p, metric=metric, k=kk, largest=largest)
    np.testing.assert_array_equal(np.asarray(i1)[:, :kk], np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1)[:, :kk], np.asarray(v2),
                               atol=1e-3)


def test_kernel_pad_sentinels_match_shared_helper(rng):
    """k > N padding: the Pallas wrapper must emit exactly the
    ``ref.pad_candidates`` sentinels (losing value, index 2**30) instead
    of hand-rolled constants, so kernel, engine, and tiled reference all
    agree bit-for-bit on the losing slots."""
    n, k = 5, 9
    for metric, largest in (("hamming", False), ("dot", True),
                            ("eucl", False)):
        q, p = _data(rng, metric, 4, n, 32)
        kv, ki = ops.cam_topk(q, p, metric=metric, k=k, largest=largest)
        # valid slots match the dense oracle at k' = n
        rv, ri = ref.cam_topk(q, p, metric=metric, k=n, largest=largest)
        np.testing.assert_array_equal(np.asarray(ki)[:, :n], np.asarray(ri))
        np.testing.assert_allclose(np.asarray(kv)[:, :n], np.asarray(rv),
                                   atol=1e-4)
        # losing slots are exactly pad_candidates' sentinels
        ev, ei = ref.pad_candidates(rv, ri, k, largest)
        np.testing.assert_array_equal(np.asarray(ki)[:, n:],
                                      np.asarray(ei)[:, n:])
        np.testing.assert_array_equal(np.asarray(kv)[:, n:],
                                      np.asarray(ev)[:, n:])
        lose = -np.inf if largest else np.inf
        assert np.all(np.asarray(kv)[:, n:] == lose)
        assert np.all(np.asarray(ki)[:, n:] == 2 ** 30)


def test_merge_topk_tie_break_lower_index():
    va = jnp.asarray([[1.0, 1.0]])
    ia = jnp.asarray([[4, 9]], dtype=jnp.int32)
    vb = jnp.asarray([[1.0, 0.5]])
    ib = jnp.asarray([[2, 3]], dtype=jnp.int32)
    v, i = ref.merge_topk(va, ia, vb, ib, k=2, largest=True)
    # stability: candidates listed first (a then b) win ties
    assert list(np.asarray(i)[0]) == [4, 9]


def test_distance_pallas_matches(rng):
    q, p = _data(rng, "eucl", 12, 56, 72)
    d1 = np.asarray(ops.cam_distances(q, p, metric="eucl"))
    d2 = np.asarray(ref.distances(q, p, "eucl"))
    np.testing.assert_allclose(d1, d2, atol=1e-3)
