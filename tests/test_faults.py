"""Device-fault injection (`repro.faults`): model determinism, engine
dispatch-time corruption, and HardenedPlan replication / healing."""

import numpy as np
import pytest

from repro.core import ArchSpec, get_plan
from repro.faults import FaultModel, HardenedPlan
from test_engine import _data, _sim_module
from test_range import _interval_data, _range_module

ARCH = ArchSpec(rows=16, cols=32)


def _search_plan(rng, metric="dot", m=6, n=48, dim=32, k=3, **kw):
    mod = _sim_module(metric, k, metric != "eucl", m, n, dim, ARCH)
    return get_plan(mod, **kw), _data(rng, metric, m, n, dim)


# -- model ----------------------------------------------------------------


def test_model_validation():
    with pytest.raises(ValueError):
        FaultModel(p_stuck=1.5)
    with pytest.raises(ValueError):
        FaultModel(p_flip=-0.1)
    with pytest.raises(ValueError):
        FaultModel(sigma=-1.0)
    with pytest.raises(ValueError):
        FaultModel(seed=-1)


def test_null_model_detection():
    assert FaultModel().is_null
    assert FaultModel(drift=0.5, t=0).is_null          # no elapsed time
    assert not FaultModel(p_flip=0.01).is_null
    assert not FaultModel(drift=0.5, t=3).is_null


def test_stuck_cells_are_permanent_flips_are_transient():
    fm = FaultModel(seed=3, p_stuck=0.05, p_flip=0.05)
    s0a, s1a = fm.stuck_masks((40, 16))
    s0b, s1b = fm.rewritten().stuck_masks((40, 16))
    np.testing.assert_array_equal(s0a, s0b)            # permanent
    np.testing.assert_array_equal(s1a, s1b)
    assert not (s0a & s1a).any()                       # disjoint
    fa = fm.flip_mask((40, 16))
    fb = fm.rewritten().flip_mask((40, 16))
    assert (fa != fb).any()                            # redrawn per epoch
    np.testing.assert_array_equal(fa, fm.flip_mask((40, 16)))


def test_drift_accumulates_in_fixed_direction():
    fm = FaultModel(seed=1, drift=0.1, t=2)
    d2 = fm.drift_shift((8, 8))
    d5 = fm.aged(3).drift_shift((8, 8))
    np.testing.assert_array_equal(np.sign(d2), np.sign(d5))
    np.testing.assert_allclose(np.abs(d5), 2.5 * np.abs(d2))
    assert fm.aged(3).suggest_guard(z=0.0) == pytest.approx(0.5)
    assert fm.rewritten().t == 0 and fm.rewritten().epoch == fm.epoch + 1


def test_corrupt_interval_stuck_semantics():
    lo = np.zeros((4, 4), np.float32)
    hi = np.ones((4, 4), np.float32)
    fm = FaultModel(seed=0, p_stuck=1.0)               # every cell stuck
    lo2, hi2 = fm.corrupt_interval(lo, hi)
    wild = (lo2 == -np.inf) & (hi2 == np.inf)          # stuck-at-1
    empty = (lo2 == np.inf) & (hi2 == -np.inf)         # stuck-at-0
    assert (wild | empty).all() and wild.any() and empty.any()


# -- engine dispatch-time injection ---------------------------------------


def test_null_model_bit_identical_to_clean(rng):
    (plan, (q, p)) = _search_plan(rng)
    v0, i0 = plan.execute(q, p)
    v1, i1 = plan.execute(q, p, faults=FaultModel())
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_faults_reject_garbage_object(rng):
    (plan, (q, p)) = _search_plan(rng)
    with pytest.raises(TypeError):
        plan.execute(q, p, faults="p=0.1")


def test_seeded_injection_reproducible_and_seed_sensitive(rng):
    (plan, (q, p)) = _search_plan(rng)
    fm = FaultModel(seed=5, p_stuck=0.02, p_flip=0.01)
    va, ia = plan.execute(q, p, faults=fm)
    vb, ib = plan.execute(q, p, faults=FaultModel(seed=5, p_stuck=0.02,
                                                  p_flip=0.01))
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    vc, ic = plan.execute(q, p, faults=FaultModel(seed=6, p_stuck=0.02,
                                                  p_flip=0.01))
    assert not np.array_equal(np.asarray(ia), np.asarray(ic))


def test_packed_and_unpacked_see_identical_faults(rng):
    """Corruption happens in the source metric domain, so the uint32
    lanes and the float slab encode the same faulted cells."""
    m, n, dim, k = 6, 64, 64, 4
    mod = _sim_module("hamming", k, False, m, n, dim, ARCH)
    q, p = _data(rng, "hamming", m, n, dim)
    fm = FaultModel(seed=2, p_stuck=0.03, p_flip=0.01)
    vp, ip = get_plan(mod, pack=True).execute(q, p, faults=fm)
    vu, iu = get_plan(mod, pack=False).execute(q, p, faults=fm)
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(iu))
    np.testing.assert_array_equal(np.asarray(vp), np.asarray(vu))


def test_engine_faults_match_oracle_on_corrupted_sources(rng):
    """Engine-with-faults == clean engine on a pre-corrupted gallery:
    injection is exactly a transformation of the stored operands."""
    (plan, (q, p)) = _search_plan(rng, metric="eucl")
    fm = FaultModel(seed=7, p_stuck=0.01, sigma=0.05, drift=0.01, t=2)
    corrupted, = fm.corrupt_stored((np.asarray(p),), plan.spec)
    v_want, i_want = plan.execute(q, corrupted)
    v_got, i_got = plan.execute(q, p, faults=fm)
    np.testing.assert_array_equal(np.asarray(i_want), np.asarray(i_got))
    np.testing.assert_array_equal(np.asarray(v_want), np.asarray(v_got))


def test_range_interval_fault_injection(rng):
    m, n, dim = 5, 40, 16
    mod = _range_module(m, n, dim, ArchSpec(rows=8, cols=16), interval=True)
    plan = get_plan(mod)
    q, lo, hi = _interval_data(rng, m, n, dim)
    fm = FaultModel(seed=4, p_stuck=0.05, sigma=0.01)
    want = np.asarray(plan.execute(
        q, *fm.corrupt_interval(np.asarray(lo), np.asarray(hi))))
    got = np.asarray(plan.execute(q, lo, hi, faults=fm))
    np.testing.assert_array_equal(want, got)
    clean = np.asarray(plan.execute(q, lo, hi))
    assert (clean != got).any()          # faults actually bit


# -- HardenedPlan ---------------------------------------------------------


def test_hardened_r1_is_bit_identical_search(rng):
    (plan, (q, p)) = _search_plan(rng, metric="eucl")
    hp = HardenedPlan(plan, replicas=1, spares=0)
    hp.prepare(p)
    v0, i0 = plan.execute(q, p)
    v1, i1 = hp.execute(q)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))


def test_hardened_r1_is_bit_identical_range(rng):
    m, n, dim = 5, 40, 16
    mod = _range_module(m, n, dim, ArchSpec(rows=8, cols=16), interval=True)
    plan = get_plan(mod)
    q, lo, hi = _interval_data(rng, m, n, dim)
    hp = HardenedPlan(plan, replicas=1, spares=0)
    hp.prepare(lo, hi)
    np.testing.assert_array_equal(np.asarray(plan.execute(q, lo, hi)),
                                  np.asarray(hp.execute(q)))


def test_replication_improves_topk_agreement(rng):
    """3x replication + median de-dup recovers top-k overlap with the
    clean result.  Averaged over fault seeds (everything is seeded, so
    this is deterministic): any single fault draw can go either way,
    the expectation must not."""
    (plan, (q, p)) = _search_plan(rng, metric="dot", m=16, n=96, dim=64)
    clean_k = plan.spec.k
    clean = np.asarray(plan.execute(q, p)[1])
    hp = HardenedPlan(plan, replicas=3, spares=0)
    hp.prepare(p)

    def agree(a):
        return np.mean([len(set(a[r]) & set(clean[r])) / clean_k
                        for r in range(clean.shape[0])])

    raw_scores, rep_scores = [], []
    for seed in range(8):
        fm = FaultModel(seed=seed, p_stuck=0.02, p_flip=0.01)
        raw_scores.append(agree(np.asarray(
            plan.execute(q, p, faults=fm)[1])))
        rep_scores.append(agree(np.asarray(hp.execute(q, faults=fm)[1])))
    assert np.mean(rep_scores) > np.mean(raw_scores)


def test_heal_remaps_faulty_rows_to_spares(rng):
    m, n, dim = 5, 40, 16
    mod = _range_module(m, n, dim, ArchSpec(rows=8, cols=16), interval=True)
    plan = get_plan(mod)
    q, lo, hi = _interval_data(rng, m, n, dim)
    fm = FaultModel(seed=11, p_stuck=0.02, p_flip=0.01)
    hp = HardenedPlan(plan, replicas=2, spares=64)
    hp.prepare(lo, hi)
    report = hp.heal(fm)
    assert report.detected > 0
    assert report.remapped > 0
    assert report.remapped <= report.detected
    snap = hp.snapshot()
    assert snap["spares_free"] == 64 - report.remapped
    if report.unrepairable == 0:
        # fully healed: the faulted physical gallery reads back clean,
        # so execution under the model matches the clean logical result
        want = np.asarray(plan.execute(q, lo, hi))
        got = np.asarray(hp.execute(q, faults=fm))
        np.testing.assert_array_equal(want, got)


def test_heal_is_idempotent_when_clean(rng):
    (plan, (q, p)) = _search_plan(rng, metric="eucl")
    hp = HardenedPlan(plan, replicas=1, spares=4)
    hp.prepare(p)
    report = hp.heal(FaultModel())          # null model: nothing to find
    assert report.detected == 0 and report.remapped == 0
    assert report.passes == 0               # short-circuits, no readback


def test_hardened_validates_inputs(rng):
    (plan, (_, p)) = _search_plan(rng)
    with pytest.raises(ValueError):
        HardenedPlan(plan, replicas=0)
    with pytest.raises(ValueError):
        HardenedPlan(plan, replicas=1, spares=-1)
    hp = HardenedPlan(plan, replicas=1, spares=0)
    with pytest.raises(RuntimeError):
        hp.execute(p)                       # prepare() not called yet
