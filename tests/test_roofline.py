"""Roofline HLO-parser unit tests on a hand-written post-SPMD module."""

import numpy as np

from repro.launch import roofline as rl

HLO = """\
HloModule jit_step, is_scheduled=true

%cond.1 (arg: (s32[], f32[4,8])) -> pred[] {
  %arg = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (arg: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %arg = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[4,8]{1,0} get-tuple-element(%arg), index=1
  %w = f32[8,8]{1,0} constant({...})
  %y = f32[4,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %r = f32[4,8]{1,0} all-reduce(%y), replica_groups=[2,8]<=[16], to_apply=%add.0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,8]) tuple(%ip, %r)
}

%add.0 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main.1 (p0: f32[4,8]) -> f32[4,8] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[4,8]) tuple(%zero, %p0)
  %w2 = f32[16,4]{1,0} constant({...})
  %loop = (s32[], f32[4,8]) while(%init), condition=%cond.1, body=%body.1
  %out = f32[4,8]{1,0} get-tuple-element(%loop), index=1
  %g = f32[4,16]{1,0} all-gather(%out), replica_groups={{0,1},{2,3}}, dimensions={1}
  ROOT %fin = f32[4,8]{1,0} dot(%g, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_parser_counts_while_weighted_flops():
    rep = rl.analyze_hlo(HLO, n_devices=16)
    # dot inside the while: 2*4*8*8 = 512 flops x 12 trips; entry dot:
    # 2*4*8*16 = 1024
    assert rep.while_trip_counts == [12]
    assert rep.dot_count == 12 + 1
    np.testing.assert_allclose(rep.flops, 512 * 12 + 1024)


def test_parser_collective_ring_costs():
    rep = rl.analyze_hlo(HLO, n_devices=16)
    # all-reduce of f32[4,8]=128B in groups of 8: 2*(7/8)*128 = 224B x 12
    # all-gather result f32[4,16]=256B in groups of 2: (1/2)*256 = 128B
    np.testing.assert_allclose(
        rep.collective_bytes_by_kind["all-reduce"], 224 * 12)
    np.testing.assert_allclose(
        rep.collective_bytes_by_kind["all-gather"], 128)
    assert rep.collective_counts == {"all-reduce": 12, "all-gather": 1}


def test_parser_compression_scales_dp_collectives():
    a = rl.analyze_hlo(HLO, n_devices=16)
    b = rl.analyze_hlo(HLO, n_devices=16, compression_ratio=0.25,
                       dp_collective_kinds=("all-reduce",))
    np.testing.assert_allclose(
        b.collective_bytes_by_kind["all-reduce"],
        0.25 * a.collective_bytes_by_kind["all-reduce"])


def test_bottleneck_classification():
    rep = rl.RooflineReport(flops=197e12, hbm_bytes=1.0,
                            collective_bytes=1.0)
    assert rep.bottleneck == "compute"
    assert abs(rep.t_compute - 1.0) < 1e-9
    rep2 = rl.RooflineReport(flops=1.0, hbm_bytes=819e9,
                             collective_bytes=1.0)
    assert rep2.bottleneck == "memory"


def test_model_flops_moe_uses_active_params():
    from repro.configs import get_config
    from repro.launch.specs import SHAPES
    moe = get_config("deepseek-moe-16b")
    assert moe.active_param_count() < 0.5 * moe.param_count()
    f = rl.model_flops(moe, SHAPES["train_4k"])
    assert f == 6.0 * moe.active_param_count() * 256 * 4096
