import numpy as np
import pytest


@pytest.fixture()
def rng():
    """Fresh deterministic generator per test.

    Function-scoped on purpose: with a session-scoped generator every
    test's data depended on which tests ran before it, so adding or
    skipping one test elsewhere reshuffled the inputs of all later ones
    (and occasionally landed float near-ties on comparison boundaries).
    """
    return np.random.default_rng(0)
