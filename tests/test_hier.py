"""Hierarchical two-stage plans: the IVF-style coarse→fine composite.

Pins the contracts the plan-graph tentpole introduced:

* **bit-identity** — at ``nprobe == clusters`` every tile is probed and
  the composite must reproduce the flat plan's results bit-for-bit
  (indices everywhere; values exactly for the integer metrics, to float
  tolerance for the analog ones), packed and unpacked, both polarities.
* **recall** — smaller ``nprobe`` trades recall monotonically (the
  probed cluster sets are nested per query).
* **update_rows** — row mutation re-assigns touched rows to their
  nearest *stored* centroid incrementally; results are placement
  invariant, so any update schedule reaching the same gallery content
  gives identical results at any fixed ``nprobe``, and a cluster
  overflow (full re-layout, same centroids) changes nothing either.
* **serving** — a hierarchical plan is a first-class primary for
  ``CamSearchServer``: searches, live ``update_gallery``, a flat-exact
  fallback level, and the ``hierarchical`` family tag in telemetry.
* **sharding** — the fine probing stage shards across devices with a
  composite-key host merge; parity checks run in a forced-8-device
  child process (this file doubles as that child:
  ``python tests/test_hier.py --child``).

Galleries here keep ``n >= k``: with ``n < k`` the flat tournament and
the probing stage fill the dead slots with different (equally losing)
filler indices — that caveat is documented on ``repro.core.engine.hier``
and exercised by the sharded suite's ``n < k`` axis instead.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

DEVICES = 8


def _assert_same(got, want, metric, msg=""):
    gv, gi = (np.asarray(x) for x in got)
    wv, wi = (np.asarray(x) for x in want)
    np.testing.assert_array_equal(gi, wi, err_msg=f"indices {msg}")
    if metric in ("hamming", "dot"):
        np.testing.assert_array_equal(gv, wv, err_msg=f"values {msg}")
    else:
        np.testing.assert_allclose(gv, wv, atol=1e-4,
                                   err_msg=f"values {msg}")


# ---------------------------------------------------------------------------
# child: sharded parity under 8 forced host devices
# ---------------------------------------------------------------------------


def _child_main() -> int:
    import jax

    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))
    from repro.core import ArchSpec, get_plan
    from repro.core.engine import get_hierarchical_plan

    assert jax.device_count() == DEVICES, jax.device_count()
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from test_engine import _data, _sim_module

    rng = np.random.default_rng(11)
    arch = ArchSpec(rows=16, cols=32)

    # gallery sizes that pad unevenly across 8 shards; every metric
    for metric, largest in (("hamming", False), ("dot", True),
                            ("cos", False), ("eucl", False)):
        for n in (137, 192, 61):
            m, dim, k = 7, 64, 5
            mod = _sim_module(metric, k, largest, m, n, dim, arch)
            q, p = _data(rng, metric, m, n, dim)
            flat = get_plan(mod, shards=1)
            fr = flat.execute(q, p)

            # sharded nprobe=all == flat (single-device)
            hs = get_hierarchical_plan(mod, clusters=6, nprobe=6,
                                       shards=DEVICES)
            assert hs.shards == DEVICES
            hv, hi = (np.asarray(x) for x in hs.execute(q, p))
            np.testing.assert_array_equal(
                hi, np.asarray(fr[1]),
                err_msg=f"sharded hier != flat: {metric} n={n}")
            if metric in ("hamming", "dot"):
                np.testing.assert_array_equal(hv, np.asarray(fr[0]))
            else:
                np.testing.assert_allclose(hv, np.asarray(fr[0]),
                                           atol=1e-4)

            # sharded partial nprobe == unsharded partial nprobe
            h1 = get_hierarchical_plan(mod, clusters=6, nprobe=2, shards=1)
            h8 = get_hierarchical_plan(mod, clusters=6, nprobe=2,
                                       shards=DEVICES)
            r1 = tuple(np.asarray(x) for x in h1.execute(q, p))
            r8 = tuple(np.asarray(x) for x in h8.execute(q, p))
            np.testing.assert_array_equal(
                r8[1], r1[1], err_msg=f"shard split changed results: "
                                      f"{metric} n={n}")

    # sharded update_rows keeps nprobe=all parity with the flat plan
    metric, m, n, dim, k = "hamming", 6, 160, 64, 4
    mod = _sim_module(metric, k, False, m, n, dim, arch)
    q, p = _data(rng, metric, m, n, dim)
    import jax.numpy as jnp

    hs = get_hierarchical_plan(mod, clusters=5, nprobe=5, shards=DEVICES)
    flat = get_plan(mod, shards=1)
    g = jnp.asarray(p)
    hs.execute(q, g)
    idx = np.asarray([0, 3, 64, 121])
    new = (rng.random((4, dim)) > 0.5).astype(np.float32)
    p2 = hs.update_rows(g, idx, new)
    fb = hs.row_update_fallbacks
    fv, fi = flat.execute(q, np.asarray(p2))
    hv, hi = hs.execute(q, p2)
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(fi))
    np.testing.assert_array_equal(np.asarray(hv), np.asarray(fv))
    assert hs.row_update_fallbacks == fb, "sharded update fell back"

    print("SHARDED-HIER-OK")
    return 0


# ---------------------------------------------------------------------------
# pytest entry points
# ---------------------------------------------------------------------------


@pytest.fixture
def arch():
    from repro.core import ArchSpec
    return ArchSpec(rows=16, cols=32)


def test_nprobe_all_bit_identical_to_flat(arch, rng):
    """Every metric x polarity x packing: probing every cluster must be
    indistinguishable from the flat plan."""
    from repro.core import clear_plan_cache, get_plan
    from repro.core.engine import get_hierarchical_plan
    from test_engine import _data, _sim_module

    clear_plan_cache()
    for metric, largest in (("hamming", False), ("dot", True),
                            ("dot", False), ("cos", True), ("eucl", False)):
        for pack in (None, False):
            m, n, dim, k = 7, 96, 64, 6
            mod = _sim_module(metric, k, largest, m, n, dim, arch)
            q, p = _data(rng, metric, m, n, dim)
            flat = get_plan(mod, pack=pack)
            hier = get_hierarchical_plan(mod, clusters=6, nprobe=6,
                                         pack=pack)
            assert hier.family == "hierarchical"
            assert hier.spec.nprobe == hier.spec.clusters == 6
            _assert_same(hier.execute(q, p), flat.execute(q, p), metric,
                         f"{metric} largest={largest} pack={pack}")


def test_recall_monotone_and_partial_probe_cost(arch, rng):
    """Recall grows monotonically in nprobe and hits 1.0 at nprobe=all;
    the composite accounts the work to itself, not the coarse stage."""
    from repro.core import clear_plan_cache, get_plan
    from repro.core.engine import get_hierarchical_plan
    from test_engine import _data, _sim_module

    clear_plan_cache()
    m, n, dim, k = 16, 256, 32, 8
    mod = _sim_module("hamming", k, False, m, n, dim, arch)
    q, p = _data(rng, "hamming", m, n, dim)
    flat = get_plan(mod)
    _, fi = (np.asarray(x) for x in flat.execute(q, p))
    flat_sets = [set(map(int, row)) for row in fi]
    recalls = []
    for nprobe in (1, 2, 4, 8):
        hp = get_hierarchical_plan(mod, clusters=8, nprobe=nprobe)
        _, hi = hp.execute(q, p)
        recalls.append(np.mean([
            len(set(map(int, row)) & fs) / k
            for row, fs in zip(np.asarray(hi), flat_sets)]))
    assert all(a <= b + 1e-9 for a, b in zip(recalls, recalls[1:])), recalls
    assert recalls[-1] == 1.0, recalls

    stats = hp.graph_stats()
    assert stats["family"] == "hierarchical"
    assert stats["executions"] >= 1
    # the coarse stage ran fused inside the composite dispatch: its own
    # counters must not have been bumped
    assert stats["stage0:search"]["executions"] == 0


def test_factory_contracts(arch, rng):
    """get_hierarchical_plan mirrors get_plan's front door: None for
    non-similarity programs, errors for unsupported axes, clamped
    clustering parameters."""
    from repro.core import ArchSpec, clear_plan_cache, compile_fn
    from repro.core.engine import get_hierarchical_plan
    from test_engine import _sim_module
    from test_range import _range_module

    clear_plan_cache()
    mod = _sim_module("hamming", 3, False, 4, 64, 32, arch)
    # non-similarity programs: None, like get_plan
    ew = compile_fn(lambda a, b: a.add(b), [(8, 8), (8, 8)],
                    ArchSpec(rows=16, cols=16))
    assert get_hierarchical_plan(ew.stages["cim_partitioned"]) is None
    assert get_hierarchical_plan(_range_module(4, 16, 32, arch)) is None
    # unsupported axes raise instead of silently degrading
    with pytest.raises(ValueError):
        get_hierarchical_plan(mod, backend="pallas")
    # clustering parameters clamp into valid range
    p = get_hierarchical_plan(mod, clusters=1000, nprobe=4000)
    assert p.spec.clusters <= 64 and p.spec.nprobe <= p.spec.clusters
    # defaults: ~sqrt(n) clusters, nprobe >= 1
    d = get_hierarchical_plan(mod)
    assert 1 <= d.spec.nprobe <= d.spec.clusters <= 64


def test_update_rows_incremental_and_overflow(arch, rng):
    """Incremental reassignment keeps nprobe=all parity with the flat
    plan through same-cluster rewrites, cross-cluster moves, and a
    cluster overflow that forces the full re-layout (same centroids)."""
    from repro.core import clear_plan_cache, get_plan
    from repro.core.engine import get_hierarchical_plan
    from test_engine import _data, _sim_module

    import jax.numpy as jnp

    clear_plan_cache()
    m, n, dim, k = 8, 192, 32, 5
    mod = _sim_module("hamming", k, False, m, n, dim, arch)
    q, p = _data(rng, "hamming", m, n, dim)
    flat = get_plan(mod)
    hier = get_hierarchical_plan(mod, clusters=6, nprobe=6)

    # the memo is keyed by jax.Array identity: keep the gallery chain
    # on-device (a numpy gallery re-prepares every call by contract)
    g = jnp.asarray(p)
    hier.execute(q, g)

    # a stream of scattered updates: moved and unmoved rows mixed
    for step in range(3):
        idx = np.sort(rng.choice(n, size=9, replace=False))
        new = (rng.random((9, dim)) > 0.5).astype(np.float32)
        g = hier.update_rows(g, idx, new)
        _assert_same(hier.execute(q, g), flat.execute(q, np.asarray(g)),
                     "hamming", f"update step {step}")
    assert hier.row_update_fallbacks == 0, \
        "scattered updates must stay on the incremental path"

    # overflow: clone one row's content everywhere -> every row lands in
    # one cluster, which cannot fit its tile group -> full re-layout
    # with the *same* centroids, still flat-identical
    idx = np.arange(128)
    new = np.tile(np.asarray(g)[n - 1], (128, 1))
    g2 = hier.update_rows(g, idx, new)
    _assert_same(hier.execute(q, g2), flat.execute(q, np.asarray(g2)),
                 "hamming", "overflow re-layout")


def test_update_schedule_invariance(arch, rng):
    """Placement invariance: two update schedules reaching the same
    gallery content give bit-identical results at a *partial* nprobe —
    incremental row moves are equivalent to a rebuild with the same
    centroids, wherever the rows physically landed."""
    from repro.core import clear_plan_cache, get_plan
    from repro.core.engine import get_hierarchical_plan
    from test_engine import _data, _sim_module

    clear_plan_cache()
    m, n, dim, k = 8, 160, 32, 4
    mod = _sim_module("hamming", k, False, m, n, dim, arch)
    q, p = _data(rng, "hamming", m, n, dim)

    import jax.numpy as jnp

    idx_all = np.sort(rng.choice(n, size=24, replace=False))
    new_all = (rng.random((24, dim)) > 0.5).astype(np.float32)

    # schedule A: one bulk update
    a = get_hierarchical_plan(mod, clusters=6, nprobe=2)
    g0a = jnp.asarray(p)
    a.execute(q, g0a)
    ga = a.update_rows(g0a, idx_all, new_all)
    ra = tuple(np.asarray(x) for x in a.execute(q, ga))

    # schedule B: same rows in three interleaved slices (different
    # vacate/fill order -> different physical slots)
    clear_plan_cache()
    b = get_hierarchical_plan(mod, clusters=6, nprobe=2)
    gb = jnp.asarray(p)
    b.execute(q, gb)
    for sl in (slice(0, 24, 3), slice(1, 24, 3), slice(2, 24, 3)):
        gb = b.update_rows(gb, idx_all[sl], new_all[sl])
    assert a.row_update_fallbacks == 0 and b.row_update_fallbacks == 0
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(ga))
    rb = tuple(np.asarray(x) for x in b.execute(q, gb))
    np.testing.assert_array_equal(rb[1], ra[1])
    np.testing.assert_array_equal(rb[0], ra[0])


def test_served_hierarchical_plan(arch, rng):
    """A hierarchical plan as the serving primary: parity with the
    served flat plan, live update_gallery on the incremental path, the
    flat-exact fallback level, and family-tagged telemetry."""
    from repro.core import clear_plan_cache, get_plan
    from repro.core.engine import get_hierarchical_plan
    from repro.serving import CamSearchServer
    from test_engine import _data, _sim_module

    clear_plan_cache()
    m, n, dim, k = 8, 192, 32, 5
    mod = _sim_module("hamming", k, False, m, n, dim, arch)
    q, p = _data(rng, "hamming", m, n, dim)
    flat = get_plan(mod)
    hier = get_hierarchical_plan(mod, clusters=6, nprobe=6)

    srv = CamSearchServer(hier, p, max_wait_ms=0.5).start()
    try:
        _assert_same(srv.search(q), flat.execute(q, p), "hamming",
                     "served")
        snap = srv.snapshot()
        assert snap["plan"]["family"] == "hierarchical"
        assert "jnp-flat" in [name for name, _ in srv._levels()]

        idx = np.arange(0, 48)
        new = (rng.random((48, dim)) > 0.5).astype(np.float32)
        fb = hier.row_update_fallbacks
        srv.update_gallery(idx, new)
        assert hier.row_update_fallbacks == fb
        g2 = p.copy()
        g2[idx] = new
        _assert_same(srv.search(q), flat.execute(q, g2), "hamming",
                     "served after update_gallery")
        assert srv.snapshot()["gallery_updates"] == 1
    finally:
        srv.stop()


def test_hier_sharded_multi_device():
    """Sharded probing parity matrix under 8 forced host devices."""
    from repro.launch.mesh import forced_host_devices_env

    env = forced_host_devices_env(DEVICES)
    env.pop("REPRO_ENGINE_MAX_CHUNK", None)
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0 and "SHARDED-HIER-OK" in out.stdout, (
        f"sharded hier child failed (rc={out.returncode}):\n"
        f"{out.stdout[-3000:]}\n{out.stderr[-3000:]}")


if __name__ == "__main__":
    if "--child" in sys.argv:
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={DEVICES}")
        raise SystemExit(_child_main())
    raise SystemExit(pytest.main([__file__, "-v"]))
