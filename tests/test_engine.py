"""Search-plan engine: plan-cache behaviour and parity with the IR
interpreter (the semantic oracle) across metrics, tile geometries,
ragged pattern counts, and micro-batched queries."""

import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (ArchSpec, Builder, Module, PassManager, TensorType,
                        clear_plan_cache, compile_fn, get_plan,
                        plan_cache_stats)
from repro.core.engine import _pick_batch
from repro.core.cim_dialect import (make_acquire, make_execute, make_release,
                                    make_similarity, make_yield)
from repro.core.engine import extract_plan_spec
from repro.core.executor import execute_module
from repro.core.passes import CompulsoryPartition


def _dot_sim(inp, weight):
    mm = inp.matmul(weight.transpose(-2, -1))
    return mm.topk(1, largest=False)


def _sim_module(metric, k, largest, m, n, dim, arch, unroll_limit=64):
    """Hand-built fused similarity module, run through the partition pass.

    Lets the parity tests cover metrics (hamming) and ragged shapes the
    traced frontend patterns never produce.
    """
    mod = Module("sim", [TensorType((m, dim)), TensorType((n, dim))])
    q, p = mod.arguments
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, [q, p],
                       [TensorType((m, k)), TensorType((m, k), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, q, p, metric=metric, k=k, largest=largest)
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition(unroll_limit=unroll_limit))
    return pm.run(mod, {"arch": arch})


def _data(rng, metric, m, n, d):
    if metric == "hamming":
        return ((rng.random((m, d)) > 0.5).astype(np.float32),
                (rng.random((n, d)) > 0.5).astype(np.float32))
    return (rng.standard_normal((m, d)).astype(np.float32),
            rng.standard_normal((n, d)).astype(np.float32))


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------


def test_plan_cache_hit_same_program():
    clear_plan_cache()
    arch = ArchSpec(rows=32, cols=64)
    p1 = compile_fn(_dot_sim, [(10, 256), (16, 256)], arch)
    p2 = compile_fn(_dot_sim, [(10, 256), (16, 256)], arch)
    assert p1.engine_plan is not None
    assert p1.engine_plan is p2.engine_plan
    stats = plan_cache_stats()
    assert stats["hits"] >= 1 and stats["misses"] >= 1


def test_plan_cache_misses_on_tile_geometry():
    clear_plan_cache()
    p1 = compile_fn(_dot_sim, [(10, 256), (16, 256)], ArchSpec(rows=16, cols=64))
    p2 = compile_fn(_dot_sim, [(10, 256), (16, 256)], ArchSpec(rows=32, cols=64))
    assert p1.engine_plan is not None and p2.engine_plan is not None
    assert p1.engine_plan is not p2.engine_plan
    assert plan_cache_stats()["misses"] >= 2


def test_dse_targets_share_one_plan():
    """Optimization targets change the mapping, not the tile grid — a DSE
    sweep over targets is exactly the cache-hit case."""
    clear_plan_cache()
    progs = [compile_fn(_dot_sim, [(10, 256), (16, 256)],
                        ArchSpec(rows=32, cols=64).with_target(t))
             for t in ("latency", "power", "density")]
    plans = {id(p.engine_plan) for p in progs}
    assert len(plans) == 1


def test_non_similarity_program_has_no_plan():
    prog = compile_fn(lambda a, b: a.add(b), [(8, 8), (8, 8)],
                      ArchSpec(rows=16, cols=16))
    assert prog.engine_plan is None
    out = prog(np.ones((8, 8), np.float32), 2 * np.ones((8, 8), np.float32))
    assert float(np.asarray(out[0]).sum()) == 8 * 8 * 3


# ---------------------------------------------------------------------------
# parity with the interpreter: engine output == interpreted output
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric,largest", [("hamming", False),
                                            ("dot", False),
                                            ("cos", True),
                                            ("eucl", False)])
@pytest.mark.parametrize("n", [37, 64, 5])      # ragged + aligned + n < k
@pytest.mark.parametrize("unroll_limit", [64, 0])
def test_engine_matches_interpreted(metric, largest, n, unroll_limit, rng):
    m, dim, k = 9, 100, 6
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module(metric, k, largest, m, n, dim, arch,
                      unroll_limit=unroll_limit)
    plan = get_plan(mod)
    assert plan is not None
    q, p = _data(rng, metric, m, n, dim)
    ev, ei = plan.execute(q, p)
    iv, ii = execute_module(mod, q, p)
    np.testing.assert_array_equal(np.asarray(ei), np.asarray(ii))
    if metric in ("hamming", "dot"):     # integer metrics: bit-identical
        np.testing.assert_array_equal(np.asarray(ev), np.asarray(iv))
    else:
        np.testing.assert_allclose(np.asarray(ev), np.asarray(iv), atol=1e-4)


def test_micro_batching_streams_chunks(rng):
    m, n, dim, k = 37, 50, 64, 3
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("eucl", k, False, m, n, dim, arch)
    plan = get_plan(mod, batch=8)
    assert plan.batch == 8
    q, p = _data(rng, "eucl", m, n, dim)
    before = plan.chunks_run
    ev, ei = plan.execute(q, p)
    assert plan.chunks_run - before == -(-m // 8)   # 5 chunks incl ragged tail
    iv, ii = execute_module(mod, q, p)
    np.testing.assert_array_equal(np.asarray(ei), np.asarray(ii))
    np.testing.assert_allclose(np.asarray(ev), np.asarray(iv), atol=1e-4)


def test_pattern_preparation_is_memoised(rng):
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("dot", 2, False, 8, 24, 64, arch)
    plan = get_plan(mod)
    q, p = _data(rng, "dot", 8, 24, 64)
    pj = jnp.asarray(p)
    plan.execute(q, pj)                   # immutable gallery: memoised
    assert len(plan._pattern_cache) == 1
    plan.execute(q, pj)                   # same gallery object: cache hit
    assert len(plan._pattern_cache) == 1
    # mutable (numpy) galleries are never memoised — in-place mutation
    # under an unchanged id must not serve stale prepared patterns
    plan.execute(q, p)
    assert len(plan._pattern_cache) == 1


def test_mutated_numpy_gallery_not_stale(rng):
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("eucl", 2, False, 4, 20, 32, arch)
    plan = get_plan(mod)
    q, p = _data(rng, "eucl", 4, 20, 32)
    plan.execute(q, p)
    p[:] = rng.standard_normal(p.shape).astype(np.float32)  # same id/shape
    _, i = plan.execute(q, p)
    _, ii = execute_module(mod, q, p)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ii))


def test_pallas_backend_parity(rng):
    clear_plan_cache()
    arch = ArchSpec(rows=32, cols=64)
    mod = _sim_module("dot", 3, False, 10, 45, 96, arch)
    plan_ref = get_plan(mod, backend="jnp")
    plan_pl = get_plan(mod, backend="pallas")
    assert plan_ref is not plan_pl
    q, p = _data(rng, "dot", 10, 45, 96)
    rv, ri = plan_ref.execute(q, p)
    pv, pi = plan_pl.execute(q, p)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(pi))
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(pv))


# ---------------------------------------------------------------------------
# micro-batch sizing
# ---------------------------------------------------------------------------


def test_pick_batch_respects_non_power_of_two_cap(monkeypatch):
    """Regression: a cap of 1000 must not round up past itself to 1024."""
    monkeypatch.setenv("REPRO_ENGINE_MAX_CHUNK", "1000")
    assert _pick_batch(5000) == 1000
    assert _pick_batch(600) <= 1000
    monkeypatch.setenv("REPRO_ENGINE_MAX_CHUNK", "1024")
    assert _pick_batch(5000) == 1024       # power-of-two caps unchanged
    assert _pick_batch(3) == 8
    monkeypatch.setenv("REPRO_ENGINE_MAX_CHUNK", "6")
    assert _pick_batch(100) == 6           # cap below the floor still wins


def test_pick_batch_cap_changes_plan_key(monkeypatch):
    clear_plan_cache()
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("eucl", 2, False, 2000, 24, 64, arch)
    monkeypatch.setenv("REPRO_ENGINE_MAX_CHUNK", "1000")
    plan = get_plan(mod)
    assert plan.batch == 1000


# ---------------------------------------------------------------------------
# concurrency: one shared plan driven from many threads
# ---------------------------------------------------------------------------


def test_threaded_execute_parity_and_counters(rng):
    """Many threads share one plan: results must match the single-thread
    output and the stats counters must not drop increments."""
    m, n, dim, k = 24, 40, 64, 4
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("dot", k, False, m, n, dim, arch)
    plan = get_plan(mod, batch=8)
    q, p = _data(rng, "dot", m, n, dim)
    pj = jnp.asarray(p)
    want_v, want_i = plan.execute(q, pj)
    exec0, chunks0 = plan.executions, plan.chunks_run

    n_threads, reps = 8, 4
    errs = []

    def worker():
        try:
            for _ in range(reps):
                v, i = plan.execute(q, pj)
                np.testing.assert_array_equal(np.asarray(i),
                                              np.asarray(want_i))
                np.testing.assert_array_equal(np.asarray(v),
                                              np.asarray(want_v))
        except Exception as e:               # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs[:1]
    runs = n_threads * reps
    assert plan.executions - exec0 == runs
    assert plan.chunks_run - chunks0 == runs * (-(-m // 8))


def test_dispatch_finalize_matches_execute(rng):
    arch = ArchSpec(rows=16, cols=32)
    mod = _sim_module("eucl", 3, False, 10, 30, 48, arch)
    plan = get_plan(mod)
    q, p = _data(rng, "eucl", 10, 30, 48)
    pending = plan.dispatch(q, p)
    v1, i1 = plan.finalize(pending)
    v2, i2 = plan.execute(q, p)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


# ---------------------------------------------------------------------------
# spec extraction
# ---------------------------------------------------------------------------


def test_spec_extraction_both_ir_forms():
    arch = ArchSpec(rows=16, cols=32)
    unrolled = _sim_module("eucl", 3, False, 4, 40, 64, arch, unroll_limit=64)
    looped = _sim_module("eucl", 3, False, 4, 40, 64, arch, unroll_limit=0)
    s1, s2 = extract_plan_spec(unrolled), extract_plan_spec(looped)
    assert s1 is not None and s1 == s2   # same plan key => same cached plan


def test_compiled_program_dispatches_to_engine(rng):
    q = rng.standard_normal((12, 512)).astype(np.float32)
    w = rng.standard_normal((10, 512)).astype(np.float32)
    prog = compile_fn(_dot_sim, [q, w], ArchSpec(rows=64, cols=128))
    assert prog.engine_plan is not None
    before = prog.engine_plan.executions
    v, i = prog(q, w)
    assert prog.engine_plan.executions == before + 1
    iv, ii = prog.execute_interpreted(q, w)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ii))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(iv))
