"""Multi-tenant replicated serving: admission control + failover, live.

Registers two tenants on one :class:`~repro.serving.CamServingGateway`
— a production tenant on two gallery replicas, and a rate-limited
batch tenant sharing the same replica set — then demonstrates the
gateway's three contracts:

1. served results are bit-identical to running the plan directly;
2. a replica killed mid-traffic is transparently failed over, then
   drained, rebuilt onto a fresh device group, and readmitted by the
   maintenance loop;
3. the batch tenant's flood is shed by ITS OWN admission budget while
   the production tenant keeps serving.

    PYTHONPATH=src python examples/multitenant_serve.py
"""

import time

import numpy as np

from repro.core import ArchSpec, compile_fn
from repro.obs import print_stats
from repro.serving import AdmissionError, CamServingGateway


def knn_kernel(queries, gallery):
    d = queries.unsqueeze(1).sub(gallery).norm(p=2, dim=-1)
    return d.topk(5, largest=False)


def main():
    rng = np.random.default_rng(3)
    n, dim = 1024, 64
    gallery = rng.standard_normal((n, dim)).astype(np.float32)
    prog = compile_fn(knn_kernel, [np.zeros((32, dim), np.float32), gallery],
                      ArchSpec(rows=64, cols=64))
    plan = prog.engine_plan

    gw = CamServingGateway(maint_ms=10.0)
    gw.register_tenant("prod", prog, gallery, replicas=2, unhealthy_k=2)
    gw.register_tenant("batch", share_with="prod",
                       rate=64.0, burst=64, queue_limit=4,
                       max_outstanding=2)

    q = rng.standard_normal((8, dim)).astype(np.float32)
    values, idx = gw.search("prod", q)
    ev, ei = plan.execute(q, gallery)
    assert np.array_equal(np.asarray(idx), np.asarray(ei))
    print("prod search: bit-identical to the plan oracle")

    # rewrite a few stored rows; the tenant reads its own writes
    new_rows = rng.standard_normal((4, dim)).astype(np.float32)
    gw.update_gallery("prod", [0, 1, 2, 3], new_rows)
    gallery[[0, 1, 2, 3]] = new_rows
    _, idx = gw.search("batch", q)        # shared set sees the update
    _, ei = plan.execute(q, gallery)
    assert np.array_equal(np.asarray(idx), np.asarray(ei))
    print("update_gallery: read-your-writes across the shared replica set")

    # chaos: lose a device group mid-traffic
    gw.kill_replica("prod", 0)
    for _ in range(20):
        _, idx = gw.search("prod", q)
        assert np.array_equal(np.asarray(idx), np.asarray(ei))
    for _ in range(500):
        reps = gw.health()["tenants"]["prod"]["replicas"]["replicas"]
        if all(r["state"] == "serving" for r in reps) and \
                any(r["rebuilds"] > 0 for r in reps):
            break
        time.sleep(0.01)
    print("replica kill: failed over, rebuilt as",
          [f"{r['device_group']} ({r['state']})" for r in reps])

    # the batch tenant exhausts its own budget, not prod's
    shed = served = 0
    for _ in range(50):
        try:
            gw.submit("batch", q)
            served += 1
        except AdmissionError:
            shed += 1
    _, idx = gw.search("prod", q)
    assert np.array_equal(np.asarray(idx), np.asarray(ei))
    print(f"admission: batch served={served} rejected={shed}; "
          f"prod unaffected")

    health = gw.health()
    print_stats({t: {"stats": e["stats"],
                     "latency": e["latency"],
                     "replicas": [r["state"]
                                  for r in e["replicas"]["replicas"]]}
                 for t, e in health["tenants"].items()},
                title="gateway health")
    gw.stop()
    print("MULTITENANT-OK")


if __name__ == "__main__":
    main()
