"""One-shot learning with TCAM wildcards (ternary packed search, served).

The classic TCAM one-shot-learning recipe (cf. analog CAM few-shot
work): store one ternary row per class — cells where the few exemplars
*agree* keep their bit and are compared; cells where they *disagree*
become "don't care" wildcards that never mismatch.  A query then
matches the class whose *stable* bits it satisfies best, so a handful
of exemplars per class generalises without any training.

This demo builds that gallery from 3 exemplars/class of noisy binary
prototypes, compiles a ternary ``cim.similarity`` program (the care
mask is a third operand), and serves concurrent classification requests
through :class:`CamSearchServer` — the plan executes bit-packed
(``popcount((q ^ p) & care)`` over uint32 lanes) and the snapshot shows
``packed: true, ternary: true``.

    PYTHONPATH=src python examples/tcam_wildcard.py
"""

import json
import threading

import numpy as np

from repro.core import (ArchSpec, Builder, Module, PassManager, TensorType,
                        get_plan)
from repro.core.cim_dialect import (make_acquire, make_execute, make_release,
                                    make_similarity, make_yield)
from repro.core.passes import CompulsoryPartition
from repro.serving import CamSearchServer

N_CLASSES = 16
DIM = 512
EXEMPLARS = 3          # one-shot-ish: a handful of examples per class
NOISE = 0.05           # per-bit flip probability
N_QUERIES = 256


def ternary_program(m, n, dim, k, arch):
    """cim IR for a TCAM wildcard search: similarity(q, p, care)."""
    mod = Module("one_shot_tcam",
                 [TensorType((m, dim)), TensorType((n, dim)),
                  TensorType((n, dim), "i8")])
    q, p, c = mod.arguments
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, [q, p, c],
                       [TensorType((m, k)), TensorType((m, k), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, q, p, metric="hamming", k=k, largest=False,
                          care=c, extra_attrs={"value_bits": 1})
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition())
    return pm.run(mod, {"arch": arch})


def learn_ternary_rows(rng):
    """One ternary (pattern, care) row per class from a few exemplars."""
    protos = (rng.random((N_CLASSES, DIM)) > 0.5).astype(np.float32)
    flips = rng.random((N_CLASSES, EXEMPLARS, DIM)) < NOISE
    exemplars = np.abs(protos[:, None, :] - flips.astype(np.float32))
    patterns = exemplars[:, 0, :]                       # any exemplar's bits
    care = (exemplars.min(1) == exemplars.max(1))       # all agree -> compare
    return protos, patterns, care.astype(np.int8)


def main():
    rng = np.random.default_rng(0)
    protos, patterns, care = learn_ternary_rows(rng)
    wild = 1.0 - care.mean()
    print(f"gallery: {N_CLASSES} ternary rows x {DIM} cells, "
          f"{100 * wild:.1f}% wildcards")

    mod = ternary_program(64, N_CLASSES, DIM, 1, ArchSpec(rows=32, cols=64))
    plan = get_plan(mod)
    print(f"plan: packed={plan.packed} batch={plan.batch} "
          f"grid={plan.spec.grid_rows}x{plan.spec.grid_cols}")

    labels = rng.integers(0, N_CLASSES, N_QUERIES)
    flips = rng.random((N_QUERIES, DIM)) < NOISE
    queries = np.abs(protos[labels] - flips.astype(np.float32))

    n_clients = 4
    slices = np.array_split(np.arange(N_QUERIES), n_clients)
    preds = {}
    with CamSearchServer(plan, patterns, care_mask=care,
                         max_wait_ms=2.0) as srv:
        def client(cid):
            _, idx = srv.search(queries[slices[cid]])
            preds[cid] = np.asarray(idx)[:, 0]

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        snap = srv.snapshot()

    pred = np.concatenate([preds[c] for c in range(n_clients)])
    acc = float((pred == labels).mean())
    print(f"one-shot TCAM accuracy ({EXEMPLARS} exemplars/class, "
          f"{100 * NOISE:.0f}% bit noise): {acc:.3f}")
    print(json.dumps(snap, indent=1, default=str))


if __name__ == "__main__":
    main()
