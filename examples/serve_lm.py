"""Serving example: batched requests against a small LM with prefill +
continuous-batched decode (the serve path lowered by the decode_32k /
long_500k dry-run shapes).

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b
"""

import argparse
import json

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import Request, Server
from repro.models import model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b",
                    help="any assigned arch id (reduced config)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, params, batch=args.batch,
                 max_len=args.prompt_len + args.max_new + 1,
                 temperature=args.temperature)

    rng = np.random.default_rng(0)
    for r in range(args.requests):
        srv.submit(Request(rid=r,
                           prompt=rng.integers(1, cfg.vocab,
                                               args.prompt_len),
                           max_new=args.max_new))
    out = srv.run()
    print(json.dumps(out, indent=1))
    assert out["completed"] == args.requests


if __name__ == "__main__":
    main()
