"""Design-space exploration — the paper's headline capability: explore
CAM architectures "without any application recoding effort" (§IV-C).

One application (HDC similarity), a grid of architectures (cell type x
subarray geometry x optimization mode), one table: latency / energy /
power / subarrays / banks per design point, plus the Pareto frontier on
(latency, power).

    PYTHONPATH=src python examples/dse_sweep.py
"""

import itertools

from repro.core import ArchSpec, CamType, OptimizationTarget, compile_fn


def hdc_kernel(inp, weight):
    others = weight.transpose(-2, -1)
    mm = inp.matmul(others)
    return mm.topk(1, largest=False)


def main():
    m, n, dim = 10_000, 10, 8192
    points = []
    for (size, cam, target) in itertools.product(
            (16, 32, 64, 128), (CamType.TCAM, CamType.ACAM),
            OptimizationTarget.ALL):
        arch = ArchSpec(rows=size, cols=size, cam_type=cam
                        ).with_target(target)
        prog = compile_fn(hdc_kernel, [(m, dim), (n, dim)], arch,
                          cam_type=cam, value_bits=1, unroll_limit=0)
        rep = prog.cost_report()
        plan = prog.plans[0]
        points.append({
            "design": f"{cam}-{size}x{size}-{target}",
            "latency_us": rep.latency_us, "energy_uj": rep.energy_uj,
            "power_w": rep.power_w, "subarrays": plan.physical_subarrays,
            "banks": plan.banks_used,
        })

    print(f"{'design':34s} {'lat_us':>9s} {'e_uJ':>8s} {'P_W':>8s} "
          f"{'subarr':>7s} {'banks':>6s}")
    for p in points:
        print(f"{p['design']:34s} {p['latency_us']:9.2f} "
              f"{p['energy_uj']:8.3f} {p['power_w']:8.4f} "
              f"{p['subarrays']:7d} {p['banks']:6d}")

    # Pareto frontier on (latency, power)
    front = [p for p in points
             if not any(q["latency_us"] <= p["latency_us"]
                        and q["power_w"] <= p["power_w"] and q is not p
                        for q in points)]
    front.sort(key=lambda p: p["latency_us"])
    print("\nPareto frontier (latency vs power):")
    for p in front:
        print(f"  {p['design']:34s} {p['latency_us']:9.2f} us "
              f"{p['power_w']:8.4f} W")
    assert len(front) >= 2, "DSE must expose a real trade-off"


if __name__ == "__main__":
    main()
