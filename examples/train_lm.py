"""End-to-end training driver: a ~100M-parameter LM for a few hundred
steps on CPU, exercising the full production stack — data pipeline,
AdamW, checkpointing, failure injection + recovery, straggler monitor,
and (for the MoE variant) the C4CAM-offloaded router.

    PYTHONPATH=src python examples/train_lm.py                 # ~100M xlstm
    PYTHONPATH=src python examples/train_lm.py --moe           # CAM router
    PYTHONPATH=src python examples/train_lm.py --steps 50      # quicker
"""

import argparse
import dataclasses
import json

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.train import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--moe", action="store_true",
                    help="train a reduced deepseek-moe with the C4CAM "
                         "router offload instead of the ~100M xlstm")
    ap.add_argument("--fail-at", type=int, default=120,
                    help="inject a simulated failure at this step "
                         "(recovery is part of the demo); -1 disables")
    args = ap.parse_args()

    if args.moe:
        cfg = dataclasses.replace(get_smoke_config("deepseek-moe-16b"),
                                  d_model=256, d_ff=512, n_layers=4,
                                  router_offload="cam")
        print(f"training reduced deepseek-moe (CAM-offloaded router), "
              f"{cfg.param_count() / 1e6:.1f}M params")
    else:
        # the full xlstm-125m config IS the ~100M model — train it as-is
        cfg = get_config("xlstm-125m")
        print(f"training xlstm-125m, {cfg.param_count() / 1e6:.1f}M params")

    loop = TrainLoop(cfg, batch=args.batch, seq=args.seq, steps=args.steps,
                     lr=1e-3, ckpt_every=50,
                     fail_at=None if args.fail_at < 0 else args.fail_at)
    out = loop.run()

    first = np.mean([h["loss"] for h in loop.history[:10]])
    last = np.mean([h["loss"] for h in loop.history[-10:]])
    print(json.dumps({
        "loss_first10": round(float(first), 4),
        "loss_last10": round(float(last), 4),
        "restarts": out["restarts"],
        "slow_steps_flagged": len(out["slow_steps"]),
        "median_step_s": round(out["median_step_s"], 3),
    }, indent=1))
    assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
