"""Serving KNN search: continuous batching over a (sharded) SearchPlan.

Compiles the paper's KNN workload once, wraps the cached SearchPlan in
the continuous-batching search server, and drives it from concurrent
client threads — the serving-layer analogue of ``examples/knn_search.py``.
With more than one host device the gallery is sharded across the
``("data",)`` mesh (run with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to see it).
The served run is traced (``repro.obs``) and the Chrome-tracing
export is written next to the temp dir — load it in Perfetto or
``chrome://tracing`` to see the batcher/engine pipeline.

    PYTHONPATH=src python examples/serve_knn.py
"""

import os
import tempfile
import threading

import jax
import numpy as np

from repro.core import ArchSpec, compile_fn
from repro.data import knn_dataset
from repro.obs import enable as enable_tracing
from repro.obs import print_stats
from repro.serving import CamSearchServer


def knn_kernel(queries, gallery):
    diff = queries.unsqueeze(1).sub(gallery)     # (Q,1,D) - (N,D)
    dist = diff.norm(p=2, dim=-1)                # (Q,N)
    return dist.topk(5, largest=False)


def main():
    gallery, g_labels, queries, q_labels = knn_dataset(
        n_gallery=8192, dim=256, n_queries=128)
    shards = jax.device_count()

    prog = compile_fn(knn_kernel, [queries[:64], gallery],
                      ArchSpec(rows=64, cols=64), value_bits=8,
                      shards=shards)
    plan = prog.engine_plan
    print(f"plan: batch={plan.batch} shards={plan.shards} "
          f"metric={plan.spec.metric} grid={plan.spec.grid_rows}x"
          f"{plan.spec.grid_cols}")

    # each client classifies a slice of the query set through the server
    n_clients = 4
    slices = np.array_split(np.arange(len(queries)), n_clients)
    preds = {}

    enable_tracing()
    trace_path = os.path.join(tempfile.gettempdir(),
                              "serve_knn_trace.json")
    with CamSearchServer(prog, gallery, max_wait_ms=2.0) as srv:
        def client(cid):
            q = queries[slices[cid]]
            _, idx = srv.search(q)
            votes = g_labels[idx]
            preds[cid] = np.apply_along_axis(
                lambda v: np.bincount(v, minlength=2).argmax(), 1, votes)

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        snap = srv.snapshot()
        srv.dump_trace(trace_path)

    pred = np.concatenate([preds[c] for c in range(n_clients)])
    acc = float((pred == q_labels).mean())
    print(f"5-NN accuracy (served): {acc:.3f}")
    print_stats(snap, title="server snapshot")
    print(f"\ntrace: {trace_path} "
          f"(load in Perfetto / chrome://tracing)")


if __name__ == "__main__":
    main()
