"""The paper's technique inside the LM framework: a DeepSeek-style MoE
router is a ``matmul -> topk`` dataflow — exactly C4CAM's
DotProdSimPattern.  This example:

1. traces the router, shows Algorithm 1 matching it,
2. prices the routing workload on a CAM accelerator vs the GPU model,
3. runs the same router inside a real MoE forward pass with
   ``router_offload="cam"`` and shows routing decisions are identical.

    PYTHONPATH=src python examples/moe_router_offload.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.camsim import QUADRO_RTX_6000
from repro.configs import get_smoke_config
from repro.core import PAPER_BASE_ARCH, compile_fn
from repro.models import moe as moe_mod


def router_kernel(tokens, router_patterns):
    scores = tokens.matmul(router_patterns.transpose(-2, -1))
    return scores.topk(6, largest=True)


def main():
    d_model, n_experts, n_tokens = 2048, 64, 4096

    rng = np.random.default_rng(0)
    x = rng.standard_normal((n_tokens, d_model)).astype(np.float32)
    w = rng.standard_normal((n_experts, d_model)).astype(np.float32)

    # 1. compile the router through C4CAM
    prog = compile_fn(router_kernel, [x, w], PAPER_BASE_ARCH, value_bits=8)
    print("Algorithm 1 match:", prog.matched_patterns)

    # 2. price it: CAM vs GPU-model
    rep = prog.cost_report()
    gpu = QUADRO_RTX_6000.similarity_workload(n_tokens, n_experts, d_model)
    print(f"CAM routing: {rep.latency_us:.1f} us, {rep.energy_uj:.2f} uJ | "
          f"GPU model: {gpu['time_s'] * 1e6:.1f} us, "
          f"{gpu['energy_j'] * 1e6:.1f} uJ")

    # 3. inside the model: deepseek-style MoE block, cam vs dense routing
    cfg_d = dataclasses.replace(get_smoke_config("deepseek-moe-16b"),
                                router_offload="dense")
    cfg_c = dataclasses.replace(cfg_d, router_offload="cam")
    key = jax.random.PRNGKey(1)
    p = moe_mod.init_moe(key, cfg_d)
    xb = jax.random.normal(key, (2, 16, cfg_d.d_model), jnp.float32)
    yd = moe_mod.moe_ffn(p, xb, cfg_d)
    yc = moe_mod.moe_ffn(p, xb, cfg_c)
    same = bool(jnp.allclose(yd.astype(jnp.float32), yc.astype(jnp.float32),
                             atol=1e-2))
    print(f"MoE outputs identical (cam vs dense routing): {same}")
    assert same and prog.matched_patterns == ["DotProdSimPattern"]


if __name__ == "__main__":
    main()
