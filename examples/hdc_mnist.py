"""End-to-end HDC on CAM: encode -> train -> retrain online -> serve.

The paper's flagship workload (Figs. 8/9, GPU comparison) run as a real
pipeline instead of a traced matmul stand-in:

* **encode** — MNIST-shaped samples quantised and encoded into bipolar
  hypervectors (record-based item/level memories, `repro.hdc.encoding`);
* **train** — one-shot: encodings bundled into per-class associative-
  memory accumulators;
* **classify** — the AM served through the compiled similarity stack
  (``cim.similarity`` dot/k=1 -> packed XOR+popcount ``SearchPlan``;
  bipolar argmax-dot == argmin-hamming);
* **retrain online** — perceptron epochs *against the live server*:
  misclassified encodings are re-bundled, and only the touched class
  rows are pushed through ``CamSearchServer.update_gallery`` (the
  engine's incremental ``update_rows`` path) while concurrent client
  traffic keeps hitting the same plan;
* **parity** — single-device, sharded (8 forced host devices), and
  served predictions are asserted bit-identical, and the engine is
  checked against the IR interpreter and a dense numpy oracle.

    PYTHONPATH=src python examples/hdc_mnist.py
"""

import os
import re

# the sharded leg needs a multi-device host; device count is fixed at
# jax import, so force it before anything imports jax
DEVICES = 8
_flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = " ".join(
    _flags.split() + [f"--xla_force_host_platform_device_count={DEVICES}"])

import json                                                   # noqa: E402
import threading                                              # noqa: E402

import numpy as np                                            # noqa: E402

from repro.core.arch import ArchSpec                          # noqa: E402
from repro.core.engine import get_plan                        # noqa: E402
from repro.data import hdc_mnist_dataset                      # noqa: E402
from repro.hdc import HdcClassifier                           # noqa: E402
from repro.serving import CamSearchServer                     # noqa: E402

N_CLASSES = 10
HV_DIM = 2048
N_LEVELS = 16
EPOCHS = 6
TRAFFIC_CLIENTS = 3


def main():
    train_x, train_y, test_x, test_y = hdc_mnist_dataset()
    clf = HdcClassifier(train_x.shape[1], N_CLASSES, dim=HV_DIM,
                        n_levels=N_LEVELS, seed=0)
    clf.fit(train_x, train_y)
    clf.compile(ArchSpec(rows=8, cols=128), batch_hint=128)
    print("hdc:", json.dumps(clf.summary(), default=str))
    assert clf.plan.packed, "bipolar AM should ride the packed fast path"

    enc_tr = clf.encode(train_x)
    enc_te = clf.encode(test_x)
    pred0 = clf.predict(encoded=enc_te)
    assert np.array_equal(pred0, clf.predict_interpreted(encoded=enc_te)), \
        "engine diverged from the IR interpreter"
    assert np.array_equal(pred0, clf.predict_reference(encoded=enc_te)), \
        "engine diverged from the dense numpy oracle"
    acc0 = float((pred0 == test_y).mean())
    print(f"one-shot HDC: test acc {acc0:.3f} "
          f"(engine == interpreter == oracle)")

    # ---- retrain ONLINE through the served gallery -------------------
    stop = threading.Event()
    traffic_errors = []

    def traffic(srv):
        """Background clients keep searching while retraining mutates
        the gallery between micro-batches."""
        rng = np.random.default_rng(17)
        while not stop.is_set():
            rows = enc_te[rng.integers(0, len(enc_te), size=4)]
            try:
                srv.search(rows, timeout=60)
            except Exception as e:             # noqa: BLE001
                traffic_errors.append(e)
                return

    pushed_total = 0
    with CamSearchServer(clf.plan, clf.gallery, max_wait_ms=1.0) as srv:
        threads = [threading.Thread(target=traffic, args=(srv,))
                   for _ in range(TRAFFIC_CLIENTS)]
        for t in threads:
            t.start()
        for ep in range(EPOCHS):
            train_acc, pushed = clf.retrain_epoch(train_x, train_y,
                                                  encoded=enc_tr, server=srv)
            pushed_total += pushed
            print(f"  epoch {ep}: train acc {train_acc:.3f}, "
                  f"{pushed} AM rows pushed live")
        stop.set()
        for t in threads:
            t.join()
        _, idx = srv.search(enc_te)
        served = np.asarray(idx)[:, 0].astype(np.int32)
        snap = srv.snapshot()
    assert not traffic_errors, traffic_errors[:1]
    assert pushed_total > 0, "retraining never updated the gallery"
    # one live update per epoch that still had misclassifications
    # (convergence legitimately stops pushing)
    assert snap["gallery_updates"] >= 1
    assert snap["rows_updated"] == pushed_total
    assert snap["plan"]["row_update_fallbacks"] == 0, \
        "gallery updates fell back to full re-prepare"
    accN = float((served == test_y).mean())
    print(f"retrained online: test acc {acc0:.3f} -> {accN:.3f} "
          f"({snap['gallery_updates']} live updates, "
          f"{snap['rows_updated']} rows, "
          f"{snap['queries']} served queries, "
          f"p50={snap.get('p50_ms', 0):.2f}ms)")
    assert accN >= acc0, "retraining should not lose accuracy here"

    # ---- single-device vs sharded vs served: bit-identical -----------
    single = clf.predict(encoded=enc_te)
    assert np.array_equal(single, served), "served predictions diverged"
    am = clf.am()
    splan = get_plan(clf.stages["cim_partitioned"], shards=DEVICES)
    assert splan.shards == DEVICES, splan.shards
    _, sidx = splan.execute(enc_te, am)
    sharded = np.asarray(sidx)[:, 0].astype(np.int32)
    assert np.array_equal(single, sharded), "sharded predictions diverged"
    assert np.array_equal(single, clf.predict_reference(encoded=enc_te))
    print(f"single-device, sharded ({DEVICES} devices), and served "
          f"predictions bit-identical")
    print("HDC-OK")


if __name__ == "__main__":
    main()
