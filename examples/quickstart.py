"""Quickstart: compile a TorchScript-like similarity kernel to a CAM
accelerator with C4CAM, inspect every IR stage, execute it functionally,
and read the latency/energy/power report.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (CamType, OptimizationTarget, PAPER_BASE_ARCH,
                        compile_fn)
from repro.data import hdc_dataset


# 1. A PyTorch-style similarity kernel (the paper's Fig. 4a HDC example):
#    best-match = largest dot-product similarity.
def hdc_similarity(queries, class_hvs):
    others = class_hvs.transpose(-2, -1)
    scores = queries.matmul(others)
    values, indices = scores.topk(1, largest=True)
    return values, indices


def main():
    # 2. A workload: 8192-d hypervectors, 10 classes, noisy recall queries.
    classes, queries, labels = hdc_dataset(n_classes=10, dim=8192,
                                           n_queries=64)

    # 3. Compile for the paper's base architecture (32x32 subarrays,
    #    8 subarrays/array, 4 arrays/mat, 4 mats/bank).
    prog = compile_fn(hdc_similarity, [queries, classes], PAPER_BASE_ARCH,
                      cam_type=CamType.TCAM, value_bits=1)

    print("pattern matched by Algorithm 1:", prog.matched_patterns)
    print("\n--- torch dialect ---")
    print(prog.dump("torch"))
    print("\n--- cim dialect (fused) ---")
    print(prog.dump("cim_fused"))
    print("\n--- cam dialect (mapped, excerpt) ---")
    print(prog.dump("cam_mapped")[:900], "…")

    # 4. Execute functionally (JAX simulation of the CAM search).
    values, indices = prog(queries, classes)
    acc = float((np.asarray(indices).ravel() == labels).mean())
    print(f"\nrecall accuracy vs labels: {acc:.3f}")

    # 5. Cost report from the Eva-CAM-analog model.
    rep = prog.cost_report()
    print(f"latency {rep.latency_us:.2f} us | energy {rep.energy_uj:.3f} uJ "
          f"| power {rep.power_w:.2f} W")

    # 6. One-knob design-space exploration: optimization targets.
    for target in OptimizationTarget.ALL:
        r = compile_fn(hdc_similarity, [queries, classes],
                       PAPER_BASE_ARCH.with_target(target),
                       value_bits=1).cost_report()
        print(f"  target={target:14s} latency={r.latency_us:9.2f} us "
              f"power={r.power_w:7.3f} W energy={r.energy_uj:8.3f} uJ")


if __name__ == "__main__":
    main()
