"""KNN classification through the C4CAM pipeline (the paper's second
benchmark): Euclidean-distance top-k search on a CAM accelerator, with the
Pallas TPU kernel as the execution backend.

    PYTHONPATH=src python examples/knn_search.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import ArchSpec, CamType, compile_fn
from repro.data import knn_dataset
from repro.kernels import ops as kops


def knn_kernel(queries, gallery):
    diff = queries.unsqueeze(1).sub(gallery)     # (Q,1,D) - (N,D)
    dist = diff.norm(p=2, dim=-1)                # (Q,N)
    return dist.topk(5, largest=False)


def main():
    gallery, g_labels, queries, q_labels = knn_dataset(
        n_gallery=8192, dim=256, n_queries=128)

    # --- compile to an ACAM (analog CAM: native Euclidean search) -------
    arch = ArchSpec(rows=64, cols=64, cam_type=CamType.ACAM)
    prog = compile_fn(knn_kernel, [queries, gallery], arch,
                      cam_type=CamType.ACAM, value_bits=8)
    print("pattern:", prog.matched_patterns)
    values, indices = prog(queries, gallery)

    # --- classify by majority vote over the top-5 ------------------------
    votes = g_labels[np.asarray(indices)]
    pred = np.apply_along_axis(lambda v: np.bincount(v, minlength=2).argmax(),
                               1, votes)
    acc = float((pred == q_labels).mean())
    print(f"5-NN accuracy (CAM pipeline): {acc:.3f}")

    # --- same search on the Pallas TPU kernel (interpret mode on CPU) ---
    v2, i2 = kops.cam_topk(jnp.asarray(queries), jnp.asarray(gallery),
                           metric="eucl", k=5, largest=False,
                           tile_rows=64, dims_per_tile=64)
    agree = float((np.asarray(i2) == np.asarray(indices)).mean())
    print(f"Pallas kernel agreement with compiled CAM result: {agree:.3f}")

    rep = prog.cost_report()
    print(f"modelled: {rep.latency_us:.1f} us, {rep.energy_uj:.2f} uJ, "
          f"{rep.power_w:.2f} W on "
          f"{prog.plans[0].banks_used} bank(s)")
    assert acc > 0.9 and agree > 0.99


if __name__ == "__main__":
    main()
