"""In-memory decision-forest inference on an analog CAM.

The flagship non-KNN CAM workload (Pedretti et al., *Tree-based machine
learning performed in-memory with memristive analog CAM*): every
root-to-leaf branch of a tree ensemble becomes one aCAM row of
``[lo, hi]`` feature intervals — features the path never tests stay
full-range wildcards — and classifying a sample is a single interval
range search (one match line per branch) plus a majority class vote.

This demo compiles a 64-tree ensemble through the C4CAM pipeline
(partition -> cim-to-cam @ ACAM -> cam-map) and runs inference through
the engine's ``RangePlan``:

* single-device, predictions checked bit-for-bit against both the IR
  interpreter and plain tree traversal,
* sharded over 8 forced host devices (the gallery's interval rows split
  at bank granularity; per-shard boolean matches concatenate),
* served concurrently through ``CamSearchServer`` (range/forest request
  type),
* with the camsim aCAM latency/energy report for the mapping.

    PYTHONPATH=src python examples/forest_inference.py
"""

import os
import re

# the sharded leg needs a multi-device host; device count is fixed at
# jax import, so force it before anything imports jax
DEVICES = 8
_flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = " ".join(
    _flags.split() + [f"--xla_force_host_platform_device_count={DEVICES}"])

import json                                                   # noqa: E402
import threading                                              # noqa: E402

import numpy as np                                            # noqa: E402

from repro.core.arch import ArchSpec, CamType                 # noqa: E402
from repro.forest import CamForestClassifier, random_forest   # noqa: E402
from repro.serving import CamSearchServer                     # noqa: E402

N_TREES = 64
DEPTH = 5
DIM = 32
N_CLASSES = 8
N_QUERIES = 512


def main():
    rng = np.random.default_rng(0)
    trees = random_forest(rng, n_trees=N_TREES, dim=DIM, depth=DEPTH,
                          n_classes=N_CLASSES, feature_frac=0.5)
    arch = ArchSpec(rows=64, cols=64, cam_type=CamType.ACAM)
    clf = CamForestClassifier(trees, dim=DIM).compile(arch, batch_hint=128)
    print("forest:", json.dumps(clf.summary(), default=str))

    x = rng.standard_normal((N_QUERIES, DIM)).astype(np.float32)
    pred = clf.predict(x)
    assert np.array_equal(pred, clf.predict_interpreted(x)), \
        "engine diverged from the IR interpreter"
    assert np.array_equal(pred, clf.predict_reference(x)), \
        "engine diverged from tree traversal"
    print(f"single-device RangePlan: {N_QUERIES} samples, predictions "
          f"bit-identical to interpreter + traversal oracle "
          f"({100 * clf.intervals.wildcard_frac:.1f}% wildcard cells)")

    # ---- sharded: interval rows split over the 8-device mesh ---------
    sclf = CamForestClassifier(trees, dim=DIM).compile(
        arch, batch_hint=128, shards=DEVICES)
    assert sclf.plan.shards == DEVICES, sclf.plan.shards
    assert np.array_equal(sclf.predict(x), pred), \
        "sharded predictions diverged"
    print(f"sharded RangePlan ({DEVICES} devices): bit-identical")

    # ---- served: concurrent clients against one shared RangePlan -----
    n_clients = 4
    slices = np.array_split(np.arange(N_QUERIES), n_clients)
    preds = {}
    with CamSearchServer(clf.plan, (clf.intervals.lo, clf.intervals.hi),
                         max_wait_ms=2.0) as srv:
        def client(cid):
            from repro.forest import vote
            matches = srv.match(x[slices[cid]])
            preds[cid] = vote(matches, clf.intervals.leaf_class,
                              clf.intervals.n_classes)

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        snap = srv.snapshot()
    served = np.concatenate([preds[c] for c in range(n_clients)])
    assert np.array_equal(served, pred), "served predictions diverged"
    print(f"served ({n_clients} clients): bit-identical; "
          f"p50={snap.get('p50_ms', 0):.2f}ms "
          f"batches={snap['batches']} fill={snap['avg_batch_fill']:.1f}")

    rep = clf.cost_report()
    print(f"camsim aCAM mapping: latency {rep.latency_us:.2f}us, "
          f"energy {rep.energy_uj:.3f}uJ, "
          f"{clf.mapping_plans[0].physical_subarrays} subarrays, "
          f"search_type={clf.mapping_plans[0].search_type}")
    print("FOREST-OK")


if __name__ == "__main__":
    main()
