"""Fault-injection benchmark: accuracy under device faults + resilient serving.

Three experiments, all fully deterministic in the fault model's seed:

* **forest** — the aCAM decision-forest workload under stuck-cell /
  bit-flip faults, unhardened ``RangePlan`` vs ``HardenedPlan``
  (3x replication + checksum-readback healing onto spare rows).
* **hdc** — the packed-hamming HDC associative memory under the same
  fault family (prototype rows replicated, median-score de-dup).
* **serving** — a ``CamSearchServer`` driven through transient backend
  outages: the resilient config (retries + breaker + degraded fallback)
  must complete 100% of non-timed-out requests, the unprotected config
  shows visible failures on the same fault schedule.

An aCAM guard-band side-table records the miss/false-match trade under
sigma-noise (guard widening recovers misses at the cost of extra
matches) — see docs/robustness.md for why guards are *not* part of the
digital-fault accuracy gate.

Writes ``BENCH_faults.json``.  Gate (``REPRO_FAULTS_GATE``, auto ->
0.9, ``0``/``off`` disables): at the sweep point where the unhardened
accuracy drops >= 10 points, hardened accuracy must stay >= gate x
clean accuracy — for *both* workloads — and the resilient server must
complete every non-timed-out request while the unprotected one fails
at least once.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import clear_plan_cache
from repro.core.arch import ArchSpec, CamType
from repro.core.envcfg import env_gate
from repro.faults import FaultModel, HardenedPlan
from repro.forest import CamForestClassifier, random_forest, vote
from repro.hdc import HdcClassifier

from .common import banner, save_bench_json, table

#: fault-rate sweeps (p_stuck; p_flip rides along at p/2).  Forest rows
#: are short conjunctions (one dead cell kills a branch) so they break
#: at rates an HDC hypervector shrugs off — each workload gets the
#: sweep that brackets its own 10-point accuracy cliff.
FOREST_RATES = (0.002, 0.005, 0.01)
HDC_RATES = (0.01, 0.02, 0.05)
REPLICAS = 3
SEED = 1


def _gate() -> float:
    return env_gate("REPRO_FAULTS_GATE", 0.9)


def _model(p: float) -> FaultModel:
    return FaultModel(seed=SEED, p_stuck=p, p_flip=p / 2)


def _sweep_forest():
    rng = np.random.default_rng(0)
    n_trees, depth, dim, m = 48, 5, 24, 256
    trees = random_forest(rng, n_trees=n_trees, dim=dim, depth=depth,
                          n_classes=8, feature_frac=0.5)
    arch = ArchSpec(rows=64, cols=64, cam_type=CamType.ACAM)
    clf = CamForestClassifier(trees, dim=dim).compile(arch, batch_hint=m)
    x = rng.standard_normal((m, dim)).astype(np.float32)
    iv = clf.intervals
    labels = clf.predict_reference(x)
    clean = float((clf.predict(x) == labels).mean())

    points = []
    for p in FOREST_RATES:
        fm = _model(p)
        match_u = np.asarray(clf.plan.execute(x, iv.lo, iv.hi, faults=fm))
        acc_u = float((vote(match_u, iv.leaf_class, iv.n_classes)
                       == labels).mean())
        hp = HardenedPlan(clf.plan, replicas=REPLICAS, spares=256)
        hp.prepare(iv.lo, iv.hi)
        rep = hp.heal(fm)
        match_h = np.asarray(hp.execute(x, faults=fm))
        acc_h = float((vote(match_h, iv.leaf_class, iv.n_classes)
                       == labels).mean())
        points.append({"p": p, "unhardened": acc_u, "hardened": acc_h,
                       "detected": rep.detected, "remapped": rep.remapped,
                       "unrepairable": rep.unrepairable})
    return {"workload": {"n_trees": n_trees, "depth": depth, "dim": dim,
                         "m": m, "rows": iv.n_rows,
                         "replicas": REPLICAS, "spares": 256},
            "clean": clean, "points": points}


def _guard_table(clf, x):
    """Sigma-noise miss/false-match trade for aCAM guard bands."""
    iv = clf.intervals
    clean = np.asarray(clf.plan.execute(x, iv.lo, iv.hi))
    fm = FaultModel(seed=SEED, sigma=0.02)
    rows = []
    for z in (0.0, 2.0, 4.0):
        hp = HardenedPlan(clf.plan, replicas=1, spares=0,
                          guard=fm.suggest_guard(z=z))
        hp.prepare(iv.lo, iv.hi)
        got = np.asarray(hp.execute(x, faults=fm))
        miss = float((clean & ~got).sum() / max(1, clean.sum()))
        false = float((~clean & got).sum() / max(1, (~clean).sum()))
        rows.append({"guard_z": z, "miss_rate": round(miss, 4),
                     "false_match_rate": round(false, 5)})
    return rows


def _sweep_hdc():
    rng = np.random.default_rng(1)
    n_feat, n_classes, dim = 32, 8, 256
    means = rng.standard_normal((n_classes, n_feat))
    def blobs(n):
        y = rng.integers(0, n_classes, n)
        xx = means[y] + 0.45 * rng.standard_normal((n, n_feat))
        return xx.astype(np.float32), y
    xtr, ytr = blobs(512)
    xte, yte = blobs(256)
    clf = HdcClassifier(n_feat, n_classes, dim=dim, n_levels=8,
                        lo=float(xtr.min()), hi=float(xtr.max()), seed=0)
    clf.fit(xtr, ytr)
    clf.compile(batch_hint=64)
    clean = float((clf.predict(xte) == yte).mean())
    enc = clf.encode(xte)

    points = []
    for p in HDC_RATES:
        fm = _model(p)
        _, idx = clf.plan.execute(enc, clf._gallery, faults=fm)
        acc_u = float((np.asarray(idx)[:, 0] == yte).mean())
        hp = HardenedPlan(clf.plan, replicas=REPLICAS, spares=4)
        hp.prepare(clf._gallery)
        rep = hp.heal(fm)
        _, hidx = hp.execute(enc, faults=fm)
        acc_h = float((np.asarray(hidx)[:, 0] == yte).mean())
        points.append({"p": p, "unhardened": acc_u, "hardened": acc_h,
                       "detected": rep.detected, "remapped": rep.remapped,
                       "unrepairable": rep.unrepairable})
    return {"workload": {"n_features": n_feat, "n_classes": n_classes,
                         "dim": dim, "test": len(yte),
                         "replicas": REPLICAS, "spares": 4},
            "clean": clean, "points": points}


class _Outage:
    """Time-windowed backend outage: every dispatch attempt on any
    level raises while an outage window is open."""

    def __init__(self):
        self.until = 0.0
        self.injected = 0

    def open_window(self, seconds: float) -> None:
        self.until = time.perf_counter() + seconds

    def __call__(self, level: str) -> None:
        if time.perf_counter() < self.until:
            self.injected += 1
            raise RuntimeError(f"injected outage ({level})")


def _serve_workload(protected: bool):
    from repro.core import get_plan
    from repro.core.cim_dialect import (make_acquire, make_execute,
                                        make_release, make_similarity,
                                        make_yield)
    from repro.core.ir import Builder, Module, PassManager, TensorType
    from repro.core.passes import CompulsoryPartition
    from repro.serving import CamSearchServer

    rng = np.random.default_rng(2)
    m, n, dim, k = 8, 128, 64, 4
    mod = Module("faults_serve", [TensorType((m, dim)), TensorType((n, dim))])
    q_arg, p_arg = mod.arguments
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, [q_arg, p_arg],
                       [TensorType((m, k)), TensorType((m, k), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, q_arg, p_arg, metric="eucl", k=k,
                          largest=False)
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    PassManager().add(CompulsoryPartition()).run(
        mod, {"arch": ArchSpec(rows=32, cols=64)})
    plan = get_plan(mod)

    gallery = rng.standard_normal((n, dim)).astype(np.float32)
    queries = [rng.standard_normal((2, dim)).astype(np.float32)
               for _ in range(24)]
    outage = _Outage()
    kw = dict(max_wait_ms=1.0, fault_injector=outage)
    if protected:
        kw.update(max_retries=3, retry_backoff_ms=10.0,
                  breaker_threshold=2, breaker_cooldown_ms=30.0)
    else:
        kw.update(max_retries=0, breaker_threshold=0)
    srv = CamSearchServer(plan, gallery, **kw)
    if not protected:
        # the unprotected baseline really is unprotected: no retries,
        # no breaker, and no degraded chain to hide behind
        srv._fallbacks = []
    completed = failed = timed_out = 0
    with srv:
        reqs = []
        for i, q in enumerate(queries):
            if i % 8 == 0:
                outage.open_window(0.008)
            reqs.append(srv.submit(q))
            time.sleep(0.003)
        for r in reqs:
            res = r.wait(timeout=120)
            if res.error is None:
                completed += 1
            elif isinstance(res.error, TimeoutError):
                timed_out += 1
            else:
                failed += 1
        health = srv.health()
    return {"requests": len(queries), "completed": completed,
            "failed": failed, "timed_out": timed_out,
            "injected_faults": outage.injected,
            "breaker_trips": health["breaker"]["trips"],
            "retries": health["retries"],
            "degraded_batches": health["degraded_batches"],
            "status": health["status"]}


def _gate_point(sweep):
    """First sweep point where unhardened accuracy fell >= 10 points."""
    for pt in sweep["points"]:
        if sweep["clean"] - pt["unhardened"] >= 0.10:
            return pt
    return None


def run():
    banner("Fault injection — accuracy under device faults + resilient "
           "serving")
    clear_plan_cache()

    forest = _sweep_forest()
    hdc = _sweep_hdc()
    for name, sweep in (("forest", forest), ("hdc", hdc)):
        rows = [{"workload": name, "p": pt["p"],
                 "clean": sweep["clean"], "unhardened": pt["unhardened"],
                 "hardened": pt["hardened"], "detected": pt["detected"],
                 "remapped": pt["remapped"]} for pt in sweep["points"]]
        print(table(rows))

    # guard-band side table (sigma noise, forest interval rows)
    rng = np.random.default_rng(0)
    trees = random_forest(rng, n_trees=16, dim=16, depth=4, n_classes=4,
                          feature_frac=0.5)
    gclf = CamForestClassifier(trees, dim=16).compile(
        ArchSpec(rows=64, cols=64, cam_type=CamType.ACAM), batch_hint=64)
    gx = rng.standard_normal((64, 16)).astype(np.float32)
    guard_rows = _guard_table(gclf, gx)
    print(table(guard_rows))

    serve_protected = _serve_workload(protected=True)
    serve_unprotected = _serve_workload(protected=False)
    print(table([dict(config="resilient", **serve_protected),
                 dict(config="unprotected", **serve_unprotected)],
                cols=["config", "requests", "completed", "failed",
                      "timed_out", "injected_faults", "retries",
                      "breaker_trips", "degraded_batches"]))

    gate = _gate()
    fpt, hpt = _gate_point(forest), _gate_point(hdc)
    payload = {
        "gate": gate,
        "forest": forest,
        "hdc": hdc,
        "guard_bands": {"sigma": 0.02, "rows": guard_rows},
        "serving": {"resilient": serve_protected,
                    "unprotected": serve_unprotected},
        "gate_points": {
            "forest": None if fpt is None else fpt["p"],
            "hdc": None if hpt is None else hpt["p"],
        },
    }
    save_bench_json("faults", payload)

    if gate:
        for name, sweep, pt in (("forest", forest, fpt), ("hdc", hdc, hpt)):
            assert pt is not None, (
                f"{name}: no sweep point dropped >= 10 accuracy points "
                f"unhardened — the sweep no longer exercises the fault "
                f"cliff; see BENCH_faults.json")
            assert pt["hardened"] >= gate * sweep["clean"], (
                f"{name}: hardened accuracy {pt['hardened']:.3f} at "
                f"p={pt['p']} fell below {gate} x clean "
                f"({sweep['clean']:.3f}); see BENCH_faults.json")
        sp, su = serve_protected, serve_unprotected
        assert sp["completed"] + sp["timed_out"] == sp["requests"], (
            f"resilient server failed {sp['failed']} requests under "
            f"transient faults; see BENCH_faults.json")
        assert su["failed"] > 0, (
            "unprotected server showed no failures — the outage "
            "schedule no longer exercises the fault path")
    return payload


if __name__ == "__main__":
    run()
