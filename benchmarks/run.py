"""Run the full benchmark suite: every paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig8 knn   # substring filter

Each suite additionally writes a ``BENCH_<name>.json`` timing record at
the repo root — wall-clock plus the search-plan-cache hit/miss deltas —
so future perf PRs have a measured baseline to compare against.
"""

from __future__ import annotations

import sys
import time
import traceback

from repro.core import plan_cache_stats

from . import (bench_engine, bench_faults, bench_forest, bench_hdc,
               bench_hier, bench_multitenant, bench_packed, bench_serve,
               bench_trace, bench_tune, fig7_validation, fig8_dse,
               fig9_isocapacity, gpu_comparison, report_roofline,
               roofline_table, table1_density, table2_knn)
from .common import banner, save_bench_json

SUITES = [
    ("fig7_validation", fig7_validation.run),
    ("fig8_dse", fig8_dse.run),
    ("table1_density", table1_density.run),
    ("table2_knn", table2_knn.run),
    ("fig9_isocapacity", fig9_isocapacity.run),
    ("gpu_comparison", gpu_comparison.run),
    ("roofline_table", roofline_table.run),
    # writes the detailed BENCH_engine.json itself; the generic record
    # for this suite lands in BENCH_engine_smoke.json
    ("engine_smoke", bench_engine.run),
    # packed XOR+popcount vs float hamming plans; detailed record in
    # BENCH_packed.json (gate REPRO_PACKED_GATE, auto = 4x at dim 1024)
    ("packed_smoke", bench_packed.run),
    # single- vs multi-device serving (subprocesses with their own
    # XLA_FLAGS); detailed record in BENCH_serve.json
    ("serve_smoke", bench_serve.run),
    # decision-forest aCAM range path vs interpreter oracle; detailed
    # record in BENCH_forest.json (gate REPRO_FOREST_GATE, auto = 2x)
    ("forest_smoke", bench_forest.run),
    # incremental update_rows vs full gallery re-prepare + HDC retrain
    # record; detailed record in BENCH_hdc.json (REPRO_HDC_GATE, auto = 3x)
    ("hdc_smoke", bench_hdc.run),
    # accuracy under injected device faults (unhardened vs HardenedPlan)
    # + resilient serving through transient outages; detailed record in
    # BENCH_faults.json (gate REPRO_FAULTS_GATE, auto = 0.9x clean)
    ("faults_smoke", bench_faults.run),
    # hierarchical coarse->fine probing vs the flat oracle at a 131k-row
    # packed gallery; detailed record in BENCH_hier.json (gate
    # REPRO_HIER_GATE, auto = 3x at the tuned recall>=0.95 nprobe)
    ("hier_smoke", bench_hier.run),
    # multi-tenant gateway: hot-tenant isolation (admission control vs a
    # naive shared server) + replica-kill failover; detailed record in
    # BENCH_multitenant.json (gate REPRO_MULTITENANT_GATE, auto = 2x
    # isolation factor)
    ("multitenant_smoke", bench_multitenant.run),
    # searched plans vs heuristic geometry + plan-store warm start
    # (cold/warm subprocesses); detailed record in BENCH_tune.json
    # (gates REPRO_TUNE_GATE, auto = 1.2x tuned speedup on >= 1 shape;
    # REPRO_TUNE_WARM_GATE, auto = 3x faster start-to-first-result)
    ("tune_smoke", bench_tune.run),
    # repro.obs tracing overhead: disabled-path cost per call site and
    # enabled wall-clock tax; detailed record in BENCH_trace.json (gate
    # REPRO_TRACE_GATE, auto = 1% disabled / 10% enabled)
    ("trace_smoke", bench_trace.run),
    # measured span timings vs the streaming-memory roofline; flags the
    # worst under-roofline kernel stage (the ranking that drove the
    # occupancy-bounded probe budget); detailed record in
    # BENCH_roofline_report.json
    ("roofline_report_smoke", report_roofline.run),
]


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    failures = []
    t00 = time.time()
    for name, fn in SUITES:
        if argv and not any(a in name for a in argv):
            continue
        t0 = time.time()
        cache0 = plan_cache_stats()
        try:
            fn()
            elapsed = time.time() - t0
            cache1 = plan_cache_stats()
            save_bench_json(name, {
                "benchmark": name, "status": "pass",
                "wall_s": round(elapsed, 3),
                "plan_cache": {
                    "hits": cache1["hits"] - cache0["hits"],
                    "misses": cache1["misses"] - cache0["misses"],
                    "plans_total": cache1["plans"],
                }})
            print(f"\n[PASS] {name} ({elapsed:.1f}s)")
        except Exception as e:                     # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            save_bench_json(name, {"benchmark": name, "status": "fail",
                                   "wall_s": round(time.time() - t0, 3),
                                   "error": f"{type(e).__name__}: {e}"})
            print(f"\n[FAIL] {name}: {type(e).__name__}: {e}")
    banner(f"benchmark suite done in {time.time() - t00:.1f}s — "
           f"{'ALL PASS' if not failures else 'FAILURES: ' + ', '.join(failures)}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
