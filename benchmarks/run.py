"""Run the full benchmark suite: every paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig8 knn   # substring filter
"""

from __future__ import annotations

import sys
import time
import traceback

from . import (fig7_validation, fig8_dse, fig9_isocapacity, gpu_comparison,
               roofline_table, table1_density, table2_knn)
from .common import banner

SUITES = [
    ("fig7_validation", fig7_validation.run),
    ("fig8_dse", fig8_dse.run),
    ("table1_density", table1_density.run),
    ("table2_knn", table2_knn.run),
    ("fig9_isocapacity", fig9_isocapacity.run),
    ("gpu_comparison", gpu_comparison.run),
    ("roofline_table", roofline_table.run),
]


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    failures = []
    t00 = time.time()
    for name, fn in SUITES:
        if argv and not any(a in name for a in argv):
            continue
        t0 = time.time()
        try:
            fn()
            print(f"\n[PASS] {name} ({time.time() - t0:.1f}s)")
        except Exception as e:                     # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"\n[FAIL] {name}: {type(e).__name__}: {e}")
    banner(f"benchmark suite done in {time.time() - t00:.1f}s — "
           f"{'ALL PASS' if not failures else 'FAILURES: ' + ', '.join(failures)}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
