"""Fig. 8 — design-space exploration: subarray size x optimization mode.

HDC / MNIST-8k on square subarrays R = C in {16, 32, 64, 128, 256} under
the four C4CAM configurations (cam-base, cam-power, cam-density,
cam-power+density); 4 mats/bank, 4 arrays/mat, 8 subarrays/array, banks as
needed.  Reports latency, energy, and power, and checks the paper's
quantitative anchors:

* cam-power @16x16 uses ~0.57x base power; largest size ~20%;
* cam-power @32x32 latency ~2x base, rising to ~4.86x at 256x256;
* cam-density energy ~0.6x base for small arrays, crossing to >1x at
  128/256 (1.4x / 5.1x in the paper);
* cam-power+density @16x16 ~23.4% base power, largest ~4.2%, with up to
  ~121x slower execution.
"""

from __future__ import annotations

from repro.core import ArchSpec, OptimizationTarget, compile_fn

from .common import banner, save_json, table

MODES = [("cam-base", OptimizationTarget.LATENCY),
         ("cam-power", OptimizationTarget.POWER),
         ("cam-density", OptimizationTarget.DENSITY),
         ("cam-power+density", OptimizationTarget.POWER_DENSITY)]

SIZES = (16, 32, 64, 128, 256)


def hdc_kernel(inp, weight):
    others = weight.transpose(-2, -1)
    mm = inp.matmul(others)
    return mm.topk(1, largest=False)


def run(n_queries: int = 10_000, dim: int = 8192, n_classes: int = 10):
    banner("Fig. 8 — DSE: subarray size x optimization mode (HDC/MNIST-8k)")
    results = {}
    rows = []
    for mode, target in MODES:
        for s in SIZES:
            arch = ArchSpec(rows=s, cols=s).with_target(target)
            prog = compile_fn(hdc_kernel, [(n_queries, dim),
                                           (n_classes, dim)], arch,
                              value_bits=1, unroll_limit=0)
            rep = prog.cost_report()
            results[(mode, s)] = rep
            rows.append({"mode": mode, "subarray": f"{s}x{s}",
                         "latency_us": rep.latency_us,
                         "energy_uj": rep.energy_uj,
                         "power_w": rep.power_w})
    print(table(rows))

    def ratio(mode, s, field):
        base = getattr(results[("cam-base", s)], field)
        return getattr(results[(mode, s)], field) / base

    checks = {
        "power@16 power ratio (paper ~0.57)": ratio("cam-power", 16, "power_w"),
        "power@256 power ratio (paper ~0.20)": ratio("cam-power", 256, "power_w"),
        "power@32 latency ratio (paper ~2x)": ratio("cam-power", 32, "latency_ns"),
        "power@256 latency ratio (paper ~4.86x)": ratio("cam-power", 256, "latency_ns"),
        "density@16..64 energy ratio (paper ~0.6)":
            sum(ratio("cam-density", s, "energy_fj") for s in (16, 32, 64)) / 3,
        "density@128 energy ratio (paper ~1.4)": ratio("cam-density", 128, "energy_fj"),
        "density@256 energy ratio (paper ~5.1)": ratio("cam-density", 256, "energy_fj"),
        "power+density@16 power ratio (paper ~0.234)":
            ratio("cam-power+density", 16, "power_w"),
        "power+density@256 power ratio (paper ~0.042)":
            ratio("cam-power+density", 256, "power_w"),
        "power+density@256 latency ratio (paper ~121x)":
            ratio("cam-power+density", 256, "latency_ns"),
    }
    print()
    for k, v in checks.items():
        print(f"  {k}: {v:.3f}")

    # direction-of-effect assertions (the reproduction claims)
    assert checks["power@16 power ratio (paper ~0.57)"] < 1.0
    assert checks["power@256 power ratio (paper ~0.20)"] < \
        checks["power@16 power ratio (paper ~0.57)"]
    assert checks["power@32 latency ratio (paper ~2x)"] > 1.5
    assert checks["density@16..64 energy ratio (paper ~0.6)"] < 1.0
    assert checks["density@256 energy ratio (paper ~5.1)"] > 1.0
    assert checks["power+density@256 power ratio (paper ~0.042)"] < 0.1
    assert checks["power+density@256 latency ratio (paper ~121x)"] > 20

    save_json("fig8_dse", {"rows": rows,
                           "checks": {k: float(v) for k, v in checks.items()}})
    return rows


if __name__ == "__main__":
    run()
