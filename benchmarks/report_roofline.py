"""Measured roofline report: trace spans joined against the model.

Where ``benchmarks/roofline_table.py`` prints the *modelled* roofline
(dry-run artifacts: per arch x shape the compute/memory/collective
terms), this report measures the real kernels with ``repro.obs``
tracing and scores each stage against a streaming-memory roofline:

* calibrate host stream bandwidth (large-block copy),
* run a traced flat packed scan and a traced hierarchical coarse→fine
  search on the same gallery,
* per stage (flat scan, ``hier.coarse``, ``hier.probe``) compute the
  bytes the stage streams, its achieved GB/s, and the roofline
  fraction (achieved / calibrated stream bandwidth).  The hier stage
  spans block on their device results under tracing, so their span
  durations are real stage time; the flat plan's ``plan.dispatch``
  span is jax-async (it times dispatch latency, not device work —
  see docs/observability.md), so the flat stage is measured by wall
  clock around the whole execute instead,
* flag the **worst under-roofline stage** among stages big enough to
  be bandwidth-bound (tiny latency-bound stages are reported but not
  ranked).

This ranking is what motivated the occupancy-bounded probe budget in
``repro.core.engine.hier``: ``hier.probe`` sat far under the flat
scan's fraction because the uniform tiles-per-cluster padding gathered
~1.8x the tiles the cluster occupancy distribution requires (416
padded steps vs 235 occupied at nprobe=16).  The fix is gated in
``BENCH_hier.json`` (``wide`` entry).

Joins the dry-run roofline table (``artifacts/bench/
roofline_table.json``, written by ``benchmarks.roofline_table``) when
present; missing artifacts degrade to the measured-only report.
Writes ``BENCH_roofline_report.json``.
"""

from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core import ArchSpec, Builder, Module, PassManager, TensorType, \
    clear_plan_cache, get_plan
from repro.core.cim_dialect import (make_acquire, make_execute, make_release,
                                    make_similarity, make_yield)
from repro.core.engine import get_hierarchical_plan
from repro.core.passes import CompulsoryPartition
from repro.obs import trace as _trace

from .common import ART, banner, save_bench_json, table

N_GALLERY = 131_072
DIM = 256
K = 10
M_QUERIES = 64
CLUSTERS = 128
NPROBE = 16
KMEANS_ITERS = 4
TRACED_RUNS = 3
#: stages streaming less than this are latency-bound, not rankable
#: against a bandwidth roofline
MIN_RANKABLE_BYTES = 1 << 20


def _module(m, n, dim, k, arch):
    mod = Module("roofline_report",
                 [TensorType((m, dim)), TensorType((n, dim))])
    q, p = mod.arguments
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, [q, p],
                       [TensorType((m, k)), TensorType((m, k), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, q, p, metric="hamming", k=k, largest=False,
                          extra_attrs={"value_bits": 1})
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition(unroll_limit=64))
    return pm.run(mod, {"arch": arch})


def _stream_bandwidth_gbs() -> float:
    """Calibrated host stream bandwidth: best-of large-block copy."""
    a = np.ones(1 << 26, np.uint8)              # 64 MiB
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        b = a.copy()
        best = min(best, time.perf_counter() - t0)
        del b
    # a copy reads + writes the block
    return 2 * a.nbytes / best / 1e9


def _traced_stats(run_fn):
    """Run ``run_fn`` TRACED_RUNS times under tracing, return the
    per-span aggregate (total over all runs)."""
    was_enabled = _trace.tracer.enabled
    _trace.tracer.clear()
    _trace.enable()
    try:
        for _ in range(TRACED_RUNS):
            run_fn()
    finally:
        if not was_enabled:
            _trace.stop()
    stats = _trace.span_stats()
    _trace.tracer.clear()
    return stats


def _probe_budget_from_events() -> int:
    """The static probe budget the traced run used (span args)."""
    for ph, name, _pid, _tid, _ts, _dur, args in _trace.tracer._events:
        if name == "hier.probe" and args:
            return int(args.get("budget", 0))
    return 0


def _load_modelled_cells():
    path = os.path.join(ART, "roofline_table.json")
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            rows = json.load(f)
    except (OSError, ValueError):
        return None
    return [{"arch": r.get("arch"), "shape": r.get("shape"),
             "bottleneck": r.get("bottleneck"),
             "roofline_frac": r.get("roofline_frac")}
            for r in rows] or None


def run():
    banner("Roofline report — measured span timings vs the stream model")
    rng = np.random.default_rng(0)
    clear_plan_cache()
    bw = _stream_bandwidth_gbs()
    print(f"calibrated stream bandwidth: {bw:.1f} GB/s")

    arch = ArchSpec(rows=128, cols=128)
    mod = _module(M_QUERIES, N_GALLERY, DIM, K, arch)
    g = jnp.asarray((rng.random((N_GALLERY, DIM)) > 0.5)
                    .astype(np.float32))
    q = (rng.random((M_QUERIES, DIM)) > 0.5).astype(np.float32)

    flat = get_plan(mod)
    hier = get_hierarchical_plan(mod, clusters=CLUSTERS, nprobe=NPROBE,
                                 kmeans_iters=KMEANS_ITERS)
    for plan in (flat, hier):                   # compile + prepare
        v, i = plan.execute(q, g)
        np.asarray(v), np.asarray(i)

    # flat stage: wall clock (the plan.dispatch span is async — it
    # times dispatch latency, not device work)
    flat_ms = float("inf")
    for _ in range(TRACED_RUNS):
        t0 = time.perf_counter()
        v, i = flat.execute(q, g)
        np.asarray(v), np.asarray(i)
        flat_ms = min(flat_ms, 1e3 * (time.perf_counter() - t0))

    budget = 0

    def run_hier():
        v, i = hier.execute(q, g)
        np.asarray(v), np.asarray(i)

    was_enabled = _trace.tracer.enabled
    _trace.tracer.clear()
    _trace.enable()
    try:
        for _ in range(TRACED_RUNS):
            run_hier()
        budget = _probe_budget_from_events()
    finally:
        if not was_enabled:
            _trace.stop()
    hier_stats = _trace.span_stats()
    _trace.tracer.clear()

    row_bytes = DIM // 8                        # packed hamming row
    tile_rows = arch.rows

    def _per_run(st):
        return None if st is None else st["total_ms"] / st["count"]

    stages = {
        # the flat scan matches every query against every packed row
        "flat.scan": (flat_ms, M_QUERIES * N_GALLERY * row_bytes),
        # coarse stage: every query against the centroid table
        "hier.coarse": (_per_run(hier_stats.get("hier.coarse")),
                        M_QUERIES * CLUSTERS * row_bytes),
        # fine stage: per query, gather `budget` tiles of `tile_rows`
        # packed rows (random access — no cross-query reuse)
        "hier.probe": (_per_run(hier_stats.get("hier.probe")),
                       M_QUERIES * budget * tile_rows * row_bytes),
    }
    rows, report = [], {}
    worst = None
    for name, (ms, bytes_per_run) in stages.items():
        if ms is None:
            continue
        gbs = bytes_per_run / (ms / 1e3) / 1e9 if ms > 0 else 0.0
        frac = gbs / bw if bw > 0 else 0.0
        rankable = bytes_per_run >= MIN_RANKABLE_BYTES
        entry = {"measured_ms": round(ms, 2),
                 "bytes_per_run": int(bytes_per_run),
                 "achieved_gbs": round(gbs, 2),
                 "roofline_frac": round(frac, 4),
                 "rankable": rankable}
        report[name] = entry
        rows.append({"stage": name, **entry})
        if rankable and (worst is None
                         or frac < report[worst]["roofline_frac"]):
            worst = name
    print(table(rows))
    if worst:
        print(f"\nworst under-roofline stage: {worst} "
              f"({report[worst]['roofline_frac']:.3f} of stream roofline)")

    modelled = _load_modelled_cells()
    if modelled is None:
        print("no dry-run roofline artifacts "
              f"({os.path.join(ART, 'roofline_table.json')}) — "
              "measured-only report; run benchmarks.roofline_table to "
              "join the modelled cells")

    payload = {
        "workload": {"n_gallery": N_GALLERY, "dim": DIM, "k": K,
                     "m_queries": M_QUERIES, "clusters": CLUSTERS,
                     "nprobe": NPROBE, "probe_budget": budget,
                     "traced_runs": TRACED_RUNS, "metric": "hamming",
                     "packed": True},
        "stream_bandwidth_gbs": round(bw, 2),
        "stages": report,
        "worst_stage": worst,
        "modelled_cells": modelled,
        "fix": {
            "stage": "hier.probe",
            "change": "occupancy-bounded probe budget "
                      "(repro.core.engine.hier._probe_budget): size the "
                      "fine gather by the top-nprobe occupied-tile "
                      "counts instead of uniform tiles-per-cluster "
                      "padding",
            "gate": "BENCH_hier.json wide entry "
                    "(REPRO_HIER_WIDE_GATE)",
        },
    }
    save_bench_json("roofline_report", payload)
    assert report, "no stages measured — tracing produced no spans"
    assert worst is not None, "no bandwidth-rankable stage measured"
    return payload


if __name__ == "__main__":
    run()
