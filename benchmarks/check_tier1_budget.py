"""Tier-1 wall-clock budget gate.

A test-suite regression (a new test accidentally quadratic, a fixture
recompiling the world) should surface in the PR that causes it, not
three PRs later.  CI times the tier-1 run and this script fails if it
exceeded ``factor`` x the recorded baseline.

    python -m benchmarks.check_tier1_budget --wall <seconds>

Baseline lives in ``.github/tier1_baseline.json``::

    {"wall_s": <seconds>, "factor": 1.5, "host": "<note>"}

The baseline is host-calibrated: re-record it (set ``wall_s`` to a
fresh CI measurement) whenever the suite legitimately grows or the
runner hardware changes.  ``REPRO_TIER1_BUDGET`` overrides the allowed
seconds directly; ``0``/``off`` disables the gate (recording still
prints).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    ".github", "tier1_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--wall", type=float, required=True,
                    help="measured tier-1 wall-clock seconds")
    args = ap.parse_args(argv)

    from repro.core.envcfg import env_gate

    with open(BASELINE_PATH) as f:
        baseline = json.load(f)
    factor = float(baseline.get("factor", 1.5))
    default_budget = float(baseline["wall_s"]) * factor
    budget = env_gate("REPRO_TIER1_BUDGET", default_budget)
    if not budget:
        print(f"tier-1 budget gate disabled; measured {args.wall:.0f}s")
        return 0
    if budget != default_budget:
        print(f"tier-1 wall clock: {args.wall:.0f}s "
              f"(REPRO_TIER1_BUDGET override -> budget {budget:.0f}s)")
    else:
        print(f"tier-1 wall clock: {args.wall:.0f}s "
              f"(baseline {baseline['wall_s']}s x {factor} -> "
              f"budget {budget:.0f}s)")
    if args.wall > budget:
        print(f"FAIL: tier-1 suite exceeded its wall-clock budget by "
              f"{args.wall - budget:.0f}s — either fix the regression or "
              f"re-record .github/tier1_baseline.json in the same PR",
              file=sys.stderr)
        return 1
    print("tier-1 budget OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
