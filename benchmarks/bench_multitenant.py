"""Multi-tenant gateway benchmark: isolation + replica failover.

Three experiments over one small compiled KNN plan (the gateway is the
system under test, not the kernel):

* **aggregate** — three tenants drive the gateway concurrently vs the
  same three workloads run back-to-back on solo servers; records the
  concurrent/sequential throughput ratio (shared plan + interleaved
  batching should keep it near or above 1).
* **isolation** — the acceptance experiment.  A victim tenant's p95 is
  measured solo, then again while a hot tenant floods (a) the gateway,
  where the hot tenant is rate-limited and shed by *its own* admission
  budget, and (b) a naive shared ``CamSearchServer`` with no admission
  layer, where the flood queues ahead of the victim.  Gate: gateway
  victim p95 <= gate x solo **and** naive victim p95 > gate x solo —
  the gateway must deliver the isolation the bare server demonstrably
  lacks.
* **failover** — one tenant on two replicas; concurrent bit-checking
  clients; one replica is killed mid-traffic.  Every request must
  complete bit-identically to the plan oracle (zero failures), the
  gateway must record failovers, and the killed replica must be
  drained, rebuilt onto a fresh device group, and readmitted by the
  maintenance loop before the run ends.

Writes ``BENCH_multitenant.json``.  Gate ``REPRO_MULTITENANT_GATE``
(auto -> 2.0, ``0``/``off`` disables) is the isolation factor above.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import ArchSpec, compile_fn
from repro.core.envcfg import env_gate
from repro.serving import AdmissionError, CamSearchServer, \
    CamServingGateway, TenantUnavailable

from .common import banner, save_bench_json, table

N, DIM, K = 512, 64, 5
ROWS = 8                   # query rows per request
SEED = 7


def _gate() -> float:
    return env_gate("REPRO_MULTITENANT_GATE", 2.0)


def _knn(q, gallery):
    d = q.unsqueeze(1).sub(gallery).norm(p=2, dim=-1)
    return d.topk(K, largest=False)


def _compile(rng):
    gal = rng.standard_normal((N, DIM)).astype(np.float32)
    prog = compile_fn(_knn, [np.zeros((32, DIM), np.float32), gal],
                      ArchSpec(rows=64, cols=64))
    return prog, gal


def _p95(lat):
    lat = sorted(lat)
    return 1e3 * lat[min(len(lat) - 1, int(len(lat) * 0.95))]


def _drive(search, queries, reps):
    """Run ``reps`` sequential requests, returning per-request wait
    latencies (seconds)."""
    out = []
    for _ in range(reps):
        t0 = time.perf_counter()
        search(queries)
        out.append(time.perf_counter() - t0)
    return out


# -- experiment 1: aggregate throughput ------------------------------------

def _bench_aggregate(prog, gal, rng):
    tenants = ["t0", "t1", "t2"]
    reps, q = 40, rng.standard_normal((ROWS, DIM)).astype(np.float32)

    solo_t0 = time.perf_counter()
    for _ in tenants:
        with CamSearchServer(prog, gal) as srv:
            _drive(srv.search, q, reps)
    solo_s = time.perf_counter() - solo_t0

    gw = CamServingGateway(maint_ms=0.0)
    gw.register_tenant(tenants[0], prog, gal)
    for t in tenants[1:]:
        gw.register_tenant(t, share_with=tenants[0])
    conc_t0 = time.perf_counter()
    threads = [threading.Thread(
        target=lambda t=t: _drive(lambda x: gw.search(t, x), q, reps))
        for t in tenants]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    conc_s = time.perf_counter() - conc_t0
    gw.stop()

    total_q = len(tenants) * reps * ROWS
    rec = {"tenants": len(tenants), "requests_per_tenant": reps,
           "sequential_s": round(solo_s, 3),
           "concurrent_s": round(conc_s, 3),
           "concurrent_qps": round(total_q / conc_s, 1),
           "throughput_ratio": round(solo_s / conc_s, 2)}
    print(table([rec]))
    return rec


# -- experiment 2: hot-tenant isolation ------------------------------------

def _flood(submit, stop_evt, counters, inflight=32):
    """Hot-tenant flood until told to stop.

    Bounded in-flight (not fire-and-forget): an unbounded flood makes
    the *naive* victim latency a function of run length, not of the
    server's scheduling — with a fixed backlog the measured isolation
    factor is stable.
    """
    pending = []
    while not stop_evt.is_set():
        try:
            h = submit()
            counters["accepted"] += 1
            if h is not None:
                pending.append(h)
        except (AdmissionError, TenantUnavailable):
            counters["rejected"] += 1
            time.sleep(1e-3)        # rejected: back off, don't busy-spin
        except RuntimeError:
            break
        while len(pending) >= inflight:
            try:
                pending.pop(0).wait(30)
            except TimeoutError:
                pass
    for h in pending:
        try:
            h.wait(30)
        except TimeoutError:
            pass


def _bench_isolation(prog, gal, rng):
    gate = _gate()
    vq = rng.standard_normal((ROWS, DIM)).astype(np.float32)
    hq = rng.standard_normal((2 * ROWS, DIM)).astype(np.float32)
    reps = 60

    # victim alone through the gateway: the baseline p95
    gw = CamServingGateway(maint_ms=0.0)
    gw.register_tenant("victim", prog, gal)
    solo = _drive(lambda x: gw.search("victim", x), vq, reps)
    gw.stop()

    # victim + admission-controlled hot tenant on the SAME replica set
    gw = CamServingGateway(maint_ms=0.0)
    gw.register_tenant("victim", prog, gal)
    gw.register_tenant("hot", share_with="victim",
                       rate=4.0 * ROWS, burst=2 * ROWS,
                       queue_limit=4, max_outstanding=2)
    stop_evt, counters = threading.Event(), {"accepted": 0, "rejected": 0}
    flooders = [threading.Thread(
        target=_flood, args=(lambda: gw.submit("hot", hq), stop_evt,
                             counters)) for _ in range(2)]
    for f in flooders:
        f.start()
    gated = _drive(lambda x: gw.search("victim", x), vq, reps)
    stop_evt.set()
    for f in flooders:
        f.join()
    gw.stop()

    # the counterfactual: one bare shared server, no admission layer —
    # the hot flood queues ahead of the victim
    srv = CamSearchServer(prog, gal).start()
    stop_evt2 = threading.Event()
    naive_counters = {"accepted": 0, "rejected": 0}
    flooders = [threading.Thread(
        target=_flood, args=(lambda: srv.submit(hq), stop_evt2,
                             naive_counters)) for _ in range(2)]
    for f in flooders:
        f.start()
    naive = _drive(lambda x: srv.search(x), vq, reps)
    stop_evt2.set()
    for f in flooders:
        f.join()
    srv.stop()

    rec = {"solo_p95_ms": round(_p95(solo), 2),
           "gateway_p95_ms": round(_p95(gated), 2),
           "naive_shared_p95_ms": round(_p95(naive), 2),
           "gateway_factor": round(_p95(gated) / _p95(solo), 2),
           "naive_factor": round(_p95(naive) / _p95(solo), 2),
           "hot_accepted": counters["accepted"],
           "hot_rejected": counters["rejected"],
           "gate": gate}
    print(table([rec]))
    if gate > 0:
        assert rec["gateway_factor"] <= gate, (
            f"victim p95 through the gateway is "
            f"{rec['gateway_factor']}x solo (gate: <= {gate}x) — "
            f"admission control failed to isolate the hot tenant")
        assert rec["naive_factor"] > gate, (
            f"naive shared server victim p95 only {rec['naive_factor']}x "
            f"solo — the flood is too weak to demonstrate isolation")
    return rec


# -- experiment 3: replica-kill failover -----------------------------------

def _bench_failover(prog, gal, rng):
    plan = prog.engine_plan
    gw = CamServingGateway(maint_ms=10.0)
    gw.register_tenant("ten", prog, gal, replicas=2, unhealthy_k=2)
    q_blocks = [rng.standard_normal((ROWS, DIM)).astype(np.float32)
                for _ in range(4)]
    oracles = [np.asarray(plan.execute(q, gal)[1]) for q in q_blocks]

    reps, kill_after = 50, 12
    errors, mismatches = [], []
    lat = {"before": [], "after": []}
    barrier = threading.Barrier(4 + 1)
    killed_evt = threading.Event()

    def client(cid):
        barrier.wait()
        for r in range(reps):
            t0 = time.perf_counter()
            try:
                _, idx = gw.search("ten", q_blocks[cid], timeout=60)
            except Exception as e:              # noqa: BLE001 — recorded
                errors.append(repr(e))
                continue
            dt = time.perf_counter() - t0
            lat["after" if killed_evt.is_set() else "before"].append(dt)
            if not np.array_equal(np.asarray(idx), oracles[cid]):
                mismatches.append((cid, r))

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(4)]
    for t in threads:
        t.start()
    barrier.wait()
    # let traffic establish, then take down a device group mid-flight
    time.sleep(kill_after * 0.01)
    gw.kill_replica("ten", 0)
    killed_evt.set()
    for t in threads:
        t.join()

    # the maintenance loop must drain + rebuild + readmit the replica
    healed = False
    for _ in range(500):
        reps_v = gw.health()["tenants"]["ten"]["replicas"]["replicas"]
        if all(r["state"] == "serving" for r in reps_v) and \
                any(r["rebuilds"] > 0 for r in reps_v):
            healed = True
            break
        time.sleep(0.01)
    h = gw.health()["tenants"]["ten"]
    post_v, post_i = gw.search("ten", q_blocks[0])
    post_ok = np.array_equal(np.asarray(post_i), oracles[0])
    gw.stop()

    rec = {"clients": 4, "requests": 4 * reps,
           "errors": len(errors), "mismatches": len(mismatches),
           "failovers": h["stats"]["failovers"],
           "healed": healed, "post_heal_bit_identical": bool(post_ok),
           "p95_before_kill_ms":
               round(_p95(lat["before"]), 2) if lat["before"] else None,
           "p95_after_kill_ms":
               round(_p95(lat["after"]), 2) if lat["after"] else None,
           "replicas": [{k: r[k] for k in
                         ("state", "generation", "rebuilds", "heals",
                          "device_group")}
                        for r in h["replicas"]["replicas"]]}
    print(table([{k: v for k, v in rec.items() if k != "replicas"}]))
    assert not errors, f"failover dropped requests: {errors[:3]}"
    assert not mismatches, f"failover broke bit-identity: {mismatches[:3]}"
    assert rec["failovers"] > 0, \
        "kill landed between requests — no failover exercised"
    assert healed, "killed replica was not rebuilt + readmitted"
    assert post_ok, "post-heal result diverged from the oracle"
    return rec


def run():
    rng = np.random.default_rng(SEED)
    prog, gal = _compile(rng)

    banner("multi-tenant aggregate throughput")
    aggregate = _bench_aggregate(prog, gal, rng)
    banner("hot-tenant isolation (gateway vs naive shared server)")
    isolation = _bench_isolation(prog, gal, rng)
    banner("replica-kill failover")
    failover = _bench_failover(prog, gal, rng)

    payload = {
        "benchmark": "multitenant",
        "workload": {"n": N, "dim": DIM, "k": K, "rows_per_request": ROWS},
        "gate": _gate(),
        "aggregate": aggregate,
        "isolation": isolation,
        "failover": failover,
    }
    save_bench_json("multitenant", payload)
    return payload


if __name__ == "__main__":
    run()
