"""Autotuner benchmark: searched plans vs heuristic plans + warm start.

Two claims, two measurements (``repro.tune``):

* **search pays** — for workloads whose arch-derived heuristic geometry
  is poor (paper-scale small subarrays -> deep serial tile scans), the
  coordinate-descent autotuner finds a verified plan >= 1.2x faster
  than the heuristic one on at least one swept shape
  (``REPRO_TUNE_GATE``).
* **the store kills cold starts** — with ``REPRO_PLAN_STORE``
  populated, a fresh process reaches its first search result >= 3x
  faster than the process that had to tune + XLA-compile from scratch
  (``REPRO_TUNE_WARM_GATE``), with **zero** tune trials, both stored
  executables adopted (zero XLA compiles), and bit-identical output.
  Each measurement runs in its own subprocess (cold-start is a
  process-lifetime property).

Writes ``BENCH_tune.json``.

    PYTHONPATH=src python -m benchmarks.bench_tune
"""

from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import tempfile
import time

from .common import banner, save_bench_json, table

_MARK = "TUNE-RESULT "
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: shapes whose heuristic geometry (tile_rows = arch rows, dims_per_tile
#: from arch cols) leaves obvious headroom for the search
SHAPES = [
    dict(name="hamming-512", metric="hamming", k=4, m=16, n=512, dim=64,
         rows=16, cols=32),
    dict(name="eucl-1k", metric="eucl", k=8, m=16, n=1024, dim=64,
         rows=16, cols=64),
    dict(name="dot-2k", metric="dot", k=8, m=32, n=2048, dim=64,
         rows=32, cols=64),
]
TRIALS = 10
REPS = 3

#: warm-start workload: non-tiny (n*dim clears REPRO_ENGINE_TINY_CELLS)
#: so the AOT-executable half of the store is on the measured path
WARM = dict(metric="hamming", k=8, m=32, n=4096, dim=64, rows=64, cols=64)
WARM_TRIALS = 6


def _gate() -> float:
    from repro.core.envcfg import env_gate
    return env_gate("REPRO_TUNE_GATE", 1.2)


def _warm_gate() -> float:
    from repro.core.envcfg import env_gate
    return env_gate("REPRO_TUNE_WARM_GATE", 3.0)


def _module(cfg):
    """Hand-built fused similarity module through the partition pass
    (same construction as the engine parity tests)."""
    from repro.core import (ArchSpec, Builder, Module, PassManager,
                            TensorType)
    from repro.core.cim_dialect import (make_acquire, make_execute,
                                        make_release, make_similarity,
                                        make_yield)
    from repro.core.passes import CompulsoryPartition

    m, n, dim, k = cfg["m"], cfg["n"], cfg["dim"], cfg["k"]
    mod = Module("bench_tune", [TensorType((m, dim)), TensorType((n, dim))])
    q, p = mod.arguments
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, [q, p],
                       [TensorType((m, k)), TensorType((m, k), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, q, p, metric=cfg["metric"], k=k,
                          largest=cfg["metric"] != "eucl")
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition())
    return pm.run(mod, {"arch": ArchSpec(rows=cfg["rows"],
                                         cols=cfg["cols"])})


def _data(cfg, seed=0):
    import numpy as np
    rng = np.random.default_rng(seed)
    m, n, dim = cfg["m"], cfg["n"], cfg["dim"]
    if cfg["metric"] == "hamming":
        return ((rng.random((m, dim)) > 0.5).astype(np.float32),
                (rng.random((n, dim)) > 0.5).astype(np.float32))
    return (rng.standard_normal((m, dim)).astype(np.float32),
            rng.standard_normal((n, dim)).astype(np.float32))


# ---------------------------------------------------------------------------
# part 1: tuned vs heuristic
# ---------------------------------------------------------------------------

def _sweep() -> list:
    from repro.tune import PlanStore, tune_plan

    # a private throwaway store so the sweep always searches (a
    # CI-configured REPRO_PLAN_STORE would otherwise short-circuit it)
    store = PlanStore(tempfile.mkdtemp(prefix="bench-tune-"))
    rows = []
    for cfg in SHAPES:
        mod = _module(cfg)
        q, p = _data(cfg)
        res = tune_plan(mod, q, p, trials=TRIALS, reps=REPS, store=store)
        rows.append({
            "shape": cfg["name"],
            "n": cfg["n"], "dim": cfg["dim"],
            "heuristic_ms": round(res.base_s * 1e3, 3),
            "tuned_ms": round(res.best_s * 1e3, 3),
            "speedup": round(res.speedup, 2),
            "trials": res.trials,
            "winner": {k: res.config[k] for k in
                       ("tile_rows", "dims_per_tile", "batch", "pack",
                        "unroll")},
        })
    return rows


# ---------------------------------------------------------------------------
# part 2: cold vs warm start (subprocesses sharing one store)
# ---------------------------------------------------------------------------

def _child() -> dict:
    """One process lifetime: tune (or store-hit) + first search result.

    ``start_to_first_result_s`` spans plan acquisition through the
    first materialised output — the window the plan store exists to
    shrink.  Cold (empty store) pays the search and every XLA compile;
    warm replays the stored config + serialized executables.
    """
    import numpy as np

    from repro.tune import plan_store_stats, tune_plan, tune_stats

    mod = _module(WARM)
    q, p = _data(WARM, seed=7)
    t0 = time.perf_counter()
    res = tune_plan(mod, q, p, trials=WARM_TRIALS, reps=1)
    import jax
    v, i = jax.block_until_ready(res.plan.execute(q, p))
    wall = time.perf_counter() - t0
    digest = hashlib.sha256(
        np.asarray(v).tobytes() + np.asarray(i).tobytes()).hexdigest()
    return {
        "start_to_first_result_s": round(wall, 4),
        "trials": res.trials,
        "from_store": res.from_store,
        "tune": tune_stats(),
        "store": plan_store_stats(),
        "result_digest": digest,
    }


def _spawn_child(store_dir: str) -> dict:
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src") + os.pathsep +
               os.environ.get("PYTHONPATH", ""),
               REPRO_PLAN_STORE=store_dir)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_tune", "--run-child"],
        env=env, capture_output=True, text=True, timeout=1800, cwd=ROOT)
    for line in out.stdout.splitlines():
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    raise RuntimeError(f"tune child produced no result:\n"
                       f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}")


def _cold_vs_warm() -> dict:
    store_dir = tempfile.mkdtemp(prefix="bench-tune-store-")
    cold = _spawn_child(store_dir)
    warm = _spawn_child(store_dir)

    assert cold["trials"] > 0 and not cold["from_store"]
    assert warm["trials"] == 0 and warm["from_store"], \
        "warm process re-ran the search"
    assert warm["store"]["exec_hits"] == 2, \
        "warm process did not adopt the stored executables (recompiled)"
    assert warm["store"]["exec_fallbacks"] == 0, \
        "adopted executables fell back to the lazy-jit (compiling) path"
    assert warm["result_digest"] == cold["result_digest"], \
        "warm-started results are not bit-identical to the tuned run"

    speedup = (cold["start_to_first_result_s"] /
               max(warm["start_to_first_result_s"], 1e-9))
    return {"workload": WARM, "cold": cold, "warm": warm,
            "warm_start_speedup": round(speedup, 2)}


def run() -> dict:
    banner("Tune — searched plans vs heuristics + plan-store warm start")
    sweep = _sweep()
    print(table(sweep, cols=["shape", "n", "heuristic_ms", "tuned_ms",
                             "speedup", "trials"]))
    best = max(r["speedup"] for r in sweep)

    cw = _cold_vs_warm()
    print(f"\ncold start : {cw['cold']['start_to_first_result_s']:.3f}s "
          f"({cw['cold']['trials']} trials)")
    print(f"warm start : {cw['warm']['start_to_first_result_s']:.3f}s "
          f"(0 trials, executables adopted)")
    print(f"warm-start speedup: {cw['warm_start_speedup']:.2f}x, "
          f"best tuned speedup: {best:.2f}x")

    gate, warm_gate = _gate(), _warm_gate()
    payload = {
        "gate": gate, "warm_gate": warm_gate,
        "trials_per_shape": TRIALS, "reps": REPS,
        "sweep": sweep, "best_tuned_speedup": best,
        "warm_start": cw,
    }
    save_bench_json("tune", payload)

    if gate > 0:
        assert best >= gate, (
            f"tuned plans only reached {best:.2f}x the heuristic on the "
            f"swept shapes (gate: >= {gate}x on at least one); see "
            f"BENCH_tune.json")
    if warm_gate > 0:
        assert cw["warm_start_speedup"] >= warm_gate, (
            f"plan-store warm start only {cw['warm_start_speedup']:.2f}x "
            f"faster to first result (gate: >= {warm_gate}x); see "
            f"BENCH_tune.json")
    return payload


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--run-child" in argv:
        print(_MARK + json.dumps(_child()))
        return 0
    run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
