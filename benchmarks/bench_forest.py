"""Forest inference benchmark: engine RangePlan vs interpreter oracle.

Compiles a synthetic decision forest onto an analog CAM
(`repro.forest`) and times the same interval-match program two ways:

* **engine**      — the compiled ``RangePlan`` (jitted row-tile scan,
  micro-batched queries, memoised interval layout behind the plan
  cache),
* **interpreter** — ``execute_module`` on the partitioned IR (the
  semantic oracle: dense ``ref.acam_match``, re-dispatched eagerly on
  every call).

Predictions must agree bit-for-bit before any timing counts (the gate
is meaningless otherwise).  A plain per-sample Python tree traversal is
timed once for the record.  Writes ``BENCH_forest.json``; the gate is
the engine speedup over the interpreter at the large point:
``REPRO_FOREST_GATE=auto`` -> 2.0, any float overrides, ``0``/``off``
disables.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import clear_plan_cache
from repro.core.envcfg import env_gate
from repro.core.arch import ArchSpec, CamType
from repro.core.executor import execute_module
from repro.forest import CamForestClassifier, random_forest, vote

from .common import banner, save_bench_json, table

#: (n_trees, depth, dim, m_queries); the first point carries the gate
POINTS = ((64, 6, 64, 256), (32, 4, 32, 128))
N_CLASSES = 8
REPEATS = 5


def _time(fn) -> float:
    fn()                                    # warmup (compile + prepare)
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _gate() -> float:
    return env_gate("REPRO_FOREST_GATE", 2.0)


def run():
    banner("Forest inference — engine RangePlan vs interpreter oracle")
    rng = np.random.default_rng(0)
    rows, results = [], {}
    for n_trees, depth, dim, m in POINTS:
        clear_plan_cache()
        trees = random_forest(rng, n_trees=n_trees, dim=dim, depth=depth,
                              n_classes=N_CLASSES, feature_frac=0.5)
        arch = ArchSpec(rows=64, cols=64, cam_type=CamType.ACAM)
        clf = CamForestClassifier(trees, dim=dim).compile(arch,
                                                          batch_hint=m)
        x = rng.standard_normal((m, dim)).astype(np.float32)
        iv = clf.intervals
        mod = clf.stages["cim_partitioned"]

        # the gate is only meaningful if the paths agree bit-for-bit
        pe = clf.predict(x)
        assert np.array_equal(pe, clf.predict_interpreted(x)), \
            "engine predictions diverged from the interpreter oracle"
        assert np.array_equal(pe, clf.predict_reference(x)), \
            "engine predictions diverged from tree traversal"

        def engine():
            m_ = clf.matches(x)
            vote(m_, iv.leaf_class, iv.n_classes)

        def interp():
            m_ = np.asarray(execute_module(mod, x, iv.lo, iv.hi)[0])
            vote(m_, iv.leaf_class, iv.n_classes)

        t_engine = _time(engine)
        t_interp = _time(interp)
        t_traverse = _time(lambda: clf.predict_reference(x))

        speedup = t_interp / max(t_engine, 1e-9)
        key = f"t{n_trees}_d{depth}"
        results[key] = {
            "n_trees": n_trees, "depth": depth, "dim": dim, "m": m,
            "rows": iv.n_rows,
            "wildcard_frac": round(iv.wildcard_frac, 4),
            "engine_ms": round(1e3 * t_engine, 2),
            "interp_ms": round(1e3 * t_interp, 2),
            "traverse_ms": round(1e3 * t_traverse, 2),
            "speedup": round(speedup, 2),
        }
        rows.append({"trees": n_trees, "rows": iv.n_rows, "m": m,
                     "engine_ms": 1e3 * t_engine,
                     "interp_ms": 1e3 * t_interp,
                     "traverse_ms": 1e3 * t_traverse, "speedup": speedup})
    print(table(rows))

    gate = _gate()
    first = POINTS[0]
    gated = results[f"t{first[0]}_d{first[1]}"]
    payload = {
        "points": results,
        "repeats": REPEATS,
        "gate": gate,
        "gate_point": f"t{first[0]}_d{first[1]}",
        "speedup": gated["speedup"],
    }
    save_bench_json("forest", payload)
    if gate:
        assert gated["speedup"] >= gate, (
            f"forest RangePlan only {gated['speedup']:.2f}x over the "
            f"interpreter oracle (gate: >= {gate}x); see BENCH_forest.json")
        # the small point regressed below 1.0x before the tiny-plan
        # dense fast path (per-tile lax.scan stepping dominated the
        # arithmetic at a few hundred rows); pin it at parity-or-better
        # so the fast path cannot rot silently
        small = POINTS[-1]
        small_speedup = results[f"t{small[0]}_d{small[1]}"]["speedup"]
        assert small_speedup >= 1.0, (
            f"small-program point t{small[0]}_d{small[1]} fell back below "
            f"the interpreter ({small_speedup:.2f}x < 1.0x): the tiny-plan "
            f"fast path regressed; see BENCH_forest.json")
    return payload


if __name__ == "__main__":
    run()
