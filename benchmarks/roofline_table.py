"""Roofline table — aggregates the dry-run artifacts for all 40 cells.

Reads ``artifacts/dryrun/*.json`` (produced by `repro.launch.dryrun`) and
prints the §Roofline table: per (arch x shape x mesh) the three roofline
terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline
fraction.  This module does NOT lower anything itself (the dry-run needs
512 placeholder devices; run ``python -m repro.launch.dryrun`` first) —
if artifacts are missing it says so and exits cleanly.
"""

from __future__ import annotations

import glob
import json
import os

from .common import banner, save_json, table

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


def load_cells(mesh: str = "16x16"):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))):
        d = json.load(open(f))
        rows.append(d)
    return rows


def run():
    banner("Roofline table (from dry-run artifacts; single-pod 16x16)")
    cells = load_cells("16x16")
    if not cells:
        print("no dry-run artifacts found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun "
              "--arch all --shape all --mesh single")
        return []
    rows = []
    n_ok = n_skip = n_fail = 0
    for d in cells:
        if d.get("skipped"):
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "bottleneck": "SKIP (full attn)"})
            n_skip += 1
            continue
        if d.get("error"):
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "bottleneck": "FAIL"})
            n_fail += 1
            continue
        r = d["roofline"]
        n_ok += 1
        rows.append({
            "arch": d["arch"], "shape": d["shape"],
            "t_compute_s": r["t_compute"], "t_memory_s": r["t_memory"],
            "t_collective_s": r["t_collective"],
            "bottleneck": r["bottleneck"],
            "peak_GiB": d["memory"]["peak_bytes"] / 2 ** 30,
            "useful_flops": d.get("useful_flops_ratio") or 0.0,
            "roofline_frac": d.get("roofline_fraction") or 0.0,
        })
    print(table(rows))
    from repro.launch.roofline import bottleneck_advice
    print("\nWhat would move the dominant term (per cell):")
    for d in cells:
        if d.get("skipped") or d.get("error"):
            continue
        adv = bottleneck_advice(d["roofline"]["bottleneck"], d["kind"],
                                d.get("family", ""))
        print(f"  {d['arch']} x {d['shape']} "
              f"[{d['roofline']['bottleneck']}]: {adv}")
    print(f"\n{n_ok} baselined, {n_skip} skipped (documented), "
          f"{n_fail} failed")
    multi = load_cells("2x16x16")
    m_ok = sum(1 for d in multi if not d.get("skipped")
               and not d.get("error"))
    m_skip = sum(1 for d in multi if d.get("skipped"))
    print(f"multi-pod (2x16x16): {m_ok} compiled, {m_skip} skipped, "
          f"of {len(multi)} recorded")
    save_json("roofline_table", rows)
    assert n_fail == 0, "dry-run failures present"
    return rows


if __name__ == "__main__":
    run()
