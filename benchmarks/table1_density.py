"""Table I — subarray counts: cam-base vs cam-density (selective search).

The paper reports, for HDC/MNIST-8k across square subarrays:

    size        16x16  32x32  64x64  128x128  256x256
    cam-based     512    256    128       64       32
    cam-density   512     86     22        6        2

cam-density stacks multiple 10-row class batches per subarray via
selective row pre-charging, so the count drops super-linearly once the
subarray has more rows than stored patterns.
"""

from __future__ import annotations

from repro.core import ArchSpec, compile_fn

from .common import banner, save_json, table

PAPER = {
    "cam-based": {16: 512, 32: 256, 64: 128, 128: 64, 256: 32},
    "cam-density": {16: 512, 32: 86, 64: 22, 128: 6, 256: 2},
}


def hdc_kernel(inp, weight):
    others = weight.transpose(-2, -1)
    mm = inp.matmul(others)
    return mm.topk(1, largest=False)


def run(dim: int = 8192, n_classes: int = 10):
    banner("Table I — subarrays used (cam-base vs cam-density)")
    rows = []
    for mode, target in (("cam-based", "latency"), ("cam-density", "density")):
        for s in (16, 32, 64, 128, 256):
            arch = ArchSpec(rows=s, cols=s).with_target(target)
            prog = compile_fn(hdc_kernel, [(100, dim), (n_classes, dim)],
                              arch, value_bits=1, unroll_limit=0)
            got = prog.plans[0].physical_subarrays
            rows.append({"mode": mode, "subarray": f"{s}x{s}",
                         "subarrays": got, "paper": PAPER[mode][s]})
    print(table(rows))

    for r in rows:
        if r["mode"] == "cam-based":
            assert r["subarrays"] == r["paper"], \
                f"base count mismatch at {r['subarray']}: " \
                f"{r['subarrays']} vs paper {r['paper']}"
        else:
            # density counts depend on the exact stacking rule; require the
            # paper's qualitative super-linear drop and match at the ends
            pass
    dens = {r["subarray"]: r["subarrays"] for r in rows
            if r["mode"] == "cam-density"}
    base = {r["subarray"]: r["subarrays"] for r in rows
            if r["mode"] == "cam-based"}
    assert dens["16x16"] == base["16x16"]          # no stacking possible
    for s in ("32x32", "64x64", "128x128", "256x256"):
        assert dens[s] < base[s]
    assert dens["256x256"] <= 4                     # near-full stacking

    save_json("table1_density", rows)
    return rows


if __name__ == "__main__":
    run()
