"""Search-plan engine benchmark: reduced-scale Table II sweep.

Runs the KNN kernel (Pneumonia-style gallery, scaled down to CI size)
over 5 subarray sizes x 2 optimization targets — the Fig. 8 / Table II
DSE shape — three ways:

* **seed**   — the pre-engine executor path (`execute_unplanned`): the
  partitioned IR walked / re-traced on every point.
* **cold**   — the search-plan engine with an empty plan cache: per-
  geometry plan build + jit compile + execution.
* **cached** — the same sweep again: every point hits the process-wide
  plan cache (targets share geometry, so 5 plans serve 10 points).

Writes ``BENCH_engine.json`` with wall-clock for all three, the
cold/cached split, plan-cache counters, and the speedup of the engine
over the seed path (the PR gate is >= 3x).  Also asserts engine results
match the interpreted oracle on one sweep point.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (ArchSpec, clear_plan_cache, compile_fn,
                        plan_cache_stats)

from .common import banner, save_bench_json, table

SIZES = (16, 32, 64, 128, 256)
MODES = (("cam-based", "latency"), ("cam-power", "power"))


def knn_kernel(q, gallery):
    diff = q.unsqueeze(1).sub(gallery)
    d = diff.norm(p=2, dim=-1)
    return d.topk(5, largest=False)


def _sweep(execute, q, g, dim):
    """Compile + execute every (target, size) point; returns results."""
    out = []
    for _, target in MODES:
        for s in SIZES:
            arch = ArchSpec(rows=s, cols=s, banks=1024).with_target(target)
            prog = compile_fn(knn_kernel, [q, g], arch, value_bits=8)
            out.append(np.asarray(execute(prog, q, g)[1]))
    return out


def run(n_gallery: int = 2048, dim: int = 128, n_queries: int = 64):
    banner("Engine — reduced Table II sweep: seed executor vs search plans")
    rng = np.random.default_rng(0)
    q = rng.standard_normal((n_queries, dim)).astype(np.float32)
    g = rng.standard_normal((n_gallery, dim)).astype(np.float32)

    clear_plan_cache()
    t0 = time.time()
    seed_idx = _sweep(lambda p, *a: p.execute_unplanned(*a), q, g, dim)
    seed_s = time.time() - t0

    clear_plan_cache()
    t0 = time.time()
    cold_idx = _sweep(lambda p, *a: p(*a), q, g, dim)
    cold_s = time.time() - t0
    cold_stats = plan_cache_stats()

    t0 = time.time()
    warm_idx = _sweep(lambda p, *a: p(*a), q, g, dim)
    warm_s = time.time() - t0
    warm_stats = plan_cache_stats()

    for a, b, c in zip(seed_idx, cold_idx, warm_idx):
        assert np.array_equal(a, b) and np.array_equal(b, c), \
            "engine sweep results diverged from the seed executor"

    speedup_cold = seed_s / max(cold_s, 1e-9)
    speedup_warm = seed_s / max(warm_s, 1e-9)
    rows = [
        {"path": "seed executor", "wall_s": seed_s, "speedup": 1.0},
        {"path": "engine (cold compile)", "wall_s": cold_s,
         "speedup": speedup_cold},
        {"path": "engine (cached execute)", "wall_s": warm_s,
         "speedup": speedup_warm},
    ]
    print(table(rows))
    print(f"\nplan cache after cold sweep: {cold_stats}")
    print(f"plan cache after cached sweep: {warm_stats}")

    payload = {
        "sweep": {"sizes": list(SIZES),
                  "targets": [t for _, t in MODES],
                  "n_gallery": n_gallery, "dim": dim,
                  "n_queries": n_queries, "k": 5, "metric": "eucl"},
        "seed_s": round(seed_s, 3),
        "engine_cold_s": round(cold_s, 3),
        "engine_cached_s": round(warm_s, 3),
        "speedup_cold": round(speedup_cold, 2),
        "speedup_cached": round(speedup_warm, 2),
        "plan_cache_cold": cold_stats,
        "plan_cache_cached": warm_stats,
    }
    save_bench_json("engine", payload)

    assert speedup_cold >= 3.0, (
        f"engine (cold) only {speedup_cold:.2f}x over the seed executor "
        f"(gate: >= 3x); see BENCH_engine.json")
    return payload


if __name__ == "__main__":
    run()
