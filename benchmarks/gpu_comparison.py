"""§IV-B — end-to-end comparison against the GPU baseline.

The paper measures PyTorch int32 HDC/MNIST on a Quadro RTX 6000 and
reports the CIM system (CAM banks + host periphery, config of [22]) to be
48x faster and 46.8x more energy-efficient, "which is nearly the same
since CAMs contribute minimally to the overall energy consumption in
their CIM system".

No GPU exists in this container, so the RTX 6000 is modelled analytically
(datasheet roofline x measured-efficiency factor; `repro.camsim.gpu`).
The efficiency factor is CALIBRATED so the modelled time ratio lands at
the paper's 48x — reported explicitly below, so what this benchmark
demonstrates is the *energy-ratio consistency* (46.8x follows from 48x +
the CIM system model, not from an independent fit) and the end-to-end
pipeline: same TorchScript-like kernel, two backends.
"""

from __future__ import annotations

from repro.camsim import CIM_SYSTEM, CostModel, QUADRO_RTX_6000
from repro.core import compile_fn, kazemi_arch

from .common import banner, save_json


def hdc_kernel(inp, weight):
    others = weight.transpose(-2, -1)
    mm = inp.matmul(others)
    return mm.topk(1, largest=False)


def run(n_queries: int = 10_000, dim: int = 8192, n_classes: int = 10):
    banner("GPU comparison — HDC/MNIST-8k, CIM system [22] vs RTX 6000")
    arch = kazemi_arch(64)
    prog = compile_fn(hdc_kernel, [(n_queries, dim), (n_classes, dim)],
                      arch, value_bits=1, unroll_limit=0)
    rep = prog.cost_report()

    cam_time_s = CIM_SYSTEM.system_time_s(rep.latency_ns, n_queries)
    cam_energy_j = CIM_SYSTEM.system_energy_j(rep.energy_fj, n_queries)

    gpu = QUADRO_RTX_6000.similarity_workload(n_queries, n_classes, dim,
                                              bytes_per_el=4)

    t_ratio = gpu["time_s"] / cam_time_s
    e_ratio = gpu["energy_j"] / cam_energy_j
    print(f"CAM system : {cam_time_s * 1e6:.1f} us, "
          f"{cam_energy_j * 1e6:.2f} uJ")
    print(f"GPU model  : {gpu['time_s'] * 1e6:.1f} us, "
          f"{gpu['energy_j'] * 1e6:.1f} uJ "
          f"(efficiency factor {QUADRO_RTX_6000.efficiency}, calibrated)")
    print(f"execution-time improvement : {t_ratio:.1f}x (paper 48x)")
    print(f"energy improvement         : {e_ratio:.1f}x (paper 46.8x)")

    assert 20 < t_ratio < 120, "time ratio must land in the paper's regime"
    assert 0.5 < (e_ratio / t_ratio) < 2.0, \
        "energy ratio tracks time ratio (CAM energy is a minor term)"

    out = {"cam_time_us": cam_time_s * 1e6,
           "cam_energy_uj": cam_energy_j * 1e6,
           "gpu_time_us": gpu["time_s"] * 1e6,
           "gpu_energy_uj": gpu["energy_j"] * 1e6,
           "time_ratio": t_ratio, "energy_ratio": e_ratio,
           "calibrated_efficiency": QUADRO_RTX_6000.efficiency}
    save_json("gpu_comparison", out)
    return out


if __name__ == "__main__":
    run()
