"""Fig. 9 — iso-capacity analysis: 2^16 TCAM cells per array.

Subarray size varies 16x16 (256 subarrays/array) .. 256x256 (1
subarray/array) with the per-array cell capacity fixed; mats/bank and
arrays/mat as before.  Note these designs are NOT iso-area (smaller
subarrays need more peripherals).

Paper observations reproduced:
* iso-base energy is nearly constant across subarray sizes,
* execution time varies in a moderate band (58us @16x16 -> 150us @256x256
  for 10k queries) — grows with column count despite constant cells/array,
* cam-density / cam-power+density average ~1.75x energy improvement over
  iso-base except at 128/256,
* power drops significantly under the density/power+density transforms.
"""

from __future__ import annotations

import numpy as np

from repro.core import ArchSpec, compile_fn

from .common import banner, save_json, table

CELLS_PER_ARRAY = 2 ** 16


def hdc_kernel(inp, weight):
    others = weight.transpose(-2, -1)
    mm = inp.matmul(others)
    return mm.topk(1, largest=False)


def run(n_queries: int = 10_000, dim: int = 8192, n_classes: int = 10):
    banner("Fig. 9 — iso-capacity (2^16 cells/array)")
    rows = []
    results = {}
    for mode, target in (("iso-base", "latency"),
                         ("cam-density", "density"),
                         ("cam-power+density", "power+density")):
        for s in (16, 32, 64, 128, 256):
            subs = CELLS_PER_ARRAY // (s * s)
            arch = ArchSpec(rows=s, cols=s, subarrays_per_array=subs,
                            arrays_per_mat=4, mats_per_bank=4,
                            banks=0).with_target(target)
            prog = compile_fn(hdc_kernel, [(n_queries, dim),
                                           (n_classes, dim)], arch,
                              value_bits=1, unroll_limit=0)
            rep = prog.cost_report()
            results[(mode, s)] = rep
            rows.append({"mode": mode, "subarray": f"{s}x{s}",
                         "subarrays/array": subs,
                         "latency_us": rep.latency_us,
                         "energy_uj": rep.energy_uj,
                         "power_w": rep.power_w})
    print(table(rows))

    base_e = [results[("iso-base", s)].energy_fj for s in (16, 32, 64, 128, 256)]
    spread = max(base_e) / min(base_e)
    print(f"\niso-base energy spread across sizes: {spread:.2f}x "
          f"(paper: nearly constant)")
    assert spread < 2.0

    base_t = [results[("iso-base", s)].latency_ns for s in (16, 32, 64, 128, 256)]
    assert base_t[-1] > base_t[0], "exec time grows with column count"
    assert base_t[-1] / base_t[0] < 6, "…but stays within a moderate band"

    imp = np.mean([results[("iso-base", s)].energy_fj
                   / results[("cam-density", s)].energy_fj
                   for s in (16, 32, 64)])
    print(f"cam-density energy improvement @16..64: {imp:.2f}x "
          f"(paper ~1.75x avg)")
    assert imp > 1.2

    for s in (16, 32, 64, 128, 256):
        assert results[("cam-power+density", s)].power_w < \
            results[("iso-base", s)].power_w

    save_json("fig9_isocapacity", rows)
    return rows


if __name__ == "__main__":
    run()
