"""Tracing overhead benchmark: the observability tax, measured.

Runs the same packed-hamming dispatch loop three ways and prices the
``repro.obs`` instrumentation against it:

* **disabled ns/call** — a tight loop over :func:`repro.obs.trace_span`
  with the recorder off: the per-call-site cost every hot path pays
  when nobody is tracing (one attribute read + branch + a shared
  singleton; no allocation).
* **disabled overhead** — that per-call cost times the span call sites
  one dispatch actually crosses, as a fraction of the dispatch time.
  Gate: <= ``REPRO_TRACE_GATE`` percent (``auto`` -> 1.0; tracing you
  are not using must be free).
* **enabled overhead** — best-of wall clock of the loop with the
  recorder on vs off.  Gate: <= 10x ``REPRO_TRACE_GATE`` percent
  (``auto`` -> 10%; recording into the bounded ring is cheap but not
  free).

Writes ``BENCH_trace.json``.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import ArchSpec, Builder, Module, PassManager, TensorType, \
    clear_plan_cache, get_plan
from repro.core.cim_dialect import (make_acquire, make_execute, make_release,
                                    make_similarity, make_yield)
from repro.core.envcfg import env_gate
from repro.core.passes import CompulsoryPartition
from repro.obs import trace as _trace

from .common import banner, save_bench_json, table

N_GALLERY = 32_768
DIM = 256
K = 10
M_QUERIES = 64
ITERS = 10          # dispatches per timed sample
REPEATS = 5         # best-of samples per configuration
CALIB_CALLS = 200_000


def _gate() -> float:
    return env_gate("REPRO_TRACE_GATE", 1.0)


def _module(m, n, dim, k, arch):
    mod = Module("trace_bench", [TensorType((m, dim)), TensorType((n, dim))])
    q, p = mod.arguments
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, [q, p],
                       [TensorType((m, k)), TensorType((m, k), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, q, p, metric="hamming", k=k, largest=False,
                          extra_attrs={"value_bits": 1})
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition(unroll_limit=64))
    return pm.run(mod, {"arch": arch})


def _disabled_ns_per_call() -> float:
    """Per-call cost of a disabled trace_span (enter+exit included)."""
    assert not _trace.tracer.enabled
    span = _trace.trace_span
    t0 = time.perf_counter_ns()
    for _ in range(CALIB_CALLS):
        with span("calib"):
            pass
    return (time.perf_counter_ns() - t0) / CALIB_CALLS


def _time_loop(plan, q, g) -> float:
    """Best-of wall clock for ITERS dispatches."""
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for _ in range(ITERS):
            v, i = plan.execute(q, g)
            np.asarray(v), np.asarray(i)
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    banner("Tracing overhead — disabled must be free, enabled cheap")
    rng = np.random.default_rng(0)
    clear_plan_cache()
    was_enabled = _trace.tracer.enabled
    _trace.stop()

    arch = ArchSpec(rows=128, cols=128)
    mod = _module(M_QUERIES, N_GALLERY, DIM, K, arch)
    g = jnp.asarray((rng.random((N_GALLERY, DIM)) > 0.5)
                    .astype(np.float32))
    q = (rng.random((M_QUERIES, DIM)) > 0.5).astype(np.float32)
    plan = get_plan(mod)
    v, i = plan.execute(q, g)                   # compile + prepare
    np.asarray(v), np.asarray(i)

    ns_per_call = _disabled_ns_per_call()
    t_off = _time_loop(plan, q, g)

    _trace.tracer.clear()
    _trace.enable()
    try:
        t_on = _time_loop(plan, q, g)
        # span call sites one dispatch actually crosses (each same-
        # thread span is one B + one E in the ring)
        _trace.tracer.clear()
        vv, ii = plan.execute(q, g)
        np.asarray(vv), np.asarray(ii)
        spans_per_dispatch = max(1, len(_trace.tracer) // 2)
    finally:
        if not was_enabled:
            _trace.stop()
        _trace.tracer.clear()

    t_dispatch_ms = 1e3 * t_off / ITERS
    off_pct = 100.0 * (ns_per_call * spans_per_dispatch) \
        / (1e9 * t_off / ITERS)
    on_pct = max(0.0, 100.0 * (t_on - t_off) / t_off)

    rows = [
        {"config": "disabled", "ns_per_call": ns_per_call,
         "dispatch_ms": t_dispatch_ms, "overhead_pct": off_pct},
        {"config": "enabled", "ns_per_call": float("nan"),
         "dispatch_ms": 1e3 * t_on / ITERS, "overhead_pct": on_pct},
    ]
    print(table(rows))
    print(f"\n{spans_per_dispatch} span call sites per dispatch")

    gate = _gate()
    payload = {
        "workload": {"n_gallery": N_GALLERY, "dim": DIM, "k": K,
                     "m_queries": M_QUERIES, "iters": ITERS,
                     "metric": "hamming", "packed": bool(plan.packed)},
        "disabled_ns_per_call": round(ns_per_call, 1),
        "spans_per_dispatch": spans_per_dispatch,
        "dispatch_ms_disabled": round(t_dispatch_ms, 3),
        "dispatch_ms_enabled": round(1e3 * t_on / ITERS, 3),
        "overhead_disabled_pct": round(off_pct, 4),
        "overhead_enabled_pct": round(on_pct, 3),
        "repeats": REPEATS,
        "gate_pct": gate,
    }
    save_bench_json("trace", payload)
    if gate:
        assert off_pct <= gate, (
            f"disabled tracing costs {off_pct:.3f}% of a dispatch "
            f"({ns_per_call:.0f} ns/call x {spans_per_dispatch} call "
            f"sites; gate: <= {gate}%); see BENCH_trace.json")
        assert on_pct <= 10 * gate, (
            f"enabled tracing costs {on_pct:.1f}% of a dispatch "
            f"(gate: <= {10 * gate}%); see BENCH_trace.json")
    return payload


if __name__ == "__main__":
    run()
