"""Packed binary/ternary search benchmark: XOR+popcount vs float hamming.

Runs the same compiled hamming search plan two ways at a CAM-realistic
geometry (128x128 subarrays, binary cells, dim >= 1024):

* **unpacked** — the float path (`pack=False`): {0,1} cells as float32,
  mismatch counts via elementwise compare+sum — 32x the memory traffic
  the data needs.
* **packed**   — the bit-packed path (`pack=True`, the default for
  binary metrics): uint32 lanes, ``popcount(q ^ p)`` — bit-identical
  results (asserted here), 1/32nd the resident gallery.

A ternary (TCAM wildcard) packed plan is timed at the same geometry for
the record.  Writes ``BENCH_packed.json``; the gate is the packed
speedup over the unpacked plan at the dim >= 1024 point:
``REPRO_PACKED_GATE=auto`` -> 4.0 (the match loop is bandwidth-bound,
so the floor is host-invariant), any float overrides, ``0``/``off``
disables.
"""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core import (ArchSpec, Builder, Module, PassManager, TensorType,
                        clear_plan_cache, get_plan)
from repro.core.cim_dialect import (make_acquire, make_execute, make_release,
                                    make_similarity, make_yield)
from repro.core.envcfg import env_gate
from repro.core.passes import CompulsoryPartition

from .common import banner, save_bench_json, table

#: (dim, n_gallery, m_queries); the first point carries the gate
POINTS = ((1024, 4096, 128), (256, 2048, 128))
K = 10
REPEATS = 5


def _hamming_module(m, n, dim, k, arch, care=False):
    """Fused (optionally ternary) hamming program through the partition
    pass — binary cells, so value_bits=1 (one CAM cell per element)."""
    args = [TensorType((m, dim)), TensorType((n, dim))]
    if care:
        args.append(TensorType((n, dim), "i8"))
    mod = Module("ham", args)
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, list(mod.arguments),
                       [TensorType((m, k)), TensorType((m, k), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, mod.arguments[0], mod.arguments[1],
                          metric="hamming", k=k, largest=False,
                          care=mod.arguments[2] if care else None,
                          extra_attrs={"value_bits": 1})
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition(unroll_limit=64))
    return pm.run(mod, {"arch": arch})


def _time_plan(plan, *inputs) -> float:
    """Best-of-REPEATS wall-clock for one full execute (host-synced)."""
    v, i = plan.execute(*inputs)                # compile + prepare (warmup)
    np.asarray(v), np.asarray(i)
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        v, i = plan.execute(*inputs)
        np.asarray(v), np.asarray(i)
        best = min(best, time.perf_counter() - t0)
    return best


def _gate() -> float:
    return env_gate("REPRO_PACKED_GATE", 4.0)


def run():
    banner("Packed search — XOR+popcount vs float hamming plans")
    rng = np.random.default_rng(0)
    arch = ArchSpec(rows=128, cols=128)
    rows, results = [], {}
    for dim, n, m in POINTS:
        mod = _hamming_module(m, n, dim, K, arch)
        clear_plan_cache()
        unpacked = get_plan(mod, pack=False)
        packed = get_plan(mod, pack=True)
        q = (rng.random((m, dim)) > 0.5).astype(np.float32)
        g = jnp.asarray((rng.random((n, dim)) > 0.5).astype(np.float32))

        # the gate is only meaningful if the paths agree bit-for-bit
        pv, pi = packed.execute(q, g)
        uv, ui = unpacked.execute(q, g)
        assert np.array_equal(np.asarray(pv), np.asarray(uv)) and \
            np.array_equal(np.asarray(pi), np.asarray(ui)), \
            "packed result diverged from the unpacked hamming plan"

        t_unpacked = _time_plan(unpacked, q, g)
        t_packed = _time_plan(packed, q, g)

        tmod = _hamming_module(m, n, dim, K, arch, care=True)
        ternary = get_plan(tmod)
        care = jnp.asarray((rng.random((n, dim)) > 0.25).astype(np.int8))
        t_ternary = _time_plan(ternary, q, g, care)

        speedup = t_unpacked / max(t_packed, 1e-9)
        results[f"dim{dim}"] = {
            "dim": dim, "n_gallery": n, "m_queries": m, "k": K,
            "unpacked_ms": round(1e3 * t_unpacked, 2),
            "packed_ms": round(1e3 * t_packed, 2),
            "ternary_packed_ms": round(1e3 * t_ternary, 2),
            "speedup": round(speedup, 2),
        }
        rows.append({"dim": dim, "unpacked_ms": 1e3 * t_unpacked,
                     "packed_ms": 1e3 * t_packed,
                     "ternary_ms": 1e3 * t_ternary, "speedup": speedup})
    print(table(rows))

    gate = _gate()
    gated = results[f"dim{POINTS[0][0]}"]
    payload = {
        "points": results,
        "repeats": REPEATS,
        "gate": gate,
        "gate_point": f"dim{POINTS[0][0]}",
        "speedup": gated["speedup"],
    }
    save_bench_json("packed", payload)
    if gate:
        assert gated["speedup"] >= gate, (
            f"packed plan only {gated['speedup']:.2f}x over the unpacked "
            f"hamming plan at dim={POINTS[0][0]} (gate: >= {gate}x); "
            f"see BENCH_packed.json")
    return payload


if __name__ == "__main__":
    run()
