"""Shared helpers for the benchmark drivers."""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, List, Optional

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(ROOT, "artifacts", "bench")


def save_json(name: str, payload: Any) -> str:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def save_bench_json(name: str, payload: Any) -> str:
    """Timing record for the perf trajectory: ``BENCH_<name>.json`` at the
    repo root, so successive perf PRs have a comparable baseline.

    ``*_smoke`` records are CI-run side products, not baselines — they
    land in a scratch directory (``REPRO_BENCH_SMOKE_DIR``, default
    under the system temp dir) instead of littering the repo root.
    A blank value raises (``env_path`` contract — a shell quoting
    accident, not a request to write into ``""``), and a relative one
    is anchored under the temp dir rather than wherever the benchmark
    process happens to be cwd'd.
    """
    if name.endswith("_smoke"):
        from repro.core.envcfg import env_path
        base = env_path("REPRO_BENCH_SMOKE_DIR")
        if base is None:
            base = os.path.join(tempfile.gettempdir(), "repro-bench-smoke")
        elif not os.path.isabs(base):
            base = os.path.join(tempfile.gettempdir(), base)
        os.makedirs(base, exist_ok=True)
        path = os.path.join(base, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        return path
    path = os.path.join(ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def table(rows: List[Dict[str, Any]], cols: Optional[List[str]] = None,
          floatfmt: str = "{:.4g}") -> str:
    if not rows:
        return "(empty)"
    cols = cols or list(rows[0])
    widths = {c: max(len(c), *(len(_fmt(r.get(c, ""), floatfmt))
                               for r in rows)) for c in cols}
    head = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-+-".join("-" * widths[c] for c in cols)
    body = "\n".join(" | ".join(_fmt(r.get(c, ""), floatfmt).ljust(widths[c])
                                for c in cols) for r in rows)
    return f"{head}\n{sep}\n{body}"


def _fmt(v: Any, floatfmt: str) -> str:
    if isinstance(v, float):
        return floatfmt.format(v)
    return str(v)


def banner(title: str) -> None:
    print("\n" + "=" * 72)
    print(title)
    print("=" * 72)
