"""HDC benchmark: incremental ``update_rows`` vs full gallery re-prepare.

The tentpole claim of the mutable-gallery engine: an online-learning
workload that touches a small fraction of a large gallery (HDC
retraining rewrites a handful of class rows; one-shot learners touch a
few exemplars) must not pay a full re-encode + re-pack + re-layout of
every stored row.  This benchmark mutates ``rows_touched`` rows spread
over a few row tiles of a large packed bipolar gallery and times

* **incremental** — ``plan.update_rows(donate=True)``: in-place source
  scatter + touched row tiles re-laid + memo seeded,
* **full**        — the same donated scatter followed by a full
  gallery re-prepare (pattern-memo miss: every row re-encoded,
  re-packed and re-laid).

Both timings run to *servable*: they block until the prepared layout
the next dispatch would use is materialised.  The per-search cost is
recorded separately (identical for both paths — a memo hit).  Results
are checked bit-identical before timing.  Writes
``BENCH_hdc.json``; the gate is the incremental speedup at the large
point: ``REPRO_HDC_GATE=auto`` -> 3.0, any float overrides, ``0``/
``off`` disables.  An informational HDC retraining record (one-shot ->
retrained accuracy on the synthetic MNIST stand-in) rides along.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import ArchSpec, clear_plan_cache, get_plan
from repro.core.envcfg import env_gate
from repro.core.cim_dialect import (make_acquire, make_execute, make_release,
                                    make_similarity, make_yield)
from repro.core.ir import Builder, Module, PassManager, TensorType
from repro.core.passes import CompulsoryPartition
from repro.hdc.encoding import random_hypervectors

from .common import banner, save_bench_json, table

#: (n_rows, dim, rows_touched, tiles_touched); first point carries the gate
POINTS = ((10_000, 2048, 100, 4), (4096, 1024, 40, 2))
REPEATS = 9


def _gate() -> float:
    return env_gate("REPRO_HDC_GATE", 3.0)


def _sim_module(m, n, dim, arch):
    mod = Module("hdc_bench", [TensorType((m, dim)), TensorType((n, dim))])
    q, p = mod.arguments
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, [q, p],
                       [TensorType((m, 1)), TensorType((m, 1), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, q, p, metric="dot", k=1, largest=True)
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition())
    return pm.run(mod, {"arch": arch})


def _time(fn) -> float:
    fn()                                    # warmup (compile + prepare)
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _draw_update(rng, n, tile_rows, rows_touched, tiles_touched, dim):
    """Rows clustered in a few tiles — the locality retraining has
    (few classes touched per epoch, class rows adjacent)."""
    tiles = rng.choice(n // tile_rows, size=tiles_touched, replace=False)
    pool = (tiles[:, None] * tile_rows
            + np.arange(tile_rows)[None, :]).reshape(-1)
    pool = pool[pool < n]
    idx = np.sort(rng.choice(pool, size=rows_touched, replace=False))
    return idx, random_hypervectors(rng, rows_touched, dim)


def _bench_updates():
    rng = np.random.default_rng(0)
    rows_out, results = [], {}
    for n, dim, touched, tiles in POINTS:
        clear_plan_cache()
        tile_rows = 128
        arch = ArchSpec(rows=tile_rows, cols=512)
        mod = _sim_module(8, n, dim, arch)
        plan = get_plan(mod)
        assert plan.packed, "bipolar dot should auto-pack"
        q = random_hypervectors(rng, 8, dim)
        g0 = jnp.asarray(random_hypervectors(rng, n, dim))
        plan.execute(q, g0)                 # compile + initial prepare

        # parity before timing: incremental layout == full re-prepare
        idx, new = _draw_update(rng, n, tile_rows, touched, tiles, dim)
        g_inc = plan.update_rows(g0, idx, new)
        v1, i1 = plan.execute(q, g_inc)
        clear_plan_cache()
        check = get_plan(mod)
        v2, i2 = check.execute(q, np.asarray(g_inc))
        assert np.array_equal(np.asarray(i1), np.asarray(i2)) and \
            np.array_equal(np.asarray(v1), np.asarray(v2)), \
            "incremental update diverged from full re-prepare"
        clear_plan_cache()
        plan = get_plan(mod)
        plan.execute(q, g0)

        state = {"g": g0, "step": 0}
        # pre-drawn update stream: the timed region is the update path
        # itself, not the RNG producing the new rows
        updates = [_draw_update(rng, n, tile_rows, touched, tiles, dim)
                   for _ in range(2 * (REPEATS + 1))]

        def next_update():
            idx, new = updates[state["step"] % len(updates)]
            state["step"] += 1
            return idx, new

        def block_prepared(g):
            """Block until the layout the next dispatch serves from is
            materialised (memo hit for inc, full prepare for full)."""
            for leaf in plan._prepared_patterns(g):
                leaf.block_until_ready()

        def incremental():
            idx, new = next_update()
            state["g"] = plan.update_rows(state["g"], idx, new, donate=True)
            block_prepared(state["g"])

        def full():
            from repro.core.engine import _scatter_rows_donated

            idx, new = next_update()
            g2 = _scatter_rows_donated(state["g"], jnp.asarray(idx),
                                       jnp.asarray(new))
            state["g"] = g2                  # fresh array: memo miss
            block_prepared(g2)

        fb0 = plan.row_update_fallbacks
        t_inc = _time(incremental)
        assert plan.row_update_fallbacks == fb0, \
            "incremental path fell back to full re-prepare"
        t_full = _time(full)
        t_search = _time(
            lambda: plan.execute(q, state["g"])[1].block_until_ready())

        speedup = t_full / max(t_inc, 1e-9)
        key = f"n{n}"
        results[key] = {
            "n": n, "dim": dim, "rows_touched": touched,
            "tiles_touched": tiles, "tile_rows": tile_rows,
            "touched_frac": round(touched / n, 4),
            "incremental_ms": round(1e3 * t_inc, 3),
            "full_reprepare_ms": round(1e3 * t_full, 3),
            "search_ms": round(1e3 * t_search, 3),
            "speedup": round(speedup, 2),
        }
        rows_out.append({"n": n, "dim": dim, "touched": touched,
                         "inc_ms": 1e3 * t_inc, "full_ms": 1e3 * t_full,
                         "search_ms": 1e3 * t_search, "speedup": speedup})
    print(table(rows_out))
    return results


def _bench_retrain():
    """Informational: the served workload the update path exists for."""
    from repro.data import hdc_mnist_dataset
    from repro.hdc import HdcClassifier

    train_x, train_y, test_x, test_y = hdc_mnist_dataset()
    clf = HdcClassifier(train_x.shape[1], 10, dim=2048, n_levels=16, seed=0)
    clf.fit(train_x, train_y).compile(ArchSpec(rows=8, cols=128),
                                      batch_hint=128)
    enc_tr = clf.encode(train_x)
    enc_te = clf.encode(test_x)
    acc0 = float((clf.predict(encoded=enc_te) == test_y).mean())
    pushed_total = 0
    for _ in range(6):
        _, pushed = clf.retrain_epoch(train_x, train_y, encoded=enc_tr)
        pushed_total += pushed
    acc1 = float((clf.predict(encoded=enc_te) == test_y).mean())
    print(f"hdc retrain: one-shot {acc0:.3f} -> retrained {acc1:.3f} "
          f"({pushed_total} AM rows pushed incrementally)")
    return {"one_shot_acc": round(acc0, 4), "retrained_acc": round(acc1, 4),
            "rows_pushed": pushed_total,
            "row_update_fallbacks": clf.plan.row_update_fallbacks}


def run():
    banner("HDC — incremental update_rows vs full gallery re-prepare")
    results = _bench_updates()
    retrain = _bench_retrain()

    gate = _gate()
    first = POINTS[0]
    gated = results[f"n{first[0]}"]
    payload = {
        "points": results,
        "retrain": retrain,
        "repeats": REPEATS,
        "gate": gate,
        "gate_point": f"n{first[0]}",
        "speedup": gated["speedup"],
    }
    save_bench_json("hdc", payload)
    if gate:
        assert gated["speedup"] >= gate, (
            f"incremental update_rows only {gated['speedup']:.2f}x over "
            f"full re-prepare (gate: >= {gate}x); see BENCH_hdc.json")
    return payload


if __name__ == "__main__":
    run()
