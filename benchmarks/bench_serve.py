"""Serving throughput/latency benchmark: single- vs multi-device sharding.

Drives the continuous-batching CAM search server
(`repro.serving.CamSearchServer`) with concurrent client threads
submitting KNN query blocks against one cached SearchPlan, twice:

* **single** — one host device, the unsharded scan executable;
* **sharded** — ``--xla_force_host_platform_device_count=N`` forced host
  devices, gallery rows sharded over the ``("data",)`` mesh with the
  cross-device ``merge_topk`` tournament.

Device count is fixed at jax import, so each configuration runs in its
own subprocess with its own ``XLA_FLAGS``; the parent collects the two
JSON records, computes the speedup, and writes ``BENCH_serve.json``.
The PR gate is >= 2x query throughput for the sharded configuration
(override with ``REPRO_SERVE_GATE``; set <= 0 to record without
gating).

    PYTHONPATH=src python -m benchmarks.bench_serve            # both + gate
    PYTHONPATH=src python -m benchmarks.bench_serve --devices 4
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import threading
import time

from .common import banner, save_bench_json, table

_MARK = "SERVE-RESULT "

# Table-II-style KNN shape on a multi-bit CAM (one cell per 8-bit value,
# so a 256-col subarray holds a full 256-dim pattern row: dims_per_tile
# = 256, grid_cols = 1).  Paper-scale 64-row subarrays make the
# single-device plan a long serial row-tile scan — exactly the regime
# the bank-level sharding attacks — and the deep gallery keeps per-chunk
# compute far above the Python serving overhead.
N_GALLERY = 32768
DIM = 256
SUBARRAY_ROWS = 256     # ArchSpec rows -> tile_rows (Table-I scale subarray)
SUBARRAY_COLS = 256     # ArchSpec cols -> dims_per_tile (at 1 cell/value)
VALUE_BITS = 8
K = 8
PLAN_BATCH = 128        # traced micro-batch (example query rows)
CLIENTS = 8
ROWS_PER_REQUEST = 128
REQUESTS_PER_CLIENT = 6
WINDOWS = 3             # timed windows per child; best-of damps CI noise


def _child(shards: int) -> dict:
    """Runs inside the subprocess (XLA_FLAGS already set by the parent)."""
    import numpy as np

    from repro.core import ArchSpec, CamType, compile_fn
    from repro.serving import CamSearchServer

    def knn_kernel(q, gallery):
        diff = q.unsqueeze(1).sub(gallery)
        d = diff.norm(p=2, dim=-1)
        return d.topk(K, largest=False)

    rng = np.random.default_rng(0)
    gallery = rng.standard_normal((N_GALLERY, DIM)).astype(np.float32)
    example_q = rng.standard_normal((PLAN_BATCH, DIM)).astype(np.float32)

    t0 = time.perf_counter()
    arch = ArchSpec(rows=SUBARRAY_ROWS, cols=SUBARRAY_COLS, banks=4096,
                    cam_type=CamType.MCAM, bits_per_cell=VALUE_BITS)
    prog = compile_fn(knn_kernel, [example_q, gallery], arch,
                      cam_type=CamType.MCAM, value_bits=VALUE_BITS,
                      shards=shards)
    plan = prog.engine_plan
    assert plan is not None
    compile_s = time.perf_counter() - t0

    srv = CamSearchServer(prog, gallery, max_wait_ms=2.0)
    total_q = CLIENTS * REQUESTS_PER_CLIENT * ROWS_PER_REQUEST
    with srv:
        # warm: trace + prepared-pattern layout out of the timed region
        srv.search(example_q)

        queries = [rng.standard_normal((ROWS_PER_REQUEST, DIM)
                                       ).astype(np.float32)
                   for _ in range(CLIENTS * REQUESTS_PER_CLIENT)]
        checks = []

        def client(cid: int):
            for r in range(REQUESTS_PER_CLIENT):
                q = queries[cid * REQUESTS_PER_CLIENT + r]
                v, i = srv.search(q)
                if r == 0:
                    checks.append((cid, q, v, i))

        walls = []
        for _ in range(WINDOWS):
            checks.clear()
            threads = [threading.Thread(target=client, args=(c,))
                       for c in range(CLIENTS)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            walls.append(time.perf_counter() - t0)

        # spot-check served results against the plan driven directly
        for _, q, v, i in checks[:2]:
            dv, di = plan.execute(q, gallery)
            assert np.array_equal(np.asarray(di), i), "served indices diverged"
            np.testing.assert_allclose(np.asarray(dv), v, atol=1e-4)

        snap = srv.snapshot()

    wall = min(walls)       # best window: steady-state, CI-noise-damped
    import jax
    return {
        "devices": jax.device_count(),
        "shards": plan.shards,
        "plan_batch": plan.batch,
        "compile_s": round(compile_s, 3),
        "wall_s": round(wall, 4),
        "window_walls_s": [round(w, 4) for w in walls],
        "queries": total_q,
        "qps": round(total_q / wall, 1),
        "requests": snap["requests"],
        "batches": snap["batches"],
        "avg_batch_fill": round(snap["avg_batch_fill"], 2),
        "p50_ms": round(snap.get("p50_ms", 0.0), 2),
        "p95_ms": round(snap.get("p95_ms", 0.0), 2),
    }


def _spawn(device_count: int, shards: int) -> dict:
    from repro.launch.mesh import forced_host_devices_env
    env = forced_host_devices_env(device_count)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src") + os.pathsep +
        env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serve",
         "--run-child", str(shards)],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    for line in out.stdout.splitlines():
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    raise RuntimeError(
        f"serve child (devices={device_count}) produced no result:\n"
        f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}")


def _plan_store_warmstart_check():
    """When CI configures ``REPRO_PLAN_STORE``, prove the serving layer
    actually consumes it: tune + persist a small workload, then build a
    server from the *heuristic* plan and assert construction swapped in
    the stored winner without re-running the search, and that the served
    results match the tuned plan bit-for-bit.  Returns the check record,
    or ``None`` when no store is configured."""
    from repro.core.envcfg import env_path
    if env_path("REPRO_PLAN_STORE") is None:
        return None
    import numpy as np

    from repro.core import get_plan
    from repro.serving import CamSearchServer
    from repro.tune import reset_tune_stats, tune_plan, tune_stats
    from .bench_tune import _data, _module

    shape = dict(metric="hamming", k=4, m=16, n=512, dim=64,
                 rows=16, cols=32)
    mod = _module(shape)
    q, p = _data(shape, seed=3)
    tuned = tune_plan(mod, q, p, trials=4, reps=1)
    heuristic = get_plan(mod)

    reset_tune_stats()
    with CamSearchServer(heuristic, p) as srv:
        assert srv.plan.spec.tile_rows == tuned.config["tile_rows"], \
            "server construction ignored the stored tuned config"
        assert tune_stats()["trials"] == 0, \
            "server warm start re-ran tune trials"
        v, i = srv.search(q)
    tv, ti = tuned.plan.execute(q, p)
    assert np.array_equal(np.asarray(ti), np.asarray(i)), \
        "warm-started server indices diverged from the tuned plan"
    assert np.array_equal(np.asarray(tv), np.asarray(v)), \
        "warm-started server values diverged from the tuned plan"
    print("plan-store warm start: server adopted the stored tuned plan "
          f"(tile_rows {heuristic.spec.tile_rows} -> "
          f"{srv.plan.spec.tile_rows}, 0 trials)")
    return {"stored_tile_rows": tuned.config["tile_rows"],
            "heuristic_tile_rows": heuristic.spec.tile_rows,
            "trials_at_serve": 0}


def run(devices: int = 8, rounds: int = 2) -> dict:
    """Interleave single/sharded child runs and score each config by its
    best round — paired scheduling plus best-of damps host noise."""
    banner("Serve — continuous-batching CAM search: single vs sharded")
    single: dict = {}
    sharded: dict = {}
    for _ in range(max(1, rounds)):
        s = _spawn(1, 1)
        m = _spawn(devices, devices)
        if not single or s["qps"] > single["qps"]:
            single = s
        if not sharded or m["qps"] > sharded["qps"]:
            sharded = m
    speedup = sharded["qps"] / max(single["qps"], 1e-9)

    rows = [{"config": "single device", **{k: single[k] for k in
             ("devices", "shards", "qps", "p50_ms", "p95_ms")}},
            {"config": f"sharded x{devices}", **{k: sharded[k] for k in
             ("devices", "shards", "qps", "p50_ms", "p95_ms")}}]
    print(table(rows))
    print(f"\nquery throughput speedup: {speedup:.2f}x")

    # Gate: the 2x target presumes the host can actually run >= 2 shard
    # programs truly in parallel (>= 4 cores, or real accelerators).
    # Compute-identical paths on an H-core host cap at ~H / (cores the
    # single-device run already uses), so a 2-core CI box tops out below
    # 2x no matter how well the sharded path runs — record that honestly
    # instead of failing on hardware the benchmark cannot control.
    from repro.core.envcfg import env_gate

    host_cores = os.cpu_count() or 1
    gate = env_gate("REPRO_SERVE_GATE",
                    2.0 if host_cores >= 4 else 1.4)

    payload = {
        "workload": {"n_gallery": N_GALLERY, "dim": DIM, "k": K,
                     "metric": "eucl", "subarray_rows": SUBARRAY_ROWS,
                     "subarray_cols": SUBARRAY_COLS,
                     "value_bits": VALUE_BITS, "plan_batch": PLAN_BATCH,
                     "clients": CLIENTS,
                     "rows_per_request": ROWS_PER_REQUEST,
                     "requests_per_client": REQUESTS_PER_CLIENT,
                     "windows": WINDOWS},
        "host_cores": host_cores,
        "gate": gate,
        "single": single,
        "sharded": sharded,
        "throughput_speedup": round(speedup, 2),
    }
    store_check = _plan_store_warmstart_check()
    if store_check is not None:
        payload["plan_store_warmstart"] = store_check
    save_bench_json("serve", payload)

    if gate > 0:
        assert speedup >= gate, (
            f"sharded serving only {speedup:.2f}x the single-device "
            f"throughput (gate: >= {gate}x on a {host_cores}-core host); "
            f"see BENCH_serve.json")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count for the sharded run")
    ap.add_argument("--run-child", type=int, default=None, metavar="SHARDS",
                    help=argparse.SUPPRESS)   # internal: in-process measure
    args = ap.parse_args(argv)
    if args.run_child is not None:
        print(_MARK + json.dumps(_child(args.run_child)))
        return 0
    run(devices=args.devices)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
