"""Fig. 7 — validation of C4CAM-generated code against the hand-crafted
HDC mapping of Kazemi et al. [22].

The paper compiles binary and multi-bit HDC (MNIST, 8k dims) for CAM
arrays of 32 x C, C in {16, 32, 64, 128}, with 4 mats/bank, 4 arrays/mat,
8 subarrays/array, and validates generated latency/energy against the
manual design (geomean deviation 0.9% / 5.5%).

Our "manual design" baseline is the closed-form mapping a designer would
write for this workload (row-major tile placement, fully parallel search,
one search cycle per query) priced by the same Eva-CAM-analog technology
model; C4CAM's numbers come from the full compile pipeline.  The check is
that the compiler reaches the hand mapping (deviation ~0 by construction
of a correct compiler — the paper's deviations stem from simulator-version
skew, which we do not reproduce) and that the *trends* match the paper:
latency grows with C (slower ML discharge), energy falls with C (fewer
peripherals), binary beats multi-bit on energy.
"""

from __future__ import annotations

import math

import numpy as np

from repro.camsim import CostModel
from repro.core import compile_fn, kazemi_arch
from repro.core.passes.cam_map import MappingPlan, derive_plan
from repro.core.passes.partition import tile_grid
from repro.data import hdc_dataset

from .common import banner, save_json, table


def hdc_kernel(inp, weight):
    others = weight.transpose(-2, -1)
    mm = inp.matmul(others)
    return mm.topk(1, largest=False)


def manual_mapping_cost(arch, m, n, dim, value_bits):
    """The hand-crafted design: closed-form row-major mapping + cost."""
    gr, gc, cpv, dpt = tile_grid(arch, n, dim, value_bits)
    plan = derive_plan(arch, dict(
        m=m, n=n, dim=dim, grid_rows=gr, grid_cols=gc, dims_per_tile=dpt,
        cells_per_value=cpv, value_bits=value_bits, metric="dot", k=1,
        largest=True))
    return CostModel(arch).plan_report(plan)


def run(n_queries: int = 10_000, dim: int = 8192, n_classes: int = 10):
    banner("Fig. 7 — validation vs hand-crafted HDC mapping "
           "(binary + multi-bit, 32 x C)")
    rows = []
    for bits, tag in ((1, "binary"), (8, "multi-bit")):
        for c in (16, 32, 64, 128):
            arch = kazemi_arch(c, rows=32, bits_per_cell=min(bits, 2))
            prog = compile_fn(hdc_kernel, [(n_queries, dim),
                                           (n_classes, dim)], arch,
                              value_bits=bits, unroll_limit=0)
            rep = prog.cost_report()
            man = manual_mapping_cost(arch, n_queries, n_classes, dim, bits)
            dev_lat = abs(rep.latency_ns - man.latency_ns) / man.latency_ns
            dev_en = abs(rep.energy_fj - man.energy_fj) / man.energy_fj
            rows.append({
                "impl": tag, "array": f"32x{c}",
                "c4cam_latency_us": rep.latency_us,
                "manual_latency_us": man.latency_us,
                "c4cam_energy_uj": rep.energy_uj,
                "manual_energy_uj": man.energy_uj,
                "dev_latency_%": 100 * dev_lat, "dev_energy_%": 100 * dev_en,
            })
    print(table(rows))

    # paper trends
    bin_rows = [r for r in rows if r["impl"] == "binary"]
    lat = [r["c4cam_latency_us"] for r in bin_rows]
    en = [r["c4cam_energy_uj"] for r in bin_rows]
    assert all(b > a for a, b in zip(lat, lat[1:])), \
        "latency must grow with C (ML discharge)"
    assert all(b < a for a, b in zip(en, en[1:])), \
        "energy must fall with C (fewer peripherals)"
    mb = [r["c4cam_energy_uj"] for r in rows if r["impl"] == "multi-bit"]
    assert all(m > b for m, b in zip(mb, en)), \
        "multi-bit must cost more energy than binary (ML/DL voltages)"
    dev = float(np.exp(np.mean([np.log(max(r["dev_latency_%"], 1e-9) + 1)
                                for r in rows])) - 1)
    print(f"\ngeomean latency deviation vs manual: {dev:.3f}% "
          f"(paper: 0.9% from simulator-version skew)")

    # functional validation: compiled CAM result classifies like the dense
    # reference on the HDC recall task.  (The paper's Fig. 4a snippet uses
    # largest=False — complement-encoded weights; recall itself is
    # best-match = largest dot = smallest Hamming.)
    def hdc_recall(inp, weight):
        mm = inp.matmul(weight.transpose(-2, -1))
        return mm.topk(1, largest=True)

    classes, queries, labels = hdc_dataset(n_classes=n_classes, dim=dim,
                                           n_queries=256)
    prog = compile_fn(hdc_recall, [queries[:256], classes],
                      kazemi_arch(32), value_bits=1)
    _, idx = prog(queries[:256], classes)
    acc = float((np.asarray(idx).ravel() == labels[:256]).mean())
    print(f"functional accuracy (CAM == dense-reference recall): {acc:.3f}")
    assert acc > 0.99

    save_json("fig7_validation", {"rows": rows, "geomean_dev_pct": dev,
                                  "functional_accuracy": acc})
    return rows


if __name__ == "__main__":
    run()
