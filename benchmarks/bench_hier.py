"""Hierarchical search benchmark: two-stage coarse→fine vs flat scan.

Builds a CAM-scale packed hamming gallery (>= 100k rows, clustered the
way real retrieval corpora are) and runs the same top-k search two
ways:

* **flat** — the ordinary ``SearchPlan``: every row tile probed for
  every query (the bit-exact oracle),
* **hierarchical** — ``get_hierarchical_plan``: a coarse centroid
  search picks ``nprobe`` clusters per query, the fine stage probes
  only those clusters' tiles.

For each ``nprobe`` in the sweep the recall against the flat oracle's
top-k, the wall-clock speedup, and a trace-derived coarse/probe stage
breakdown (``repro.obs`` spans) are recorded.  Writes
``BENCH_hier.json``; two gates:

* **tuned** — the smallest swept ``nprobe`` whose recall clears
  ``RECALL_FLOOR`` (0.95) must beat the flat plan by
  ``REPRO_HIER_GATE`` (``auto`` -> 3.0, any float overrides,
  ``0``/``off`` disables);
* **wide** — the widest swept ``nprobe`` must not *lose* to the flat
  plan (``REPRO_HIER_WIDE_GATE``, ``auto`` -> 1.0).  Before the
  occupancy-bounded probe budget (the fix the roofline report drove)
  nprobe=16 ran at 0.82x — uniform tiles-per-cluster padding made the
  fine gather touch ~1.8x the steps the occupancy distribution needs.

Bit-identity at ``nprobe == clusters`` is pinned by the test suite
(``tests/test_hier.py``, ``tests/test_parity_fuzz.py``), not re-timed
here.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import ArchSpec, Builder, Module, PassManager, TensorType, \
    clear_plan_cache, get_plan
from repro.core.cim_dialect import (make_acquire, make_execute, make_release,
                                    make_similarity, make_yield)
from repro.core.engine import get_hierarchical_plan
from repro.core.envcfg import env_gate
from repro.core.passes import CompulsoryPartition
from repro.obs import trace as _trace

from .common import banner, save_bench_json, table

N_GALLERY = 131_072
DIM = 256
K = 10
M_QUERIES = 64
CLUSTERS = 128
NPROBES = (4, 8, 16)
KMEANS_ITERS = 4
REPEATS = 5
#: the tuned operating point must recall at least this much of the
#: flat oracle's top-k
RECALL_FLOOR = 0.95


def _gate() -> float:
    return env_gate("REPRO_HIER_GATE", 3.0)


def _wide_gate() -> float:
    return env_gate("REPRO_HIER_WIDE_GATE", 1.0)


def _stage_breakdown(plan, q, g):
    """One traced execute -> {coarse_ms, probe_ms} from the engine
    spans (off the timed path; the recorder is cleared afterwards)."""
    was_enabled = _trace.tracer.enabled
    _trace.tracer.clear()
    _trace.enable()
    try:
        v, i = plan.execute(q, g)
        np.asarray(v), np.asarray(i)
    finally:
        if not was_enabled:
            _trace.stop()
    stats = _trace.span_stats()
    out = {}
    for span, key in (("hier.coarse", "coarse_ms"),
                      ("hier.probe", "probe_ms")):
        if span in stats:
            out[key] = round(stats[span]["total_ms"], 2)
    _trace.tracer.clear()
    return out


def _hamming_module(m, n, dim, k, arch):
    mod = Module("hier_bench", [TensorType((m, dim)), TensorType((n, dim))])
    q, p = mod.arguments
    b = Builder(mod.body)
    dev = make_acquire(b)
    exe = make_execute(b, dev.result, [q, p],
                       [TensorType((m, k)), TensorType((m, k), "i32")])
    blk = exe.region().block()
    sim = make_similarity(blk, q, p, metric="hamming", k=k, largest=False,
                          extra_attrs={"value_bits": 1})
    make_yield(blk, sim.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    pm = PassManager()
    pm.add(CompulsoryPartition(unroll_limit=64))
    return pm.run(mod, {"arch": arch})


def _clustered_gallery(rng, n, dim, centers, flip=0.05):
    """Binary rows drawn around ``centers`` prototypes — the locality a
    retrieval corpus has and the coarse stage exploits."""
    protos = (rng.random((centers, dim)) > 0.5)
    owner = rng.integers(centers, size=n)
    rows = protos[owner] ^ (rng.random((n, dim)) < flip)
    return rows.astype(np.float32)


def _time_plan(plan, q, g) -> float:
    v, i = plan.execute(q, g)                   # compile + prepare (warmup)
    np.asarray(v), np.asarray(i)
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        v, i = plan.execute(q, g)
        np.asarray(v), np.asarray(i)
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    banner("Hierarchical search — coarse→fine probing vs flat scan")
    rng = np.random.default_rng(0)
    clear_plan_cache()
    arch = ArchSpec(rows=128, cols=128)
    mod = _hamming_module(M_QUERIES, N_GALLERY, DIM, K, arch)

    g_np = _clustered_gallery(rng, N_GALLERY, DIM, CLUSTERS)
    # queries: perturbed gallery rows — nearest neighbours exist and are
    # cluster-local, the regime hierarchical probing is for
    qi = rng.choice(N_GALLERY, size=M_QUERIES, replace=False)
    q = (g_np[qi].astype(bool)
         ^ (rng.random((M_QUERIES, DIM)) < 0.05)).astype(np.float32)
    g = jnp.asarray(g_np)

    flat = get_plan(mod)
    assert flat.packed, "hamming at this geometry should auto-pack"
    t_flat = _time_plan(flat, q, g)
    fv, fi = flat.execute(q, g)
    flat_sets = [set(map(int, row)) for row in np.asarray(fi)]

    rows_out, sweep = [], {}
    for nprobe in NPROBES:
        plan = get_hierarchical_plan(mod, clusters=CLUSTERS, nprobe=nprobe,
                                     kmeans_iters=KMEANS_ITERS)
        t = _time_plan(plan, q, g)
        _, hi = plan.execute(q, g)
        recall = float(np.mean([
            len(set(map(int, row)) & fs) / K
            for row, fs in zip(np.asarray(hi), flat_sets)]))
        speedup = t_flat / max(t, 1e-9)
        stages = _stage_breakdown(plan, q, g)
        sweep[f"nprobe{nprobe}"] = {
            "nprobe": nprobe, "clusters": CLUSTERS,
            "probed_frac": round(nprobe / CLUSTERS, 4),
            "hier_ms": round(1e3 * t, 2),
            "recall": round(recall, 4),
            "speedup": round(speedup, 2),
            "stages": stages,
        }
        rows_out.append({"nprobe": nprobe, "hier_ms": 1e3 * t,
                         "flat_ms": 1e3 * t_flat, "recall": recall,
                         "speedup": speedup, **stages})
    print(table(rows_out))

    gate = _gate()
    wide_gate = _wide_gate()
    tuned = next((s for s in sweep.values() if s["recall"] >= RECALL_FLOOR),
                 None)
    wide = sweep[f"nprobe{max(NPROBES)}"]
    payload = {
        "workload": {"n_gallery": N_GALLERY, "dim": DIM, "k": K,
                     "m_queries": M_QUERIES, "clusters": CLUSTERS,
                     "kmeans_iters": KMEANS_ITERS, "metric": "hamming",
                     "packed": True},
        "flat_ms": round(1e3 * t_flat, 2),
        "sweep": sweep,
        "repeats": REPEATS,
        "recall_floor": RECALL_FLOOR,
        "gate": gate,
        "tuned": tuned,
        "wide_gate": wide_gate,
        "wide": wide,
    }
    save_bench_json("hier", payload)
    if wide_gate:
        assert wide["speedup"] >= wide_gate, (
            f"hierarchical plan at the widest probe "
            f"(nprobe={wide['nprobe']}) only {wide['speedup']:.2f}x over "
            f"the flat plan (gate: >= {wide_gate}x) — the occupancy-"
            f"bounded probe budget should keep the wide point ahead of "
            f"a dense scan; see BENCH_hier.json")
    if gate:
        assert tuned is not None, (
            f"no swept nprobe reached recall >= {RECALL_FLOOR} "
            f"(sweep: { {k: s['recall'] for k, s in sweep.items()} }); "
            f"see BENCH_hier.json")
        assert tuned["speedup"] >= gate, (
            f"hierarchical plan at nprobe={tuned['nprobe']} (recall "
            f"{tuned['recall']:.3f}) only {tuned['speedup']:.2f}x over the "
            f"flat plan (gate: >= {gate}x); see BENCH_hier.json")
    return payload


if __name__ == "__main__":
    run()
