"""Table II — KNN: EDP and power across subarray sizes.

KNN on the Pneumonia X-ray stand-in gallery (180k patterns, 1024-d
features, 8-bit quantized -> thermometer-coded cells).  The paper reports
EDP (nJ*s) and power (W) for cam-based and cam-power at square subarray
sizes 16..256; absolute values are much higher than HDC "simply due to the
sheer size of the Pneumonia dataset, requiring many banks".

Reproduction claims: cam-power cuts power by the same mechanism as HDC
(fewer active subarrays), raises EDP (latency grows faster than energy
stays flat), both EDP columns fall with subarray size, and KNN needs
orders of magnitude more banks than HDC.
"""

from __future__ import annotations

import numpy as np

from repro.core import ArchSpec, compile_fn
from repro.data import knn_dataset
from repro.kernels import ref as kref

from .common import banner, save_json, table


def knn_kernel(q, gallery):
    diff = q.unsqueeze(1).sub(gallery)
    d = diff.norm(p=2, dim=-1)
    return d.topk(5, largest=False)


def run(n_gallery: int = 180_000, dim: int = 1024, n_queries: int = 624,
        banks: int = 1024):
    """``banks``: Pneumonia exceeds any fixed system's capacity, so the
    compiler emits the sequential bank-refill *rounds* loop (paper
    §III-D2: "an additional loop is introduced") — each round re-programs
    the CAM, which is what makes small subarrays so expensive here."""
    banner("Table II — KNN EDP + power (Pneumonia-scale gallery)")
    rows = []
    for mode, target in (("cam-based", "latency"), ("cam-power", "power")):
        for s in (16, 32, 64, 128, 256):
            arch = ArchSpec(rows=s, cols=s, banks=banks).with_target(target)
            prog = compile_fn(knn_kernel, [(n_queries, dim),
                                           (n_gallery, dim)], arch,
                              value_bits=8, unroll_limit=0)
            rep = prog.cost_report()
            rows.append({"mode": mode, "subarray": f"{s}x{s}",
                         "edp_nj_s": rep.edp_nj_s, "power_w": rep.power_w,
                         "banks": prog.plans[0].banks_used,
                         "rounds": prog.plans[0].rounds})
    print(table(rows))

    base = {r["subarray"]: r for r in rows if r["mode"] == "cam-based"}
    powr = {r["subarray"]: r for r in rows if r["mode"] == "cam-power"}
    for s in base:
        assert powr[s]["power_w"] < base[s]["power_w"]
        assert powr[s]["edp_nj_s"] > base[s]["edp_nj_s"]
    edps = [base[f"{s}x{s}"]["edp_nj_s"] for s in (16, 32, 64, 128, 256)]
    pows = [base[f"{s}x{s}"]["power_w"] for s in (16, 32, 64, 128, 256)]
    # paper trends: EDP falls steeply while re-fill rounds dominate and
    # stays orders of magnitude below the 16x16 point at large sizes
    # (our ML-discharge latency law turns EDP slightly up at 256x256 —
    # noted deviation); power falls monotonically in both modes.
    assert all(b < a for a, b in zip(edps[:3], edps[1:4]))
    assert max(edps[3:]) < edps[0] / 100
    assert all(b < a for a, b in zip(pows, pows[1:]))

    # functional spot-check on a smaller slice: CAM top-5 == dense top-5
    g, gl, q, ql = knn_dataset(n_gallery=4096, dim=dim, n_queries=32)
    prog = compile_fn(knn_kernel, [q, g], ArchSpec(rows=64, cols=64),
                      value_bits=8)
    _, idx = prog(q, g)
    import jax.numpy as jnp
    _, ref_idx = kref.cam_topk(jnp.asarray(q), jnp.asarray(g),
                               metric="eucl", k=5, largest=False)
    match = float((np.asarray(idx) == np.asarray(ref_idx)).mean())
    acc = float((gl[np.asarray(idx)[:, 0]] == ql).mean())
    print(f"\nfunctional: top-5 index match vs dense = {match:.3f}, "
          f"1-NN label accuracy = {acc:.3f}")
    assert match > 0.99

    save_json("table2_knn", rows)
    return rows


if __name__ == "__main__":
    run()
