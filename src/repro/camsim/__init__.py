"""CAM device simulation: Eva-CAM-analog cost model + GPU baseline model.

`repro.camsim` plays the role of the paper's extended simulation
infrastructure (§IV-A2): it models the architecture, estimates performance
and energy from the compiler's :class:`~repro.core.passes.cam_map.MappingPlan`,
supports different underlying CAM designs (TCAM binary / MCAM multi-bit /
ACAM analog), and performs chip-level estimation including peripherals.
"""

from .cost import TechParams, CostModel, CostReport, FEFET_45NM
from .gpu import CIM_SYSTEM, CimSystemModel, GpuModel, QUADRO_RTX_6000

__all__ = ["TechParams", "CostModel", "CostReport", "FEFET_45NM",
           "GpuModel", "QUADRO_RTX_6000", "CimSystemModel", "CIM_SYSTEM"]
