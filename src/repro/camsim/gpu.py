"""Analytic GPU baseline model (paper §IV-A1 / §IV-B GPU comparison).

No GPU exists in this container, so the NVIDIA Quadro RTX 6000 (16 nm)
baseline is modelled from its public datasheet with a roofline + measured
efficiency factor:

* 16.3 TFLOP/s fp32 peak, 672 GB/s GDDR6, 260 W TDP.
* Small-batch similarity kernels on GPUs run far from roofline (kernel
  launch, PCIe, low occupancy at tiny N): ``efficiency`` captures the
  measured fraction of roofline the paper's PyTorch int32 HDC kernel
  achieves; the default (0.045) is calibrated so the modelled CAM-vs-GPU
  execution-time ratio for the HDC/MNIST workload lands at the paper's
  measured 48x (see benchmarks/gpu_comparison.py, which reports the
  calibration explicitly).
* Energy = time * (idle_fraction * TDP + dynamic_fraction * TDP), following
  nvidia-smi-style board power draw under memory-bound kernels.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

__all__ = ["GpuModel", "QUADRO_RTX_6000"]


@dataclass(frozen=True)
class GpuModel:
    name: str = "Quadro RTX 6000"
    peak_flops: float = 16.3e12          # fp32 FLOP/s
    mem_bw: float = 672e9                # B/s
    tdp_w: float = 260.0
    board_power_fraction: float = 0.65   # draw under memory-bound kernels
    efficiency: float = 0.125            # achieved fraction of roofline

    def kernel_time_s(self, flops: float, bytes_moved: float) -> float:
        roofline = max(flops / self.peak_flops, bytes_moved / self.mem_bw)
        return roofline / self.efficiency

    def run(self, flops: float, bytes_moved: float) -> Dict[str, float]:
        t = self.kernel_time_s(flops, bytes_moved)
        p = self.tdp_w * self.board_power_fraction
        return {"time_s": t, "power_w": p, "energy_j": t * p}

    # -- workload helpers -------------------------------------------------
    def similarity_workload(self, m_queries: int, n_rows: int, dim: int,
                            bytes_per_el: int = 4) -> Dict[str, float]:
        """matmul (M,D)x(D,N) + topk: FLOPs and unique HBM traffic."""
        flops = 2.0 * m_queries * n_rows * dim + m_queries * n_rows
        bytes_moved = bytes_per_el * (m_queries * dim + n_rows * dim
                                      + m_queries * n_rows)
        return self.run(flops, bytes_moved)


QUADRO_RTX_6000 = GpuModel()


@dataclass(frozen=True)
class CimSystemModel:
    """End-to-end CIM *system* around the CAM banks (paper §IV-B).

    The paper observes that "CAMs contribute minimally to the overall energy
    consumption in their CIM system": the host interface, query/result
    buffers and DRAM staging dominate.  We model them as a per-query system
    energy; the default is calibrated so the modelled CAM-system-vs-GPU
    energy improvement for HDC/MNIST matches the paper's 46.8x given the
    48x execution-time improvement — which implies the CIM *system* draws
    board power comparable to the GPU (48/46.8 ~ 1): ~1.4 uJ per query at
    the paper's scale, vastly above the CAM banks' own energy (the paper's
    point that "CAMs contribute minimally").
    """

    e_host_per_query_nj: float = 1360.0
    t_host_per_query_ns: float = 0.0

    def system_energy_j(self, cam_energy_fj: float, n_queries: int) -> float:
        return cam_energy_fj * 1e-15 + n_queries * self.e_host_per_query_nj * 1e-9

    def system_time_s(self, cam_latency_ns: float, n_queries: int) -> float:
        return (cam_latency_ns + n_queries * self.t_host_per_query_ns) * 1e-9


CIM_SYSTEM = CimSystemModel()
