"""Eva-CAM-analog energy/latency model for FeFET CAM hierarchies.

Technology anchor points (paper §IV-A1, 2FeFET CAM [20] @ 45 nm, numbers
extracted from Eva-CAM [29]):

* search latency 0.86 ns for a 16x16 subarray, 7.5 ns for 256x256 — the
  match-line discharges more slowly for larger columns; we fit the power law
  ``t_search(C) = 0.86 ns * (C/16)**0.78`` through both points.
* readout/merge peripheral latency grows with the priority-encoder depth,
  ``t_periph(R) = gamma*log2(R) + delta``; gamma/delta are fit to the
  iso-capacity execution-time anchors (58 us @16x16 -> 150 us @256x256 for
  10k HDC queries, Fig. 9).

Latency composition per query (validated against the paper's mode ratios):

    t_query = stack * (t_periph + n_seq_search * t_search)

* ``stack`` — selective-search batches per subarray (cam-density): each
  batch is a full search+sense sub-cycle.
* ``n_seq_search`` — serialized subarray searches inside one sub-cycle:
  cam-power enables one subarray slot of an array at a time (fixed schedule
  over all S slots), sequential-access levels multiply in.
* parallel searches across arrays/mats/banks overlap; the sensing/merge
  periphery is pipelined once per sub-cycle.

Energy composition per query:

    E = sum over logical tiles of
          cols * (rows_active*e_cell + rows_programmed*e_ml)   # cell + ML/DL
        + rows_active * e_sa                                   # sensing
        + per-cycle hierarchy periphery (bank/mat/array/subarray drivers)

``rows_programmed = rows_active * stack`` under selective search: stacked
batches keep their data lines loaded, reproducing the paper's density-mode
energy crossover (cheaper at small subarrays — fewer banks — but 1.4x/5.1x
at 128/256 where parasitics dominate).  Multi-bit cells raise ML/DL voltage:
``e_cell``/``e_ml`` scale by ``multibit_energy_factor`` (paper Fig. 7b).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, asdict
from typing import Dict, Iterable, List, Optional, Sequence

from ..core.arch import ArchSpec, CamType
from ..core.passes.cam_map import MappingPlan

__all__ = ["TechParams", "CostModel", "CostReport", "FEFET_45NM"]


@dataclass(frozen=True)
class TechParams:
    """Technology constants (energies in femtojoule, times in nanoseconds)."""

    name: str = "2FeFET-45nm"
    # latency
    t_search16_ns: float = 0.86          # 16x16 anchor
    t_search_col_exp: float = 0.78       # fits 7.5 ns @ C=256
    t_periph_gamma_ns: float = 0.64      # * log2(R)
    t_periph_delta_ns: float = 2.38
    t_write_row_ns: float = 4.0          # FeFET program pulse per row
    # energy (fJ)
    e_cell_fj: float = 0.1               # per active cell per search
    e_ml_fj: float = 0.04                # ML/DL parasitic per programmed row-col
    e_sa_fj: float = 0.5                 # sense amp per active row
    # subarray periphery scales with its perimeter (row drivers + column
    # sense/encode), anchored at 32x32 — this is what keeps iso-capacity
    # configurations near-constant in energy (paper Fig. 9)
    e_sub_fj: float = 150.0              # subarray periphery @32x32 per query
    e_array_fj: float = 60.0             # array drivers per query
    e_mat_fj: float = 250.0              # mat routing per query
    e_bank_fj: float = 9000.0            # bank periphery per query

    def e_sub_scaled_fj(self, rows: int, cols: int) -> float:
        return self.e_sub_fj * (rows + cols) / 64.0
    e_write_cell_fj: float = 50.0        # FeFET program energy per cell
    # multi-bit (MCAM) factors — higher ML and DL voltages (paper IV-B)
    multibit_energy_factor: float = 2.2
    multibit_latency_factor: float = 1.15
    # analog (ACAM) sensing: ADC cost instead of SA
    acam_sense_factor: float = 3.0

    def t_search_ns(self, cols: int, cam_type: str = CamType.TCAM,
                    bits_per_cell: int = 1) -> float:
        t = self.t_search16_ns * (max(cols, 1) / 16.0) ** self.t_search_col_exp
        if bits_per_cell > 1 or cam_type == CamType.MCAM:
            t *= self.multibit_latency_factor
        return t

    def t_periph_ns(self, rows: int) -> float:
        return self.t_periph_gamma_ns * math.log2(max(rows, 2)) + self.t_periph_delta_ns


FEFET_45NM = TechParams()


@dataclass
class CostReport:
    """Latency / energy / power summary for one compiled program."""

    latency_ns: float = 0.0
    energy_fj: float = 0.0
    write_latency_ns: float = 0.0
    write_energy_fj: float = 0.0
    breakdown_fj: Dict[str, float] = field(default_factory=dict)
    search_cycles: int = 0
    queries: int = 0

    # -- derived -----------------------------------------------------------
    @property
    def latency_us(self) -> float:
        return self.latency_ns * 1e-3

    @property
    def energy_uj(self) -> float:
        return self.energy_fj * 1e-9

    @property
    def power_w(self) -> float:
        # fJ / ns == microwatt*1e0 ... (1e-15 J / 1e-9 s) = 1e-6 W
        return (self.energy_fj / max(self.latency_ns, 1e-12)) * 1e-6

    @property
    def edp_nj_s(self) -> float:
        # energy (nJ) * latency (s)
        return (self.energy_fj * 1e-6) * (self.latency_ns * 1e-9)

    def merged_with(self, other: "CostReport") -> "CostReport":
        br = dict(self.breakdown_fj)
        for k, v in other.breakdown_fj.items():
            br[k] = br.get(k, 0.0) + v
        return CostReport(
            latency_ns=self.latency_ns + other.latency_ns,
            energy_fj=self.energy_fj + other.energy_fj,
            write_latency_ns=self.write_latency_ns + other.write_latency_ns,
            write_energy_fj=self.write_energy_fj + other.write_energy_fj,
            breakdown_fj=br,
            search_cycles=self.search_cycles + other.search_cycles,
            queries=self.queries + other.queries)

    def as_dict(self) -> Dict[str, float]:
        return {"latency_us": self.latency_us, "energy_uj": self.energy_uj,
                "power_w": self.power_w, "edp_nj_s": self.edp_nj_s,
                "search_cycles": self.search_cycles, "queries": self.queries,
                **{f"e_{k}_fj": v for k, v in self.breakdown_fj.items()}}


class CostModel:
    """Evaluates MappingPlans against :class:`TechParams`."""

    def __init__(self, arch: ArchSpec, tech: TechParams = FEFET_45NM):
        self.arch = arch
        self.tech = tech

    # ------------------------------------------------------------------
    def plan_report(self, plan: MappingPlan) -> CostReport:
        a, t = plan.arch, self.tech
        mb = a.bits_per_cell > 1 or a.cam_type == CamType.MCAM
        e_scale = t.multibit_energy_factor if mb else 1.0
        sense_scale = t.acam_sense_factor if a.cam_type == CamType.ACAM else 1.0

        t_search = t.t_search_ns(a.cols, a.cam_type, a.bits_per_cell)
        t_periph = t.t_periph_ns(a.rows)

        # ---- sequential search factor inside one sub-cycle -------------
        arrays_used = max(1, math.ceil(plan.physical_subarrays / a.subarrays_per_array))
        mats_used = max(1, math.ceil(arrays_used / a.arrays_per_mat))
        if a.max_active_subarrays == 1:
            # cam-power: fixed one-slot-at-a-time schedule over the S slots
            sub_factor = a.subarrays_per_array
        elif a.max_active_subarrays > 1:
            sub_factor = math.ceil(a.subarrays_per_array / a.max_active_subarrays)
        elif a.access["subarray"] == "sequential":
            sub_factor = min(a.subarrays_per_array, plan.physical_subarrays)
        else:
            sub_factor = 1
        lvl_factor = 1
        if a.access["array"] == "sequential":
            lvl_factor *= min(a.arrays_per_mat, arrays_used)
        if a.access["mat"] == "sequential":
            lvl_factor *= min(a.mats_per_bank, mats_used)
        if a.access["bank"] == "sequential":
            lvl_factor *= plan.banks_used
        n_seq = sub_factor * lvl_factor

        t_query_ns = plan.stack * (t_periph + n_seq * t_search)
        latency_ns = plan.m_queries * plan.rounds * t_query_ns

        # ---- energy ------------------------------------------------------
        rows_act = plan.rows_active_per_search
        rows_prog = min(a.rows, rows_act * plan.stack)
        cols = a.cols
        per_tile = (cols * (rows_act * t.e_cell_fj + rows_prog * t.e_ml_fj) * e_scale
                    + rows_act * t.e_sa_fj * sense_scale)
        e_cells = plan.searches * per_tile
        # hierarchy periphery: drivers/routing of the *provisioned* units fire
        # once per query (stacked sub-cycles reuse the charged periphery, so
        # cam-density's fewer subarrays/banks save energy — paper Fig. 8a)
        cycles = plan.m_queries * plan.rounds * plan.stack
        queries = plan.m_queries * plan.rounds
        e_hier = queries * (plan.banks_used * t.e_bank_fj
                            + mats_used * t.e_mat_fj
                            + arrays_used * t.e_array_fj
                            + plan.physical_subarrays
                            * t.e_sub_scaled_fj(a.rows, a.cols))
        e_search_total = e_cells + e_hier

        # ---- one-time writes (program the CAM) ---------------------------
        w_lat = plan.rounds * plan.stack * rows_act * t.t_write_row_ns
        w_en = (plan.logical_tiles * rows_act * cols * t.e_write_cell_fj
                * e_scale * plan.rounds)

        return CostReport(
            latency_ns=latency_ns + w_lat,
            energy_fj=e_search_total + w_en,
            write_latency_ns=w_lat,
            write_energy_fj=w_en,
            breakdown_fj={"cells": e_cells, "hierarchy": e_hier, "write": w_en},
            search_cycles=int(cycles * n_seq),
            queries=plan.m_queries)

    def report(self, plans: Sequence[MappingPlan]) -> CostReport:
        total = CostReport()
        for p in plans:
            total = total.merged_with(self.plan_report(p))
        return total
