"""Shared pretty-printer for server/gateway stats dicts.

``CamSearchServer.snapshot()`` and ``CamServingGateway.health()``
return nested dicts; the examples used to ``json.dumps`` them raw,
which buried the numbers people actually look at (latency windows,
counters) under quoting noise.  :func:`format_stats` renders the same
structure as an aligned, indented key tree with floats rounded to a
sane width, so example output and ``snapshot()`` keys stay in
lockstep — there is exactly one renderer to update when telemetry
grows a field.
"""

from __future__ import annotations

from typing import Any, List

__all__ = ["format_stats", "print_stats"]


def _fmt_scalar(v: Any) -> str:
    if isinstance(v, bool) or v is None:
        return str(v)
    if isinstance(v, float):
        if v != v:                      # NaN
            return "nan"
        if v == 0 or 0.001 <= abs(v) < 1e7:
            return f"{v:.3f}".rstrip("0").rstrip(".")
        return f"{v:.3e}"
    return str(v)


def _render(obj: Any, indent: int, lines: List[str]) -> None:
    pad = "  " * indent
    if isinstance(obj, dict):
        width = max((len(str(k)) for k in obj), default=0)
        for k, v in obj.items():
            if isinstance(v, dict) and v:
                lines.append(f"{pad}{k}:")
                _render(v, indent + 1, lines)
            elif isinstance(v, (list, tuple)) and v and all(
                    isinstance(x, dict) for x in v):
                lines.append(f"{pad}{k}:")
                for i, x in enumerate(v):
                    lines.append(f"{pad}  [{i}]")
                    _render(x, indent + 2, lines)
            else:
                if isinstance(v, (list, tuple)):
                    body = "[" + ", ".join(_fmt_scalar(x) for x in v) + "]"
                else:
                    body = _fmt_scalar(v)
                lines.append(f"{pad}{str(k):<{width}}  {body}")
    else:
        lines.append(f"{pad}{_fmt_scalar(obj)}")


def format_stats(stats: Any, title: str = "") -> str:
    """Render a (nested) stats dict as an aligned key tree."""
    lines: List[str] = []
    if title:
        lines.append(title)
        lines.append("-" * len(title))
    _render(stats, 0, lines)
    return "\n".join(lines)


def print_stats(stats: Any, title: str = "") -> None:
    print(format_stats(stats, title))
