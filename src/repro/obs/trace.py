"""Bounded, thread-safe span recorder with Chrome-tracing export.

One process-wide :class:`TraceRecorder` collects timing spans from the
engine (plan compile/prepare/dispatch/finalize), the serving batcher
(batch fill/dispatch/finalize, per-request queue-wait vs service
windows) and the gateway (admission, routing, failover, healing).  The
export is the Chrome Trace Event JSON format, loadable in Perfetto or
``chrome://tracing``: duration events (``ph: B``/``E``) for same-thread
nesting, complete events (``ph: X``) for cross-thread request windows,
instants (``ph: i``) for point occurrences, and ``M`` metadata rows
naming processes and threads.

Design constraints, in order:

* **Disabled must cost ~nothing.**  Every call site sits on a serving
  or engine hot path; when tracing is off, :func:`trace_span` returns
  one preallocated singleton and :func:`trace_begin` returns ``None``
  without allocating.  Event ``args`` are therefore a plain optional
  ``dict`` parameter, never ``**kwargs`` (which would build a dict per
  call even when disabled).
* **Bounded.**  Events land in a ``deque(maxlen=...)`` ring
  (``REPRO_TRACE_EVENTS``, default 65536): a long-running server keeps
  the most recent window and never grows without bound.  CPython's
  ``deque.append`` is atomic, so the hot path takes no lock.
* **Always exportable.**  ``to_chrome()`` repairs what a ring buffer
  and crashing threads can leave behind: an ``E`` whose ``B`` was
  evicted is dropped, a ``B`` that never saw its ``E`` is closed at
  the trace horizon.  Every ``B`` in the export has a matching ``E``.

Enabling: set ``REPRO_TRACE=/path/to/trace.json`` before import (the
trace is dumped at interpreter exit), or call :func:`enable` /
:func:`configure_from_env` explicitly.  ``CamSearchServer.dump_trace``
and ``CamServingGateway.dump_trace`` write the same process-wide
buffer on demand.
"""

from __future__ import annotations

import atexit
import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

from ..core.envcfg import env_choice, env_int, env_path

__all__ = [
    "TraceRecorder", "tracer", "enable", "stop", "configure_from_env",
    "trace_span", "trace_begin", "instant", "to_chrome", "dump",
    "span_stats",
]

#: stable pid assignment per component so cross-component traces line
#: up identically run to run
_PIDS = {"engine": 1, "serving": 2, "gateway": 3}


def _clock_ns() -> int:
    return time.perf_counter_ns()


class TraceRecorder:
    """Bounded ring of raw trace events.

    ``enabled`` is a plain attribute read (no property, no lock) — the
    disabled fast path is one attribute load and a branch.
    """

    def __init__(self, capacity: int = 65536, clock: str = "perf"):
        self.enabled = False
        self.capacity = int(capacity)
        self.clock = clock
        self._clock_ns = (time.monotonic_ns if clock == "mono"
                          else time.perf_counter_ns)
        self._events: deque = deque(maxlen=self.capacity)
        self._thread_names: Dict[int, str] = {}
        self._names_lock = threading.Lock()
        self._atexit_path: Optional[str] = None

    # -- hot path -------------------------------------------------------
    def now(self) -> int:
        return self._clock_ns()

    def emit(self, ph: str, name: str, pid: str, ts: int,
             dur: Optional[int] = None,
             args: Optional[Dict[str, Any]] = None,
             tid: Optional[int] = None) -> None:
        """Append one raw event.  Lock-free: ``deque.append`` with a
        ``maxlen`` is atomic under the GIL, and eviction of the oldest
        event is exactly the bounded-ring semantics we want."""
        if tid is None:
            t = threading.get_ident()
            if t not in self._thread_names:
                with self._names_lock:
                    self._thread_names.setdefault(
                        t, threading.current_thread().name)
        else:
            t = tid      # explicit origin tid: its name was learned
                         # when the origin thread opened the handle
        self._events.append((ph, name, pid, t, ts, dur, args))

    # -- control --------------------------------------------------------
    def start(self) -> None:
        self.enabled = True

    def stop(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        self._events.clear()

    def __len__(self) -> int:
        return len(self._events)

    # -- export ---------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """Render the ring as a Chrome Trace Event JSON object.

        The raw ring may hold an ``E`` whose ``B`` was evicted (drop
        it) or a ``B`` still open when the snapshot was taken (close it
        at the horizon): the output satisfies "every B has an E" and
        per-thread monotonic nesting, which is what Perfetto requires
        to build flame graphs instead of dropping tracks.
        """
        raw = sorted(self._events, key=lambda e: e[4])
        if raw:
            t0 = raw[0][4]
            horizon = max(e[4] + (e[5] or 0) for e in raw)
        else:
            t0 = horizon = 0
        out: List[Dict[str, Any]] = []
        pids_seen: Dict[str, int] = {}
        tids_seen: Dict[int, str] = {}
        open_b: Dict[tuple, List[Dict[str, Any]]] = {}
        for ph, name, pid_label, tid, ts, dur, args in raw:
            pid = _PIDS.get(pid_label)
            if pid is None:
                pid = _PIDS[pid_label] = len(_PIDS) + 1
            pids_seen[pid_label] = pid
            tids_seen.setdefault(tid, self._thread_names.get(tid, ""))
            ev: Dict[str, Any] = {
                "name": name, "ph": ph, "pid": pid, "tid": tid,
                "ts": (ts - t0) / 1e3,      # ns -> µs
            }
            if args:
                ev["args"] = args
            if ph == "B":
                open_b.setdefault((pid, tid), []).append(ev)
            elif ph == "E":
                stack = open_b.get((pid, tid))
                if not stack:
                    continue                # B evicted from the ring
                stack.pop()
            elif ph == "X":
                ev["dur"] = (dur or 0) / 1e3
            elif ph == "i":
                ev["s"] = "t"               # thread-scoped instant
            out.append(ev)
        # close spans whose E never landed (thread died / ring snapshot
        # taken mid-span): synthesize the E at the trace horizon
        end_us = (horizon - t0) / 1e3
        for (pid, tid), stack in open_b.items():
            while stack:
                b = stack.pop()
                out.append({"name": b["name"], "ph": "E", "pid": pid,
                            "tid": tid, "ts": end_us})
        meta: List[Dict[str, Any]] = []
        for label, pid in sorted(pids_seen.items(), key=lambda kv: kv[1]):
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0, "args": {"name": label}})
        for tid, tname in tids_seen.items():
            for pid in pids_seen.values():
                meta.append({"name": "thread_name", "ph": "M",
                             "pid": pid, "tid": tid,
                             "args": {"name": tname or f"thread-{tid}"}})
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> str:
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


#: process-wide recorder all call sites share
tracer = TraceRecorder()

#: singleton returned by trace_span when tracing is disabled — the
#: entire disabled path is: one attribute read, return this object
class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Same-thread duration span (``B`` on enter, ``E`` on exit)."""

    __slots__ = ("name", "pid", "args")

    def __init__(self, name: str, pid: str,
                 args: Optional[Dict[str, Any]]):
        self.name = name
        self.pid = pid
        self.args = args

    def __enter__(self):
        tracer.emit("B", self.name, self.pid, tracer.now(),
                    args=self.args)
        return self

    def __exit__(self, *exc):
        tracer.emit("E", self.name, self.pid, tracer.now())
        return False


def trace_span(name: str, pid: str = "engine",
               args: Optional[Dict[str, Any]] = None):
    """Context manager for a same-thread span.  Near-free when tracing
    is disabled: returns a shared no-op singleton without allocating."""
    if not tracer.enabled:
        return _NULL_SPAN
    return _Span(name, pid, args)


class _Handle:
    """Explicit begin/lap/end handle for cross-thread request flows.

    The lifetime is rendered as ``X`` (complete) events pinned to the
    *origin* thread, so one request stays a single track even though
    its phases execute on the submitter, batcher and completer threads.
    ``lap`` emits the window since the previous lap; ``end`` emits the
    whole lifetime.
    """

    __slots__ = ("name", "pid", "tid", "t0", "t_last", "args")

    def __init__(self, name: str, pid: str,
                 args: Optional[Dict[str, Any]]):
        self.name = name
        self.pid = pid
        self.tid = threading.get_ident()
        if self.tid not in tracer._thread_names:
            with tracer._names_lock:
                tracer._thread_names.setdefault(
                    self.tid, threading.current_thread().name)
        self.t0 = self.t_last = tracer.now()
        self.args = args

    def lap(self, name: str,
            args: Optional[Dict[str, Any]] = None) -> None:
        now = tracer.now()
        tracer.emit("X", name, self.pid, self.t_last,
                    dur=now - self.t_last, args=args, tid=self.tid)
        self.t_last = now

    def end(self, args: Optional[Dict[str, Any]] = None) -> None:
        now = tracer.now()
        merged = self.args
        if args:
            merged = {**(self.args or {}), **args}
        tracer.emit("X", self.name, self.pid, self.t0,
                    dur=now - self.t0, args=merged, tid=self.tid)


def trace_begin(name: str, pid: str = "serving",
                args: Optional[Dict[str, Any]] = None):
    """Open a cross-thread handle, or ``None`` when disabled (callers
    guard laps with ``if handle is not None``)."""
    if not tracer.enabled:
        return None
    return _Handle(name, pid, args)


def instant(name: str, pid: str = "serving",
            args: Optional[Dict[str, Any]] = None) -> None:
    """Point event (``ph: i``); no-op when disabled."""
    if not tracer.enabled:
        return
    tracer.emit("i", name, pid, tracer.now(), args=args)


def enable(capacity: Optional[int] = None,
           clock: Optional[str] = None) -> TraceRecorder:
    """(Re)configure and start the process-wide recorder."""
    if capacity is not None and capacity != tracer.capacity:
        tracer.capacity = int(capacity)
        tracer._events = deque(tracer._events, maxlen=tracer.capacity)
    if clock is not None and clock != tracer.clock:
        tracer.clock = clock
        tracer._clock_ns = (time.monotonic_ns if clock == "mono"
                            else time.perf_counter_ns)
    tracer.start()
    return tracer


def stop() -> None:
    tracer.stop()


def to_chrome() -> Dict[str, Any]:
    return tracer.to_chrome()


def dump(path: str) -> str:
    return tracer.dump(path)


def span_stats() -> Dict[str, Dict[str, float]]:
    """Aggregate the ring into per-span-name timing statistics.

    Pairs ``B``/``E`` duration events per (pid, tid) stack and takes
    ``X`` durations directly; returns ``{name: {count, total_ms,
    mean_ms, max_ms}}``.  This is the measured side of the roofline
    report (``benchmarks/report_roofline.py``) and the per-stage
    breakdown in ``bench_hier``.
    """
    raw = sorted(tracer._events, key=lambda e: e[4])
    open_b: Dict[tuple, List[tuple]] = {}
    agg: Dict[str, List[int]] = {}
    for ph, name, pid, tid, ts, dur, _args in raw:
        if ph == "B":
            open_b.setdefault((pid, tid), []).append((name, ts))
        elif ph == "E":
            stack = open_b.get((pid, tid))
            if stack:
                bname, bts = stack.pop()
                agg.setdefault(bname, []).append(ts - bts)
        elif ph == "X" and dur:
            agg.setdefault(name, []).append(dur)
    return {name: {"count": float(len(ds)),
                   "total_ms": sum(ds) / 1e6,
                   "mean_ms": sum(ds) / len(ds) / 1e6,
                   "max_ms": max(ds) / 1e6}
            for name, ds in sorted(agg.items())}


def _dump_atexit() -> None:
    if tracer._atexit_path and len(tracer):
        try:
            tracer.dump(tracer._atexit_path)
        except OSError:
            pass


def configure_from_env() -> Optional[str]:
    """Apply ``REPRO_TRACE`` / ``REPRO_TRACE_EVENTS`` /
    ``REPRO_TRACE_CLOCK``.  Returns the dump path when tracing was
    enabled by the environment, else ``None``.  Called once at import;
    tests call it again after monkeypatching the environment."""
    capacity = env_int("REPRO_TRACE_EVENTS", 65536, min_value=1)
    clock = env_choice("REPRO_TRACE_CLOCK", "perf", ("perf", "mono"))
    path = env_path("REPRO_TRACE")
    if path is None:
        # knobs still apply if tracing is later enabled explicitly
        if capacity != tracer.capacity or clock != tracer.clock:
            enable(capacity, clock)
            tracer.stop()
        tracer._atexit_path = None
        return None
    enable(capacity, clock)
    tracer._atexit_path = path
    return path


configure_from_env()
atexit.register(_dump_atexit)
