"""Observability: execution tracing and telemetry rendering.

``repro.obs`` is deliberately a leaf package: it imports only
``repro.core.envcfg`` so the engine, serving layer and gateway can all
emit spans without import cycles.  See ``docs/observability.md`` for
the span taxonomy and a Perfetto walkthrough.
"""

from .trace import (TraceRecorder, configure_from_env, dump, enable,
                    instant, span_stats, stop, to_chrome, trace_begin,
                    trace_span, tracer)
from .pretty import format_stats, print_stats

__all__ = [
    "TraceRecorder", "tracer", "enable", "stop", "configure_from_env",
    "trace_span", "trace_begin", "instant", "to_chrome", "dump",
    "span_stats", "format_stats", "print_stats",
]
