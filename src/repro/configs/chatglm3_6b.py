"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024, 2d (half-rotary) RoPE.  [arXiv:2406.12793; hf]

long_500k skipped (full attention)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    qkv_bias=True,          # chatglm: bias on QKV only
    rope="2d",
    act="swiglu",
    norm="rmsnorm",
)
