"""mistral-large-123b [dense] — 88L d_model=12288 96H (GQA kv=8)
d_ff=28672 vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407;
unverified]

Pure full attention: the long_500k shape is skipped (DESIGN.md
§Arch-applicability)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    rope="standard",
    rope_theta=1000000.0,
    act="swiglu",
    norm="rmsnorm",
)
