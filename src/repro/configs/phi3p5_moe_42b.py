"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8)
expert d_ff=6400 vocab=32064; 16 experts top-2, no shared experts, all
layers MoE.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]

long_500k skipped (full attention)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    d_expert=6400,
    vocab=32064,
    n_experts=16,
    n_shared_experts=0,
    moe_top_k=2,
    first_dense_layers=0,
    capacity_factor=1.25,
    rope="standard",
    act="swiglu",
    norm="layernorm",       # phi3.5 uses LayerNorm
)
