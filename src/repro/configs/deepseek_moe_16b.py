"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (MHA kv=16) expert
d_ff=1408 vocab=102400; 64 routed experts top-6 + 2 shared, first layer
dense (d_ff=10944).  [arXiv:2401.06066; hf]

The router is ``matmul -> topk`` — the paper's DotProdSimPattern; with
``router_offload="cam"`` it runs through the C4CAM search primitive.
long_500k skipped (full attention)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,              # routed expert hidden dim (fine-grained)
    d_expert=1408,
    dense_d_ff=10944,       # layer-0 dense FFN [hf config]
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    first_dense_layers=1,
    capacity_factor=1.25,
    rope="standard",
    act="swiglu",
    norm="rmsnorm",
)
