"""whisper-medium [audio] — enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=4096 vocab=51865; conv frontend STUB
(``input_specs()`` provides 1500 precomputed frame embeddings).
[arXiv:2212.04356; unverified]

vocab 51865 is odd -> the vocab axis falls back to replicated under the
16-way model axis (sharding rule fallback).  Decode shapes exercise the
*decoder* with self+cross attention; long_500k skipped (full attention).
Sinusoidal positions stand in for whisper's learned decoder positions."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,            # decoder layers
    n_encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    qkv_bias=True,          # whisper uses biases
    rope="none",
    act="gelu",
    norm="layernorm",
)
