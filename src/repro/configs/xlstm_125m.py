"""xlstm-125m [ssm] — 12L d_model=768 4H vocab=50304, alternating
sLSTM + mLSTM blocks.  [arXiv:2405.04517; unverified]

d_ff=0 per assignment: blocks are pure mixers (no separate FFN; the
released model's pre/post up-projections are folded away — DESIGN.md).
O(S) sequence mixing -> runs long_500k."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_every=2,
    rope="standard",        # unused (no attention); avoids abs-pos stub
    act="gelu",
    norm="layernorm",
)
