"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block.

54L d_model=2560 32H (kv=32) shared-block d_ff=10240 vocab=32000
ssm_state=64.  [arXiv:2411.15242; hf]

Zamba2's single shared transformer block (full attention + MLP) is invoked
every 6 Mamba2 blocks with *shared* weights; the per-invocation LoRA
adapters of the released model are omitted (see DESIGN.md deviations).
Sub-quadratic sequence mixing -> runs the long_500k shape.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    shared_attn_every=6,
    rope="standard",
    act="gelu",            # zamba2 shared MLP uses gelu
    norm="rmsnorm",
)
