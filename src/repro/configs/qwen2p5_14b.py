"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]

40 heads do not divide the 16-way model axis: attention falls back to
replicated heads (FFN/vocab stay TP) — this makes qwen a §Perf hillclimb
target.  long_500k skipped (full attention)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    rope="standard",
    rope_theta=1000000.0,
    act="swiglu",
    norm="rmsnorm",
)
