"""Assigned-architecture registry: ``get_config("<arch-id>")``.

One module per architecture with the exact published configuration
(``[source; verified-tier]`` noted per file).  ``ARCHS`` maps arch id ->
module; every module exposes ``CONFIG`` (full) and ``smoke_config()``
(reduced, CPU-runnable).
"""

from importlib import import_module
from typing import Dict, List

from ..models.config import ModelConfig, reduced

_MODULES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "mistral-large-123b": "mistral_large_123b",
    "qwen2.5-14b": "qwen2p5_14b",
    "qwen1.5-32b": "qwen1p5_32b",
    "chatglm3-6b": "chatglm3_6b",
    "xlstm-125m": "xlstm_125m",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "phi3.5-moe-42b-a6.6b": "phi3p5_moe_42b",
    "paligemma-3b": "paligemma_3b",
    "whisper-medium": "whisper_medium",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return import_module(f"repro.configs.{_MODULES[arch]}").CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return reduced(get_config(arch))


__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "ModelConfig"]
