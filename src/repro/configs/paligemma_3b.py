"""paligemma-3b [vlm] — gemma-2b decoder: 18L d_model=2048 8H (MQA kv=1)
d_ff=16384 vocab=257216 + SigLIP vision tower (STUB: ``input_specs()``
provides 256 precomputed patch embeddings; prefix-LM mask over the vision
prefix).  [arXiv:2407.07726; hf]

long_500k skipped (full attention)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,             # gemma: head_dim 256 (8*256 = 2048)
    d_ff=16384,
    vocab=257216,
    n_vision_tokens=256,
    rope="standard",
    act="gelu",             # gemma uses gelu (geglu folded to gelu MLP)
    norm="rmsnorm",
    tie_embeddings=True,    # gemma ties input/output embeddings
)
