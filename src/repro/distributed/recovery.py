"""Failure recovery + elastic re-sharding supervisor.

``Supervisor.run`` wraps the train loop body:

* catches step failures (raised exceptions, injected ``SimulatedFailure``,
  and NaN/Inf loss — the "silent" failure mode),
* restores the newest checkpoint and replays the data stream to the
  restored step (loader state is one integer),
* enforces a retry budget per failure domain,
* on restore, re-shards to the *current* mesh (`restore_pytree` takes the
  new shardings) — elastic scale-up/down between runs is the same code
  path, exercised by tests/test_recovery.py with different device counts.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from ..checkpoint import AsyncCheckpointer, latest_step, restore_pytree

__all__ = ["RecoveryConfig", "SimulatedFailure", "Supervisor"]


class SimulatedFailure(RuntimeError):
    """Injected fault (stands in for a lost TPU slice / preemption)."""


@dataclass(frozen=True)
class RecoveryConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 3
    nan_is_failure: bool = True
    keep: int = 3


@dataclass
class Supervisor:
    cfg: RecoveryConfig
    restarts: int = 0
    log: list = field(default_factory=list)

    def __post_init__(self):
        self.ckpt = AsyncCheckpointer(self.cfg.ckpt_dir, keep=self.cfg.keep)

    # ------------------------------------------------------------------
    def maybe_save(self, state: Any, step: int,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        if step % self.cfg.ckpt_every == 0 and step > 0:
            self.ckpt.save(state, step, extra)

    def check_health(self, metrics: Dict[str, Any]) -> None:
        if not self.cfg.nan_is_failure:
            return
        loss = metrics.get("loss")
        if loss is not None and not math.isfinite(float(loss)):
            raise SimulatedFailure(f"non-finite loss {loss!r}")

    def restore(self, template: Any, shardings: Optional[Any] = None
                ) -> Tuple[Any, int]:
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            raise FileNotFoundError(
                f"no checkpoint to restore under {self.cfg.ckpt_dir}")
        state = restore_pytree(template, self.cfg.ckpt_dir, step, shardings)
        return state, step

    # ------------------------------------------------------------------
    def run(self, state: Any, n_steps: int,
            step_fn: Callable[[Any, int], Tuple[Any, Dict[str, Any]]],
            start_step: int = 0, shardings: Optional[Any] = None,
            on_metrics: Optional[Callable[[int, Dict[str, Any]], None]] = None
            ) -> Tuple[Any, Dict[str, Any]]:
        """Supervised loop: ``step_fn(state, step)`` with auto-recovery."""
        step = start_step
        last_metrics: Dict[str, Any] = {}
        while step < n_steps:
            try:
                new_state, metrics = step_fn(state, step)
                self.check_health(metrics)
                state = new_state
                last_metrics = metrics
                step += 1
                self.maybe_save(state, step)
                if on_metrics:
                    on_metrics(step, metrics)
            except (SimulatedFailure, FloatingPointError) as e:
                self.restarts += 1
                self.log.append({"step": step, "error": repr(e),
                                 "restart": self.restarts})
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"retry budget exhausted after {self.restarts - 1} "
                        f"restarts") from e
                self.ckpt.wait()
                state, step = self.restore(state, shardings)
                self.log.append({"restored_to": step})
        self.ckpt.wait()
        return state, last_metrics
