"""Straggler detection: per-step deadline monitor with robust statistics.

At 1000+ nodes the common failure mode is not crashes but *slow* steps
(thermal throttling, a flaky HBM stack, background daemons).  The monitor
keeps an exponential moving average and a median-absolute-deviation window
of step wall-times; a step exceeding ``ema + z * 1.4826 * MAD`` (or the
hard deadline) is flagged.  Hooks:

* ``on_straggle(step, dt, stats)`` — logging / paging;
* ``suggest_rebalance()`` — when a *persistent* slow rank is detected the
  caller may shrink that rank's microbatch share (the train loop re-slices
  its per-host batch); here this returns the recommended fraction.
"""

from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

__all__ = ["StragglerMonitor"]


@dataclass
class StragglerMonitor:
    window: int = 32
    z_threshold: float = 4.0
    hard_deadline_s: float = 0.0          # 0 = none
    ema_alpha: float = 0.1
    on_straggle: Optional[Callable[[int, float, Dict[str, float]], None]] = None

    _times: Deque[float] = field(default_factory=collections.deque)
    _ema: float = 0.0
    _t0: float = 0.0
    baseline_median: float = 0.0      # frozen after the first full window
    slow_steps: List[int] = field(default_factory=list)
    step_count: int = 0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self.record(dt)
        return dt

    def record(self, dt: float) -> bool:
        """Returns True when the step is flagged as a straggler."""
        self.step_count += 1
        stats = self.stats()
        slow = False
        if len(self._times) >= 8:
            # MAD floor of 2% of the median: identical step times otherwise
            # make the bound degenerate and flag ordinary jitter.
            mad = max(stats["mad"], 0.02 * stats["median"])
            bound = stats["median"] + self.z_threshold * 1.4826 * mad
            slow = dt > bound
        if self.hard_deadline_s and dt > self.hard_deadline_s:
            slow = True
        self._ema = dt if not self._ema else \
            (1 - self.ema_alpha) * self._ema + self.ema_alpha * dt
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.popleft()
        if not self.baseline_median and len(self._times) >= self.window:
            self.baseline_median = stats["median"]
        if slow:
            self.slow_steps.append(self.step_count)
            if self.on_straggle:
                self.on_straggle(self.step_count, dt, stats)
        return slow

    def stats(self) -> Dict[str, float]:
        ts = sorted(self._times)
        if not ts:
            return {"median": 0.0, "mad": 0.0, "ema": self._ema}
        median = ts[len(ts) // 2]
        mad = sorted(abs(t - median) for t in ts)[len(ts) // 2]
        return {"median": median, "mad": mad, "ema": self._ema}

    def suggest_rebalance(self) -> float:
        """Fraction of the nominal microbatch this rank should keep.

        Compares the smoothed current step time (EMA) against the frozen
        healthy baseline; a persistent >20% slowdown suggests shedding load
        proportional to it (one-off spikes barely move the EMA)."""
        if not self.baseline_median or self._ema <= 0:
            return 1.0
        if self._ema < 1.2 * self.baseline_median:
            return 1.0
        return max(0.5, min(1.0, self.baseline_median / self._ema))
