"""Distributed-runtime substrate: fault tolerance, stragglers, compression.

* `recovery`    — step-loop supervisor: failure detection (exceptions, NaN
  loss, simulated chip failures), automatic restore-from-checkpoint with
  bounded retries, and elastic re-shard on mesh changes.
* `straggler`   — per-step deadline monitor (EMA + MAD outlier detection)
  with slow-step logging and a microbatch rebalancing hook.
* `compression` — error-feedback gradient compressors (int8 quantization /
  top-k sparsification) for DP all-reduces.  On a GSPMD mesh the all-reduce
  is implicit (XLA inserts it for data-sharded batches), so the compressor
  transforms gradients *before* the optimizer; the error-feedback state
  makes the compression unbiased over time.
"""

from .compression import (CompressionState, ErrorFeedbackInt8,
                          ErrorFeedbackTopK, NoCompression)
from .recovery import RecoveryConfig, Supervisor, SimulatedFailure
from .straggler import StragglerMonitor

__all__ = ["CompressionState", "ErrorFeedbackInt8", "ErrorFeedbackTopK",
           "NoCompression", "RecoveryConfig", "Supervisor",
           "SimulatedFailure", "StragglerMonitor"]
