"""Error-feedback gradient compression.

Both compressors follow the EF-SGD recipe (Karimireddy et al. 2019):

    c_t   = C(g_t + e_t)          # compress gradient + carried error
    e_t+1 = (g_t + e_t) - c_t     # residual stays local, re-injected later

which keeps the *long-run* gradient unbiased even though every step's
all-reduced message is lossy.  State is one fp32 residual per parameter
leaf, sharded like the parameter.

On the GSPMD mesh the DP all-reduce is implicit; what compression buys at
scale is the *pod-crossing* (DCN) traffic: int8 cuts gradient bytes 4x,
top-k by ``1/density``.  The transform is applied to the gradient pytree
before ``adamw_update`` (`repro.models.steps.make_train_step(compressor=)`),
and the byte savings are modelled in the roofline collective term
(benchmarks/roofline: ``collective_bytes * compression_ratio``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionState", "NoCompression", "ErrorFeedbackInt8",
           "ErrorFeedbackTopK"]


class CompressionState(NamedTuple):
    error: Any            # residual pytree (fp32)


def init_state(params: Any) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))


@dataclass(frozen=True)
class NoCompression:
    ratio: float = 1.0

    def init(self, params):
        return CompressionState(error=None)

    def __call__(self, grads, state: CompressionState
                 ) -> Tuple[Any, CompressionState]:
        return grads, state


@dataclass(frozen=True)
class ErrorFeedbackInt8:
    """Per-tensor symmetric int8 quantization with error feedback."""

    ratio: float = 0.25          # bytes vs fp32... (int8 / fp32)

    def init(self, params):
        return init_state(params)

    def _q(self, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return q, scale

    def __call__(self, grads, state: CompressionState
                 ) -> Tuple[Any, CompressionState]:
        def leaf(g, e):
            x = g.astype(jnp.float32) + e
            q, scale = self._q(x)
            c = q.astype(jnp.float32) * scale
            return c, x - c
        out = jax.tree.map(leaf, grads, state.error)
        comp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        return comp, CompressionState(error=err)


@dataclass(frozen=True)
class ErrorFeedbackTopK:
    """Magnitude top-k sparsification (density = kept fraction)."""

    density: float = 0.1

    @property
    def ratio(self) -> float:
        return 2.0 * self.density    # value+index per kept entry

    def init(self, params):
        return init_state(params)

    def __call__(self, grads, state: CompressionState
                 ) -> Tuple[Any, CompressionState]:
        def leaf(g, e):
            x = g.astype(jnp.float32) + e
            flat = x.reshape(-1)
            k = max(1, int(flat.size * self.density))
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            kept = jnp.where(jnp.abs(x) >= thresh, x, 0.0)
            return kept, x - kept
        out = jax.tree.map(leaf, grads, state.error)
        comp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        return comp, CompressionState(error=err)
