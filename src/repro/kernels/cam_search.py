"""Pallas TPU kernels for CAM search (fused distance + per-block top-k).

Hardware adaptation (DESIGN.md §2): a CAM subarray is a broadcast-compare-
reduce engine.  On TPU the profitable mapping is through the MXU: every
supported CAM metric decomposes into a matmul plus rank-1 row/column
corrections,

    hamming(q, p) = rowsum(q) + colsum(p) - 2 q.p      (q, p in {0,1})
    eucl^2(q, p)  = rowsum(q^2) + colsum(p^2) - 2 q.p
    dot(q, p)     =                              q.p

so one kernel covers all metrics with coefficients (alpha, beta, gamma).

For *binary/ternary* galleries there is additionally a packed-bit
XOR+popcount kernel (``fused_topk_packed_pallas``) over uint32 lanes
(``kernels.packing``): 32x less operand traffic, pure integer arithmetic,
bit-identical candidates.  On TPU it runs on the VPU rather than the MXU —
slower per *element* but the packed gallery moves 1/32nd the bytes, which
wins when the search is bandwidth-bound (de Lima et al., CAM-only DNN
inference).  The engine chooses per metric; analog metrics stay on the
float kernel.

Kernel structure (mirrors the CAM hierarchy):

* grid = (M/bm, N/bn, D/bd); the D axis accumulates the distance block in a
  VMEM scratch accumulator (like a subarray accumulating partial match-line
  counts across column tiles = ``cim.merge_partial horizontal``),
* at the last D step the kernel extracts a block-local top-k (the
  subarray's winner-take-all periphery) into the output — a single-pass
  segmented running merge, O(bn + k^2) per block (see ``_extract``),
* the host-side merge of block-local candidate lists is
  ``cim.merge_partial vertical`` — `ops.cam_topk` finishes with one stable
  top-k over (n_blocks * k) candidates per query.

Block shapes default to MXU-aligned (128, 128) x bd=512 and are clamped to
the problem size; VMEM footprint = bm*bd + bn*bd + bm*bn + 2*bm*k floats
(~0.75 MB at defaults), comfortably inside the ~16 MB/core budget with
double-buffering.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .packing import popcount32
from .pallas_compat import CompilerParams as _CompilerParams

__all__ = ["fused_topk_pallas", "fused_topk_packed_pallas",
           "distance_pallas", "METRIC_COEFFS"]

#: metric -> (alpha, beta, gamma, q_term, p_term)
METRIC_COEFFS = {
    "hamming": (-2.0, 1.0, 1.0, "x", "x"),
    "eucl": (-2.0, 1.0, 1.0, "x2", "x2"),
    "dot": (1.0, 0.0, 0.0, "none", "none"),
}

_NEG_BIG = -3.0e38
_POS_BIG = 3.0e38


def _term(x, kind):
    if kind == "x":
        return x
    if kind == "x2":
        return x * x
    return None


def _extract_block_topk(dist, ov_ref, oi_ref, *, j, bn: int, k: int,
                        largest: bool, n_total: int):
    """Write the block-local top-k of a (bm, bn) distance block.

    Shared by the float (matmul-decomposed) and packed (XOR+popcount)
    kernels so both emit identical candidate lists — the host-side
    stable merge relies on that for bit-exact equivalence.

    Single-pass segmented extraction (sort-free).  The block is split
    into S = min(k, bn) segments of width w; one vectorized pass finds
    each segment's champion (leftmost max), then each of the k
    extraction rounds touches only the k champions plus the one
    segment that lost its champion: O(bn + k*(k + w)) = O(bn + k^2)
    per block, vs O(k*bn) for the former per-round max+mask over the
    whole block.  Consumed elements need no mask array: within a
    segment they are exactly the elements lexicographically >= the
    last consumed (value, index) pair, so the champion recompute
    filters on that pair alone.  Ordering (value desc, global index
    asc) is identical to the former loop, so emitted candidates — and
    the host-side stable merge — are bit-identical.
    """
    bm = dist.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    gidx = col + j * bn
    # mask padded pattern rows so they never win
    lose = _NEG_BIG if largest else _POS_BIG
    dist = jnp.where(gidx < n_total, dist, lose)
    key = dist if largest else -dist   # key domain: larger wins
    S = max(1, min(k, bn))
    w = -(-bn // S)
    if S * w > bn:
        key = jnp.pad(key, ((0, 0), (0, S * w - bn)),
                      constant_values=_NEG_BIG)
    key3 = key.reshape(bm, S, w)
    wcol = jax.lax.broadcasted_iota(jnp.int32, (bm, S, w), 2)
    s_iota = jax.lax.broadcasted_iota(jnp.int32, (bm, S), 1)
    base = j * bn + s_iota * w         # global index of segment starts

    champ_v = jnp.max(key3, axis=2)
    champ_pos = jnp.min(jnp.where(key3 == champ_v[:, :, None], wcol,
                                  jnp.int32(2 ** 30)), axis=2)
    champ_i = base + champ_pos

    wrow = wcol[:, 0, :]               # (bm, w) within-segment offsets
    for t in range(k):
        best_v = jnp.max(champ_v, axis=1)
        tie = champ_v == best_v[:, None]
        best_i = jnp.min(jnp.where(tie, champ_i, jnp.int32(2 ** 30)),
                         axis=1)
        ov_ref[:, t] = best_v if largest else -best_v
        oi_ref[:, t] = best_i
        # refill the winning segment's champion
        win = tie & (champ_i == best_i[:, None])
        sstar = jnp.min(jnp.where(win, s_iota, jnp.int32(2 ** 30)),
                        axis=1)
        seg = jnp.take_along_axis(key3, sstar[:, None, None],
                                  axis=1)[:, 0, :]
        seg_gid = j * bn + sstar[:, None] * w + wrow
        alive = (seg < best_v[:, None]) | \
            ((seg == best_v[:, None]) & (seg_gid > best_i[:, None]))
        seg = jnp.where(alive, seg, _NEG_BIG)
        new_v = jnp.max(seg, axis=1)
        new_pos = jnp.min(jnp.where(seg == new_v[:, None], wrow,
                                    jnp.int32(2 ** 30)), axis=1)
        new_i = j * bn + sstar * w + new_pos
        refill = s_iota == sstar[:, None]
        champ_v = jnp.where(refill, new_v[:, None], champ_v)
        champ_i = jnp.where(refill, new_i[:, None], champ_i)


def _fused_kernel(q_ref, p_ref, ov_ref, oi_ref, acc_ref, *, metric: str,
                  k: int, largest: bool, n_total: int, bn: int, nd: int):
    """One (i, j, d) grid step; d accumulates, last d extracts local top-k."""
    d = pl.program_id(2)
    j = pl.program_id(1)   # hoisted: program_id inside pl.when bodies does
    # not lower in interpret mode under jit (jax 0.8.2)
    alpha, beta, gamma, qk, pk = METRIC_COEFFS[metric]

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    part = alpha * jax.lax.dot_general(
        q, p, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    if beta:
        part = part + beta * jnp.sum(_term(q, qk), axis=1, keepdims=True)
    if gamma:
        part = part + gamma * jnp.sum(_term(p, pk), axis=1)[None, :]
    acc_ref[...] += part

    @pl.when(d == nd - 1)
    def _extract():
        _extract_block_topk(acc_ref[...], ov_ref, oi_ref, j=j, bn=bn, k=k,
                            largest=largest, n_total=n_total)


def fused_topk_pallas(queries: jax.Array, patterns: jax.Array, *, metric: str,
                      k: int, largest: bool, block_m: int = 128,
                      block_n: int = 128, block_d: int = 512,
                      n_valid: int | None = None, interpret: bool = True
                      ) -> Tuple[jax.Array, jax.Array]:
    """Block-local top-k: returns (M, n_blocks*k) candidate values/indices.

    ``n_valid``: number of real pattern rows (rows >= n_valid are padding
    and are masked out).  The caller merges candidate lists (stable top-k)
    — see `ops.cam_topk`.
    """
    m, dim = queries.shape
    n = patterns.shape[0]
    n_valid = n if n_valid is None else n_valid
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(k, n))
    bd = min(block_d, dim)
    nm, nn, nd = -(-m // bm), -(-n // bn), -(-dim // bd)
    k = min(k, n)

    grid = (nm, nn, nd)
    out_v = jax.ShapeDtypeStruct((nm * bm, nn * k), jnp.float32)
    out_i = jax.ShapeDtypeStruct((nm * bm, nn * k), jnp.int32)

    kern = functools.partial(_fused_kernel, metric=metric, k=k,
                             largest=largest, n_total=n_valid, bn=bn, nd=nd)
    vals, idx = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bn, bd), lambda i, j, d: (j, d)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j, d: (i, j)),
            pl.BlockSpec((bm, k), lambda i, j, d: (i, j)),
        ],
        out_shape=[out_v, out_i],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(queries, patterns)
    return vals[:m], idx[:m]


def _packed_accumulate(q, p, care, acc_ref, d_id):
    """Shared body of the packed kernels: XOR + popcount over one lane
    block, accumulated into the float32 distance scratch (counts are
    < 2**24, so the float accumulation is exact integer arithmetic)."""

    @pl.when(d_id == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = q[:, None, :] ^ p[None, :, :]
    if care is not None:
        x = x & care[None, :, :]
    acc_ref[...] += popcount32(x).sum(-1).astype(jnp.float32)


def _packed_kernel(q_ref, p_ref, ov_ref, oi_ref, acc_ref, *, k: int,
                   largest: bool, n_total: int, bn: int, nl: int):
    """Packed-binary (i, j, l) grid step: hamming = popcount(q ^ p)."""
    d = pl.program_id(2)
    j = pl.program_id(1)
    _packed_accumulate(q_ref[...], p_ref[...], None, acc_ref, d)

    @pl.when(d == nl - 1)
    def _extract():
        _extract_block_topk(acc_ref[...], ov_ref, oi_ref, j=j, bn=bn, k=k,
                            largest=largest, n_total=n_total)


def _packed_ternary_kernel(q_ref, p_ref, c_ref, ov_ref, oi_ref, acc_ref, *,
                           k: int, largest: bool, n_total: int, bn: int,
                           nl: int):
    """Packed-ternary grid step: hamming = popcount((q ^ p) & care)."""
    d = pl.program_id(2)
    j = pl.program_id(1)
    _packed_accumulate(q_ref[...], p_ref[...], c_ref[...], acc_ref, d)

    @pl.when(d == nl - 1)
    def _extract():
        _extract_block_topk(acc_ref[...], ov_ref, oi_ref, j=j, bn=bn, k=k,
                            largest=largest, n_total=n_total)


def fused_topk_packed_pallas(qbits: jax.Array, pbits: jax.Array,
                             care: jax.Array | None = None, *, k: int,
                             largest: bool, block_m: int = 128,
                             block_n: int = 128, block_l: int = 64,
                             n_valid: int | None = None,
                             interpret: bool = True
                             ) -> Tuple[jax.Array, jax.Array]:
    """Packed binary/ternary variant of :func:`fused_topk_pallas`.

    Operands are uint32 lane arrays (``packing.pack_bits``): ``qbits``
    (M, L), ``pbits`` (N, L), optional per-pattern TCAM ``care`` mask
    (N, L).  The distance block is ``popcount(q ^ p [& care])``
    accumulated over lane blocks — integer arithmetic end to end, so
    results are bit-identical to the unpacked hamming path (same
    extraction, same candidate ordering) at 1/32nd the operand traffic.
    On TPU this path runs on the VPU (bitwise + popcount); it exists
    for bandwidth-bound packed galleries, whereas the float kernel
    feeds the MXU — the engine picks per metric/dtype.
    """
    m, L = qbits.shape
    n = pbits.shape[0]
    n_valid = n if n_valid is None else n_valid
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(k, n))
    bl = min(block_l, L)
    nm, nn, nl = -(-m // bm), -(-n // bn), -(-L // bl)
    k = min(k, n)

    grid = (nm, nn, nl)
    out_v = jax.ShapeDtypeStruct((nm * bm, nn * k), jnp.float32)
    out_i = jax.ShapeDtypeStruct((nm * bm, nn * k), jnp.int32)

    q_spec = pl.BlockSpec((bm, bl), lambda i, j, d: (i, d))
    p_spec = pl.BlockSpec((bn, bl), lambda i, j, d: (j, d))
    if care is None:
        kern = functools.partial(_packed_kernel, k=k, largest=largest,
                                 n_total=n_valid, bn=bn, nl=nl)
        in_specs, operands = [q_spec, p_spec], (qbits, pbits)
    else:
        kern = functools.partial(_packed_ternary_kernel, k=k, largest=largest,
                                 n_total=n_valid, bn=bn, nl=nl)
        in_specs, operands = [q_spec, p_spec, p_spec], (qbits, pbits, care)
    vals, idx = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j, d: (i, j)),
            pl.BlockSpec((bm, k), lambda i, j, d: (i, j)),
        ],
        out_shape=[out_v, out_i],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return vals[:m], idx[:m]


def _dist_kernel(q_ref, p_ref, o_ref, *, metric: str, nd: int):
    d = pl.program_id(2)
    alpha, beta, gamma, qk, pk = METRIC_COEFFS[metric]

    @pl.when(d == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    part = alpha * jax.lax.dot_general(
        q, p, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    if beta:
        part = part + beta * jnp.sum(_term(q, qk), axis=1, keepdims=True)
    if gamma:
        part = part + gamma * jnp.sum(_term(p, pk), axis=1)[None, :]
    o_ref[...] += part


def distance_pallas(queries: jax.Array, patterns: jax.Array, *, metric: str,
                    block_m: int = 128, block_n: int = 128,
                    block_d: int = 512, interpret: bool = True) -> jax.Array:
    """Full (M, N) distance matrix (used by exact/range match)."""
    m, dim = queries.shape
    n = patterns.shape[0]
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    bd = min(block_d, dim)
    nm, nn, nd = -(-m // bm), -(-n // bn), -(-dim // bd)
    kern = functools.partial(_dist_kernel, metric=metric, nd=nd)
    out = pl.pallas_call(
        kern,
        grid=(nm, nn, nd),
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bn, bd), lambda i, j, d: (j, d)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(queries, patterns)
    return out[:m, :n]
