"""Fused Pallas kernel for HDC hypervector encoding.

Record-based encoding binds each feature's *key* (position) hypervector
with the hypervector of the feature's quantised *level*, then majority-
bundles across features::

    enc[m] = sign( sum_f  keys[f] * levels[q[m, f]] )        (bipolar)

The gather ``levels[q[m, f]]`` is hostile to the MXU, but the sum
decomposes over the (small, static) level alphabet into L matmuls::

    sum_f keys[f, h] * levels[q[m, f], h]
        = sum_l ( onehot_l @ keys )[m, h] * levels[l, h]

where ``onehot_l[m, f] = (q[m, f] == l)`` — a compare (VPU), a matmul
(MXU) and a broadcast multiply per level, no gathers.  Every product is
±1 and every sum is a small integer, so float32 accumulation is exact
and the kernel is **bit-identical** to :func:`repro.kernels.ref.
hdc_encode` (sign tie -> +1) regardless of accumulation order.

Grid = (M/bm, H/bh, F/bf); the F axis accumulates partial sums in a
VMEM scratch block, the last F step applies the sign.  ``levels`` is
blocked on H only (L is a handful of rows and rides along whole).
Padding contract: pad ``q`` with level 0 and ``keys`` with zero rows —
a padded feature's one-hot hits only zeroed key rows, contributing
nothing (the `ops.hdc_encode` wrapper does this).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams as _CompilerParams

__all__ = ["hdc_encode_pallas"]


def _encode_kernel(q_ref, k_ref, l_ref, o_ref, acc_ref, *, n_levels: int,
                   nf: int):
    """One (i, h, f) grid step; f accumulates, last f extracts the sign."""
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                          # (bm, bf) int32 levels
    keys = k_ref[...].astype(jnp.float32)   # (bf, bh) bipolar (0 = pad)
    lv = l_ref[...].astype(jnp.float32)     # (L, bh) bipolar
    acc = acc_ref[...]
    for level in range(n_levels):
        onehot = (q == level).astype(jnp.float32)
        part = jax.lax.dot_general(
            onehot, keys, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc = acc + part * lv[level][None, :]
    acc_ref[...] = acc

    @pl.when(f == nf - 1)
    def _sign():
        o_ref[...] = jnp.where(acc_ref[...] >= 0, 1.0, -1.0)


def hdc_encode_pallas(level_idx: jax.Array, keys: jax.Array,
                      levels: jax.Array, *, block_m: int = 128,
                      block_f: int = 256, block_h: int = 256,
                      interpret: bool = True) -> jax.Array:
    """(M, H) bipolar encodings; operands must be block-aligned.

    ``level_idx`` (M, F) int32, ``keys`` (F, H) float32 bipolar (zero
    rows = padded features), ``levels`` (L, H) float32 bipolar with a
    small static L.  See `ops.hdc_encode` for the padding wrapper.
    """
    m, dim_f = level_idx.shape
    n_levels, h = levels.shape
    bm = min(block_m, max(8, m))
    bf = min(block_f, dim_f)
    bh = min(block_h, h)
    nm, nh, nf = -(-m // bm), -(-h // bh), -(-dim_f // bf)

    kern = functools.partial(_encode_kernel, n_levels=n_levels, nf=nf)
    out = pl.pallas_call(
        kern,
        grid=(nm, nh, nf),
        in_specs=[
            pl.BlockSpec((bm, bf), lambda i, hh, f: (i, f)),
            pl.BlockSpec((bf, bh), lambda i, hh, f: (f, hh)),
            pl.BlockSpec((n_levels, bh), lambda i, hh, f: (0, hh)),
        ],
        out_specs=pl.BlockSpec((bm, bh), lambda i, hh, f: (i, hh)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nh * bh), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bh), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(level_idx, keys, levels)
    return out[:m, :h]
