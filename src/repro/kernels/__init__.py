"""Pallas TPU kernels for the compute hot-spots.

* `cam_search`      — the paper's primitive: fused distance + block top-k
                      (hamming / dot / L2), `ops.py` wrappers, `ref.py`
                      pure-jnp oracles.
* `packing`         — uint32 bit-lane packing + popcount for the packed
                      binary/ternary (TCAM wildcard) fast path:
                      `hamming = popcount(q ^ p)`, ternary
                      `popcount((q ^ p) & care)`.
* `acam`            — analog-CAM range search: fused interval match
                      (`lo <= q <= hi` per cell, wildcard = full range)
                      and in-kernel thresholded distance match (the
                      paper's TH sensing mode).
* `hdc_encode`      — fused HDC hypervector encoding (bind + majority
                      bundle via the one-hot matmul decomposition);
                      oracles `ref.hdc_bind/hdc_bundle/hdc_permute/
                      hdc_encode`.
* `flash_attention` — online-softmax attention forward (the LM framework's
                      hot spot; §Perf cell B's TPU answer).

All kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling)
and validated on CPU in interpret mode against the oracles.
"""
