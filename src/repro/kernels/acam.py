"""Pallas TPU kernels for analog-CAM range search (interval + threshold).

An analog CAM cell stores an *interval* ``[lo, hi]`` and matches while
the analog input voltage lies inside it (Li et al., *Analog content
addressable memories with memristors*); a row's match line stays high
iff every cell matches.  That single primitive executes a root-to-leaf
decision-tree branch in one search (Pedretti et al., *Tree-based
machine learning performed in-memory with memristive analog CAM*) —
the flagship non-KNN CAM workload.

Two fused kernels, both emitting a compact ``int8`` match matrix
instead of a float distance surface:

* ``acam_match_pallas`` — interval match: grid ``(M/bm, N/bn, D/bd)``,
  the D axis accumulates per-block *violation counts*
  (``q < lo or q > hi`` per cell) in a VMEM scratch, and the last D
  step writes ``violations == 0``.  A wildcard dimension is a
  full-range interval (``lo = -inf``/``hi = +inf``) and can never add
  a violation.  Counts are integers in float32 (exact), so the result
  equals ``ref.acam_match`` bit-for-bit under any tiling.
* ``range_match_pallas`` — thresholded variant of the existing
  distance kernels: the same MXU matmul decomposition as
  ``cam_search._fused_kernel`` accumulates the distance block, the
  last D step converts to the logical metric domain (``dot = D - 2h``
  for bipolar search) and writes ``dist <= tau`` (or ``>= tau``) —
  the paper's TH sensing mode, batched over queries.

Padding contract (shared with the engine layouts): zero-padded
dimensions carry ``q = lo = hi = 0`` / ``q = p = 0`` and contribute no
violation / no mismatch; pattern rows at or beyond ``n_total`` are
forced to non-match and sliced off by the wrappers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .cam_search import METRIC_COEFFS, _term
from .pallas_compat import CompilerParams as _CompilerParams

__all__ = ["acam_match_pallas", "range_match_pallas"]


def _pad_f32(x: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad to block multiples as float32 (the kernels' shared
    padding contract: zero-padded dims can never add a violation or a
    mismatch).  Mirrors ``ops.pad_to_blocks``, which cannot be imported
    here (``ops`` imports this module)."""
    pr, pc = (-x.shape[0]) % rows, (-x.shape[1]) % cols
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x.astype(jnp.float32)


def _write_match(acc, o_ref, *, j: int, bn: int, n_total: int, tau: float,
                 below: bool, to_logical: str, dim: int):
    """Threshold + row-mask + int8 store shared by both kernels.

    ``to_logical``: ``"identity"`` keeps the accumulated value,
    ``"bipolar"`` converts a physical Hamming count to the dot/cos
    domain (``v = dim - 2h``) — the same elementwise translation the
    jnp engine path applies, so the compare sees identical floats.
    """
    v = acc if to_logical == "identity" else float(dim) - 2.0 * acc
    hit = (v <= tau) if below else (v >= tau)
    col = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1)
    hit = hit & (col + j * bn < n_total)     # padded rows never match
    o_ref[...] = hit.astype(jnp.int8)


def _interval_kernel(q_ref, lo_ref, hi_ref, o_ref, acc_ref, *, nd: int,
                     n_total: int, bn: int):
    """One (i, j, d) grid step of the interval match: d accumulates the
    violation count, the last d emits ``violations == 0``."""
    d = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)[:, None, :]
    lo = lo_ref[...].astype(jnp.float32)[None, :, :]
    hi = hi_ref[...].astype(jnp.float32)[None, :, :]
    viol = ((q < lo) | (q > hi)).sum(-1)
    acc_ref[...] += viol.astype(jnp.float32)

    @pl.when(d == nd - 1)
    def _emit():
        _write_match(acc_ref[...], o_ref, j=j, bn=bn, n_total=n_total,
                     tau=0.0, below=True, to_logical="identity", dim=0)


def acam_match_pallas(queries: jax.Array, lo: jax.Array, hi: jax.Array, *,
                      block_m: int = 128, block_n: int = 128,
                      block_d: int = 128, n_valid: int | None = None,
                      interpret: bool = True) -> jax.Array:
    """(M, N) int8 interval-match matrix (1 = row matches the query).

    ``queries`` (M, D); ``lo``/``hi`` (N, D) per-row interval bounds.
    Inputs need not be block-aligned — zero padding is applied here
    (zero-width padded intervals match the zero-padded query dims, so
    padding never flips a result; ``n_valid`` masks padded rows).
    """
    m, dim = queries.shape
    n = lo.shape[0]
    n_valid = n if n_valid is None else n_valid
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    bd = min(block_d, dim)
    nm, nn, nd = -(-m // bm), -(-n // bn), -(-dim // bd)

    qp = _pad_f32(queries, bm, bd)
    lop, hip = _pad_f32(lo, bn, bd), _pad_f32(hi, bn, bd)
    kern = functools.partial(_interval_kernel, nd=nd, n_total=n_valid, bn=bn)
    out = pl.pallas_call(
        kern,
        grid=(nm, nn, nd),
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bn, bd), lambda i, j, d: (j, d)),
            pl.BlockSpec((bn, bd), lambda i, j, d: (j, d)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, lop, hip)
    return out[:m, :n]


def _range_kernel(q_ref, p_ref, o_ref, acc_ref, *, metric: str, nd: int,
                  n_total: int, bn: int, tau: float, below: bool,
                  to_logical: str, dim: int):
    """Distance accumulation (MXU decomposition) + threshold at last d."""
    d = pl.program_id(2)
    j = pl.program_id(1)
    alpha, beta, gamma, qk, pk = METRIC_COEFFS[metric]

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    part = alpha * jax.lax.dot_general(
        q, p, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    if beta:
        part = part + beta * jnp.sum(_term(q, qk), axis=1, keepdims=True)
    if gamma:
        part = part + gamma * jnp.sum(_term(p, pk), axis=1)[None, :]
    acc_ref[...] += part

    @pl.when(d == nd - 1)
    def _emit():
        _write_match(acc_ref[...], o_ref, j=j, bn=bn, n_total=n_total,
                     tau=tau, below=below, to_logical=to_logical, dim=dim)


def range_match_pallas(queries: jax.Array, patterns: jax.Array, *,
                       metric: str, threshold: float, below: bool = True,
                       to_logical: str = "identity", dim: int | None = None,
                       block_m: int = 128, block_n: int = 128,
                       block_d: int = 512, n_valid: int | None = None,
                       interpret: bool = True) -> jax.Array:
    """(M, N) int8 threshold-match matrix (TH sensing, ``dist <= tau``).

    ``metric`` is the *physical* metric (hamming / dot / eucl — the
    MXU decomposition); ``to_logical="bipolar"`` converts the Hamming
    count to ``dim - 2h`` before the compare, mirroring the engine's
    metric-domain translation bit-for-bit.
    """
    m, d_ = queries.shape
    n = patterns.shape[0]
    n_valid = n if n_valid is None else n_valid
    dim = d_ if dim is None else dim
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    bd = min(block_d, d_)
    nm, nn, nd = -(-m // bm), -(-n // bn), -(-d_ // bd)

    qp, pp = _pad_f32(queries, bm, bd), _pad_f32(patterns, bn, bd)
    kern = functools.partial(_range_kernel, metric=metric, nd=nd,
                             n_total=n_valid, bn=bn, tau=float(threshold),
                             below=below, to_logical=to_logical, dim=dim)
    out = pl.pallas_call(
        kern,
        grid=(nm, nn, nd),
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bn, bd), lambda i, j, d: (j, d)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, pp)
    return out[:m, :n]
