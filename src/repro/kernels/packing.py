"""Bit-packing for the binary/ternary CAM fast path.

CAM arrays match *cells*, not floats: BCAM rows are bit vectors, TCAM
rows are bit vectors with per-cell "don't care" wildcards.  Encoding a
binary / bipolar workload as dense float32 (one 4-byte float per cell)
pays 32x the memory traffic the data needs — and match throughput on
word-packed patterns is bandwidth-bound (de Lima et al., *Full-Stack
Optimization for CAM-Only DNN Inference*; Li et al., analog CAMs).

This module packs logical cells into uint32 **lanes** (32 cells per
lane, LSB-first: cell ``j`` of a lane group lands in bit ``j`` of lane
``j // 32``) so a Hamming search becomes ``popcount(q ^ p)`` and a TCAM
wildcard search becomes ``popcount((q ^ p) & care)`` — pure integer
ops, bit-identical to the unpacked mismatch count.

Tail handling: a dimension that is not a multiple of 32 leaves the top
bits of the last lane zero in *both* operands (and zero in the care
mask), so padded bits never contribute to a match count.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LANE_BITS", "lanes", "pack_bits", "pack_bipolar", "unpack_bits",
           "popcount32", "popcount32_lut"]

#: cells per packed lane
LANE_BITS = 32

# SWAR popcount masks (Hacker's Delight fig. 5-2), kept as numpy scalars
# so the jitted kernels see weakly-typed uint32 constants
_M1 = np.uint32(0x55555555)
_M2 = np.uint32(0x33333333)
_M4 = np.uint32(0x0F0F0F0F)
_M6 = np.uint32(0x0000003F)

#: byte -> popcount table for the LUT variant
_POP8 = np.unpackbits(np.arange(256, dtype=np.uint8)[:, None],
                      axis=1).sum(1).astype(np.int32)


def lanes(dim: int) -> int:
    """uint32 lanes needed for ``dim`` cells: ``ceil(dim / 32)``."""
    return -(-int(dim) // LANE_BITS)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack cells along the last axis into uint32 lanes (LSB-first).

    Any dtype is accepted; a cell is set iff the element is non-zero
    (bipolar data wants :func:`pack_bipolar`, which thresholds at
    ``> 0`` instead).  ``(..., dim)`` -> ``(..., lanes(dim))``; tail
    bits of the last lane are zero.
    """
    b = jnp.asarray(bits)
    if b.dtype != jnp.bool_:
        b = b != 0
    dim = b.shape[-1]
    nl = lanes(dim)
    pad = nl * LANE_BITS - dim
    if pad:
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    u = b.reshape(b.shape[:-1] + (nl, LANE_BITS)).astype(jnp.uint32)
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    return (u << shifts).sum(-1, dtype=jnp.uint32)


def pack_bipolar(x: jax.Array) -> jax.Array:
    """Sign-pack bipolar data: cell set iff the element is positive.

    Matches the engine's float encoding for ``dot``/``cos`` — both
    binarise via ``x > 0`` — so the packed and unpacked paths see the
    same cells for *any* real-valued input.
    """
    return pack_bits(jnp.asarray(x) > 0)


def unpack_bits(packed: jax.Array, dim: int) -> jax.Array:
    """Inverse of :func:`pack_bits`: ``(..., lanes)`` -> ``(..., dim)``
    as uint8 in {0, 1} (tail lanes sliced off)."""
    u = jnp.asarray(packed).astype(jnp.uint32)
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    bits = (u[..., :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(u.shape[:-1] + (u.shape[-1] * LANE_BITS,))
    return bits[..., :dim].astype(jnp.uint8)


def popcount32(x: jax.Array) -> jax.Array:
    """Per-element population count of a uint32 array (SWAR, branch-free).

    The classic shift-add reduction — 12 integer vector ops, no lookup
    traffic — used by the packed kernels in both the jnp and Pallas
    execution paths.  Returns int32.
    """
    x = jnp.asarray(x).astype(jnp.uint32)
    x = x - ((x >> 1) & _M1)
    x = (x & _M2) + ((x >> 2) & _M2)
    x = (x + (x >> 4)) & _M4
    x = x + (x >> 8)
    x = x + (x >> 16)
    return (x & _M6).astype(jnp.int32)


def popcount32_lut(x: jax.Array) -> jax.Array:
    """Lookup-table popcount (four byte-table gathers per lane).

    Kept alongside the SWAR variant because gather-friendly substrates
    (CPU interpret paths, scalar cores) can prefer it; both must agree
    bit-for-bit (pinned by tests).  Returns int32.
    """
    x = jnp.asarray(x).astype(jnp.uint32)
    t = jnp.asarray(_POP8)
    mask = jnp.uint32(0xFF)
    return (t[x & mask] + t[(x >> 8) & mask]
            + t[(x >> 16) & mask] + t[(x >> 24) & mask])
