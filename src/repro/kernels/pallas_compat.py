"""Version-compat shims for the Pallas TPU API.

jax has renamed ``CompilerParams`` <-> ``TPUCompilerParams`` across
releases; every kernel module imports the resolved class from here so
the next rename is a one-line fix.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
