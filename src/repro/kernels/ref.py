"""Pure-jnp oracles for the CAM search kernels.

These define the *semantics* that both the Pallas TPU kernels
(`repro.kernels.cam_search`) and the C4CAM functional executor must match
bit-for-bit (integer metrics) or to float tolerance (analog metrics).

Conventions
-----------
queries  : (M, D)  — one query per row
patterns : (N, D)  — the stored CAM content ("database")
returns  : (values, indices), each (M, K)

Metrics
-------
* ``hamming``  — # of mismatching cells; inputs are {0,1} (or booleans).
* ``dot``      — inner product; for bipolar +-1 data ``dot = D - 2*hamming``.
* ``eucl``     — squared L2 distance (sqrt is monotone; CAM sensing
  compares squared sums, so we keep squares end-to-end).
* ``cos``      — cosine similarity.

Match types
-----------
* best-k  : top-k by value (largest=True for similarities, False for
  distances) with deterministic lowest-index tie-breaking.
* exact   : rows with distance == 0 (boolean match vector).
* range   : rows with distance <= threshold (boolean match vector).
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["distances", "packed_distances", "ternary_distances",
           "tile_distance", "tiled_distances", "cam_topk",
           "cam_topk_ternary", "cam_exact", "cam_range", "acam_match",
           "acam_violations", "cam_topk_tiled", "merge_topk",
           "pad_candidates", "hdc_bind", "hdc_bundle", "hdc_permute",
           "hdc_encode"]


def distances(queries: jax.Array, patterns: jax.Array, metric: str) -> jax.Array:
    """(M, N) distance/similarity matrix."""
    q = queries.astype(jnp.float32)
    p = patterns.astype(jnp.float32)
    if metric == "hamming":
        # mismatch count; inputs {0,1}
        return (q[:, None, :] != p[None, :, :]).sum(-1).astype(jnp.float32)
    if metric == "dot":
        return q @ p.T
    if metric == "eucl":
        # squared L2 via expansion (matches tiled partial-sum accumulation)
        qq = (q * q).sum(-1, keepdims=True)
        pp = (p * p).sum(-1)
        return qq + pp[None, :] - 2.0 * (q @ p.T)
    if metric == "cos":
        qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        pn = p / jnp.maximum(jnp.linalg.norm(p, axis=-1, keepdims=True), 1e-12)
        return qn @ pn.T
    raise ValueError(f"unknown metric {metric!r}")


def packed_distances(qbits: jax.Array, pbits: jax.Array,
                     care: jax.Array | None = None) -> jax.Array:
    """(M, N) Hamming distances on bit-packed uint32 operands.

    ``qbits``: (M, L), ``pbits``: (N, L) — :func:`packing.pack_bits`
    lanes.  ``hamming = popcount(q ^ p)``; with a packed per-pattern
    ``care`` mask (N, L) the TCAM wildcard search is
    ``popcount((q ^ p) & care)`` — cells whose care bit is clear can
    never mismatch.  Bit-identical (as integers) to
    :func:`distances(metric="hamming")` / :func:`ternary_distances` on
    the unpacked cells, because both count exactly the same mismatching
    positions.  Returned as float32 to match the unpacked kernels
    (counts are < 2**24, so the conversion is exact).
    """
    from .packing import popcount32

    x = qbits[:, None, :] ^ pbits[None, :, :]
    if care is not None:
        x = x & care[None, :, :]
    return popcount32(x).sum(-1).astype(jnp.float32)


def ternary_distances(queries: jax.Array, patterns: jax.Array,
                      care: jax.Array) -> jax.Array:
    """(M, N) TCAM wildcard Hamming distance on *unpacked* cells.

    ``care``: (N, D) per-pattern mask — non-zero entries are compared,
    zero entries are "don't care" wildcards that never mismatch.  This
    is the semantic oracle the packed ternary kernels must match
    bit-for-bit (integer counts).
    """
    mism = queries[:, None, :] != patterns[None, :, :]
    return (mism & (care[None, :, :] != 0)).sum(-1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# HDC hypervector algebra (bipolar {-1, +1} convention)
# ---------------------------------------------------------------------------


def hdc_bind(a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise bind of bipolar hypervectors: multiplication.

    For bipolar ±1 data, multiply *is* XOR in the sign domain
    (``(-1)^(x ^ y)``), which is the TCAM-friendly binding the packed
    engine path exploits — binding never changes the alphabet.
    """
    return (a * b).astype(jnp.float32)


def hdc_bundle(stack: jax.Array) -> jax.Array:
    """Majority bundle along axis 0: sign of the elementwise sum.

    Ties (an even stack splitting evenly) resolve to **+1** — the
    deterministic contract every execution path (oracle, fused encode
    kernel, classifier AM refresh) must share for bit-identity.
    """
    s = jnp.sum(stack.astype(jnp.float32), axis=0)
    return jnp.where(s >= 0, 1.0, -1.0).astype(jnp.float32)


def hdc_permute(x: jax.Array, shift: int) -> jax.Array:
    """Cyclic permutation (roll) along the hypervector dimension —
    the sequence/position operator of the HDC algebra."""
    return jnp.roll(x, shift, axis=-1)


def hdc_encode(level_idx: jax.Array, keys: jax.Array,
               levels: jax.Array) -> jax.Array:
    """Record-based hypervector encoding — the semantic oracle.

    ``level_idx``: (M, F) int quantised feature levels; ``keys``: (F, H)
    bipolar per-feature (position) hypervectors; ``levels``: (L, H)
    bipolar level hypervectors.  Sample ``m`` encodes as the majority
    bundle over features of ``bind(keys[f], levels[level_idx[m, f]])``,
    tie -> +1 (:func:`hdc_bundle`).  All sums are small integers, exact
    in float32 — the fused Pallas kernel's matmul decomposition
    (:mod:`repro.kernels.hdc_encode`) reproduces them bit-for-bit.

    Materialises the dense (M, F, H) bound tensor: oracle use only (the
    production paths are the fused kernel and the one-hot matmul
    decomposition in :mod:`repro.hdc.encoding`).
    """
    bound = keys[None, :, :].astype(jnp.float32) * \
        levels.astype(jnp.float32)[level_idx]              # (M, F, H)
    s = bound.sum(axis=1)
    return jnp.where(s >= 0, 1.0, -1.0).astype(jnp.float32)


def _topk_with_ties(scores: jax.Array, k: int, largest: bool
                    ) -> Tuple[jax.Array, jax.Array]:
    """Deterministic top-k: ties broken toward the lower index.

    ``jax.lax.top_k`` is stable (equal elements keep ascending-index order),
    which we rely on for bit-exact equivalence between the dense and tiled
    execution paths.
    """
    key = scores if largest else -scores
    _, idx = jax.lax.top_k(key, k)
    true_vals = jnp.take_along_axis(scores, idx, axis=-1)
    return true_vals, idx.astype(jnp.int32)


@partial(jax.jit, static_argnames=("metric", "k", "largest"))
def cam_topk(queries: jax.Array, patterns: jax.Array, *, metric: str,
             k: int, largest: bool) -> Tuple[jax.Array, jax.Array]:
    """Best-match search: top-k rows of ``patterns`` per query."""
    d = distances(queries, patterns, metric)
    return _topk_with_ties(d, k, largest)


@partial(jax.jit, static_argnames=("k", "largest"))
def cam_topk_ternary(queries: jax.Array, patterns: jax.Array,
                     care: jax.Array, *, k: int, largest: bool = False
                     ) -> Tuple[jax.Array, jax.Array]:
    """TCAM wildcard best-match: top-k by care-masked Hamming distance."""
    d = ternary_distances(queries, patterns, care)
    return _topk_with_ties(d, k, largest)


@partial(jax.jit, static_argnames=("metric",))
def cam_exact(queries: jax.Array, patterns: jax.Array, *, metric: str = "hamming"
              ) -> jax.Array:
    """(M, N) boolean exact-match matrix (distance == 0)."""
    d = distances(queries, patterns, metric)
    return d == 0


@partial(jax.jit, static_argnames=("metric",))
def cam_range(queries: jax.Array, patterns: jax.Array, threshold: float,
              *, metric: str = "hamming") -> jax.Array:
    """(M, N) boolean threshold-match matrix (distance <= threshold).

    The paper's TH sensing mode: a row matches iff its distance is at
    or below the threshold — ties are *inclusive* (a match-line that
    discharges exactly at the reference level still latches).  For
    similarity metrics (``dot``/``cos``) the same ``<=`` contract holds
    on the similarity value; callers wanting "at least this similar"
    negate or use the engine's ``below=False`` range programs.
    """
    d = distances(queries, patterns, metric)
    return d <= threshold


def acam_violations(queries: jax.Array, lo: jax.Array, hi: jax.Array
                    ) -> jax.Array:
    """(M, N) count of interval violations per (query, row) pair.

    ``lo``/``hi``: (N, D) per-row per-dimension interval bounds of an
    analog CAM (each aCAM cell stores an interval and matches iff the
    analog input falls inside it — Li et al., *Analog content
    addressable memories with memristors*).  A wildcard dimension is a
    full-range interval (``lo = -inf, hi = +inf``), which can never be
    violated.  Counts are small integers returned as float32 (exact),
    and they are *additive over dimension tiles* — the tiled engine
    path accumulates per-column-tile partial counts and reproduces the
    dense count bit-for-bit.
    """
    q = queries.astype(jnp.float32)[:, None, :]
    viol = (q < lo.astype(jnp.float32)[None, :, :]) | \
        (q > hi.astype(jnp.float32)[None, :, :])
    return viol.sum(-1).astype(jnp.float32)


@jax.jit
def acam_match(queries: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """(M, N) boolean aCAM interval-match matrix.

    Row ``j`` matches query ``i`` iff ``lo[j, d] <= q[i, d] <= hi[j, d]``
    for every dimension ``d`` — the analog CAM match-line stays charged
    only when all cells are inside their stored interval.  This is the
    semantic contract the Pallas interval kernel and the engine's
    ``RangePlan`` interval mode must match exactly (pure comparisons and
    integer counts: no arithmetic, so the result is tiling-invariant).
    """
    return acam_violations(queries, lo, hi) == 0


def tile_distance(q_t: jax.Array, p_t: jax.Array, metric: str) -> jax.Array:
    """One column tile's (M, rows) partial distance block.

    The *single* definition of the per-tile arithmetic every tiled path
    shares — :func:`cam_topk_tiled`, :func:`tiled_distances`, and the
    engine's scan executables all accumulate exactly these float
    operations, which is what makes their bit-identity a structural
    property rather than a maintained coincidence.
    """
    if metric == "hamming":
        return (q_t[:, None, :] != p_t[None, :, :]).sum(-1).astype(jnp.float32)
    if metric == "dot":
        return q_t @ p_t.T
    if metric == "eucl":
        qq = (q_t * q_t).sum(-1, keepdims=True)
        ppv = (p_t * p_t).sum(-1)
        return qq + ppv[None, :] - 2.0 * (q_t @ p_t.T)
    raise ValueError(f"tiled path does not support metric {metric!r}")


def tiled_distances(queries: jax.Array, patterns: jax.Array, *, metric: str,
                    tile_rows: int, dims_per_tile: int) -> jax.Array:
    """(M, N) distance matrix with *tiled* partial-sum accumulation.

    Same per-column-tile arithmetic (:func:`tile_distance`) and
    left-to-right accumulation order as :func:`cam_topk_tiled` — this
    is the distance surface the partitioned hardware actually senses,
    and the oracle the engine's ``RangePlan`` threshold path must match
    bit-for-bit (identical float operations in identical order, for
    *every* metric including eucl).  Bit-identical to
    :func:`distances` for the integer metrics.
    """
    m, dim = queries.shape
    n = patterns.shape[0]
    gr = -(-n // tile_rows)
    gc = -(-dim // dims_per_tile)
    qp = jnp.pad(queries.astype(jnp.float32),
                 ((0, 0), (0, gc * dims_per_tile - dim)))
    pp = jnp.pad(patterns.astype(jnp.float32),
                 ((0, gr * tile_rows - n), (0, gc * dims_per_tile - dim)))

    rows = []
    for r in range(gr):
        p_rows = pp[r * tile_rows:(r + 1) * tile_rows]
        dist = None
        for c in range(gc):
            sl = slice(c * dims_per_tile, (c + 1) * dims_per_tile)
            part = tile_distance(qp[:, sl], p_rows[:, sl], metric)
            dist = part if dist is None else dist + part   # horizontal merge
        rows.append(dist)
    return jnp.concatenate(rows, axis=-1)[:, :n]


def pad_candidates(vals: jax.Array, idx: jax.Array, k: int, largest: bool
                   ) -> Tuple[jax.Array, jax.Array]:
    """Pad an (M, k') candidate list up to k with losing sentinels.

    Shared by the tiled reference below and the search-plan engine
    (`repro.core.engine`) so both paths emit identical pad content —
    the stable merges rely on that for bit-exact equivalence.
    """
    short = k - vals.shape[-1]
    if short <= 0:
        return vals, idx
    lose = -jnp.inf if largest else jnp.inf
    return (jnp.pad(vals, ((0, 0), (0, short)), constant_values=lose),
            jnp.pad(idx, ((0, 0), (0, short)), constant_values=2 ** 30))


def merge_topk(values_a: jax.Array, idx_a: jax.Array, values_b: jax.Array,
               idx_b: jax.Array, *, k: int, largest: bool
               ) -> Tuple[jax.Array, jax.Array]:
    """Vertical merge of two (M, k) candidate lists (cam.merge_partial)."""
    vals = jnp.concatenate([values_a, values_b], axis=-1)
    idxs = jnp.concatenate([idx_a, idx_b], axis=-1)
    key = vals if largest else -vals
    # stability of lax.top_k + "lists concatenated in ascending global row
    # order" gives lower-global-index tie-breaking, matching cam_topk.
    _, sel = jax.lax.top_k(key, k)
    return (jnp.take_along_axis(vals, sel, axis=-1),
            jnp.take_along_axis(idxs, sel, axis=-1))


def cam_topk_tiled(queries: jax.Array, patterns: jax.Array, *, metric: str,
                   k: int, largest: bool, tile_rows: int, dims_per_tile: int,
                   care: jax.Array | None = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Reference for the *tiled* (partitioned) execution path.

    Mirrors the compulsory-partitioning semantics exactly: horizontal
    accumulation of per-column-tile partial distances, per-row-tile top-k,
    then vertical tournament merge with global index offsets.  Must equal
    :func:`cam_topk` for additive metrics (hamming / dot / eucl).

    ``care`` (hamming only): per-pattern (N, D) TCAM wildcard mask —
    zero entries never mismatch (see :func:`ternary_distances`).  The
    mask is additive over column tiles like the plain mismatch count, so
    the tiled result equals the dense oracle bit-for-bit.
    """
    m, dim = queries.shape
    n = patterns.shape[0]
    gr = -(-n // tile_rows)
    gc = -(-dim // dims_per_tile)
    pad_n = gr * tile_rows - n
    pad_d = gc * dims_per_tile - dim
    fill = 0.0
    qp = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pad_d)))
    pp = jnp.pad(patterns.astype(jnp.float32), ((0, pad_n), (0, pad_d)))
    if care is not None:
        if metric != "hamming":
            raise ValueError("care masks require metric='hamming'")
        cp = jnp.pad((jnp.asarray(care) != 0).astype(jnp.float32),
                     ((0, pad_n), (0, pad_d)))

    def col_tile(ct, q_t, p_t, c_t=None):
        if c_t is not None:
            return ((q_t[:, None, :] != p_t[None, :, :])
                    & (c_t[None, :, :] != 0)).sum(-1).astype(jnp.float32)
        return tile_distance(q_t, p_t, metric)

    acc_v = acc_i = None
    for r in range(gr):
        p_rows = pp[r * tile_rows:(r + 1) * tile_rows]
        c_rows = cp[r * tile_rows:(r + 1) * tile_rows] if care is not None \
            else None
        dist = None
        for c in range(gc):
            sl = slice(c * dims_per_tile, (c + 1) * dims_per_tile)
            part = col_tile(c, qp[:, sl], p_rows[:, sl],
                            None if c_rows is None else c_rows[:, sl])
            dist = part if dist is None else dist + part   # horizontal merge
        # mask padded rows so they never win
        if r == gr - 1 and pad_n:
            bad = jnp.full((m, pad_n), -jnp.inf if largest else jnp.inf)
            dist = dist.at[:, tile_rows - pad_n:].set(bad)
        v, i = _topk_with_ties(dist, min(k, tile_rows), largest)
        v, i = pad_candidates(v, i + r * tile_rows, k, largest)
        if acc_v is None:
            acc_v, acc_i = v, i
        else:
            acc_v, acc_i = merge_topk(acc_v, acc_i, v, i, k=k, largest=largest)
    return acc_v, acc_i
