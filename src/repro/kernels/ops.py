"""Jitted public wrappers around the Pallas CAM-search kernels.

Semantics match `repro.kernels.ref` bit-for-bit (integer metrics) /
to float tolerance (analog).  ``cam_topk`` pads inputs to block multiples
on every call so the kernels only ever see aligned shapes; the search-plan
engine (`repro.core.engine`) instead hoists that padding behind its plan
cache — patterns are laid out once per stored array via
:func:`pad_to_blocks` and streamed through :func:`cam_topk_prepadded`.
`interpret` defaults to True off-TPU (this container is CPU-only; on a
real TPU backend the same code path compiles through Mosaic).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref as kref
from .acam import acam_match_pallas, range_match_pallas
from .cam_search import (distance_pallas, fused_topk_pallas,
                         fused_topk_packed_pallas)
from .hdc_encode import hdc_encode_pallas

__all__ = ["cam_topk", "cam_topk_prepadded", "cam_topk_packed",
           "cam_topk_packed_prepadded", "pad_to_blocks", "cam_exact",
           "cam_range", "acam_match", "acam_match_prepadded",
           "cam_range_match", "cam_range_match_prepadded",
           "hdc_bind", "hdc_bundle", "hdc_permute", "hdc_encode"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_to_blocks(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    """Zero-pad a 2-D operand up to block multiples (rows, cols)."""
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


_pad_to = pad_to_blocks   # backwards-compatible internal alias


@functools.partial(jax.jit, static_argnames=("metric", "k", "largest",
                                             "n_valid", "block_m", "block_n",
                                             "block_d", "interpret"))
def cam_topk_prepadded(qp: jax.Array, pp: jax.Array, *, metric: str, k: int,
                       largest: bool, n_valid: int, block_m: int,
                       block_n: int, block_d: int,
                       interpret: Optional[bool] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Kernel launch + candidate merge for block-aligned operands.

    The hot path of the search-plan engine: operand padding already
    happened (once, behind the plan cache) so each micro-batch chunk goes
    straight to the fused kernel.  ``k`` must already be clamped to
    ``n_valid``.  Returns padded-row results; callers slice to valid rows.
    """
    if interpret is None:
        interpret = not _on_tpu()
    vals, idx = fused_topk_pallas(qp, pp, metric=metric, k=k,
                                  largest=largest, block_m=block_m,
                                  block_n=block_n, block_d=block_d,
                                  n_valid=n_valid, interpret=interpret)
    # final candidate merge (stable: block-major order == ascending global
    # row index, so ties resolve to the lower index, matching ref)
    key = vals if largest else -vals
    _, sel = jax.lax.top_k(key, k)
    return (jnp.take_along_axis(vals, sel, axis=-1),
            jnp.take_along_axis(idx, sel, axis=-1))


@functools.partial(jax.jit, static_argnames=("k", "largest", "n_valid",
                                             "block_m", "block_n", "block_l",
                                             "interpret"))
def cam_topk_packed_prepadded(qp: jax.Array, pp: jax.Array,
                              cp: Optional[jax.Array] = None, *, k: int,
                              largest: bool, n_valid: int, block_m: int,
                              block_n: int, block_l: int,
                              interpret: Optional[bool] = None
                              ) -> Tuple[jax.Array, jax.Array]:
    """Packed-lane analogue of :func:`cam_topk_prepadded`.

    Operands are uint32 lane arrays already padded to block multiples
    (zero lanes match in both operands, so padding never contributes a
    mismatch).  ``cp`` is the optional packed per-pattern TCAM care
    mask.  Same final stable candidate merge as the float path.
    """
    if interpret is None:
        interpret = not _on_tpu()
    vals, idx = fused_topk_packed_pallas(
        qp, pp, cp, k=k, largest=largest, block_m=block_m, block_n=block_n,
        block_l=block_l, n_valid=n_valid, interpret=interpret)
    key = vals if largest else -vals
    _, sel = jax.lax.top_k(key, k)
    return (jnp.take_along_axis(vals, sel, axis=-1),
            jnp.take_along_axis(idx, sel, axis=-1))


@functools.partial(jax.jit, static_argnames=("k", "largest", "tile_rows",
                                             "lanes_per_tile", "block_m",
                                             "interpret"))
def cam_topk_packed(qbits: jax.Array, pbits: jax.Array,
                    care: Optional[jax.Array] = None, *, k: int,
                    largest: bool = False, tile_rows: int = 128,
                    lanes_per_tile: int = 64, block_m: int = 128,
                    interpret: Optional[bool] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Fused best-match search over bit-packed binary/ternary operands.

    ``qbits`` (M, L) / ``pbits`` (N, L) are ``packing.pack_bits`` lanes;
    ``care`` (N, L) marks TCAM cared cells (wildcard = 0).  Results are
    bit-identical to ``cam_topk(metric="hamming")`` on the unpacked
    cells (counts are the same integers, candidate order is the same).
    """
    m, L = qbits.shape
    n = pbits.shape[0]
    k_eff = min(k, n)
    bn = max(8, min(tile_rows, n))
    bl = min(lanes_per_tile, L)
    bm = min(block_m, max(8, m))
    qp = pad_to_blocks(qbits, bm, bl)
    pp = pad_to_blocks(pbits, bn, bl)
    cp = None if care is None else pad_to_blocks(care, bn, bl)
    vals, idx = cam_topk_packed_prepadded(
        qp, pp, cp, k=k_eff, largest=largest, n_valid=n, block_m=bm,
        block_n=bn, block_l=bl, interpret=interpret)
    return kref.pad_candidates(vals[:m], idx[:m], k, largest)


@functools.partial(jax.jit, static_argnames=("metric", "k", "largest",
                                             "tile_rows", "dims_per_tile",
                                             "block_m", "interpret"))
def cam_topk(queries: jax.Array, patterns: jax.Array, *, metric: str, k: int,
             largest: bool, tile_rows: int = 128, dims_per_tile: int = 512,
             block_m: int = 128, interpret: Optional[bool] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Fused CAM best-match search via the Pallas kernel.

    ``tile_rows``/``dims_per_tile`` take the role of the CAM subarray
    geometry (block_n / block_d); the cross-block candidate merge mirrors
    ``cim.merge_partial vertical``.
    """
    m, dim = queries.shape
    n = patterns.shape[0]
    k_eff = min(k, n)
    bn = max(8, min(tile_rows, n))
    bd = min(dims_per_tile, dim)
    bm = min(block_m, max(8, m))
    qp = pad_to_blocks(queries.astype(jnp.float32), bm, bd)
    pp = pad_to_blocks(patterns.astype(jnp.float32), bn, bd)
    vals, idx = cam_topk_prepadded(qp, pp, metric=metric, k=k_eff,
                                   largest=largest, n_valid=n, block_m=bm,
                                   block_n=bn, block_d=bd,
                                   interpret=interpret)
    # k > N: pad with the shared losing sentinels (same helper the engine
    # and tiled reference use, so every path emits identical pad content)
    return kref.pad_candidates(vals[:m], idx[:m], k, largest)


# ---------------------------------------------------------------------------
# HDC hypervector encoding
# ---------------------------------------------------------------------------

#: bind / bundle / permute are pure jnp in every execution path (the
#: fused encode kernel inlines bind+bundle); the public wrappers jit the
#: pinned oracles so callers get one import surface for the HDC algebra
hdc_bind = jax.jit(kref.hdc_bind)
hdc_bundle = jax.jit(kref.hdc_bundle)
hdc_permute = jax.jit(kref.hdc_permute, static_argnames=("shift",))


@functools.partial(jax.jit, static_argnames=("block_m", "block_f", "block_h",
                                             "interpret"))
def hdc_encode(level_idx: jax.Array, keys: jax.Array, levels: jax.Array, *,
               block_m: int = 128, block_f: int = 256, block_h: int = 256,
               interpret: Optional[bool] = None) -> jax.Array:
    """(M, H) bipolar encodings via the fused Pallas kernel.

    Pads ``level_idx`` with level 0 and ``keys`` with zero rows (a
    padded feature's one-hot only ever hits zeroed key rows, so padding
    contributes nothing — see ``kernels/hdc_encode.py``), launches the
    kernel, and slices the valid block.  Bit-identical to
    :func:`ref.hdc_encode` (integer sums, sign tie -> +1).
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, f = level_idx.shape
    h = keys.shape[1]
    bm = min(block_m, max(8, m))
    bf = min(block_f, f)
    bh = min(block_h, h)
    qp = pad_to_blocks(level_idx.astype(jnp.int32), bm, bf)
    kp = pad_to_blocks(keys.astype(jnp.float32), bf, bh)
    lp = jnp.pad(levels.astype(jnp.float32), ((0, 0), (0, (-h) % bh)))
    out = hdc_encode_pallas(qp, kp, lp, block_m=bm, block_f=bf, block_h=bh,
                            interpret=interpret)
    return out[:m, :h]


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def cam_distances(queries: jax.Array, patterns: jax.Array, *, metric: str,
                  interpret: Optional[bool] = None) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    m, dim = queries.shape
    n = patterns.shape[0]
    qp = _pad_to(queries.astype(jnp.float32), 8, 128)
    pp = _pad_to(patterns.astype(jnp.float32), 8, 128)
    d = distance_pallas(qp, pp, metric=metric, interpret=interpret)
    return d[:m, :n]


def cam_exact(queries: jax.Array, patterns: jax.Array, *,
              metric: str = "hamming",
              interpret: Optional[bool] = None) -> jax.Array:
    return cam_distances(queries, patterns, metric=metric,
                         interpret=interpret) == 0


def cam_range(queries: jax.Array, patterns: jax.Array, threshold: float, *,
              metric: str = "hamming",
              interpret: Optional[bool] = None) -> jax.Array:
    return cam_distances(queries, patterns, metric=metric,
                         interpret=interpret) <= threshold


# ---------------------------------------------------------------------------
# aCAM range search (interval + fused threshold match)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_valid", "block_m", "block_n",
                                             "block_d", "interpret"))
def acam_match_prepadded(qp: jax.Array, lop: jax.Array, hip: jax.Array, *,
                         n_valid: int, block_m: int, block_n: int,
                         block_d: int, interpret: Optional[bool] = None
                         ) -> jax.Array:
    """Interval-match kernel launch for block-aligned operands.

    The hot path of the engine's interval ``RangePlan`` on the pallas
    backend: ``lo``/``hi`` were padded once behind the plan cache.
    Returns the padded ``(M_pad, N_pad)`` int8 matrix; callers slice.
    """
    if interpret is None:
        interpret = not _on_tpu()
    return acam_match_pallas(qp, lop, hip, block_m=block_m, block_n=block_n,
                             block_d=block_d, n_valid=n_valid,
                             interpret=interpret)


@jax.jit
def acam_match(queries: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """(M, N) boolean aCAM interval match via the fused Pallas kernel.

    Semantics pinned by :func:`ref.acam_match`: row ``j`` matches iff
    ``lo[j, d] <= q[i, d] <= hi[j, d]`` for all ``d`` (wildcard = full
    range).  Pure comparisons + integer counts, so kernel and oracle
    agree bit-for-bit.
    """
    m = queries.shape[0]
    n = lo.shape[0]
    out = acam_match_pallas(queries.astype(jnp.float32),
                            lo.astype(jnp.float32), hi.astype(jnp.float32),
                            n_valid=n, interpret=not _on_tpu())
    return out[:m, :n] != 0


@functools.partial(jax.jit, static_argnames=(
    "metric", "threshold", "below", "to_logical", "dim", "n_valid", "block_m",
    "block_n", "block_d", "interpret"))
def cam_range_match_prepadded(qp: jax.Array, pp: jax.Array, *, metric: str,
                              threshold: float, below: bool, to_logical: str,
                              dim: int, n_valid: int, block_m: int,
                              block_n: int, block_d: int,
                              interpret: Optional[bool] = None) -> jax.Array:
    """Fused threshold-match launch for block-aligned operands (int8)."""
    if interpret is None:
        interpret = not _on_tpu()
    return range_match_pallas(qp, pp, metric=metric, threshold=threshold,
                              below=below, to_logical=to_logical, dim=dim,
                              block_m=block_m, block_n=block_n,
                              block_d=block_d, n_valid=n_valid,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("metric", "threshold", "below",
                                             "interpret"))
def cam_range_match(queries: jax.Array, patterns: jax.Array, *, metric: str,
                    threshold: float, below: bool = True,
                    interpret: Optional[bool] = None) -> jax.Array:
    """(M, N) boolean threshold match with the threshold fused in-kernel.

    Unlike :func:`cam_range` (distance matrix materialised as float32,
    compared on the host), the compare happens at block-extraction time
    and only an int8 matrix leaves the kernel — 4x less result traffic
    for the TH sensing mode.  Physical-metric contract matches
    :func:`ref.cam_range` on hamming/dot/eucl.
    """
    if interpret is None:
        interpret = not _on_tpu()
    out = range_match_pallas(queries.astype(jnp.float32),
                             patterns.astype(jnp.float32), metric=metric,
                             threshold=threshold, below=below,
                             n_valid=patterns.shape[0], interpret=interpret)
    return out != 0
