"""Jitted public wrappers around the Pallas CAM-search kernels.

Semantics match `repro.kernels.ref` bit-for-bit (integer metrics) /
to float tolerance (analog).  Inputs are padded to block multiples here so
the kernels only ever see aligned shapes; `interpret` defaults to True off-
TPU (this container is CPU-only; on a real TPU backend the same code path
compiles through Mosaic).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .cam_search import distance_pallas, fused_topk_pallas

__all__ = ["cam_topk", "cam_exact", "cam_range"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("metric", "k", "largest",
                                             "tile_rows", "dims_per_tile",
                                             "block_m", "interpret"))
def cam_topk(queries: jax.Array, patterns: jax.Array, *, metric: str, k: int,
             largest: bool, tile_rows: int = 128, dims_per_tile: int = 512,
             block_m: int = 128, interpret: Optional[bool] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Fused CAM best-match search via the Pallas kernel.

    ``tile_rows``/``dims_per_tile`` take the role of the CAM subarray
    geometry (block_n / block_d); the cross-block candidate merge mirrors
    ``cim.merge_partial vertical``.
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, dim = queries.shape
    n = patterns.shape[0]
    k_eff = min(k, n)
    bn = max(8, min(tile_rows, n))
    bd = min(dims_per_tile, dim)
    bm = min(block_m, max(8, m))
    qp = _pad_to(queries.astype(jnp.float32), bm, bd)
    pp = _pad_to(patterns.astype(jnp.float32), bn, bd)
    vals, idx = fused_topk_pallas(qp, pp, metric=metric, k=k_eff,
                                  largest=largest, block_m=bm, block_n=bn,
                                  block_d=bd, n_valid=n, interpret=interpret)
    vals, idx = vals[:m], idx[:m]
    # final candidate merge (stable: block-major order == ascending global
    # row index, so ties resolve to the lower index, matching ref)
    key = vals if largest else -vals
    _, sel = jax.lax.top_k(key, k_eff)
    out_v = jnp.take_along_axis(vals, sel, axis=-1)
    out_i = jnp.take_along_axis(idx, sel, axis=-1)
    if k_eff < k:
        out_v = jnp.pad(out_v, ((0, 0), (0, k - k_eff)),
                        constant_values=-jnp.inf if largest else jnp.inf)
        out_i = jnp.pad(out_i, ((0, 0), (0, k - k_eff)),
                        constant_values=2 ** 30)
    return out_v, out_i


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def cam_distances(queries: jax.Array, patterns: jax.Array, *, metric: str,
                  interpret: Optional[bool] = None) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    m, dim = queries.shape
    n = patterns.shape[0]
    qp = _pad_to(queries.astype(jnp.float32), 8, 128)
    pp = _pad_to(patterns.astype(jnp.float32), 8, 128)
    d = distance_pallas(qp, pp, metric=metric, interpret=interpret)
    return d[:m, :n]


def cam_exact(queries: jax.Array, patterns: jax.Array, *,
              metric: str = "hamming",
              interpret: Optional[bool] = None) -> jax.Array:
    return cam_distances(queries, patterns, metric=metric,
                         interpret=interpret) == 0


def cam_range(queries: jax.Array, patterns: jax.Array, threshold: float, *,
              metric: str = "hamming",
              interpret: Optional[bool] = None) -> jax.Array:
    return cam_distances(queries, patterns, metric=metric,
                         interpret=interpret) <= threshold
