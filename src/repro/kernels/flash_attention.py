"""Pallas TPU flash-attention forward kernel.

This is the TPU answer to the §Perf cell-B finding: the pure-JAX chunked
attention (`models/layers.attn_core`) still materializes (qc, T) score
blocks in HBM between fusions; here the whole online-softmax block loop
runs in VMEM and only the (bq, dh) output tile is written back.

Layout: heads are folded into the leading grid axis (GQA: q-head h reads
kv-head h // group).  Grid = (B*H, nq, nk) with the kv axis innermost and
sequential; scratch carries the running max ``m``, normalizer ``l`` and
the unnormalized accumulator across kv steps (the standard flash-forward
recurrence):

    m'   = max(m, rowmax(S))
    l'   = l * e^(m-m') + rowsum(e^(S-m'))
    acc' = acc * e^(m-m') + e^(S-m') @ V

Block shapes default to MXU-aligned (128 q rows x 128 kv rows x full
head dim); VMEM footprint = bq*dh + bk*dh * 2 + bq*bk + bq*(dh+2) floats
(~0.4 MB at dh=128), far inside the ~16 MB/core budget.  Causal masking
is done on global row/col indices so padding rows never contribute.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams as _CompilerParams

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, prefix_len: int,
                  kv_len: Optional[int], bq: int, bk: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q = q_ref[...]                                   # (bq, dh)
    k = k_ref[...]                                   # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    allow = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        allow = cols <= rows
        if prefix_len:
            allow = allow | (cols < prefix_len)
    if kv_len is not None:
        allow = allow & (cols < kv_len)
    s = jnp.where(allow, s, _NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                           # (bq, bk)
    l_new = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[...],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_new = acc_ref[...] * alpha + pv
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, prefix_len: int = 0,
                           kv_len: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B, S, H, dh); k/v: (B, T, KV, dh) -> (B, S, H, dh).

    GQA folds (B, head) into the grid's leading axis; kv blocks index the
    owning kv head.  ``kv_len`` masks cache tail rows (prefill/decode).
    """
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / (dh ** 0.5)

    bq = min(block_q, s)
    bk = min(block_k, t)
    nq = -(-s // bq)
    nk = -(-t // bk)
    pad_s, pad_t = nq * bq - s, nk * bk - t

    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, dh)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * kvh, t, dh)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * kvh, t, dh)
    if pad_s:
        qf = jnp.pad(qf, ((0, 0), (0, pad_s), (0, 0)))
    if pad_t:
        kf = jnp.pad(kf, ((0, 0), (0, pad_t), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_t), (0, 0)))
    # padded kv rows must never win: clamp the valid length
    eff_kv_len = t if kv_len is None else kv_len

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, prefix_len=prefix_len,
        kv_len=eff_kv_len, bq=bq, bk=bk, nk=nk)

    out = pl.pallas_call(
        kern,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((None, bk, dh), lambda bh, i, j, g=g: (bh // g, j, 0)),
            pl.BlockSpec((None, bk, dh), lambda bh, i, j, g=g: (bh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nq * bq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :s].reshape(b, h, s, dh)
    return jnp.moveaxis(out, 1, 2)