"""HDC classifier: associative memory served by the search engine.

Training keeps per-class **integer accumulators** (sums of bipolar
training encodings); the served associative memory is their sign
(majority bundle, tie -> +1).  Classification lowers to the compiled
similarity stack: a ``cim.similarity`` program (``metric="dot"``,
``k=1``, ``largest=True``) over bipolar operands, which the engine
executes as a packed XOR+popcount hamming search (argmax-dot ==
argmin-hamming for bipolar data — the ``cim_to_cam`` identity), exactly
the Kazemi et al. [22] hand-crafted design the compiler targets.

Retraining is the perceptron-style HDC update: each misclassified
encoding is subtracted from the predicted class's accumulator and added
to the true class's.  Only the touched classes' AM rows change, which
is what :meth:`SearchPlan.update_rows` /
:meth:`CamSearchServer.update_gallery` make cheap — `retrain_epoch`
pushes just those rows, so retraining runs *online* against live
search traffic (see ``examples/hdc_mnist.py``).
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .encoding import ItemMemory

__all__ = ["HdcClassifier"]


class HdcClassifier:
    """Encode -> associative-memory classify -> retrain, on the engine.

    Parameters mirror :class:`ItemMemory` (features, hypervector dim,
    quantisation levels/range); ``n_classes`` sizes the associative
    memory.  Call :meth:`fit` (one-shot bundling), :meth:`compile`
    (lower to a SearchPlan), then :meth:`predict` /
    :meth:`retrain_epoch`.
    """

    def __init__(self, n_features: int, n_classes: int, *, dim: int = 2048,
                 n_levels: int = 16, lo: float = 0.0, hi: float = 1.0,
                 seed: int = 0):
        self.item = ItemMemory(n_features, dim=dim, n_levels=n_levels,
                               lo=lo, hi=hi, seed=seed)
        self.n_classes = int(n_classes)
        self.dim = int(dim)
        # integer accumulators: sums of +-1 encodings stay exact
        self.class_sums = np.zeros((self.n_classes, self.dim), np.int64)
        self.plan = None
        self._gallery = None

    # -- encoding / training ----------------------------------------------

    def encode(self, x: np.ndarray) -> np.ndarray:
        """(M, F) features -> (M, H) bipolar encodings (float32)."""
        return self.item.encode(x)

    def am(self) -> np.ndarray:
        """(C, H) bipolar associative memory: sign of the accumulators,
        tie -> +1 (the :func:`~repro.kernels.ref.hdc_bundle` contract)."""
        return np.where(self.class_sums >= 0, 1.0, -1.0).astype(np.float32)

    def fit(self, x: np.ndarray, y: np.ndarray,
            encoded: Optional[np.ndarray] = None) -> "HdcClassifier":
        """One-shot training: bundle every encoding into its class."""
        enc = self.encode(x) if encoded is None else encoded
        y = np.asarray(y, np.int64)
        np.add.at(self.class_sums, y, enc.astype(np.int64))
        self._refresh_gallery(np.unique(y))
        return self

    # -- lowering ----------------------------------------------------------

    def compile(self, arch=None, *, batch_hint: int = 64,
                backend: str = "jnp", shards: Optional[int] = None,
                pack: Optional[bool] = None) -> "HdcClassifier":
        """Lower classification onto ``arch`` and build the engine plan.

        The program is a hand-built fused ``cim.similarity`` (dot, k=1,
        largest) run through ``CompulsoryPartition`` — the same stack
        every compiled workload uses — so the plan lands in the
        process-wide cache and packs automatically.  Returns ``self``.
        """
        import jax.numpy as jnp

        from ..core.arch import ArchSpec
        from ..core.cim_dialect import (make_acquire, make_execute,
                                        make_release, make_similarity,
                                        make_yield)
        from ..core.engine import get_plan
        from ..core.ir import Builder, Module, PassManager, TensorType
        from ..core.passes import CompulsoryPartition

        if arch is None:
            arch = ArchSpec(rows=32, cols=64)
        m = max(1, int(batch_hint))
        mod = Module("hdc_classify",
                     [TensorType((m, self.dim)),
                      TensorType((self.n_classes, self.dim))],
                     arg_names=["queries", "am"])
        b = Builder(mod.body)
        dev = make_acquire(b)
        exe = make_execute(b, dev.result, list(mod.arguments),
                           [TensorType((m, 1)), TensorType((m, 1), "i32")])
        blk = exe.region().block()
        sim = make_similarity(blk, mod.arguments[0], mod.arguments[1],
                              metric="dot", k=1, largest=True)
        make_yield(blk, sim.results)
        make_release(b, dev.result)
        b.ret(exe.results)

        pm = PassManager()
        pm.add(CompulsoryPartition())
        self.stages = {"cim_partitioned": pm.run(mod, {"arch": arch})}
        self.arch = arch
        self.plan = get_plan(self.stages["cim_partitioned"], backend=backend,
                             shards=shards, pack=pack)
        if self.plan is None:                  # pragma: no cover
            raise RuntimeError("HDC program did not yield a SearchPlan")
        self._gallery = jnp.asarray(self.am())
        return self

    def _require_compiled(self):
        if self.plan is None:
            raise RuntimeError("call compile() first")

    @property
    def gallery(self):
        """The served associative memory (jax array, plan-memoised)."""
        self._require_compiled()
        return self._gallery

    def _refresh_gallery(self, changed: np.ndarray) -> None:
        """Push changed AM rows into the plan's memoised layout."""
        if self.plan is None or self._gallery is None:
            return
        changed = np.asarray(changed, np.int64)
        if changed.size == 0:
            return
        self._gallery = self.plan.update_rows(self._gallery, changed,
                                              self.am()[changed])

    # -- inference ---------------------------------------------------------

    def predict(self, x: Optional[np.ndarray] = None, *,
                encoded: Optional[np.ndarray] = None) -> np.ndarray:
        """(M,) class predictions through the compiled search plan."""
        self._require_compiled()
        enc = self.encode(x) if encoded is None else encoded
        _, idx = self.plan.execute(enc, self._gallery)
        return np.asarray(idx)[:, 0].astype(np.int32)

    def predict_interpreted(self, x: Optional[np.ndarray] = None, *,
                            encoded: Optional[np.ndarray] = None
                            ) -> np.ndarray:
        """Predictions via the IR interpreter (semantic oracle)."""
        from ..core.executor import execute_module

        self._require_compiled()
        enc = self.encode(x) if encoded is None else encoded
        am = self.am()
        # the interpreter executes the traced shape exactly — chunk to
        # the module's query count (padding the tail with row 0, sliced
        # off below; the engine instead re-chunks internally)
        m = self.plan.spec.m
        outs = [np.empty((0,), np.int32)]
        for s in range(0, enc.shape[0], m):
            chunk = enc[s:s + m]
            valid = chunk.shape[0]
            if valid < m:
                chunk = np.pad(chunk, ((0, m - valid), (0, 0)),
                               mode="edge")
            _, idx = execute_module(self.stages["cim_partitioned"], chunk, am)
            outs.append(np.asarray(idx)[:valid, 0])
        return np.concatenate(outs).astype(np.int32)

    def predict_reference(self, x: Optional[np.ndarray] = None, *,
                          encoded: Optional[np.ndarray] = None) -> np.ndarray:
        """Predictions via dense numpy argmax-dot (lowest-index ties —
        the same deterministic tie-break the engine pins)."""
        enc = self.encode(x) if encoded is None else encoded
        scores = enc.astype(np.float32) @ self.am().T
        return np.argmax(scores, axis=1).astype(np.int32)

    # -- retraining --------------------------------------------------------

    def retrain_step(self, encoded: np.ndarray, y: np.ndarray,
                     preds: np.ndarray) -> np.ndarray:
        """Apply the perceptron update for one prediction batch.

        Misclassified encodings move from the predicted class's
        accumulator to the true class's.  Returns the (sorted, unique)
        class ids whose accumulators changed — the rows a server must
        re-serve.  The caller owns pushing those rows
        (:meth:`retrain_epoch` does both).
        """
        y = np.asarray(y, np.int64)
        preds = np.asarray(preds, np.int64)
        wrong = preds != y
        if not wrong.any():
            return np.empty((0,), np.int64)
        enc = encoded[wrong].astype(np.int64)
        np.add.at(self.class_sums, y[wrong], enc)
        np.subtract.at(self.class_sums, preds[wrong], enc)
        return np.unique(np.concatenate([y[wrong], preds[wrong]]))

    def retrain_epoch(self, x: np.ndarray, y: np.ndarray, *,
                      encoded: Optional[np.ndarray] = None,
                      server=None) -> Tuple[float, int]:
        """One retraining epoch; returns (pre-update accuracy, #rows pushed).

        Predictions come from the live path — the attached
        ``CamSearchServer`` when given (so retraining competes with
        real traffic), the compiled plan otherwise — and the touched AM
        rows are pushed back through ``server.update_gallery`` /
        ``plan.update_rows``, i.e. the gallery mutates **between
        micro-batches while the server keeps serving**.
        """
        self._require_compiled()
        enc = self.encode(x) if encoded is None else encoded
        if server is not None:
            _, idx = server.search(enc)
            preds = np.asarray(idx)[:, 0].astype(np.int64)
        else:
            preds = self.predict(encoded=enc).astype(np.int64)
        acc = float((preds == np.asarray(y)).mean())
        changed = self.retrain_step(enc, y, preds)
        if changed.size:
            if server is not None:
                server.update_gallery(changed, self.am()[changed])
                self._gallery = server.gallery
            else:
                self._refresh_gallery(changed)
        return acc, int(changed.size)

    def summary(self) -> dict:
        out = {"classes": self.n_classes, "dim": self.dim,
               "features": self.item.n_features,
               "levels": self.item.n_levels}
        if self.plan is not None:
            out.update(backend=self.plan.backend, shards=self.plan.shards,
                       packed=self.plan.packed, batch=self.plan.batch,
                       grid=(self.plan.spec.grid_rows,
                             self.plan.spec.grid_cols))
        return out
