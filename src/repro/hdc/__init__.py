"""Hyperdimensional computing on CAM — the paper's flagship workload.

The Fig. 8/9 and GPU-comparison experiments all classify MNIST-style
data with HDC on the hand-crafted CAM design of Kazemi et al. [22]:
samples are encoded into high-dimensional bipolar *hypervectors*
(record-based encoding: per-feature key hypervectors bound with
quantised level hypervectors, majority-bundled), class prototypes live
in an **associative memory** of bundled training encodings, and
classification is a nearest-neighbour search — which is exactly the
engine's packed-hamming :class:`~repro.core.engine.SearchPlan` (bipolar
argmax-dot == argmin-hamming, the ``cim_to_cam`` identity).

* :mod:`repro.hdc.encoding` — item/level memories and the hypervector
  encoder (one-hot matmul decomposition, fused Pallas kernel, and the
  dense oracle all bit-identical).
* :mod:`repro.hdc.classifier` — :class:`HdcClassifier`: one-shot
  training, perceptron-style retraining (misclassified encodings
  subtracted from the wrong class and re-bundled into the right one),
  and **online** retraining against live search traffic through
  ``CamSearchServer.update_gallery`` / ``SearchPlan.update_rows``.

See ``docs/hdc.md`` and ``examples/hdc_mnist.py``.
"""

from .classifier import HdcClassifier
from .encoding import ItemMemory, level_hypervectors

__all__ = ["HdcClassifier", "ItemMemory", "level_hypervectors"]
