"""Item/level memories and the record-based hypervector encoder.

Encoding contract (shared bit-for-bit by three execution paths):

* features are quantised into ``n_levels`` buckets over ``[lo, hi]``;
* each feature position owns a random bipolar *key* hypervector, each
  level a *level* hypervector from a thermometer code (adjacent levels
  differ in ``H / (2 * (L - 1))`` dimensions, so level similarity
  decays with level distance — the standard HDC encoding for
  continuous features);
* a sample is the majority bundle over features of
  ``bind(key[f], level[q[f]])``, sign ties -> +1.

The default host path computes the bundle through the one-hot matmul
decomposition (``sum_l (q == l) @ keys * levels[l]`` — no (M, F, H)
intermediate); ``REPRO_HDC_KERNEL`` selects the fused Pallas kernel
(``pallas``, auto-on on TPU) or the dense oracle (``ref``).  All sums
are small integers, exact in float32, so every path emits identical
hypervectors.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.envcfg import env_choice
from ..kernels import ops as kops
from ..kernels import ref as kref

__all__ = ["ItemMemory", "level_hypervectors", "random_hypervectors"]


def random_hypervectors(rng: np.random.Generator, n: int,
                        dim: int) -> np.ndarray:
    """(n, dim) i.i.d. random bipolar +-1 hypervectors (float32)."""
    return np.where(rng.random((n, dim)) < 0.5, -1.0, 1.0).astype(np.float32)


def level_hypervectors(rng: np.random.Generator, n_levels: int,
                       dim: int) -> np.ndarray:
    """(L, dim) thermometer-correlated level hypervectors.

    Level 0 is random; each next level flips a fresh segment of
    ``dim // (2 * (L - 1))`` dimensions (no dimension flips twice), so
    the top level sits at ~50% hamming distance from the bottom and
    similarity decays linearly with level distance.
    """
    lv = np.empty((n_levels, dim), np.float32)
    lv[0] = random_hypervectors(rng, 1, dim)[0]
    if n_levels == 1:
        return lv
    perm = rng.permutation(dim)
    seg = dim // (2 * (n_levels - 1))
    for level in range(1, n_levels):
        lv[level] = lv[level - 1]
        flip = perm[(level - 1) * seg:level * seg]
        lv[level, flip] = -lv[level, flip]
    return lv


@functools.partial(jax.jit, static_argnames=("n_levels",))
def _encode_matmul(q: jax.Array, keys: jax.Array, levels: jax.Array, *,
                   n_levels: int) -> jax.Array:
    """One-hot matmul decomposition of the encode sum (see module doc)."""
    acc = jnp.zeros((q.shape[0], keys.shape[1]), jnp.float32)
    for level in range(n_levels):
        onehot = (q == level).astype(jnp.float32)
        acc = acc + (onehot @ keys) * levels[level][None, :]
    return jnp.where(acc >= 0, 1.0, -1.0)


def _kernel_choice() -> str:
    env = env_choice("REPRO_HDC_KERNEL", "auto",
                     ("auto", "matmul", "pallas", "ref"))
    if env == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "matmul"
    return env


class ItemMemory:
    """Key + level hypervector memories with a fixed quantisation range.

    Deterministic in ``seed``; ``encode`` accepts ``(M, F)`` float
    features and returns ``(M, H)`` bipolar hypervectors (numpy
    float32).  The encode path is selected by ``REPRO_HDC_KERNEL``
    (``kernel=`` overrides) — all paths are bit-identical.
    """

    def __init__(self, n_features: int, *, dim: int = 2048,
                 n_levels: int = 16, lo: float = 0.0, hi: float = 1.0,
                 seed: int = 0):
        if n_levels < 1:
            raise ValueError("n_levels must be >= 1")
        if not hi > lo:
            raise ValueError(f"need hi > lo, got [{lo}, {hi}]")
        self.n_features = int(n_features)
        self.dim = int(dim)
        self.n_levels = int(n_levels)
        self.lo, self.hi = float(lo), float(hi)
        rng = np.random.default_rng(np.random.SeedSequence([seed, 0]))
        self.keys = random_hypervectors(rng, self.n_features, self.dim)
        self.levels = level_hypervectors(rng, self.n_levels, self.dim)
        self._keys_j = jnp.asarray(self.keys)
        self._levels_j = jnp.asarray(self.levels)

    def quantize(self, x: np.ndarray) -> np.ndarray:
        """(M, F) float features -> (M, F) int32 level indices."""
        x = np.asarray(x, np.float32)
        if x.ndim != 2 or x.shape[1] != self.n_features:
            raise ValueError(f"features must be (M, {self.n_features}), "
                             f"got {x.shape}")
        t = (x - self.lo) / (self.hi - self.lo)
        return np.clip((t * self.n_levels).astype(np.int32), 0,
                       self.n_levels - 1)

    def encode(self, x: np.ndarray,
               kernel: Optional[str] = None) -> np.ndarray:
        """(M, F) features -> (M, H) bipolar hypervectors (float32)."""
        q = jnp.asarray(self.quantize(x))
        kind = kernel or _kernel_choice()
        if kind == "pallas":
            enc = kops.hdc_encode(q, self._keys_j, self._levels_j)
        elif kind == "ref":
            enc = kref.hdc_encode(q, self._keys_j, self._levels_j)
        else:
            enc = _encode_matmul(q, self._keys_j, self._levels_j,
                                 n_levels=self.n_levels)
        return np.asarray(enc)
