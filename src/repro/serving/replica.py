"""Gallery replicas: load balancing, update fan-out, health, healing.

A :class:`ReplicaSet` serves one tenant's gallery from ``R`` replica
:class:`~repro.serving.CamSearchServer` instances, each standing in
for a CAM **device group** with its own fault exposure (its own
:class:`~repro.faults.FaultModel` / chaos injector).  The design
follows the PR 6 hardening layer up one level: where
:class:`~repro.faults.HardenedPlan` replicates *rows inside one
device*, a replica set replicates *whole galleries across device
groups* — and reuses the same digest machinery
(:func:`~repro.faults.row_checksums` /
:func:`~repro.faults.detect_faulty_rows`) to decide when a copy has
degraded.

**Replica prepare reuse.**  Every replica server is constructed around
the *same* jax stored arrays (primed once via
:meth:`~repro.core.engine.PlanBase.warm`), so the shared plan's
pattern memo holds ONE prepared layout for the whole set.
``update_gallery`` fan-out computes one incremental
:meth:`~repro.core.engine.SearchPlan.update_rows` against the shared
arrays and every serving replica adopts the result
(:meth:`~repro.serving.CamSearchServer.adopt_gallery`) under the write
side of a writer-priority lock — routing pauses, so a request
submitted after the update returns can only land on a replica that
already serves the new version (read-your-writes per tenant).

**Health / heal lifecycle** (``serving → draining → rebuilding →
serving``): consecutive request failures (``unhealthy_k``) or a failed
digest/fault check drain a replica — routing stops sending it new
work, in-flight requests finish or fail over.  Once idle it is healed:
a *scrub* (the fault model's write epoch bumps, redrawing transient
faults — the :meth:`~repro.faults.HardenedPlan.heal` rewrite story at
device-group granularity) when that clears the fault check, else a
*rebuild* onto a fresh device group (new generation, replacement fault
model) from peer content — the shared stored arrays its healthy peers
serve.  Either way the replica re-enters routing with its canonical
content resynced and its failure counters reset.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.engine import RangePlan
from ..core.envcfg import env_int
from ..faults import detect_faulty_rows, row_checksums
from ..obs.trace import trace_begin
from .resilience import _WriterPriorityLock
from .server import CamSearchServer

__all__ = ["Replica", "ReplicaSet"]


class Replica:
    """One device group's copy of a tenant gallery.

    Owns the serving :class:`CamSearchServer`, the group's fault
    exposure (``fault_model`` + optional user chaos injector), the
    health state machine and its counters.  Thread-safe where it
    matters: ``outstanding`` and the state transitions are guarded by
    a per-replica lock (routing reads them under the set's read lock,
    completions mutate them from server completer threads).
    """

    def __init__(self, idx: int, device_group: str,
                 fault_model: Any = None,
                 fault_injector: Optional[Callable[[str], None]] = None):
        self.idx = int(idx)
        self.device_group = device_group
        self.generation = 0
        self.fault_model = fault_model
        self._user_injector = fault_injector
        self._killed = False
        self.state = "serving"
        self.server: Optional[CamSearchServer] = None
        self.needs_resync = False
        self._lock = threading.Lock()
        self.outstanding = 0
        self.failures = 0
        self.consecutive_failures = 0
        self.successes = 0
        self.heals = 0
        self.rebuilds = 0
        self.drains = 0
        self.rows_resynced = 0

    @property
    def key(self) -> Tuple[int, int]:
        """Routing identity: a rebuilt replica (new generation) is a
        new failover target even for a request that already tried the
        old incarnation."""
        return (self.idx, self.generation)

    def _injector_hook(self, level: str) -> None:
        """Installed as the server's ``fault_injector``: a killed
        device group fails every dispatch level; otherwise the user's
        chaos injector (if any) decides."""
        if self._killed:
            raise RuntimeError(
                f"replica {self.idx} device group {self.device_group!r} "
                f"is down")
        if self._user_injector is not None:
            self._user_injector(level)

    def kill(self, *, hard: bool = False) -> None:
        """Simulate losing the device group: every subsequent dispatch
        on this replica fails (``hard`` also stops the server, so
        in-flight requests fail immediately instead of at dispatch).
        The replica drains after ``unhealthy_k`` consecutive failures
        and is rebuilt onto a fresh group by the next heal."""
        self._killed = True
        if hard and self.server is not None:
            try:
                self.server.stop()
            except Exception:                   # noqa: BLE001 — chaos
                pass

    def inc_outstanding(self) -> None:
        with self._lock:
            self.outstanding += 1

    def dec_outstanding(self) -> None:
        with self._lock:
            self.outstanding -= 1

    def note_success(self) -> None:
        with self._lock:
            self.successes += 1
            self.consecutive_failures = 0

    def note_failure(self, unhealthy_k: int) -> bool:
        """Record a request-level failure; returns True when this
        failure newly drained the replica."""
        with self._lock:
            self.failures += 1
            self.consecutive_failures += 1
            if unhealthy_k > 0 and \
                    self.consecutive_failures >= unhealthy_k and \
                    self.state == "serving":
                self.state = "draining"
                self.drains += 1
                return True
            return False

    def view(self) -> Dict[str, Any]:
        with self._lock:
            return {"idx": self.idx, "device_group": self.device_group,
                    "generation": self.generation, "state": self.state,
                    "killed": self._killed,
                    "outstanding": self.outstanding,
                    "failures": self.failures,
                    "consecutive_failures": self.consecutive_failures,
                    "successes": self.successes, "heals": self.heals,
                    "rebuilds": self.rebuilds, "drains": self.drains,
                    "rows_resynced": self.rows_resynced,
                    "fault_model": None if self.fault_model is None
                    else repr(self.fault_model)}


class ReplicaSet:
    """``R`` replicas of one gallery behind one shared plan.

    Parameters
    ----------
    plan:
        The shared engine plan (one plan-cache citizen serves every
        replica and every tenant with this spec).
    gallery / care_mask:
        Logical stored content, exactly as
        :class:`~repro.serving.CamSearchServer` takes it.
    replicas:
        Replica count (``REPRO_SERVE_REPLICAS`` default).
    fault_models / fault_injectors / device_groups:
        Optional per-replica fault exposure and naming (lists indexed
        by replica; shorter lists pad with ``None`` / generated names).
    unhealthy_k:
        Consecutive request failures that drain a replica
        (``REPRO_SERVE_UNHEALTHY_K``).
    max_fault_rows:
        Digest-check budget: a serving replica whose simulated device
        readback shows more than this many faulty rows is drained for
        healing (``REPRO_SERVE_MAX_FAULT_ROWS``).
    rebuild_fault_model:
        ``f(replica, generation) -> FaultModel | None`` for rebuilt
        replicas; default rebuilds land on a pristine device group
        (no fault model).
    server_kwargs:
        Extra :class:`CamSearchServer` constructor knobs applied to
        every replica (``max_wait_ms``, ``max_retries``, ...).
    """

    def __init__(self, plan, gallery, *, care_mask=None,
                 replicas: Optional[int] = None,
                 fault_models: Optional[Sequence[Any]] = None,
                 fault_injectors: Optional[Sequence[Any]] = None,
                 device_groups: Optional[Sequence[str]] = None,
                 unhealthy_k: Optional[int] = None,
                 max_fault_rows: Optional[int] = None,
                 rebuild_fault_model: Optional[Callable] = None,
                 server_kwargs: Optional[Dict[str, Any]] = None):
        self.plan = plan
        self.is_range = isinstance(plan, RangePlan)
        self.multi = self.is_range and len(plan.spec.pattern_args) == 2
        n_rep = env_int("REPRO_SERVE_REPLICAS", 1, min_value=1) \
            if replicas is None else int(replicas)
        if n_rep < 1:
            raise ValueError(f"replicas must be >= 1, got {n_rep}")
        self.unhealthy_k = env_int("REPRO_SERVE_UNHEALTHY_K", 3,
                                   min_value=1) \
            if unhealthy_k is None else int(unhealthy_k)
        self.max_fault_rows = env_int("REPRO_SERVE_MAX_FAULT_ROWS", 0,
                                      min_value=0) \
            if max_fault_rows is None else int(max_fault_rows)
        self._rebuild_model = rebuild_fault_model
        self._server_kwargs = dict(server_kwargs or {})
        self._rw = _WriterPriorityLock()
        self._maint_lock = threading.Lock()
        self.version = 0
        self.refs = 1                    # tenants sharing this set

        # one warm() primes the shared plan's pattern memo; the
        # returned jax arrays are THE fleet content every replica
        # serves (replica prepare reuse)
        if self.is_range:
            stored_in = tuple(gallery) if self.multi else (gallery,)
            if self.multi and len(stored_in) != 2:
                raise ValueError("interval range plan needs "
                                 "gallery=(lo, hi)")
            shared = plan.warm(*stored_in)
            self._care = None
        elif care_mask is not None:
            shared = plan.warm(gallery, care_mask)
            self._care = shared[1]
            shared = shared[:1]
        else:
            shared = plan.warm(gallery)
            self._care = None
        self._shared: Tuple[Any, ...] = shared
        # canonical host copy + per-row digest of the fleet content —
        # what replicas are compared against (and resynced from)
        # np.array (not asarray): a jax array's __array__ view can be
        # read-only, and fan_out scatters updated rows into this copy
        self._canonical = tuple(np.array(s, np.float32)
                                for s in self._shared)
        self._crc = row_checksums(self._canonical)

        models = list(fault_models or [])
        injectors = list(fault_injectors or [])
        groups = list(device_groups or [])
        self.replicas: List[Replica] = []
        for i in range(n_rep):
            r = Replica(
                i,
                groups[i] if i < len(groups) else f"devgroup-{i}",
                fault_model=models[i] if i < len(models) else None,
                fault_injector=injectors[i] if i < len(injectors) else None)
            r.server = self._make_server(r)
            r.server.start()
            self.replicas.append(r)

    # -- construction helpers ----------------------------------------------

    def _server_gallery(self):
        """The shared stored content in the server constructor's
        ``gallery`` convention."""
        if self.is_range:
            return self._shared if self.multi else self._shared[0]
        return self._shared[0]

    def _make_server(self, r: Replica) -> CamSearchServer:
        return CamSearchServer(
            self.plan, self._server_gallery(), care_mask=self._care,
            fault_model=r.fault_model, fault_injector=r._injector_hook,
            **self._server_kwargs)

    # -- routing -----------------------------------------------------------

    def route(self, exclude=()) -> Optional[Replica]:
        """Pick the least-loaded serving replica (read side of the
        update lock: routing pauses while an update fans out, which is
        what makes read-your-writes hold)."""
        self._rw.acquire_read()
        try:
            best = None
            for r in self.replicas:
                if r.state != "serving" or r.key in exclude:
                    continue
                if best is None or r.outstanding < best.outstanding:
                    best = r
            return best
        finally:
            self._rw.release_read()

    # -- update fan-out ----------------------------------------------------

    def fan_out(self, indices, new_rows) -> int:
        """Apply one ``update_rows`` to the shared content and fan the
        result out to every serving replica.

        Writer side of the update lock: no request is routed while the
        fleet content is mid-fan-out, so a client that saw
        ``update_gallery`` return can never read a pre-update replica
        (read-your-writes).  The incremental re-prepare runs ONCE —
        replicas adopt the same resulting jax arrays.  Draining /
        rebuilding replicas are skipped; the heal path resyncs them
        from canonical content before readmission.
        """
        if self.multi and not (isinstance(new_rows, (tuple, list))
                               and len(new_rows) == 2):
            raise ValueError(
                "interval range plan needs new_rows=(lo_rows, hi_rows)")
        self._rw.acquire_write()
        try:
            idx = np.atleast_1d(np.asarray(indices, np.int64))
            if self.is_range:
                stored = self._shared if self.multi else self._shared[0]
                upd = self.plan.update_rows(stored, idx, new_rows)
                self._shared = tuple(upd) if self.multi else (upd,)
            else:
                self._shared = (self.plan.update_rows(
                    self._shared[0], idx, new_rows, care=self._care),)
            news = tuple(new_rows) if self.multi else (new_rows,)
            for canon, blk in zip(self._canonical, news):
                canon[idx] = np.asarray(blk, np.float32)
            self._crc[idx] = row_checksums(
                tuple(c[idx] for c in self._canonical))
            self.version += 1
            gal = self._server_gallery()
            for r in self.replicas:
                if r.state != "serving":
                    r.needs_resync = True
                    continue
                try:
                    r.server.adopt_gallery(gal, rows_updated=int(idx.size))
                except Exception:               # noqa: BLE001 — resync later
                    r.needs_resync = True
            return int(idx.size)
        finally:
            self._rw.release_write()

    # -- health: digests, fault readback, heal -----------------------------

    def _divergence(self, r: Replica) -> np.ndarray:
        """Rows where the replica's served content differs from the
        canonical fleet content (missed fan-out, corruption)."""
        g = r.server.gallery
        comps = tuple(g) if isinstance(g, tuple) else (g,)
        crc = row_checksums(tuple(np.asarray(c, np.float32)
                                  for c in comps))
        return crc != self._crc

    def _fault_rows(self, model) -> int:
        """Faulty-row count from a simulated device readback of the
        canonical content under ``model`` — the same digest check
        :meth:`~repro.faults.HardenedPlan.heal` runs per physical row,
        at replica granularity."""
        if model is None or model.is_null:
            return 0
        full = self._canonical if self._care is None \
            else self._canonical + (np.asarray(self._care, np.float32),)
        readback = model.corrupt_stored(full, self.plan.spec)
        # tolerance from the *fresh-write* guard (t=0): the model's own
        # guard grows with drift*t, which would hide exactly the aging
        # a scrub exists to clear
        bad = detect_faulty_rows(readback, full,
                                 model.rewritten().suggest_guard(z=4.0))
        return int(bad.sum())

    def check(self) -> List[Dict[str, Any]]:
        """Digest/fault sweep over the serving replicas.

        Content divergence (missed updates) is repaired in place by
        re-adopting the canonical shared arrays; a replica whose fault
        readback exceeds ``max_fault_rows`` is drained for healing.
        Returns one report entry per replica checked.
        """
        report = []
        self._rw.acquire_write()
        try:
            for r in self.replicas:
                if r.state != "serving":
                    continue
                entry: Dict[str, Any] = {"replica": r.idx,
                                         "device_group": r.device_group}
                div = int(self._divergence(r).sum())
                if div:
                    r.server.adopt_gallery(self._server_gallery(),
                                           rows_updated=div)
                    r.rows_resynced += div
                    r.needs_resync = False
                entry["rows_resynced"] = div
                fr = self._fault_rows(r.fault_model)
                entry["fault_rows"] = fr
                if fr > self.max_fault_rows:
                    with r._lock:
                        if r.state == "serving":
                            r.state = "draining"
                            r.drains += 1
                    entry["drained"] = True
                report.append(entry)
        finally:
            self._rw.release_write()
        return report

    def heal_drained(self) -> List[Dict[str, Any]]:
        """Heal every drained replica that has gone idle."""
        out = []
        for r in self.replicas:
            if r.state == "draining" and r.outstanding == 0:
                rep = self._heal_one(r)
                if rep is not None:
                    out.append(rep)
        return out

    def _heal_one(self, r: Replica) -> Optional[Dict[str, Any]]:
        """Scrub-or-rebuild one idle drained replica, then readmit it.

        Three phases so no lock is held across a server stop/start
        (stopping a server joins its completer thread, which may be
        mid-failover and about to take the routing read lock — holding
        the write lock there would deadlock):

        1. under the write lock: mark ``rebuilding`` (routing skips
           it), snapshot the shared content + version, measure content
           divergence, and pick the heal mode — **scrub** when bumping
           the fault model's write epoch (``rewritten()``) clears the
           fault check (transient faults redraw, stuck cells persist),
           else **rebuild** onto a fresh generation/device group with a
           replacement model;
        2. unlocked: stop the old server, build + start the new one
           from the snapshot (peer content — the same arrays the
           healthy replicas serve);
        3. under the write lock: catch up any fan-out that landed
           mid-heal, swap the server in, reset counters, readmit.
        """
        self._rw.acquire_write()
        try:
            with r._lock:
                if r.state != "draining" or r.outstanding != 0:
                    return None
                r.state = "rebuilding"
            hspan = trace_begin("heal", "gateway",
                                {"replica": r.idx,
                                 "device": r.device_group})
            version0 = self.version
            gal0 = self._server_gallery()
            try:
                diverged = int(self._divergence(r).sum())
            except Exception:                   # noqa: BLE001 — dead copy
                diverged = int(self._canonical[0].shape[0])
            mode = "resync"
            new_model = r.fault_model
            if r._killed:
                mode = "rebuild"
            elif self._fault_rows(r.fault_model) > self.max_fault_rows:
                scrub = r.fault_model.rewritten()
                if self._fault_rows(scrub) <= self.max_fault_rows:
                    mode = "scrub"
                    new_model = scrub
                else:
                    mode = "rebuild"
            if mode == "rebuild":
                r.generation += 1
                new_model = None if self._rebuild_model is None \
                    else self._rebuild_model(r, r.generation)
        finally:
            self._rw.release_write()
        if hspan is not None:
            hspan.lap("heal.diagnose", {"mode": mode,
                                        "diverged": diverged})

        old = r.server
        try:
            old.stop()
        except Exception:                       # noqa: BLE001 — chaos
            pass
        r.fault_model = new_model
        r._killed = False
        if mode == "rebuild":
            r.device_group = f"{r.device_group.split('+g')[0]}" \
                             f"+g{r.generation}"
        fresh = CamSearchServer(
            self.plan, gal0, care_mask=self._care,
            fault_model=r.fault_model, fault_injector=r._injector_hook,
            **self._server_kwargs)
        fresh.start()
        if hspan is not None:
            hspan.lap("heal.rebuild")

        self._rw.acquire_write()
        try:
            if self.version != version0:        # fan-out landed mid-heal
                fresh.adopt_gallery(self._server_gallery())
                diverged = max(diverged, 1)
            r.server = fresh
            with r._lock:
                r.heals += 1
                if mode == "rebuild":
                    r.rebuilds += 1
                r.rows_resynced += diverged
                r.consecutive_failures = 0
                r.needs_resync = False
                r.state = "serving"
        finally:
            self._rw.release_write()
        if hspan is not None:
            hspan.lap("heal.readmit")
            hspan.end({"mode": mode, "rows_resynced": diverged,
                       "generation": r.generation})
        return {"replica": r.idx, "mode": mode, "rows_resynced": diverged,
                "generation": r.generation,
                "device_group": r.device_group}

    def maintain(self, *, check: bool = False) -> Dict[str, Any]:
        """One maintenance sweep: optional digest/fault check, then
        heal whatever is drained and idle.  Serialised — the periodic
        maintenance thread and explicit ``check_tenant`` calls never
        run surgery concurrently."""
        with self._maint_lock:
            report: Dict[str, Any] = {"checked": [], "healed": []}
            if check:
                report["checked"] = self.check()
            report["healed"] = self.heal_drained()
            return report

    # -- lifecycle / telemetry ---------------------------------------------

    def stop(self) -> None:
        for r in self.replicas:
            try:
                r.server.stop()
            except Exception:                   # noqa: BLE001 — best effort
                pass

    def view(self) -> Dict[str, Any]:
        return {"replicas": [r.view() for r in self.replicas],
                "version": self.version, "refs": self.refs,
                "unhealthy_k": self.unhealthy_k,
                "max_fault_rows": self.max_fault_rows,
                "serving": sum(1 for r in self.replicas
                               if r.state == "serving")}
