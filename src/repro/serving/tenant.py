"""Per-tenant admission control: rate limits, priorities, bounded queues.

A multi-tenant gateway cannot let one hot tenant wedge the batcher for
everyone (ROADMAP item 4's p95-isolation requirement).  The admission
primitives here are deliberately tiny and lock-cheap:

* :class:`_TokenBucket` — rows-per-second rate limiting with a burst
  allowance.  ``rate <= 0`` disables the bucket (unlimited).
* :class:`_PendingQueue` — a bounded priority queue that **sheds the
  lowest-priority, newest work first** when full, instead of blocking
  the submitter or growing without bound.  FIFO within a priority.
* :class:`AdmissionConfig` — the per-tenant knob bundle, defaulted
  from ``REPRO_TENANT_*`` via the strict env parsers.

Rejections are :class:`AdmissionError` (the client did too much — a
retryable 429) vs :class:`TenantUnavailable` (the tenant's replicas or
circuit breaker are down — a 503).
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from ..core.envcfg import env_float, env_int

__all__ = ["AdmissionError", "TenantUnavailable", "AdmissionConfig",
           "_TokenBucket", "_PendingQueue"]


class AdmissionError(RuntimeError):
    """Request rejected by admission control (rate limit, full queue,
    or shed by higher-priority work) — the client should back off and
    retry; the tenant itself is healthy."""


class TenantUnavailable(RuntimeError):
    """No serving replica could take the request, or the tenant's
    circuit breaker is open — the tenant is (temporarily) down."""


class _TokenBucket:
    """Rows-per-second token bucket; ``rate <= 0`` means unlimited.

    ``try_acquire(n)`` is non-blocking: admission control rejects
    instead of queueing the client thread (the pending queue is where
    accepted-but-not-yet-forwarded work waits).
    """

    def __init__(self, rate: float, burst: int):
        self.rate = float(rate)
        self.burst = max(1, int(burst))
        self._tokens = float(self.burst)
        self._last = time.perf_counter()
        self._lock = threading.Lock()

    def try_acquire(self, n: int = 1) -> bool:
        if self.rate <= 0:
            return True
        with self._lock:
            now = time.perf_counter()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._last) * self.rate)
            self._last = now
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False


class _PendingQueue:
    """Bounded priority queue with lowest-priority-first shedding.

    ``push`` returns the shed victim when the queue is full: the
    lowest-priority pending entry (newest within that priority), or
    the incoming item itself if nothing pending ranks below it.  The
    caller settles the victim with an :class:`AdmissionError` — the
    queue never silently drops work and never blocks.  Not
    thread-safe; the owner holds its tenant lock around every call.
    """

    def __init__(self, limit: int):
        self.limit = max(1, int(limit))
        self._heap: List[Any] = []       # (-priority, seq, item)
        self._seq = itertools.count()

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, priority: int, item: Any) -> Optional[Any]:
        if len(self._heap) >= self.limit:
            # victim: lowest priority, then newest arrival
            victim = max(self._heap, key=lambda e: (e[0], e[1]))
            if priority <= -victim[0]:
                return item             # incoming ranks at/below the floor
            self._heap.remove(victim)
            heapq.heapify(self._heap)
            heapq.heappush(self._heap, (-priority, next(self._seq), item))
            return victim[2]
        heapq.heappush(self._heap, (-priority, next(self._seq), item))
        return None

    def pop(self) -> Optional[Any]:
        """Highest priority first, FIFO within a priority."""
        if not self._heap:
            return None
        return heapq.heappop(self._heap)[2]

    def drain(self) -> List[Any]:
        items = [e[2] for e in sorted(self._heap)]
        self._heap.clear()
        return items


@dataclass(frozen=True)
class AdmissionConfig:
    """Per-tenant admission knobs (resolved once at registration)."""

    #: token-bucket refill in query rows/second; 0 = unlimited
    rate: float
    #: token-bucket burst allowance, rows
    burst: int
    #: bound on queued-but-not-forwarded requests
    queue_limit: int
    #: bound on requests forwarded to replicas and not yet settled
    max_outstanding: int
    #: consecutive all-replica failures that open the tenant breaker
    #: (0 disables)
    breaker_threshold: int
    breaker_cooldown_s: float
    #: default per-request deadline, seconds (0 = none)
    deadline_s: float

    @classmethod
    def from_env(cls, *, rate: Optional[float] = None,
                 burst: Optional[int] = None,
                 queue_limit: Optional[int] = None,
                 max_outstanding: Optional[int] = None,
                 breaker_threshold: Optional[int] = None,
                 breaker_cooldown_ms: Optional[float] = None,
                 deadline_ms: Optional[float] = None) -> "AdmissionConfig":
        """Explicit arguments win; unset ones fall back to the strict
        ``REPRO_TENANT_*`` environment defaults (garbage raises)."""
        return cls(
            rate=env_float("REPRO_TENANT_RATE", 0.0, min_value=0.0)
            if rate is None else float(rate),
            burst=env_int("REPRO_TENANT_BURST", 64, min_value=1)
            if burst is None else int(burst),
            queue_limit=env_int("REPRO_TENANT_QUEUE", 256, min_value=1)
            if queue_limit is None else int(queue_limit),
            max_outstanding=env_int("REPRO_TENANT_OUTSTANDING", 8,
                                    min_value=1)
            if max_outstanding is None else int(max_outstanding),
            breaker_threshold=env_int("REPRO_TENANT_BREAKER_K", 8,
                                      min_value=0)
            if breaker_threshold is None else int(breaker_threshold),
            breaker_cooldown_s=(env_float("REPRO_TENANT_BREAKER_COOLDOWN_MS",
                                          100.0, min_value=0.0)
                                if breaker_cooldown_ms is None
                                else float(breaker_cooldown_ms)) / 1e3,
            deadline_s=(env_float("REPRO_TENANT_DEADLINE_MS", 0.0,
                                  min_value=0.0)
                        if deadline_ms is None else float(deadline_ms)) / 1e3,
        )

    def view(self) -> Dict[str, Any]:
        return {"rate": self.rate, "burst": self.burst,
                "queue_limit": self.queue_limit,
                "max_outstanding": self.max_outstanding,
                "breaker_threshold": self.breaker_threshold,
                "breaker_cooldown_ms": 1e3 * self.breaker_cooldown_s,
                "deadline_ms": 1e3 * self.deadline_s}
