"""Multi-tenant serving gateway: registry, admission, replica failover.

:class:`CamServingGateway` fronts any number of named **tenants**, each
serving its own gallery from a :class:`~repro.serving.replica.ReplicaSet`
of :class:`~repro.serving.CamSearchServer` replicas:

* **Registry / plan sharing** — tenants are registered by name with a
  compiled program (or plan) and gallery.  Plans come from the
  process-wide plan cache, so tenants with identical specs share ONE
  compiled plan; ``share_with=`` goes further and shares a whole
  replica set (same gallery, same servers) between tenant names that
  differ only in admission policy.
* **Admission control** — per-tenant token-bucket rate limits (query
  rows/second), request priorities, and a bounded pending queue that
  sheds the lowest-priority newest work first
  (:mod:`repro.serving.tenant`).  A hot tenant exhausts *its own*
  budget and queue; the victim tenant's latency stays near its solo
  profile (the ``BENCH_multitenant.json`` isolation gate).
* **Replica failover** — requests route to the least-loaded serving
  replica; a replica failure settles nothing: the request transparently
  retries on the next replica (``GatewayResult.failovers`` counts the
  hops).  Failover is callback-driven — no thread is parked per
  in-flight request.
* **Health integration** — replicas drain after ``unhealthy_k``
  consecutive failures or a failed digest/fault check, heal via the
  scrub/rebuild machinery (:meth:`~repro.serving.replica.ReplicaSet.
  _heal_one`), and readmit — driven by the gateway's maintenance
  thread (``REPRO_SERVE_MAINT_MS`` / ``REPRO_SERVE_CHECK_MS``).
* **Read-your-writes** — :meth:`CamServingGateway.update_gallery` fans
  one incremental re-prepare out to every serving replica under the
  set's writer-priority lock before returning; any request submitted
  after that sees the new rows regardless of routing.

See ``docs/serving.md`` for the full multi-tenancy story and knob
table.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.envcfg import env_float
from ..obs.trace import instant, trace_begin, tracer
from .replica import Replica, ReplicaSet
from .resilience import _CircuitBreaker
from .server import _validate_queries
from .telemetry import ServerStats
from .tenant import (AdmissionConfig, AdmissionError, TenantUnavailable,
                     _PendingQueue, _TokenBucket)

__all__ = ["CamServingGateway", "GatewayRequest", "GatewayResult",
           "AdmissionError", "TenantUnavailable"]


@dataclass
class GatewayResult:
    """Terminal outcome of one gateway request."""

    tenant: str
    rid: int
    values: Optional[np.ndarray] = None
    indices: Optional[np.ndarray] = None
    matches: Optional[np.ndarray] = None
    error: Optional[BaseException] = None
    #: device group that served the request (None on failure)
    replica: Optional[str] = None
    #: replica hops after the first dispatch attempt
    failovers: int = 0
    submitted_at: float = 0.0
    completed_at: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.completed_at - self.submitted_at


@dataclass
class GatewayRequest:
    """Waitable handle for a submitted gateway request.

    Settles exactly once — with arrays on success, or with the
    terminal error (admission shed, deadline, tenant unavailable,
    gateway stopped) on ``result.error``.
    """

    rid: int
    tenant: str
    queries: np.ndarray
    priority: int
    result: GatewayResult
    deadline: Optional[float] = None
    attempts: int = 0
    #: replica incarnations already tried (failover skips them)
    tried: set = field(default_factory=set)
    #: cross-thread trace handle (``repro.obs.trace_begin``); ``None``
    #: when tracing is disabled
    _tspan: Any = None
    _done: threading.Event = field(default_factory=threading.Event)

    def wait(self, timeout: Optional[float] = None) -> GatewayResult:
        """Block until settled; raises :class:`TimeoutError` only when
        *this wait* times out (a missed request deadline settles the
        result with the error instead)."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"gateway request {self.rid} not completed "
                f"within {timeout}s")
        return self.result

    def done(self) -> bool:
        return self._done.is_set()

    def _settle(self, *, error: Optional[BaseException] = None,
                values=None, indices=None, matches=None,
                replica: Optional[str] = None) -> None:
        self.result.error = error
        self.result.values = values
        self.result.indices = indices
        self.result.matches = matches
        self.result.replica = replica
        self.result.completed_at = time.perf_counter()
        if self._tspan is not None:
            self._tspan.end(
                {"error": type(error).__name__} if error is not None
                else {"replica": replica,
                      "failovers": self.result.failovers})
        self._done.set()


class _Tenant:
    """Registry entry: replica set + admission state + counters."""

    def __init__(self, name: str, rset: ReplicaSet, cfg: AdmissionConfig):
        self.name = name
        self.rset = rset
        self.cfg = cfg
        self.bucket = _TokenBucket(cfg.rate, cfg.burst)
        self.pending = _PendingQueue(cfg.queue_limit)
        self.lock = threading.Lock()
        self.outstanding = 0
        self.breaker = _CircuitBreaker(cfg.breaker_threshold,
                                       cfg.breaker_cooldown_s)
        self.stats = ServerStats(
            "submitted", "completed", "failed", "queries",
            "rejected_rate", "rejected_queue", "rejected_breaker",
            "shed", "failovers", "deadline_misses",
            "gallery_updates", "rows_updated",
            window=1024)


class CamServingGateway:
    """Multi-tenant front door over replicated CAM search servers.

    Parameters
    ----------
    maint_ms:
        Maintenance sweep period, milliseconds: each sweep heals
        drained-and-idle replicas across every replica set; 0 disables
        the background thread (``check_tenant`` still heals on demand).
        Default ``REPRO_SERVE_MAINT_MS``.
    check_ms:
        How often a maintenance sweep additionally runs the
        digest-divergence + fault-readback check (expensive: hashes
        every replica's gallery); 0 = on demand only.  Default
        ``REPRO_SERVE_CHECK_MS``.
    """

    def __init__(self, *, maint_ms: Optional[float] = None,
                 check_ms: Optional[float] = None):
        self._maint_s = (env_float("REPRO_SERVE_MAINT_MS", 20.0,
                                   min_value=0.0)
                         if maint_ms is None else float(maint_ms)) / 1e3
        self._check_s = (env_float("REPRO_SERVE_CHECK_MS", 0.0,
                                   min_value=0.0)
                         if check_ms is None else float(check_ms)) / 1e3
        self._tenants: Dict[str, _Tenant] = {}
        self._lock = threading.Lock()
        self._rid = itertools.count()
        self._accepting = True
        self._stop_evt = threading.Event()
        self._maint_thread: Optional[threading.Thread] = None
        if self._maint_s > 0:
            self._maint_thread = threading.Thread(
                target=self._maint_loop, name="cam-gateway-maint",
                daemon=True)
            self._maint_thread.start()

    # -- registry ----------------------------------------------------------

    def register_tenant(self, name: str, program: Any = None,
                        gallery: Any = None, *,
                        care_mask: Any = None,
                        replicas: Optional[int] = None,
                        share_with: Optional[str] = None,
                        fault_models: Optional[Sequence[Any]] = None,
                        fault_injectors: Optional[Sequence[Any]] = None,
                        device_groups: Optional[Sequence[str]] = None,
                        rate: Optional[float] = None,
                        burst: Optional[int] = None,
                        queue_limit: Optional[int] = None,
                        max_outstanding: Optional[int] = None,
                        breaker_threshold: Optional[int] = None,
                        breaker_cooldown_ms: Optional[float] = None,
                        deadline_ms: Optional[float] = None,
                        unhealthy_k: Optional[int] = None,
                        max_fault_rows: Optional[int] = None,
                        rebuild_fault_model: Optional[Callable] = None,
                        server_kwargs: Optional[Dict[str, Any]] = None,
                        tuned: Optional[bool] = None
                        ) -> "CamServingGateway":
        """Register a named tenant.

        ``share_with=`` reuses another tenant's replica set — same
        gallery, same replica servers, *different* admission policy
        (rate, priority budget, breaker).  That is the cheap way to
        give one dataset two service classes.  Otherwise ``program`` +
        ``gallery`` build a fresh :class:`ReplicaSet`; tenants whose
        specs coincide still share the compiled plan through the
        process-wide plan cache.

        Admission knobs left ``None`` fall back to the strict
        ``REPRO_TENANT_*`` environment defaults (garbage in the
        environment raises here, at registration).

        ``tuned`` (default ``REPRO_TUNE_SERVE``, on) enables the
        plan-store warm start: with ``REPRO_PLAN_STORE`` configured the
        tenant's plan is swapped for its stored tuned equivalent before
        the replica set is built (see ``CamSearchServer``).
        """
        cfg = AdmissionConfig.from_env(
            rate=rate, burst=burst, queue_limit=queue_limit,
            max_outstanding=max_outstanding,
            breaker_threshold=breaker_threshold,
            breaker_cooldown_ms=breaker_cooldown_ms,
            deadline_ms=deadline_ms)
        with self._lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            if share_with is not None:
                if program is not None or gallery is not None:
                    raise ValueError(
                        "share_with reuses the peer tenant's replica "
                        "set; do not pass program/gallery")
                peer = self._tenants.get(share_with)
                if peer is None:
                    raise KeyError(f"unknown tenant {share_with!r}")
                rset = peer.rset
                rset.refs += 1
            else:
                if program is None or gallery is None:
                    raise ValueError(
                        "register_tenant needs program+gallery "
                        "(or share_with=)")
                from .server import _resolve_plan
                rset = ReplicaSet(
                    _resolve_plan(program, tuned=tuned), gallery,
                    care_mask=care_mask,
                    replicas=replicas, fault_models=fault_models,
                    fault_injectors=fault_injectors,
                    device_groups=device_groups, unhealthy_k=unhealthy_k,
                    max_fault_rows=max_fault_rows,
                    rebuild_fault_model=rebuild_fault_model,
                    server_kwargs=server_kwargs)
            self._tenants[name] = _Tenant(name, rset, cfg)
        return self

    def _tenant(self, name: str) -> _Tenant:
        with self._lock:
            t = self._tenants.get(name)
        if t is None:
            raise KeyError(f"unknown tenant {name!r}")
        return t

    @property
    def tenants(self) -> List[str]:
        with self._lock:
            return sorted(self._tenants)

    # -- client API --------------------------------------------------------

    def submit(self, tenant: str, queries: np.ndarray, *,
               priority: int = 0,
               deadline_ms: Optional[float] = None) -> GatewayRequest:
        """Admit + route one query block for ``tenant``.

        Synchronous rejections raise — :class:`AdmissionError` for
        rate-limit / full-queue (back off and retry),
        :class:`TenantUnavailable` when the tenant breaker is open.
        Accepted requests return a waitable handle; a queued request
        later shed by higher-priority work settles with an
        :class:`AdmissionError` on its result instead of raising.
        Higher ``priority`` wins queue order and sheds last.
        """
        t = self._tenant(tenant)
        if not self._accepting:
            raise RuntimeError("gateway stopped")
        q = _validate_queries(t.rset.plan, queries)
        t.stats.bump(submitted=1)
        if not t.breaker.allow_primary():
            t.stats.bump(rejected_breaker=1)
            instant("gw.reject", "gateway",
                    {"reason": "breaker", "tenant": tenant})
            raise TenantUnavailable(
                f"tenant {tenant!r} circuit breaker open")
        if not t.bucket.try_acquire(q.shape[0]):
            t.stats.bump(rejected_rate=1)
            instant("gw.reject", "gateway",
                    {"reason": "rate", "tenant": tenant})
            raise AdmissionError(
                f"tenant {tenant!r} over rate limit "
                f"({t.cfg.rate:g} rows/s)")
        now = time.perf_counter()
        budget = t.cfg.deadline_s if deadline_ms is None \
            else float(deadline_ms) / 1e3
        greq = GatewayRequest(
            rid=next(self._rid), tenant=tenant, queries=q,
            priority=int(priority),
            deadline=now + budget if budget > 0 else None,
            result=GatewayResult(tenant=tenant, rid=0, submitted_at=now))
        greq.result.rid = greq.rid
        greq._tspan = trace_begin(
            "request", "gateway",
            {"rid": greq.rid, "tenant": tenant, "rows": int(q.shape[0])})
        victim = None
        forward = False
        with t.lock:
            if t.outstanding < t.cfg.max_outstanding \
                    and len(t.pending) == 0:
                t.outstanding += 1
                forward = True
            else:
                victim = t.pending.push(greq.priority, greq)
        if forward:
            self._pump(t, greq)
            return greq
        if victim is greq:
            t.stats.bump(rejected_queue=1)
            instant("gw.reject", "gateway",
                    {"reason": "queue", "tenant": tenant})
            if greq._tspan is not None:
                greq._tspan.end({"error": "AdmissionError"})
            raise AdmissionError(
                f"tenant {tenant!r} pending queue full "
                f"({t.cfg.queue_limit})")
        if victim is not None:
            t.stats.bump(shed=1)
            instant("gw.reject", "gateway",
                    {"reason": "shed", "tenant": tenant,
                     "rid": victim.rid})
            victim._settle(error=AdmissionError(
                f"shed by higher-priority work (queue limit "
                f"{t.cfg.queue_limit})"))
            t.stats.bump(failed=1)
        return greq

    def search(self, tenant: str, queries: np.ndarray, *,
               priority: int = 0, deadline_ms: Optional[float] = None,
               timeout: Optional[float] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Blocking best-match search via the gateway."""
        res = self.submit(tenant, queries, priority=priority,
                          deadline_ms=deadline_ms).wait(timeout)
        if res.error is not None:
            raise res.error
        return res.values, res.indices

    def match(self, tenant: str, queries: np.ndarray, *,
              priority: int = 0, deadline_ms: Optional[float] = None,
              timeout: Optional[float] = None) -> np.ndarray:
        """Blocking range match via the gateway."""
        res = self.submit(tenant, queries, priority=priority,
                          deadline_ms=deadline_ms).wait(timeout)
        if res.error is not None:
            raise res.error
        return res.matches

    def update_gallery(self, tenant: str, indices, new_rows) -> int:
        """Rewrite stored rows across every replica of ``tenant``.

        One incremental :meth:`~repro.core.engine.SearchPlan.
        update_rows` runs against the shared fleet arrays and every
        serving replica adopts the result before this returns —
        writer-priority against routing, so the tenant reads its own
        writes on every subsequent request.  Returns the row count.
        """
        t = self._tenant(tenant)
        count = t.rset.fan_out(indices, new_rows)
        t.stats.bump(gallery_updates=1, rows_updated=count)
        return count

    # -- routing / failover ------------------------------------------------

    def _pump(self, t: _Tenant, g: Optional[GatewayRequest] = None) -> None:
        """Drive one outstanding slot: forward ``g`` (or the next
        pending request) until something is dispatched or the queue is
        dry.  Iterative — settling a dead request and moving to the
        next must not recurse to queue depth."""
        while True:
            if g is None:
                with t.lock:
                    g = t.pending.pop()
                    if g is None:
                        t.outstanding -= 1
                        return
            if self._forward_once(t, g):
                return
            g = None

    def _forward_once(self, t: _Tenant, g: GatewayRequest) -> bool:
        """Try to dispatch ``g`` onto some serving replica.

        True: dispatched — the outstanding slot rides along and is
        released by the completion callback.  False: ``g`` settled
        terminally; the caller forwards the next pending request.
        """
        if not self._accepting:
            t.stats.bump(failed=1)
            g._settle(error=RuntimeError("gateway stopped"))
            return False
        now = time.perf_counter()
        if g.deadline is not None and now >= g.deadline:
            t.stats.bump(deadline_misses=1, failed=1)
            g._settle(error=TimeoutError(
                f"request {g.rid} missed its deadline before dispatch"))
            return False
        while True:
            rep = t.rset.route(g.tried)
            if rep is None:
                t.breaker.record_failure()
                t.stats.bump(failed=1)
                g._settle(error=TenantUnavailable(
                    f"tenant {g.tenant!r}: no serving replica left "
                    f"(tried {len(g.tried)})"))
                return False
            remaining_ms = None
            if g.deadline is not None:
                remaining_ms = max(1.0, 1e3 * (g.deadline - now))
            try:
                sreq = rep.server.submit(g.queries,
                                         deadline_ms=remaining_ms)
            except Exception:                   # noqa: BLE001 — failover
                rep.note_failure(t.rset.unhealthy_k)
                g.tried.add(rep.key)
                continue
            rep.inc_outstanding()
            g.attempts += 1
            if tracer.enabled:
                # cross-pid link: this gateway request's spans continue
                # as server request ``server_rid`` on the serving track
                instant("gw.route", "gateway",
                        {"rid": g.rid, "server_rid": sreq.rid,
                         "replica": rep.device_group,
                         "tenant": g.tenant, "attempt": g.attempts})
                if g._tspan is not None:
                    # closes the admission window: submit -> dispatch
                    g._tspan.lap("gw.admission",
                                 {"replica": rep.device_group})
            sreq.add_done_callback(
                lambda r, _t=t, _g=g, _rep=rep: self._on_done(_t, _g,
                                                              _rep, r))
            return True

    def _on_done(self, t: _Tenant, g: GatewayRequest, rep: Replica,
                 sreq) -> None:
        """Replica completion callback: settle, fail over, or time out
        — then hand the outstanding slot to the next pending request.
        Runs on the replica server's completer thread; must not
        block."""
        rep.dec_outstanding()
        res = sreq.result
        if res.error is None:
            rep.note_success()
            t.breaker.record_success()
            g._settle(values=res.values, indices=res.indices,
                      matches=res.matches, replica=rep.device_group)
            t.stats.bump(_latency_s=g.result.latency_s, completed=1,
                         queries=int(g.queries.shape[0]))
            self._pump(t)
            return
        if isinstance(res.error, TimeoutError):
            # the request's own deadline died, not the replica
            t.stats.bump(deadline_misses=1, failed=1)
            g._settle(error=res.error)
            self._pump(t)
            return
        rep.note_failure(t.rset.unhealthy_k)
        g.tried.add(rep.key)
        g.result.failovers += 1
        t.stats.bump(failovers=1)
        instant("gw.failover", "gateway",
                {"rid": g.rid, "tenant": g.tenant,
                 "replica": rep.device_group,
                 "error": type(res.error).__name__})
        self._pump(t, g)                        # retry elsewhere, same slot

    # -- maintenance / chaos -----------------------------------------------

    def _maint_loop(self) -> None:
        last_check = time.perf_counter()
        while not self._stop_evt.wait(self._maint_s):
            now = time.perf_counter()
            check = self._check_s > 0 and now - last_check >= self._check_s
            if check:
                last_check = now
            for rset in self._replica_sets():
                try:
                    rset.maintain(check=check)
                except Exception:               # noqa: BLE001 — keep sweeping
                    pass

    def _replica_sets(self) -> List[ReplicaSet]:
        with self._lock:
            seen: Dict[int, ReplicaSet] = {}
            for t in self._tenants.values():
                seen.setdefault(id(t.rset), t.rset)
            return list(seen.values())

    def check_tenant(self, name: str) -> Dict[str, Any]:
        """Synchronous digest/fault check + heal sweep for one tenant's
        replica set (what the maintenance thread does periodically)."""
        return self._tenant(name).rset.maintain(check=True)

    def kill_replica(self, tenant: str, idx: int, *,
                     hard: bool = False) -> None:
        """Chaos hook: take one of ``tenant``'s replica device groups
        down.  Soft kill fails every new dispatch on the replica; hard
        kill also stops its server so in-flight requests fail over
        immediately."""
        t = self._tenant(tenant)
        t.rset.replicas[idx].kill(hard=hard)

    # -- telemetry ---------------------------------------------------------

    def dump_trace(self, path: str) -> str:
        """Write the process-wide Chrome-tracing export (gateway,
        serving and engine tracks all land in the same file) to
        ``path``.  Convenience mirror of :func:`repro.obs.dump`;
        tracing must be enabled.  See ``docs/observability.md``."""
        from ..obs.trace import dump
        return dump(path)

    def health(self) -> Dict[str, Any]:
        """Aggregated fleet health: per-tenant admission/breaker stats
        and per-replica lifecycle state.  ``status`` degrades when any
        tenant breaker is open or any replica is not serving."""
        out: Dict[str, Any] = {"status": "ok",
                               "accepting": self._accepting,
                               "tenants": {}}
        with self._lock:
            tenants = dict(self._tenants)
        for name, t in tenants.items():
            counts, lat = t.stats.view()
            br = t.breaker.snapshot()
            with t.lock:
                pending = len(t.pending)
                outstanding = t.outstanding
            entry = {"admission": t.cfg.view(), "breaker": br,
                     "pending": pending, "outstanding": outstanding,
                     "stats": counts,
                     "latency": ServerStats.percentiles(lat),
                     "replicas": t.rset.view()}
            if br["state"] != "closed" or \
                    entry["replicas"]["serving"] < len(t.rset.replicas):
                out["status"] = "degraded"
            out["tenants"][name] = entry
        return out

    def snapshot(self) -> Dict[str, Any]:
        """Alias for :meth:`health` plus per-replica server snapshots
        (throughput counters, batch fill, plan telemetry)."""
        out = self.health()
        for name, entry in out["tenants"].items():
            t = self._tenant(name)
            entry["servers"] = []
            for r in t.rset.replicas:
                try:
                    entry["servers"].append(r.server.snapshot())
                except Exception:               # noqa: BLE001 — dead replica
                    entry["servers"].append(None)
        return out

    # -- lifecycle ---------------------------------------------------------

    def stop(self) -> None:
        """Stop accepting, stop maintenance, stop every replica server,
        then settle whatever is still queued.  Every outstanding
        request handle resolves — in-flight ones through the servers'
        own stop path (callbacks fire with the terminal error), queued
        ones here."""
        self._accepting = False
        self._stop_evt.set()
        if self._maint_thread is not None:
            self._maint_thread.join()
            self._maint_thread = None
        for rset in self._replica_sets():
            rset.stop()
        with self._lock:
            tenants = list(self._tenants.values())
        for t in tenants:
            while True:
                with t.lock:
                    g = t.pending.pop()
                if g is None:
                    break
                t.stats.bump(failed=1)
                g._settle(error=RuntimeError("gateway stopped"))

    def __enter__(self) -> "CamServingGateway":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
