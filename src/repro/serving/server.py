"""Continuous-batching CAM search server.

The LM serving driver (:mod:`repro.launch.serve`) batches *sequences*
at decode-step granularity; this module applies the same idea to CAM
similarity search, the paper's actual workload.  Many worker threads
(RPC handlers, classifier shards, HDC encoders) submit small KNN / HDC
query blocks concurrently; a single batcher thread coalesces whatever
is pending into **plan-sized micro-batches** and drives ONE cached
:class:`~repro.core.engine.SearchPlan` — single-device or sharded
across a ``("data",)`` device mesh — so the jitted executable, the
memoised prepared gallery, and the device mesh are shared by every
request in the process.

Request lifecycle::

    client thread              batcher thread             completion thread
    -------------              --------------             -----------------
    search(q) ─► queue ───────► drain pending (≤ batch    plan.finalize(...)
      blocks on event           rows, ≤ max_wait linger)  syncs the device +
                                stack rows                cross-shard merge,
                                plan.dispatch(...) ─────► scatter rows to
      results ◄─────────────────────────────────────────  requests, set
                                (loops immediately: next  events, record
                                batch dispatches while the latency
                                device runs the previous)

The batcher never blocks on device results: ``plan.dispatch`` enqueues
the micro-batch and returns a ``PendingSearch`` of async jax arrays.  A
bounded completion queue hands it to the completion thread, whose
``plan.finalize`` blocks on the transfer (and runs the host-side
cross-shard merge for sharded plans) before scattering rows back to
their requests and waking the clients — host-side batching overlaps
device compute, and the bound provides backpressure when clients outrun
the device.  (``plan.execute`` is ``finalize(dispatch(...))`` — calling
it in the batcher would serialise the pipeline on device results.)

Coalescing is row-granular: a request carrying 3 query rows and one
carrying 61 share a 64-row micro-batch; an oversized request simply
spans chunks inside the plan (which micro-batches internally).
Results are identical to calling the plan directly — batching changes
scheduling, never arithmetic.

Ternary (TCAM wildcard) programs are first-class served workloads:
construct the server with ``care_mask=...`` and every batch carries the
per-pattern wildcard mask alongside the gallery (both memoised behind
the plan's pattern cache; binary/bipolar plans additionally run
bit-packed — see the packed section of ``docs/engine.md``).

Live gallery mutation
---------------------
:meth:`CamSearchServer.update_gallery` rewrites stored rows **between
micro-batches** while the server keeps serving: a writer-priority
reader/writer lock covers the batcher's dispatch (reader) and the
update (writer), so every dispatched batch sees exactly one gallery
version — a request's rows are never computed against a half-applied
update — and a pending writer blocks *new* batches rather than starving
behind a steady request stream.  The row rewrite itself is the engine's
incremental :meth:`~repro.core.engine.SearchPlan.update_rows` path
(only the touched row tiles of the memoised prepared layout are
re-encoded/re-packed), which is what makes online HDC retraining —
misclassified queries re-bundled into class vectors, then re-served —
cheap against live traffic (see ``repro.hdc`` and ``docs/hdc.md``).

Resilience (deadlines, retries, circuit breaker, degraded mode)
---------------------------------------------------------------
Production serving assumes the backend sometimes fails: a pallas
kernel hits a driver bug, a device wedges, a gallery transfer throws.
The failure-domain machinery (see ``docs/robustness.md``):

* **Per-request deadlines** (``deadline_ms`` / ``REPRO_SERVE_DEADLINE_MS``)
  — an expired request is failed with a ``TimeoutError`` *without*
  losing its batch slot: the rest of the coalesced batch still
  dispatches, and results that arrive after the deadline are dropped
  as misses rather than delivered late.
* **Bounded retry with exponential backoff** — transient dispatch
  failures retry up to ``REPRO_SERVE_RETRIES`` times per fallback
  level, sleeping ``backoff * 2^attempt`` between attempts.
* **Circuit breaker** — ``REPRO_SERVE_BREAKER_K`` consecutive primary-
  backend errors trip the breaker open: batches skip straight to the
  degraded chain until a cooldown elapses, then a half-open probe
  batch tests the primary and closes the breaker on success.
* **Degraded fallback chain** — pallas → jnp (same packing) → jnp
  unpacked → IR interpreter; sharded plans degrade to single-device
  first.  Every level serves the same gallery (and the same fault
  model, when one is injected), so a degraded response is a correct
  response, just slower.
* **health()** — breaker state, fault-cell counters, deadline-miss
  rate, degraded/retry telemetry; ``snapshot()`` keeps the
  throughput/latency counters.
"""

from __future__ import annotations

import itertools
import queue
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.compiler import CompiledCamProgram
from ..core.engine import PlanBase, RangePlan
from ..core.envcfg import env_float, env_int

__all__ = ["SearchRequest", "SearchResult", "CamSearchServer"]


class _CircuitBreaker:
    """Closed → open → half-open circuit breaker over the primary backend.

    ``threshold`` consecutive primary failures trip the breaker
    **open**; while open, batches go straight to the degraded chain.
    After ``cooldown`` seconds the next batch runs as a **half-open**
    probe against the primary: success closes the breaker, failure
    re-opens it (and restarts the cooldown).  ``threshold=0`` disables
    the breaker entirely (every batch tries the primary).
    """

    def __init__(self, threshold: int, cooldown_s: float):
        self.threshold = int(threshold)
        self.cooldown = float(cooldown_s)
        self.state = "closed"
        self.consecutive = 0
        self.trips = 0
        self.probes = 0
        self.recoveries = 0
        self._opened_at = 0.0
        self._lock = threading.Lock()

    @property
    def enabled(self) -> bool:
        return self.threshold > 0

    def allow_primary(self) -> bool:
        if not self.enabled:
            return True
        with self._lock:
            if self.state == "closed":
                return True
            if time.perf_counter() - self._opened_at >= self.cooldown:
                self.state = "half-open"
                self.probes += 1
                return True
            return False

    def record_failure(self) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.consecutive += 1
            if self.state == "half-open" or \
                    self.consecutive >= self.threshold:
                if self.state != "open":
                    self.trips += 1
                self.state = "open"
                self._opened_at = time.perf_counter()

    def record_success(self) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.consecutive = 0
            if self.state != "closed":
                self.state = "closed"
                self.recoveries += 1

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"state": self.state, "threshold": self.threshold,
                    "consecutive_failures": self.consecutive,
                    "trips": self.trips, "probes": self.probes,
                    "recoveries": self.recoveries,
                    "cooldown_ms": 1e3 * self.cooldown}


class _InterpreterExecutor:
    """Last-resort fallback level: the IR interpreter.

    Synthesises a fused module for the plan's spec
    (:func:`~repro.core.engine.module_for_spec`) and executes it with
    :func:`~repro.core.executor.execute_module`, chunked to the traced
    query count.  Synchronous (``dispatch`` computes eagerly) and slow,
    but it has no jit/pallas/device dependency at all — when every
    compiled level is failing, correctness-over-latency is the only
    remaining contract.  Fault models corrupt the stored operands here
    exactly like the compiled levels, so the degraded results match.
    """

    backend = "interpreter"

    def __init__(self, spec):
        from ..core.engine import RangeSpec, module_for_spec
        self.spec = spec
        self.is_range = isinstance(spec, RangeSpec)
        self._module = module_for_spec(spec)

    def dispatch(self, *inputs, faults=None):
        from ..core.executor import execute_module
        spec = self.spec
        rows = np.asarray(inputs[spec.query_arg], np.float32)
        if self.is_range:
            stored = tuple(np.asarray(inputs[i], np.float32)
                           for i in spec.pattern_args)
        else:
            stored = (np.asarray(inputs[spec.pattern_arg], np.float32),)
            if spec.care_arg is not None:
                stored += (np.asarray(inputs[spec.care_arg], np.float32),)
        if faults is not None and not faults.is_null:
            stored = tuple(np.asarray(s, np.float32)
                           for s in faults.corrupt_stored(stored, spec))
        m = spec.m
        outs = []
        for s in range(0, rows.shape[0], m):
            chunk = rows[s:s + m]
            valid = chunk.shape[0]
            if valid < m:        # pad the ragged tail to the traced shape
                chunk = np.concatenate(
                    [chunk, np.zeros((m - valid, chunk.shape[1]),
                                     chunk.dtype)])
            res = execute_module(self._module, chunk, *stored)
            outs.append((tuple(np.asarray(r) for r in res), valid))
        return outs

    def finalize(self, pending):
        if self.is_range:
            return np.concatenate([r[0][:v] for r, v in pending], axis=0)
        return (np.concatenate([r[0][:v] for r, v in pending], axis=0),
                np.concatenate([r[1][:v] for r, v in pending], axis=0))


class _WriterPriorityLock:
    """A reader/writer lock where waiting writers block new readers.

    The batcher takes the read side around every batch dispatch (many
    batches may overlap the completion pipeline, but dispatch itself is
    the only point that reads the gallery); ``update_gallery`` takes
    the write side.  Writer priority matters under load: a steady
    request stream keeps the read side continuously busy, and a plain
    RW lock would starve the update forever.
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writers_waiting = 0
        self._writing = False

    def acquire_read(self) -> None:
        with self._cond:
            while self._writing or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if not self._readers:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writing or self._readers:
                    self._cond.wait()
            finally:
                self._writers_waiting -= 1
            self._writing = True

    def release_write(self) -> None:
        with self._cond:
            self._writing = False
            self._cond.notify_all()


@dataclass
class SearchResult:
    """Per-request outcome: top-k values/indices (best-match plans) or
    the boolean match rows (range plans), row-aligned with the
    submitted queries, plus queueing/batching latency telemetry."""

    rid: int
    values: Optional[np.ndarray] = None
    indices: Optional[np.ndarray] = None
    #: range-plan requests: (rows, n) boolean match matrix
    matches: Optional[np.ndarray] = None
    error: Optional[BaseException] = None
    submitted_at: float = 0.0
    completed_at: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.completed_at - self.submitted_at


@dataclass
class SearchRequest:
    """One in-flight query block (``queries``: ``(rows, dim)``).

    ``deadline`` (absolute ``time.perf_counter()`` seconds, or ``None``)
    is the server-side budget: an expired request is failed with a
    ``TimeoutError`` instead of dispatched (or instead of delivered, if
    the result arrives late) — its batch never waits for it.
    """

    rid: int
    queries: np.ndarray
    result: SearchResult
    deadline: Optional[float] = None
    _done: threading.Event = field(default_factory=threading.Event)

    def wait(self, timeout: Optional[float] = None) -> SearchResult:
        if not self._done.wait(timeout):
            raise TimeoutError(f"search request {self.rid} timed out")
        return self.result


class CamSearchServer:
    """Row-granular continuous batching over one shared ``SearchPlan``.

    Parameters
    ----------
    program:
        A :class:`CompiledCamProgram` whose ``engine_plan`` is set (any
        pure similarity *or* range program), or a bare
        :class:`SearchPlan` / :class:`RangePlan`.  Range plans make the
        server a match server: each request's result carries the
        boolean ``matches`` rows instead of values/indices — this is
        the decision-forest serving path (one interval row per tree
        branch; see ``docs/forest.md``).
    gallery:
        The stored patterns — or, for an *interval* range plan, the
        ``(lo, hi)`` pair of per-row bound arrays.  Converted to jax
        arrays once so the plan's pattern memo (and, for sharded plans,
        the device layout) is hit by every batch.
    care_mask:
        Per-pattern TCAM wildcard mask ``(n, dim)`` — required when the
        plan's program is ternary (a care-mask operand in its spec),
        rejected otherwise.  Non-zero cells are compared, zero cells
        never mismatch; one-shot-learning galleries store the bits the
        class exemplars agree on and wildcard the rest.
    max_wait_ms:
        Linger: how long the batcher waits for more rows after the
        first pending request before launching a partial batch.
    max_batch:
        Rows per coalesced batch; defaults to the plan's micro-batch
        size (anything larger would be re-chunked inside the plan
        anyway).
    max_inflight:
        Bound on dispatched-but-unsynced batches (the completion
        queue); backpressure against clients outrunning the device.
    fault_model:
        Optional :class:`repro.faults.FaultModel` injected into every
        dispatch (all fallback levels included) — the served gallery
        executes with the model's device faults while clients see the
        plan's normal output contract.
    deadline_ms:
        Default per-request deadline (0/None = none;
        ``REPRO_SERVE_DEADLINE_MS`` sets the process default).
        ``submit(..., deadline_ms=...)`` overrides per request.
    max_retries / retry_backoff_ms:
        Bounded retry for transient dispatch failures: each fallback
        level gets ``max_retries`` extra attempts with exponential
        backoff (``REPRO_SERVE_RETRIES`` / ``REPRO_SERVE_BACKOFF_MS``).
    breaker_threshold / breaker_cooldown_ms:
        Circuit breaker: after ``breaker_threshold`` consecutive
        primary-backend errors the breaker opens and batches go
        straight to the degraded chain until a cooldown-elapsed probe
        succeeds.  0 disables (``REPRO_SERVE_BREAKER_K`` /
        ``REPRO_SERVE_BREAKER_COOLDOWN_MS``).
    fault_injector:
        Test/chaos hook: called as ``fault_injector(level_name)``
        immediately before every dispatch attempt; raising simulates a
        backend failure at that level and exercises the retry /
        breaker / degraded machinery.
    """

    def __init__(self, program: Any, gallery: np.ndarray, *,
                 care_mask: Optional[np.ndarray] = None,
                 max_wait_ms: float = 2.0, max_batch: Optional[int] = None,
                 max_inflight: int = 4,
                 fault_model: Any = None,
                 deadline_ms: Optional[float] = None,
                 max_retries: Optional[int] = None,
                 retry_backoff_ms: Optional[float] = None,
                 breaker_threshold: Optional[int] = None,
                 breaker_cooldown_ms: Optional[float] = None,
                 fault_injector: Any = None):
        if isinstance(program, CompiledCamProgram):
            plan = program.engine_plan
            if plan is None:
                raise ValueError(
                    "program has no engine plan (not a pure similarity "
                    "program); the search server needs a SearchPlan")
        elif isinstance(program, PlanBase):
            plan = program
        else:
            raise TypeError(f"expected CompiledCamProgram or an engine "
                            f"plan, got {type(program).__name__}")
        import jax.numpy as jnp
        self.plan = plan
        self.is_range = isinstance(plan, RangePlan)
        if self.is_range:
            if care_mask is not None:
                raise ValueError("care_mask only applies to ternary "
                                 "best-match plans, not range plans")
            n_pats = len(plan.spec.pattern_args)
            if n_pats == 2:       # interval mode: gallery is (lo, hi)
                if not (isinstance(gallery, (tuple, list))
                        and len(gallery) == 2):
                    raise ValueError(
                        "interval range plan needs gallery=(lo, hi)")
                self.gallery = tuple(jnp.asarray(g) for g in gallery)
            else:
                self.gallery = (jnp.asarray(gallery),)
            for g in self.gallery:
                if tuple(g.shape) != (plan.spec.n, plan.spec.dim):
                    raise ValueError(
                        f"stored operand shape {tuple(g.shape)} != plan "
                        f"geometry ({plan.spec.n}, {plan.spec.dim})")
            self.care = None
        else:
            self.gallery = jnp.asarray(gallery)
            if plan.spec.care_arg is not None:
                if care_mask is None:
                    raise ValueError("ternary plan (TCAM wildcard search) "
                                     "needs a care_mask")
                care = np.asarray(care_mask)
                if care.shape != (plan.spec.n, plan.spec.dim):
                    raise ValueError(
                        f"care_mask shape {care.shape} != gallery geometry "
                        f"({plan.spec.n}, {plan.spec.dim})")
                # jax array for the same reason as the gallery: the plan's
                # pattern memo keys on the (gallery, care) pair of arrays
                self.care = jnp.asarray(care)
            elif care_mask is not None:
                raise ValueError("care_mask given but the plan's program "
                                 "has no care operand (not a ternary "
                                 "search)")
            else:
                self.care = None
        self.max_wait = max_wait_ms / 1e3
        self.max_batch = int(max_batch or plan.batch)
        if fault_model is not None and not hasattr(fault_model, "is_null"):
            raise TypeError("fault_model must be a repro.faults.FaultModel")
        self._faults = None if fault_model is None or fault_model.is_null \
            else fault_model
        self._deadline_s = (env_float("REPRO_SERVE_DEADLINE_MS", 0.0,
                                      min_value=0.0)
                            if deadline_ms is None else float(deadline_ms)
                            ) / 1e3
        self._max_retries = env_int("REPRO_SERVE_RETRIES", 2, min_value=0) \
            if max_retries is None else int(max_retries)
        self._backoff_s = (env_float("REPRO_SERVE_BACKOFF_MS", 2.0,
                                     min_value=0.0)
                           if retry_backoff_ms is None
                           else float(retry_backoff_ms)) / 1e3
        self._breaker = _CircuitBreaker(
            env_int("REPRO_SERVE_BREAKER_K", 3, min_value=0)
            if breaker_threshold is None else int(breaker_threshold),
            (env_float("REPRO_SERVE_BREAKER_COOLDOWN_MS", 100.0,
                       min_value=0.0)
             if breaker_cooldown_ms is None
             else float(breaker_cooldown_ms)) / 1e3)
        self._fault_injector = fault_injector
        self._fallbacks: Optional[List[Tuple[str, Any]]] = None
        self._init_state(max_inflight)

    def _init_state(self, max_inflight: int) -> None:
        self._queue: "queue.Queue[Optional[SearchRequest]]" = queue.Queue()
        self._completions: "queue.Queue[Optional[Tuple[Any, ...]]]" = \
            queue.Queue(maxsize=max(1, int(max_inflight)))
        self._rid = itertools.count()
        self._thread: Optional[threading.Thread] = None
        self._completer: Optional[threading.Thread] = None
        self._running = False
        self._accepting = False
        self._lock = threading.Lock()
        # gallery consistency: batch dispatch reads, update_gallery writes
        self._gallery_lock = _WriterPriorityLock()
        # bounded: a long-lived server must not grow per-request state
        self._latencies: "deque[float]" = deque(maxlen=4096)
        self._completer_alive = False
        self.stats: Dict[str, Any] = {
            "requests": 0, "queries": 0, "batches": 0,
            "batched_rows": 0, "errors": 0,
            "gallery_updates": 0, "rows_updated": 0,
            "deadline_misses": 0, "backend_errors": 0, "retries": 0,
            "degraded_batches": 0, "breaker_skips": 0,
        }

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "CamSearchServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._running = True
        self._accepting = True
        self._thread = threading.Thread(target=self._loop,
                                        name="cam-search-batcher", daemon=True)
        self._completer = threading.Thread(target=self._completion_loop,
                                           name="cam-search-completer",
                                           daemon=True)
        self._completer.start()
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        # close the front door under the lock BEFORE the shutdown
        # sentinel: any submit that won its lock race has its request in
        # the queue ahead of the sentinel, so the batcher still serves
        # it; later submits raise instead of enqueueing into a dead queue
        with self._lock:
            self._accepting = False
        self._running = False
        self._queue.put(None)               # wake the batcher
        self._thread.join()
        self._thread = None
        # batcher done: flush the completer.  The sentinel put must not
        # hang when the completion queue is full and the completer is
        # already dead (e.g. it crashed mid-run) — poll instead of block.
        while True:
            try:
                self._completions.put(None, timeout=0.05)
                break
            except queue.Full:
                if not self._completer_alive:
                    break
        self._completer.join()
        self._completer = None
        # a crashed completer strands undelivered batches in the queue;
        # fail them so no waiter blocks forever on a stopped server
        self._drain_completions()

    def _drain_completions(self) -> None:
        while True:
            try:
                item = self._completions.get_nowait()
            except queue.Empty:
                return
            if item is None:
                continue
            for r in item[0]:
                self._fail(r, RuntimeError(
                    "server stopped before completion"))

    def __enter__(self) -> "CamSearchServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API --------------------------------------------------------

    def submit(self, queries: np.ndarray, *,
               deadline_ms: Optional[float] = None) -> SearchRequest:
        """Enqueue a query block; returns a waitable request handle.

        Malformed blocks are rejected here, synchronously — one bad
        request must never poison the innocent requests it would have
        been coalesced with.  ``deadline_ms`` overrides the server's
        default per-request deadline (0 = none for this request).
        """
        q = np.asarray(queries)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2:
            raise ValueError(f"queries must be (rows, dim), got {q.shape}")
        if q.shape[0] == 0:
            raise ValueError("empty query block")
        dim = self.plan.spec.dim
        if q.shape[1] != dim:
            raise ValueError(
                f"query feature dimension {q.shape[1]} != plan dim {dim}")
        rid = next(self._rid)
        now = time.perf_counter()
        budget = self._deadline_s if deadline_ms is None \
            else float(deadline_ms) / 1e3
        req = SearchRequest(rid=rid, queries=q,
                            deadline=now + budget if budget > 0 else None,
                            result=SearchResult(rid=rid, submitted_at=now))
        with self._lock:
            if not self._accepting:
                raise RuntimeError("server not started")
            self._queue.put(req)
        return req

    def search(self, queries: np.ndarray,
               timeout: Optional[float] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Blocking search: submit + wait, raising the batch's error if
        execution failed.  Thread-safe; this is the worker-thread API.
        Best-match plans only — range plans use :meth:`match`."""
        if self.is_range:
            raise TypeError("range plan: use match() (boolean matches, "
                            "not values/indices)")
        res = self.submit(queries).wait(timeout)
        if res.error is not None:
            raise res.error
        return res.values, res.indices

    def match(self, queries: np.ndarray,
              timeout: Optional[float] = None) -> np.ndarray:
        """Blocking range search: the ``(rows, n)`` boolean match matrix
        for this request's query rows (range plans only) — each row of
        a forest gallery flags the tree branches the sample satisfies."""
        if not self.is_range:
            raise TypeError("best-match plan: use search()")
        res = self.submit(queries).wait(timeout)
        if res.error is not None:
            raise res.error
        return res.matches

    def update_gallery(self, indices, new_rows, *,
                       donate: bool = False) -> None:
        """Rewrite stored gallery rows between micro-batches, live.

        ``indices``: row ids to replace; ``new_rows``: ``(len(indices),
        dim)`` replacement rows — for *interval* range plans a
        ``(lo_rows, hi_rows)`` pair.  Applied under the writer side of
        the gallery lock: in-flight batches finish against the old
        gallery, every batch dispatched afterwards sees the new one
        (never a mix), and a pending update blocks new batches instead
        of starving behind steady traffic.  The rewrite itself is the
        plan's incremental :meth:`~repro.core.engine.SearchPlan.
        update_rows` — only the touched row tiles are re-prepared, so
        online-learning loops can call this at high rate.

        Thread-safe; raises (synchronously, nothing half-applied) on
        malformed indices/rows.  Ternary servers keep their care mask
        fixed — wildcards describe the program, not the data.

        ``donate=True`` forwards the engine's buffer-donation contract
        (in-place scatter, no full-gallery copy): pass it only when no
        code outside the server still reads the current gallery array
        (e.g. the array handed to the constructor was numpy, so the
        server owns its jax copy).
        """
        if self.is_range and len(self.plan.spec.pattern_args) == 2:
            if not (isinstance(new_rows, (tuple, list))
                    and len(new_rows) == 2):
                raise ValueError(
                    "interval range plan needs new_rows=(lo_rows, hi_rows)")
        self._gallery_lock.acquire_write()
        try:
            if self.is_range:
                multi = len(self.plan.spec.pattern_args) == 2
                stored = self.gallery if multi else self.gallery[0]
                updated = self.plan.update_rows(stored, indices, new_rows,
                                                donate=donate)
                self.gallery = tuple(updated) if multi else (updated,)
            else:
                self.gallery = self.plan.update_rows(
                    self.gallery, indices, new_rows, care=self.care,
                    donate=donate)
            n_rows = int(np.atleast_1d(np.asarray(indices)).size)
            with self._lock:
                self.stats["gallery_updates"] += 1
                self.stats["rows_updated"] += n_rows
        finally:
            self._gallery_lock.release_write()

    # -- batcher -----------------------------------------------------------

    def _drain(self, first: SearchRequest) -> List[SearchRequest]:
        """Coalesce pending requests after ``first`` into one batch:
        up to ``max_batch`` rows, lingering at most ``max_wait``."""
        batch = [first]
        rows = first.queries.shape[0]
        deadline = time.perf_counter() + self.max_wait
        while rows < self.max_batch:
            remaining = deadline - time.perf_counter()
            try:
                req = self._queue.get(
                    timeout=max(remaining, 0) if remaining > 0 else None,
                    block=remaining > 0)
            except queue.Empty:
                break
            if req is None:                 # shutdown sentinel
                self._queue.put(None)       # leave it for the main loop
                break
            batch.append(req)
            rows += req.queries.shape[0]
        return batch

    def _loop(self) -> None:
        while True:
            req = self._queue.get()
            if req is None:
                if self._running:
                    continue                # stray sentinel from a drain
                break
            batch = self._drain(req)
            self._execute_batch(batch)
        # drain anything left after shutdown so no client blocks forever
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if req is not None:
                self._fail(req, RuntimeError("server stopped"))

    def _inputs_for(self, spec, rows: np.ndarray) -> List[Any]:
        """Module-argument list for one executor's spec (fallback levels
        may order arguments differently from the primary plan)."""
        if self.is_range:
            n_args = max(spec.query_arg, *spec.pattern_args) + 1
            inputs: List[Any] = [None] * n_args
            inputs[spec.query_arg] = rows
            for pos, g in zip(spec.pattern_args, self.gallery):
                inputs[pos] = g
        else:
            n_args = max(spec.query_arg, spec.pattern_arg,
                         -1 if spec.care_arg is None
                         else spec.care_arg) + 1
            inputs = [None] * n_args
            inputs[spec.query_arg] = rows
            inputs[spec.pattern_arg] = self.gallery
            if spec.care_arg is not None:
                inputs[spec.care_arg] = self.care
        return inputs

    def _build_fallbacks(self) -> List[Tuple[str, Any]]:
        """Degraded chain below the primary plan, most- to least-capable:
        single-device (for sharded primaries) → jnp (for pallas) → jnp
        unpacked (for packed) → IR interpreter.  Every level is an
        ordinary plan-cache citizen compiled for the same spec/batch."""
        from ..core.engine import CompositePlan, get_plan, module_for_spec
        spec = self.plan.spec
        mod = module_for_spec(spec)
        chain: List[Tuple[str, Any]] = []

        def add(name: str, **kw) -> None:
            try:
                p = get_plan(mod, batch=self.plan.batch, **kw)
            except Exception:       # level not buildable here: skip it
                return
            if p is not None and p is not self.plan and \
                    all(p is not e for _, e in chain):
                chain.append((name, p))

        if isinstance(self.plan, CompositePlan):
            # composite primaries degrade to the *exact* flat search
            # first — module_for_spec resolved the flat equivalent above
            add("jnp-flat", backend="jnp", pack=self.plan.packed,
                shards=self.plan.shards)
        if self.plan.shards > 1:
            add("jnp-single", backend="jnp", pack=self.plan.packed)
        if self.plan.backend == "pallas":
            add("jnp", backend="jnp", pack=self.plan.packed)
        if self.plan.packed:
            add("jnp-unpacked", backend="jnp", pack=False)
        chain.append(("interpreter", _InterpreterExecutor(spec)))
        return chain

    def _levels(self) -> List[Tuple[str, Any]]:
        with self._lock:
            if self._fallbacks is None:
                self._fallbacks = self._build_fallbacks()
            fallbacks = self._fallbacks
        return [("primary", self.plan)] + fallbacks

    def _dispatch_resilient(self, rows: np.ndarray) -> Tuple[Any, Any]:
        """Dispatch with retry, breaker, and degraded fallback.

        Walks the level chain (skipping the primary while the breaker
        is open), giving each level ``max_retries`` extra attempts with
        exponential backoff.  Returns ``(executor, pending)`` from the
        first level that accepts the dispatch; raises the last error
        only when *every* level (including the interpreter) failed.
        """
        levels = self._levels()
        start = 0
        if not self._breaker.allow_primary():
            start = 1
            with self._lock:
                self.stats["breaker_skips"] += 1
        last: Optional[BaseException] = None
        for li in range(start, len(levels)):
            name, ex = levels[li]
            primary = li == 0
            for attempt in range(self._max_retries + 1):
                try:
                    if self._fault_injector is not None:
                        self._fault_injector(name)
                    pending = ex.dispatch(*self._inputs_for(ex.spec, rows),
                                          faults=self._faults)
                except BaseException as e:      # noqa: BLE001 — retried
                    last = e
                    if primary:
                        self._breaker.record_failure()
                    with self._lock:
                        self.stats["backend_errors"] += 1
                    if attempt < self._max_retries:
                        with self._lock:
                            self.stats["retries"] += 1
                        if self._backoff_s:
                            time.sleep(self._backoff_s * (2 ** attempt))
                    continue
                if primary:
                    self._breaker.record_success()
                else:
                    with self._lock:
                        self.stats["degraded_batches"] += 1
                return ex, pending
        raise last if last is not None else RuntimeError("no dispatch level")

    def _execute_batch(self, batch: Sequence[SearchRequest]) -> None:
        """Dispatch one coalesced batch; the device result (async jax
        arrays) goes to the completion thread, so the batcher is free to
        coalesce and dispatch the next batch immediately."""
        # expire dead-on-arrival requests first: a missed deadline costs
        # a TimeoutError, never the rest of the batch's slot
        now = time.perf_counter()
        live = []
        for r in batch:
            if r.deadline is not None and now > r.deadline:
                self._fail_timeout(r)
            else:
                live.append(r)
        if not live:
            return
        batch = live
        # reader side of the gallery lock: the whole read-gallery +
        # dispatch sequence sees exactly one gallery version, and a
        # waiting update_gallery writer gets in before the *next* batch
        self._gallery_lock.acquire_read()
        try:
            rows = np.concatenate([r.queries for r in batch], axis=0)
            executor, pending = self._dispatch_resilient(rows)
        except BaseException as e:          # noqa: BLE001 — fanned out
            for r in batch:
                self._fail(r, e)
            return
        finally:
            self._gallery_lock.release_read()
        with self._lock:
            self.stats["batches"] += 1
            self.stats["batched_rows"] += rows.shape[0]
        self._put_completion((batch, executor, pending, rows))

    def _put_completion(self, item: Tuple[Any, ...]) -> None:
        """Backpressured hand-off that cannot hang shutdown: the put
        polls so a dead completion thread fails the batch instead of
        blocking the batcher (and therefore ``stop()``) forever."""
        while True:
            try:
                self._completions.put(item, timeout=0.05)
                return
            except queue.Full:
                if not self._completer_alive:
                    for r in item[0]:
                        self._fail(r, RuntimeError(
                            "completion thread is not running"))
                    return

    def _rescue(self, batch: Sequence[SearchRequest], rows: np.ndarray,
                failed: Any):
        """Synchronous finalize-failure recovery in the completion
        thread: re-run the batch through the levels below the one that
        failed (under the gallery read lock, so the retry still sees
        one gallery version)."""
        levels = self._levels()
        idx = next((i for i, (_, ex) in enumerate(levels)
                    if ex is failed), -1)
        self._gallery_lock.acquire_read()
        try:
            for name, ex in levels[idx + 1:]:
                try:
                    if self._fault_injector is not None:
                        self._fault_injector(name)
                    pending = ex.dispatch(
                        *self._inputs_for(ex.spec, rows),
                        faults=self._faults)
                    out = ex.finalize(pending)
                except BaseException:       # noqa: BLE001 — next level
                    with self._lock:
                        self.stats["backend_errors"] += 1
                    continue
                with self._lock:
                    self.stats["degraded_batches"] += 1
                return out
        finally:
            self._gallery_lock.release_read()
        return None

    def _completion_loop(self) -> None:
        self._completer_alive = True
        try:
            while True:
                item = self._completions.get()
                if item is None:
                    break
                self._complete_one(item)
        finally:
            self._completer_alive = False

    def _complete_one(self, item: Tuple[Any, ...]) -> None:
        batch, executor, pending, rows_arr = item
        rows = rows_arr.shape[0]
        try:
            out = executor.finalize(pending)
        except BaseException as e:          # noqa: BLE001 — rescued
            if executor is self.plan:
                self._breaker.record_failure()
            with self._lock:
                self.stats["backend_errors"] += 1
            out = self._rescue(batch, rows_arr, executor)
            if out is None:
                for r in batch:
                    self._fail(r, e)
                return
        if self.is_range:
            matches = np.asarray(out).reshape(rows, -1)
            values = indices = None
        else:
            values, indices = out
            # finalize shapes outputs for the *compiled module* (which
            # may have been traced with 1-D or stacked queries); the
            # scatter below is strictly row-major
            values = np.asarray(values).reshape(rows, -1)
            indices = np.asarray(indices).reshape(rows, -1)
        now = time.perf_counter()
        off = 0
        with self._lock:
            self.stats["requests"] += len(batch)
            self.stats["queries"] += rows
        for r in batch:
            m = r.queries.shape[0]
            if r.deadline is not None and now > r.deadline:
                # result arrived, but past the budget: a miss, not a
                # late delivery the client already gave up on
                off += m
                self._fail_timeout(r)
                continue
            if self.is_range:
                r.result.matches = matches[off:off + m]
            else:
                r.result.values = values[off:off + m]
                r.result.indices = indices[off:off + m]
            r.result.completed_at = now
            off += m
            with self._lock:
                self._latencies.append(r.result.latency_s)
            r._done.set()

    def _fail(self, req: SearchRequest, err: BaseException) -> None:
        req.result.error = err
        req.result.completed_at = time.perf_counter()
        with self._lock:
            self.stats["errors"] += 1
        req._done.set()

    def _fail_timeout(self, req: SearchRequest) -> None:
        req.result.error = TimeoutError(
            f"request {req.rid} missed its deadline")
        req.result.completed_at = time.perf_counter()
        with self._lock:
            self.stats["deadline_misses"] += 1
        req._done.set()

    # -- telemetry ---------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time stats: throughput-ready counters plus latency
        percentiles (over a bounded recent window) and the mean batch
        fill (rows per launched batch)."""
        with self._lock:
            lat = sorted(self._latencies)
            out = dict(self.stats)
        out["avg_batch_fill"] = (out["batched_rows"] / out["batches"]
                                 if out["batches"] else 0.0)
        if lat:
            out["p50_ms"] = 1e3 * lat[len(lat) // 2]
            out["p95_ms"] = 1e3 * lat[min(len(lat) - 1,
                                          int(len(lat) * 0.95))]
        spec = self.plan.spec
        out["plan"] = {"batch": self.plan.batch, "shards": self.plan.shards,
                       "backend": self.plan.backend,
                       "packed": self.plan.packed,
                       "family": self.plan.family,
                       "ternary": getattr(spec, "care_arg", None) is not None,
                       "metric": spec.metric,
                       "executions": self.plan.executions,
                       "chunks_run": self.plan.chunks_run,
                       "row_updates": self.plan.row_updates,
                       "row_update_fallbacks":
                           self.plan.row_update_fallbacks}
        if self.is_range:
            out["plan"]["mode"] = spec.mode
        else:
            out["plan"]["k"] = spec.k
        return out

    def health(self) -> Dict[str, Any]:
        """Liveness/degradation endpoint: breaker state, fault-model
        telemetry, deadline-miss rate, and the degraded chain.

        ``status`` is ``"ok"`` while the primary backend serves,
        ``"degraded"`` once the breaker is open or any batch has been
        served by a fallback level.
        """
        with self._lock:
            st = dict(self.stats)
            fallbacks = self._fallbacks
        br = self._breaker.snapshot()
        misses = st["deadline_misses"]
        degraded = br["state"] != "closed" or st["degraded_batches"] > 0
        out: Dict[str, Any] = {
            "status": "degraded" if degraded else "ok",
            "running": self._running,
            "breaker": br,
            "deadline_miss_rate":
                misses / max(1, misses + st["requests"]),
            "deadline_misses": misses,
            "backend_errors": st["backend_errors"],
            "retries": st["retries"],
            "degraded_batches": st["degraded_batches"],
            "breaker_skips": st["breaker_skips"],
            "fallback_levels":
                None if fallbacks is None else [n for n, _ in fallbacks],
        }
        if self._faults is not None:
            spec = self.plan.spec
            out["fault_model"] = {
                "seed": self._faults.seed,
                "p_stuck": self._faults.p_stuck,
                "p_flip": self._faults.p_flip,
                "sigma": self._faults.sigma,
                "drift": self._faults.drift, "t": self._faults.t,
                "epoch": self._faults.epoch,
                "cells": self._faults.cell_fault_counts(
                    (spec.n, spec.dim)),
            }
        return out
