"""Continuous-batching CAM search server.

The LM serving driver (:mod:`repro.launch.serve`) batches *sequences*
at decode-step granularity; this module applies the same idea to CAM
similarity search, the paper's actual workload.  Many worker threads
(RPC handlers, classifier shards, HDC encoders) submit small KNN / HDC
query blocks concurrently; a single batcher thread coalesces whatever
is pending into **plan-sized micro-batches** and drives ONE cached
:class:`~repro.core.engine.SearchPlan` — single-device or sharded
across a ``("data",)`` device mesh — so the jitted executable, the
memoised prepared gallery, and the device mesh are shared by every
request in the process.

Request lifecycle::

    client thread              batcher thread             completion thread
    -------------              --------------             -----------------
    search(q) ─► queue ───────► drain pending (≤ batch    plan.finalize(...)
      blocks on event           rows, ≤ max_wait linger)  syncs the device +
                                stack rows                cross-shard merge,
                                plan.dispatch(...) ─────► scatter rows to
      results ◄─────────────────────────────────────────  requests, set
                                (loops immediately: next  events, record
                                batch dispatches while the latency
                                device runs the previous)

The batcher never blocks on device results: ``plan.dispatch`` enqueues
the micro-batch and returns a ``PendingSearch`` of async jax arrays.  A
bounded completion queue hands it to the completion thread, whose
``plan.finalize`` blocks on the transfer (and runs the host-side
cross-shard merge for sharded plans) before scattering rows back to
their requests and waking the clients — host-side batching overlaps
device compute, and the bound provides backpressure when clients outrun
the device.  (``plan.execute`` is ``finalize(dispatch(...))`` — calling
it in the batcher would serialise the pipeline on device results.)

Coalescing is row-granular: a request carrying 3 query rows and one
carrying 61 share a 64-row micro-batch; an oversized request simply
spans chunks inside the plan (which micro-batches internally).
Results are identical to calling the plan directly — batching changes
scheduling, never arithmetic.

Ternary (TCAM wildcard) programs are first-class served workloads:
construct the server with ``care_mask=...`` and every batch carries the
per-pattern wildcard mask alongside the gallery (both memoised behind
the plan's pattern cache; binary/bipolar plans additionally run
bit-packed — see the packed section of ``docs/engine.md``).

Live gallery mutation
---------------------
:meth:`CamSearchServer.update_gallery` rewrites stored rows **between
micro-batches** while the server keeps serving: a writer-priority
reader/writer lock covers the batcher's dispatch (reader) and the
update (writer), so every dispatched batch sees exactly one gallery
version — a request's rows are never computed against a half-applied
update — and a pending writer blocks *new* batches rather than starving
behind a steady request stream.  The row rewrite itself is the engine's
incremental :meth:`~repro.core.engine.SearchPlan.update_rows` path
(only the touched row tiles of the memoised prepared layout are
re-encoded/re-packed), which is what makes online HDC retraining —
misclassified queries re-bundled into class vectors, then re-served —
cheap against live traffic (see ``repro.hdc`` and ``docs/hdc.md``).
:meth:`CamSearchServer.adopt_gallery` is the replicated-serving
variant: the multi-tenant gateway computes one ``update_rows`` against
a gallery array shared by every replica and each replica server adopts
the same resulting jax array — the plan's pattern memo is primed once
for the whole fleet.

Resilience (deadlines, retries, circuit breaker, degraded mode)
---------------------------------------------------------------
Production serving assumes the backend sometimes fails: a pallas
kernel hits a driver bug, a device wedges, a gallery transfer throws.
The failure-domain machinery lives in :mod:`repro.serving.resilience`
(see ``docs/robustness.md``): per-request deadlines
(``REPRO_SERVE_DEADLINE_MS``), bounded retry with exponential backoff
(``REPRO_SERVE_RETRIES`` / ``REPRO_SERVE_BACKOFF_MS``), a circuit
breaker over the primary backend (``REPRO_SERVE_BREAKER_K`` /
``REPRO_SERVE_BREAKER_COOLDOWN_MS``), and a degraded fallback chain
(pallas → jnp → jnp unpacked → IR interpreter) that serves the same
gallery at every level.  ``health()`` surfaces breaker state,
fault-cell counters and deadline-miss rates; ``snapshot()`` keeps the
throughput/latency counters — both read a **consistent** view of the
stats (every related counter group is updated atomically, see
:class:`~repro.serving.telemetry.ServerStats`).

This module is the package's assembly point: the batching loop lives
in :mod:`repro.serving.batcher`, the failure machinery in
:mod:`repro.serving.resilience`, counters/requests in
:mod:`repro.serving.telemetry`, and the multi-tenant layer on top in
:mod:`repro.serving.gateway`.
"""

from __future__ import annotations

import itertools
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.compiler import CompiledCamProgram
from ..core.engine import PlanBase, RangePlan
from ..core.envcfg import env_flag, env_float, env_int
from ..obs import trace as _trace
from .batcher import _BatcherMixin
from .resilience import _CircuitBreaker, _ResilienceMixin, \
    _WriterPriorityLock
from .telemetry import SearchRequest, SearchResult, ServerStats

__all__ = ["SearchRequest", "SearchResult", "CamSearchServer"]

#: process-global request/batch id streams shared by every server so
#: ids stay unique inside the shared trace recorder (see _init_state)
_RIDS = itertools.count()
_BATCH_IDS = itertools.count()


def _resolve_plan(program: Any, tuned: Optional[bool] = None) -> PlanBase:
    """Accept a :class:`CompiledCamProgram` (with an engine plan) or a
    bare plan; reject anything else synchronously.

    ``tuned`` (default ``REPRO_TUNE_SERVE``, on) consults the
    persistent plan store: when ``REPRO_PLAN_STORE`` is configured and
    holds a tuned config for this workload, the heuristically-built
    leaf plan is swapped for its tuned equivalent — including any
    stored AOT executables, so a fresh serving process skips autotuning
    *and* XLA compilation (see :mod:`repro.tune`).  Without a store
    this is a no-op.
    """
    if isinstance(program, CompiledCamProgram):
        plan = program.engine_plan
        if plan is None:
            raise ValueError(
                "program has no engine plan (not a pure similarity "
                "program); the search server needs a SearchPlan")
    elif isinstance(program, PlanBase):
        plan = program
    else:
        raise TypeError(f"expected CompiledCamProgram or an engine "
                        f"plan, got {type(program).__name__}")
    if tuned is None:
        tuned = env_flag("REPRO_TUNE_SERVE", True)
    if tuned:
        try:
            from ..tune import warm_start_plan
            plan = warm_start_plan(plan)
        except Exception:
            # warm start is an optimisation: a corrupt store record or
            # import failure must never block server construction
            pass
    return plan


def _validate_queries(plan: PlanBase, queries: np.ndarray) -> np.ndarray:
    """Normalise a query block to ``(rows, dim)`` numpy, rejecting
    malformed blocks synchronously — one bad request must never poison
    the innocent requests it would have been coalesced with."""
    q = np.asarray(queries)
    if q.ndim == 1:
        q = q[None, :]
    if q.ndim != 2:
        raise ValueError(f"queries must be (rows, dim), got {q.shape}")
    if q.shape[0] == 0:
        raise ValueError("empty query block")
    dim = plan.spec.dim
    if q.shape[1] != dim:
        raise ValueError(
            f"query feature dimension {q.shape[1]} != plan dim {dim}")
    return q


def _coerce_stored(plan: PlanBase, is_range: bool, gallery: Any):
    """Validate + convert the stored operands to the server's gallery
    attribute: a jax array for best-match plans, a tuple of jax arrays
    for range plans (``(lo, hi)`` in interval mode)."""
    import jax.numpy as jnp
    if is_range:
        n_pats = len(plan.spec.pattern_args)
        if n_pats == 2:           # interval mode: gallery is (lo, hi)
            if not (isinstance(gallery, (tuple, list))
                    and len(gallery) == 2):
                raise ValueError(
                    "interval range plan needs gallery=(lo, hi)")
            stored = tuple(jnp.asarray(g) for g in gallery)
        else:
            stored = (jnp.asarray(gallery),)
        for g in stored:
            if tuple(g.shape) != (plan.spec.n, plan.spec.dim):
                raise ValueError(
                    f"stored operand shape {tuple(g.shape)} != plan "
                    f"geometry ({plan.spec.n}, {plan.spec.dim})")
        return stored
    return jnp.asarray(gallery)


class CamSearchServer(_BatcherMixin, _ResilienceMixin):
    """Row-granular continuous batching over one shared ``SearchPlan``.

    Parameters
    ----------
    program:
        A :class:`CompiledCamProgram` whose ``engine_plan`` is set (any
        pure similarity *or* range program), or a bare
        :class:`SearchPlan` / :class:`RangePlan`.  Range plans make the
        server a match server: each request's result carries the
        boolean ``matches`` rows instead of values/indices — this is
        the decision-forest serving path (one interval row per tree
        branch; see ``docs/forest.md``).
    gallery:
        The stored patterns — or, for an *interval* range plan, the
        ``(lo, hi)`` pair of per-row bound arrays.  Converted to jax
        arrays once so the plan's pattern memo (and, for sharded plans,
        the device layout) is hit by every batch.
    care_mask:
        Per-pattern TCAM wildcard mask ``(n, dim)`` — required when the
        plan's program is ternary (a care-mask operand in its spec),
        rejected otherwise.  Non-zero cells are compared, zero cells
        never mismatch; one-shot-learning galleries store the bits the
        class exemplars agree on and wildcard the rest.
    max_wait_ms:
        Linger: how long the batcher waits for more rows after the
        first pending request before launching a partial batch.
    max_batch:
        Rows per coalesced batch; defaults to the plan's micro-batch
        size (anything larger would be re-chunked inside the plan
        anyway).
    max_inflight:
        Bound on dispatched-but-unsynced batches (the completion
        queue); backpressure against clients outrunning the device.
    fault_model:
        Optional :class:`repro.faults.FaultModel` injected into every
        dispatch (all fallback levels included) — the served gallery
        executes with the model's device faults while clients see the
        plan's normal output contract.
    deadline_ms:
        Default per-request deadline (0/None = none;
        ``REPRO_SERVE_DEADLINE_MS`` sets the process default).
        ``submit(..., deadline_ms=...)`` overrides per request.
    max_retries / retry_backoff_ms:
        Bounded retry for transient dispatch failures: each fallback
        level gets ``max_retries`` extra attempts with exponential
        backoff (``REPRO_SERVE_RETRIES`` / ``REPRO_SERVE_BACKOFF_MS``).
    breaker_threshold / breaker_cooldown_ms:
        Circuit breaker: after ``breaker_threshold`` consecutive
        primary-backend errors the breaker opens and batches go
        straight to the degraded chain until a cooldown-elapsed probe
        succeeds.  0 disables (``REPRO_SERVE_BREAKER_K`` /
        ``REPRO_SERVE_BREAKER_COOLDOWN_MS``).
    fault_injector:
        Test/chaos hook: called as ``fault_injector(level_name)``
        immediately before every dispatch attempt; raising simulates a
        backend failure at that level and exercises the retry /
        breaker / degraded machinery.
    tuned:
        Plan-store warm start (default ``REPRO_TUNE_SERVE``, on): swap
        the program's plan for its stored tuned equivalent when
        ``REPRO_PLAN_STORE`` holds one.  ``False`` serves the plan
        exactly as given.
    """

    def __init__(self, program: Any, gallery: np.ndarray, *,
                 care_mask: Optional[np.ndarray] = None,
                 max_wait_ms: float = 2.0, max_batch: Optional[int] = None,
                 max_inflight: int = 4,
                 fault_model: Any = None,
                 deadline_ms: Optional[float] = None,
                 max_retries: Optional[int] = None,
                 retry_backoff_ms: Optional[float] = None,
                 breaker_threshold: Optional[int] = None,
                 breaker_cooldown_ms: Optional[float] = None,
                 fault_injector: Any = None,
                 tuned: Optional[bool] = None):
        plan = _resolve_plan(program, tuned=tuned)
        import jax.numpy as jnp
        self.plan = plan
        self.is_range = isinstance(plan, RangePlan)
        if self.is_range:
            if care_mask is not None:
                raise ValueError("care_mask only applies to ternary "
                                 "best-match plans, not range plans")
            self.gallery = _coerce_stored(plan, True, gallery)
            self.care = None
        else:
            self.gallery = _coerce_stored(plan, False, gallery)
            if plan.spec.care_arg is not None:
                if care_mask is None:
                    raise ValueError("ternary plan (TCAM wildcard search) "
                                     "needs a care_mask")
                if tuple(np.shape(care_mask)) != (plan.spec.n,
                                                  plan.spec.dim):
                    raise ValueError(
                        f"care_mask shape {tuple(np.shape(care_mask))} != "
                        f"gallery geometry ({plan.spec.n}, {plan.spec.dim})")
                # jax array for the same reason as the gallery: the plan's
                # pattern memo keys on the (gallery, care) pair of arrays —
                # and jnp.asarray preserves the identity of a jax input,
                # so replica servers handed one shared care array share
                # one memo entry
                self.care = jnp.asarray(care_mask)
            elif care_mask is not None:
                raise ValueError("care_mask given but the plan's program "
                                 "has no care operand (not a ternary "
                                 "search)")
            else:
                self.care = None
        self.max_wait = max_wait_ms / 1e3
        self.max_batch = int(max_batch or plan.batch)
        if fault_model is not None and not hasattr(fault_model, "is_null"):
            raise TypeError("fault_model must be a repro.faults.FaultModel")
        self._faults = None if fault_model is None or fault_model.is_null \
            else fault_model
        self._deadline_s = (env_float("REPRO_SERVE_DEADLINE_MS", 0.0,
                                      min_value=0.0)
                            if deadline_ms is None else float(deadline_ms)
                            ) / 1e3
        self._max_retries = env_int("REPRO_SERVE_RETRIES", 2, min_value=0) \
            if max_retries is None else int(max_retries)
        self._backoff_s = (env_float("REPRO_SERVE_BACKOFF_MS", 2.0,
                                     min_value=0.0)
                           if retry_backoff_ms is None
                           else float(retry_backoff_ms)) / 1e3
        self._breaker = _CircuitBreaker(
            env_int("REPRO_SERVE_BREAKER_K", 3, min_value=0)
            if breaker_threshold is None else int(breaker_threshold),
            (env_float("REPRO_SERVE_BREAKER_COOLDOWN_MS", 100.0,
                       min_value=0.0)
             if breaker_cooldown_ms is None
             else float(breaker_cooldown_ms)) / 1e3)
        self._fault_injector = fault_injector
        self._fallbacks: Optional[List[Tuple[str, Any]]] = None
        self._init_state(max_inflight)

    def _init_state(self, max_inflight: int) -> None:
        self._queue: "queue.Queue[Optional[SearchRequest]]" = queue.Queue()
        self._completions: "queue.Queue[Optional[Tuple[Any, ...]]]" = \
            queue.Queue(maxsize=max(1, int(max_inflight)))
        # process-global id streams: a multi-tenant gateway runs many
        # servers into ONE trace recorder, so request/batch ids must be
        # unique across servers for the trace joins (gw.route links a
        # gateway rid to a serving rid) to be unambiguous
        self._rid = _RIDS
        self._batch_ids = _BATCH_IDS
        self._thread: Optional[threading.Thread] = None
        self._completer: Optional[threading.Thread] = None
        self._running = False
        self._accepting = False
        self._lock = threading.Lock()
        # gallery consistency: batch dispatch reads, update_gallery writes
        self._gallery_lock = _WriterPriorityLock()
        self._completer_alive = False
        self._stats = ServerStats(
            "requests", "queries", "batches", "batched_rows", "errors",
            "gallery_updates", "rows_updated", "deadline_misses",
            "backend_errors", "retries", "degraded_batches",
            "breaker_skips")

    @property
    def stats(self) -> Dict[str, int]:
        """Consistent copy of the raw counters (one lock acquisition);
        ``snapshot()`` adds derived rates and plan telemetry."""
        return self._stats.view()[0]

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "CamSearchServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._running = True
        self._accepting = True
        self._thread = threading.Thread(target=self._loop,
                                        name="cam-search-batcher", daemon=True)
        self._completer = threading.Thread(target=self._completion_loop,
                                           name="cam-search-completer",
                                           daemon=True)
        self._completer.start()
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        # close the front door under the lock BEFORE the shutdown
        # sentinel: any submit that won its lock race has its request in
        # the queue ahead of the sentinel, so the batcher still serves
        # it; later submits raise instead of enqueueing into a dead queue
        with self._lock:
            self._accepting = False
        self._running = False
        self._queue.put(None)               # wake the batcher
        self._thread.join()
        self._thread = None
        # batcher done: flush the completer.  The sentinel put must not
        # hang when the completion queue is full and the completer is
        # already dead (e.g. it crashed mid-run) — poll instead of block.
        while True:
            try:
                self._completions.put(None, timeout=0.05)
                break
            except queue.Full:
                if not self._completer_alive:
                    break
        self._completer.join()
        self._completer = None
        # a crashed completer strands undelivered batches in the queue;
        # fail them so no waiter blocks forever on a stopped server
        self._drain_completions()

    def _drain_completions(self) -> None:
        while True:
            try:
                item = self._completions.get_nowait()
            except queue.Empty:
                return
            if item is None:
                continue
            for r in item[0]:
                self._fail(r, RuntimeError(
                    "server stopped before completion"))

    def __enter__(self) -> "CamSearchServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API --------------------------------------------------------

    def submit(self, queries: np.ndarray, *,
               deadline_ms: Optional[float] = None) -> SearchRequest:
        """Enqueue a query block; returns a waitable request handle.

        Malformed blocks are rejected here, synchronously.
        ``deadline_ms`` overrides the server's default per-request
        deadline (0 = none for this request).
        """
        q = _validate_queries(self.plan, queries)
        rid = next(self._rid)
        now = time.perf_counter()
        budget = self._deadline_s if deadline_ms is None \
            else float(deadline_ms) / 1e3
        req = SearchRequest(rid=rid, queries=q,
                            deadline=now + budget if budget > 0 else None,
                            result=SearchResult(rid=rid, submitted_at=now))
        req._tspan = _trace.trace_begin(
            "request", "serving", {"rid": rid, "rows": int(q.shape[0])})
        with self._lock:
            if not self._accepting:
                raise RuntimeError("server not started")
            self._queue.put(req)
        return req

    def search(self, queries: np.ndarray,
               timeout: Optional[float] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Blocking search: submit + wait, raising the batch's error if
        execution failed.  Thread-safe; this is the worker-thread API.
        Best-match plans only — range plans use :meth:`match`."""
        if self.is_range:
            raise TypeError("range plan: use match() (boolean matches, "
                            "not values/indices)")
        res = self.submit(queries).wait(timeout)
        if res.error is not None:
            raise res.error
        return res.values, res.indices

    def match(self, queries: np.ndarray,
              timeout: Optional[float] = None) -> np.ndarray:
        """Blocking range search: the ``(rows, n)`` boolean match matrix
        for this request's query rows (range plans only) — each row of
        a forest gallery flags the tree branches the sample satisfies."""
        if not self.is_range:
            raise TypeError("best-match plan: use search()")
        res = self.submit(queries).wait(timeout)
        if res.error is not None:
            raise res.error
        return res.matches

    def update_gallery(self, indices, new_rows, *,
                       donate: bool = False) -> None:
        """Rewrite stored gallery rows between micro-batches, live.

        ``indices``: row ids to replace; ``new_rows``: ``(len(indices),
        dim)`` replacement rows — for *interval* range plans a
        ``(lo_rows, hi_rows)`` pair.  Applied under the writer side of
        the gallery lock: in-flight batches finish against the old
        gallery, every batch dispatched afterwards sees the new one
        (never a mix), and a pending update blocks new batches instead
        of starving behind steady traffic.  The rewrite itself is the
        plan's incremental :meth:`~repro.core.engine.SearchPlan.
        update_rows` — only the touched row tiles are re-prepared, so
        online-learning loops can call this at high rate.

        Thread-safe; raises (synchronously, nothing half-applied) on
        malformed indices/rows.  Ternary servers keep their care mask
        fixed — wildcards describe the program, not the data.

        ``donate=True`` forwards the engine's buffer-donation contract
        (in-place scatter, no full-gallery copy): pass it only when no
        code outside the server still reads the current gallery array
        (e.g. the array handed to the constructor was numpy, so the
        server owns its jax copy).
        """
        if self.is_range and len(self.plan.spec.pattern_args) == 2:
            if not (isinstance(new_rows, (tuple, list))
                    and len(new_rows) == 2):
                raise ValueError(
                    "interval range plan needs new_rows=(lo_rows, hi_rows)")
        self._gallery_lock.acquire_write()
        try:
            if self.is_range:
                multi = len(self.plan.spec.pattern_args) == 2
                stored = self.gallery if multi else self.gallery[0]
                updated = self.plan.update_rows(stored, indices, new_rows,
                                                donate=donate)
                self.gallery = tuple(updated) if multi else (updated,)
            else:
                self.gallery = self.plan.update_rows(
                    self.gallery, indices, new_rows, care=self.care,
                    donate=donate)
            n_rows = int(np.atleast_1d(np.asarray(indices)).size)
            self._stats.bump(gallery_updates=1, rows_updated=n_rows)
        finally:
            self._gallery_lock.release_write()

    def adopt_gallery(self, gallery, *, rows_updated: int = 0) -> None:
        """Swap in an externally-updated gallery wholesale.

        The replicated-serving write path: a
        :class:`~repro.serving.replica.ReplicaSet` computes **one**
        incremental :meth:`~repro.core.engine.SearchPlan.update_rows`
        against the jax gallery array its replicas share, then every
        replica server adopts the same resulting array — the plan's
        pattern memo (seeded once by ``update_rows``) serves the whole
        fleet, instead of each replica re-preparing its own copy.

        Validated like the constructor's ``gallery`` argument and
        applied under the writer side of the gallery lock (in-flight
        batches finish on the old version; every later batch sees the
        new one).  The care mask is fixed.  ``rows_updated`` is
        telemetry only.
        """
        stored = _coerce_stored(self.plan, self.is_range, gallery)
        self._gallery_lock.acquire_write()
        try:
            self.gallery = stored
            self._stats.bump(gallery_updates=1,
                             rows_updated=int(rows_updated))
        finally:
            self._gallery_lock.release_write()

    # -- telemetry ---------------------------------------------------------

    def dump_trace(self, path: str) -> str:
        """Write the process-wide execution trace as Chrome-tracing
        JSON (Perfetto-loadable).  The recorder is process-global —
        engine and gateway spans land in the same file — so this is a
        convenience mirror of :func:`repro.obs.dump`; tracing must be
        enabled (``REPRO_TRACE=...`` or :func:`repro.obs.enable`)."""
        return _trace.dump(path)

    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time stats: throughput-ready counters plus latency
        percentiles (over a bounded recent window) and the mean batch
        fill (rows per launched batch).  The counters are one
        consistent view — every related group was updated atomically
        and the whole copy is taken in one lock acquisition."""
        out, lat, qw, sv = self._stats.view_windows()
        out["avg_batch_fill"] = (out["batched_rows"] / out["batches"]
                                 if out["batches"] else 0.0)
        out.update(ServerStats.percentiles(lat))
        # end-to-end latency attribution: queue-wait (submit -> batch
        # dispatch) vs service (dispatch -> delivery)
        out.update(ServerStats.percentiles(qw, prefix="queue_wait_"))
        out.update(ServerStats.percentiles(sv, prefix="service_"))
        spec = self.plan.spec
        plan_counters = self.plan.counters()
        out["plan"] = {"batch": self.plan.batch, "shards": self.plan.shards,
                       "backend": self.plan.backend,
                       "packed": self.plan.packed,
                       "family": self.plan.family,
                       "ternary": getattr(spec, "care_arg", None) is not None,
                       "metric": spec.metric,
                       "executions": plan_counters["executions"],
                       "chunks_run": plan_counters["chunks_run"],
                       "row_updates": plan_counters["row_updates"],
                       "row_update_fallbacks":
                           plan_counters["row_update_fallbacks"]}
        if self.is_range:
            out["plan"]["mode"] = spec.mode
        else:
            out["plan"]["k"] = spec.k
        return out

    def health(self) -> Dict[str, Any]:
        """Liveness/degradation endpoint: breaker state, fault-model
        telemetry, deadline-miss rate, and the degraded chain.

        ``status`` is ``"ok"`` while the primary backend serves,
        ``"degraded"`` once the breaker is open or any batch has been
        served by a fallback level.
        """
        st, _, qw, sv = self._stats.view_windows()
        with self._lock:
            fallbacks = self._fallbacks
        br = self._breaker.snapshot()
        misses = st["deadline_misses"]
        degraded = br["state"] != "closed" or st["degraded_batches"] > 0
        out: Dict[str, Any] = {
            "status": "degraded" if degraded else "ok",
            "running": self._running,
            "breaker": br,
            "deadline_miss_rate":
                misses / max(1, misses + st["requests"]),
            "deadline_misses": misses,
            "backend_errors": st["backend_errors"],
            "retries": st["retries"],
            "degraded_batches": st["degraded_batches"],
            "breaker_skips": st["breaker_skips"],
            "fallback_levels":
                None if fallbacks is None else [n for n, _ in fallbacks],
            "latency": {**ServerStats.percentiles(qw, prefix="queue_wait_"),
                        **ServerStats.percentiles(sv, prefix="service_")},
        }
        if self._faults is not None:
            spec = self.plan.spec
            out["fault_model"] = {
                "seed": self._faults.seed,
                "p_stuck": self._faults.p_stuck,
                "p_flip": self._faults.p_flip,
                "sigma": self._faults.sigma,
                "drift": self._faults.drift, "t": self._faults.t,
                "epoch": self._faults.epoch,
                "cells": self._faults.cell_fault_counts(
                    (spec.n, spec.dim)),
            }
        return out
