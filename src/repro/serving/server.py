"""Continuous-batching CAM search server.

The LM serving driver (:mod:`repro.launch.serve`) batches *sequences*
at decode-step granularity; this module applies the same idea to CAM
similarity search, the paper's actual workload.  Many worker threads
(RPC handlers, classifier shards, HDC encoders) submit small KNN / HDC
query blocks concurrently; a single batcher thread coalesces whatever
is pending into **plan-sized micro-batches** and drives ONE cached
:class:`~repro.core.engine.SearchPlan` — single-device or sharded
across a ``("data",)`` device mesh — so the jitted executable, the
memoised prepared gallery, and the device mesh are shared by every
request in the process.

Request lifecycle::

    client thread              batcher thread             completion thread
    -------------              --------------             -----------------
    search(q) ─► queue ───────► drain pending (≤ batch    plan.finalize(...)
      blocks on event           rows, ≤ max_wait linger)  syncs the device +
                                stack rows                cross-shard merge,
                                plan.dispatch(...) ─────► scatter rows to
      results ◄─────────────────────────────────────────  requests, set
                                (loops immediately: next  events, record
                                batch dispatches while the latency
                                device runs the previous)

The batcher never blocks on device results: ``plan.dispatch`` enqueues
the micro-batch and returns a ``PendingSearch`` of async jax arrays.  A
bounded completion queue hands it to the completion thread, whose
``plan.finalize`` blocks on the transfer (and runs the host-side
cross-shard merge for sharded plans) before scattering rows back to
their requests and waking the clients — host-side batching overlaps
device compute, and the bound provides backpressure when clients outrun
the device.  (``plan.execute`` is ``finalize(dispatch(...))`` — calling
it in the batcher would serialise the pipeline on device results.)

Coalescing is row-granular: a request carrying 3 query rows and one
carrying 61 share a 64-row micro-batch; an oversized request simply
spans chunks inside the plan (which micro-batches internally).
Results are identical to calling the plan directly — batching changes
scheduling, never arithmetic.

Ternary (TCAM wildcard) programs are first-class served workloads:
construct the server with ``care_mask=...`` and every batch carries the
per-pattern wildcard mask alongside the gallery (both memoised behind
the plan's pattern cache; binary/bipolar plans additionally run
bit-packed — see the packed section of ``docs/engine.md``).

Live gallery mutation
---------------------
:meth:`CamSearchServer.update_gallery` rewrites stored rows **between
micro-batches** while the server keeps serving: a writer-priority
reader/writer lock covers the batcher's dispatch (reader) and the
update (writer), so every dispatched batch sees exactly one gallery
version — a request's rows are never computed against a half-applied
update — and a pending writer blocks *new* batches rather than starving
behind a steady request stream.  The row rewrite itself is the engine's
incremental :meth:`~repro.core.engine.SearchPlan.update_rows` path
(only the touched row tiles of the memoised prepared layout are
re-encoded/re-packed), which is what makes online HDC retraining —
misclassified queries re-bundled into class vectors, then re-served —
cheap against live traffic (see ``repro.hdc`` and ``docs/hdc.md``).
"""

from __future__ import annotations

import itertools
import queue
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.compiler import CompiledCamProgram
from ..core.engine import RangePlan, SearchPlan

__all__ = ["SearchRequest", "SearchResult", "CamSearchServer"]


class _WriterPriorityLock:
    """A reader/writer lock where waiting writers block new readers.

    The batcher takes the read side around every batch dispatch (many
    batches may overlap the completion pipeline, but dispatch itself is
    the only point that reads the gallery); ``update_gallery`` takes
    the write side.  Writer priority matters under load: a steady
    request stream keeps the read side continuously busy, and a plain
    RW lock would starve the update forever.
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writers_waiting = 0
        self._writing = False

    def acquire_read(self) -> None:
        with self._cond:
            while self._writing or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if not self._readers:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writing or self._readers:
                    self._cond.wait()
            finally:
                self._writers_waiting -= 1
            self._writing = True

    def release_write(self) -> None:
        with self._cond:
            self._writing = False
            self._cond.notify_all()


@dataclass
class SearchResult:
    """Per-request outcome: top-k values/indices (best-match plans) or
    the boolean match rows (range plans), row-aligned with the
    submitted queries, plus queueing/batching latency telemetry."""

    rid: int
    values: Optional[np.ndarray] = None
    indices: Optional[np.ndarray] = None
    #: range-plan requests: (rows, n) boolean match matrix
    matches: Optional[np.ndarray] = None
    error: Optional[BaseException] = None
    submitted_at: float = 0.0
    completed_at: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.completed_at - self.submitted_at


@dataclass
class SearchRequest:
    """One in-flight query block (``queries``: ``(rows, dim)``)."""

    rid: int
    queries: np.ndarray
    result: SearchResult
    _done: threading.Event = field(default_factory=threading.Event)

    def wait(self, timeout: Optional[float] = None) -> SearchResult:
        if not self._done.wait(timeout):
            raise TimeoutError(f"search request {self.rid} timed out")
        return self.result


class CamSearchServer:
    """Row-granular continuous batching over one shared ``SearchPlan``.

    Parameters
    ----------
    program:
        A :class:`CompiledCamProgram` whose ``engine_plan`` is set (any
        pure similarity *or* range program), or a bare
        :class:`SearchPlan` / :class:`RangePlan`.  Range plans make the
        server a match server: each request's result carries the
        boolean ``matches`` rows instead of values/indices — this is
        the decision-forest serving path (one interval row per tree
        branch; see ``docs/forest.md``).
    gallery:
        The stored patterns — or, for an *interval* range plan, the
        ``(lo, hi)`` pair of per-row bound arrays.  Converted to jax
        arrays once so the plan's pattern memo (and, for sharded plans,
        the device layout) is hit by every batch.
    care_mask:
        Per-pattern TCAM wildcard mask ``(n, dim)`` — required when the
        plan's program is ternary (a care-mask operand in its spec),
        rejected otherwise.  Non-zero cells are compared, zero cells
        never mismatch; one-shot-learning galleries store the bits the
        class exemplars agree on and wildcard the rest.
    max_wait_ms:
        Linger: how long the batcher waits for more rows after the
        first pending request before launching a partial batch.
    max_batch:
        Rows per coalesced batch; defaults to the plan's micro-batch
        size (anything larger would be re-chunked inside the plan
        anyway).
    max_inflight:
        Bound on dispatched-but-unsynced batches (the completion
        queue); backpressure against clients outrunning the device.
    """

    def __init__(self, program: Any, gallery: np.ndarray, *,
                 care_mask: Optional[np.ndarray] = None,
                 max_wait_ms: float = 2.0, max_batch: Optional[int] = None,
                 max_inflight: int = 4):
        if isinstance(program, CompiledCamProgram):
            plan = program.engine_plan
            if plan is None:
                raise ValueError(
                    "program has no engine plan (not a pure similarity "
                    "program); the search server needs a SearchPlan")
        elif isinstance(program, SearchPlan):
            plan = program
        else:
            raise TypeError(f"expected CompiledCamProgram or SearchPlan, "
                            f"got {type(program).__name__}")
        import jax.numpy as jnp
        self.plan = plan
        self.is_range = isinstance(plan, RangePlan)
        if self.is_range:
            if care_mask is not None:
                raise ValueError("care_mask only applies to ternary "
                                 "best-match plans, not range plans")
            n_pats = len(plan.spec.pattern_args)
            if n_pats == 2:       # interval mode: gallery is (lo, hi)
                if not (isinstance(gallery, (tuple, list))
                        and len(gallery) == 2):
                    raise ValueError(
                        "interval range plan needs gallery=(lo, hi)")
                self.gallery = tuple(jnp.asarray(g) for g in gallery)
            else:
                self.gallery = (jnp.asarray(gallery),)
            for g in self.gallery:
                if tuple(g.shape) != (plan.spec.n, plan.spec.dim):
                    raise ValueError(
                        f"stored operand shape {tuple(g.shape)} != plan "
                        f"geometry ({plan.spec.n}, {plan.spec.dim})")
            self.care = None
        else:
            self.gallery = jnp.asarray(gallery)
            if plan.spec.care_arg is not None:
                if care_mask is None:
                    raise ValueError("ternary plan (TCAM wildcard search) "
                                     "needs a care_mask")
                care = np.asarray(care_mask)
                if care.shape != (plan.spec.n, plan.spec.dim):
                    raise ValueError(
                        f"care_mask shape {care.shape} != gallery geometry "
                        f"({plan.spec.n}, {plan.spec.dim})")
                # jax array for the same reason as the gallery: the plan's
                # pattern memo keys on the (gallery, care) pair of arrays
                self.care = jnp.asarray(care)
            elif care_mask is not None:
                raise ValueError("care_mask given but the plan's program "
                                 "has no care operand (not a ternary "
                                 "search)")
            else:
                self.care = None
        self.max_wait = max_wait_ms / 1e3
        self.max_batch = int(max_batch or plan.batch)
        self._init_state(max_inflight)

    def _init_state(self, max_inflight: int) -> None:
        self._queue: "queue.Queue[Optional[SearchRequest]]" = queue.Queue()
        self._completions: "queue.Queue[Optional[Tuple[Any, ...]]]" = \
            queue.Queue(maxsize=max(1, int(max_inflight)))
        self._rid = itertools.count()
        self._thread: Optional[threading.Thread] = None
        self._completer: Optional[threading.Thread] = None
        self._running = False
        self._accepting = False
        self._lock = threading.Lock()
        # gallery consistency: batch dispatch reads, update_gallery writes
        self._gallery_lock = _WriterPriorityLock()
        # bounded: a long-lived server must not grow per-request state
        self._latencies: "deque[float]" = deque(maxlen=4096)
        self.stats: Dict[str, Any] = {
            "requests": 0, "queries": 0, "batches": 0,
            "batched_rows": 0, "errors": 0,
            "gallery_updates": 0, "rows_updated": 0,
        }

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "CamSearchServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._running = True
        self._accepting = True
        self._thread = threading.Thread(target=self._loop,
                                        name="cam-search-batcher", daemon=True)
        self._completer = threading.Thread(target=self._completion_loop,
                                           name="cam-search-completer",
                                           daemon=True)
        self._completer.start()
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        # close the front door under the lock BEFORE the shutdown
        # sentinel: any submit that won its lock race has its request in
        # the queue ahead of the sentinel, so the batcher still serves
        # it; later submits raise instead of enqueueing into a dead queue
        with self._lock:
            self._accepting = False
        self._running = False
        self._queue.put(None)               # wake the batcher
        self._thread.join()
        self._thread = None
        self._completions.put(None)         # batcher done: flush completer
        self._completer.join()
        self._completer = None

    def __enter__(self) -> "CamSearchServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API --------------------------------------------------------

    def submit(self, queries: np.ndarray) -> SearchRequest:
        """Enqueue a query block; returns a waitable request handle.

        Malformed blocks are rejected here, synchronously — one bad
        request must never poison the innocent requests it would have
        been coalesced with.
        """
        q = np.asarray(queries)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2:
            raise ValueError(f"queries must be (rows, dim), got {q.shape}")
        if q.shape[0] == 0:
            raise ValueError("empty query block")
        dim = self.plan.spec.dim
        if q.shape[1] != dim:
            raise ValueError(
                f"query feature dimension {q.shape[1]} != plan dim {dim}")
        rid = next(self._rid)
        req = SearchRequest(rid=rid, queries=q,
                            result=SearchResult(rid=rid,
                                                submitted_at=time.perf_counter()))
        with self._lock:
            if not self._accepting:
                raise RuntimeError("server not started")
            self._queue.put(req)
        return req

    def search(self, queries: np.ndarray,
               timeout: Optional[float] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Blocking search: submit + wait, raising the batch's error if
        execution failed.  Thread-safe; this is the worker-thread API.
        Best-match plans only — range plans use :meth:`match`."""
        if self.is_range:
            raise TypeError("range plan: use match() (boolean matches, "
                            "not values/indices)")
        res = self.submit(queries).wait(timeout)
        if res.error is not None:
            raise res.error
        return res.values, res.indices

    def match(self, queries: np.ndarray,
              timeout: Optional[float] = None) -> np.ndarray:
        """Blocking range search: the ``(rows, n)`` boolean match matrix
        for this request's query rows (range plans only) — each row of
        a forest gallery flags the tree branches the sample satisfies."""
        if not self.is_range:
            raise TypeError("best-match plan: use search()")
        res = self.submit(queries).wait(timeout)
        if res.error is not None:
            raise res.error
        return res.matches

    def update_gallery(self, indices, new_rows, *,
                       donate: bool = False) -> None:
        """Rewrite stored gallery rows between micro-batches, live.

        ``indices``: row ids to replace; ``new_rows``: ``(len(indices),
        dim)`` replacement rows — for *interval* range plans a
        ``(lo_rows, hi_rows)`` pair.  Applied under the writer side of
        the gallery lock: in-flight batches finish against the old
        gallery, every batch dispatched afterwards sees the new one
        (never a mix), and a pending update blocks new batches instead
        of starving behind steady traffic.  The rewrite itself is the
        plan's incremental :meth:`~repro.core.engine.SearchPlan.
        update_rows` — only the touched row tiles are re-prepared, so
        online-learning loops can call this at high rate.

        Thread-safe; raises (synchronously, nothing half-applied) on
        malformed indices/rows.  Ternary servers keep their care mask
        fixed — wildcards describe the program, not the data.

        ``donate=True`` forwards the engine's buffer-donation contract
        (in-place scatter, no full-gallery copy): pass it only when no
        code outside the server still reads the current gallery array
        (e.g. the array handed to the constructor was numpy, so the
        server owns its jax copy).
        """
        if self.is_range and len(self.plan.spec.pattern_args) == 2:
            if not (isinstance(new_rows, (tuple, list))
                    and len(new_rows) == 2):
                raise ValueError(
                    "interval range plan needs new_rows=(lo_rows, hi_rows)")
        self._gallery_lock.acquire_write()
        try:
            if self.is_range:
                multi = len(self.plan.spec.pattern_args) == 2
                stored = self.gallery if multi else self.gallery[0]
                updated = self.plan.update_rows(stored, indices, new_rows,
                                                donate=donate)
                self.gallery = tuple(updated) if multi else (updated,)
            else:
                self.gallery = self.plan.update_rows(
                    self.gallery, indices, new_rows, care=self.care,
                    donate=donate)
            n_rows = int(np.atleast_1d(np.asarray(indices)).size)
            with self._lock:
                self.stats["gallery_updates"] += 1
                self.stats["rows_updated"] += n_rows
        finally:
            self._gallery_lock.release_write()

    # -- batcher -----------------------------------------------------------

    def _drain(self, first: SearchRequest) -> List[SearchRequest]:
        """Coalesce pending requests after ``first`` into one batch:
        up to ``max_batch`` rows, lingering at most ``max_wait``."""
        batch = [first]
        rows = first.queries.shape[0]
        deadline = time.perf_counter() + self.max_wait
        while rows < self.max_batch:
            remaining = deadline - time.perf_counter()
            try:
                req = self._queue.get(
                    timeout=max(remaining, 0) if remaining > 0 else None,
                    block=remaining > 0)
            except queue.Empty:
                break
            if req is None:                 # shutdown sentinel
                self._queue.put(None)       # leave it for the main loop
                break
            batch.append(req)
            rows += req.queries.shape[0]
        return batch

    def _loop(self) -> None:
        while True:
            req = self._queue.get()
            if req is None:
                if self._running:
                    continue                # stray sentinel from a drain
                break
            batch = self._drain(req)
            self._execute_batch(batch)
        # drain anything left after shutdown so no client blocks forever
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if req is not None:
                self._fail(req, RuntimeError("server stopped"))

    def _execute_batch(self, batch: Sequence[SearchRequest]) -> None:
        """Dispatch one coalesced batch; the device result (async jax
        arrays) goes to the completion thread, so the batcher is free to
        coalesce and dispatch the next batch immediately."""
        # reader side of the gallery lock: the whole read-gallery +
        # dispatch sequence sees exactly one gallery version, and a
        # waiting update_gallery writer gets in before the *next* batch
        self._gallery_lock.acquire_read()
        try:
            rows = np.concatenate([r.queries for r in batch], axis=0)
            spec = self.plan.spec
            if self.is_range:
                n_args = max(spec.query_arg, *spec.pattern_args) + 1
                inputs: List[Any] = [None] * n_args
                inputs[spec.query_arg] = rows
                for pos, g in zip(spec.pattern_args, self.gallery):
                    inputs[pos] = g
            else:
                n_args = max(spec.query_arg, spec.pattern_arg,
                             -1 if spec.care_arg is None
                             else spec.care_arg) + 1
                inputs = [None] * n_args
                inputs[spec.query_arg] = rows
                inputs[spec.pattern_arg] = self.gallery
                if spec.care_arg is not None:
                    inputs[spec.care_arg] = self.care
            pending = self.plan.dispatch(*inputs)
        except BaseException as e:          # noqa: BLE001 — fanned out
            for r in batch:
                self._fail(r, e)
            return
        finally:
            self._gallery_lock.release_read()
        with self._lock:
            self.stats["batches"] += 1
            self.stats["batched_rows"] += rows.shape[0]
        self._completions.put((batch, pending, rows.shape[0]))  # backpressured

    def _completion_loop(self) -> None:
        while True:
            item = self._completions.get()
            if item is None:
                break
            batch, pending, rows = item
            try:
                if self.is_range:
                    matches = np.asarray(self.plan.finalize(pending))
                    matches = matches.reshape(rows, -1)
                    values = indices = None
                else:
                    values, indices = self.plan.finalize(pending)
                    # finalize shapes outputs for the *compiled module*
                    # (which may have been traced with 1-D or stacked
                    # queries); the scatter below is strictly row-major
                    values = np.asarray(values).reshape(rows, -1)
                    indices = np.asarray(indices).reshape(rows, -1)
            except BaseException as e:          # noqa: BLE001 — fanned out
                for r in batch:
                    self._fail(r, e)
                continue
            now = time.perf_counter()
            off = 0
            with self._lock:
                self.stats["requests"] += len(batch)
                self.stats["queries"] += rows
            for r in batch:
                m = r.queries.shape[0]
                if self.is_range:
                    r.result.matches = matches[off:off + m]
                else:
                    r.result.values = values[off:off + m]
                    r.result.indices = indices[off:off + m]
                r.result.completed_at = now
                off += m
                with self._lock:
                    self._latencies.append(r.result.latency_s)
                r._done.set()

    def _fail(self, req: SearchRequest, err: BaseException) -> None:
        req.result.error = err
        req.result.completed_at = time.perf_counter()
        with self._lock:
            self.stats["errors"] += 1
        req._done.set()

    # -- telemetry ---------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time stats: throughput-ready counters plus latency
        percentiles (over a bounded recent window) and the mean batch
        fill (rows per launched batch)."""
        with self._lock:
            lat = sorted(self._latencies)
            out = dict(self.stats)
        out["avg_batch_fill"] = (out["batched_rows"] / out["batches"]
                                 if out["batches"] else 0.0)
        if lat:
            out["p50_ms"] = 1e3 * lat[len(lat) // 2]
            out["p95_ms"] = 1e3 * lat[min(len(lat) - 1,
                                          int(len(lat) * 0.95))]
        spec = self.plan.spec
        out["plan"] = {"batch": self.plan.batch, "shards": self.plan.shards,
                       "backend": self.plan.backend,
                       "packed": self.plan.packed,
                       "family": "range" if self.is_range else "search",
                       "ternary": getattr(spec, "care_arg", None) is not None,
                       "metric": spec.metric,
                       "executions": self.plan.executions,
                       "chunks_run": self.plan.chunks_run,
                       "row_updates": self.plan.row_updates,
                       "row_update_fallbacks":
                           self.plan.row_update_fallbacks}
        if self.is_range:
            out["plan"]["mode"] = spec.mode
        else:
            out["plan"]["k"] = spec.k
        return out
