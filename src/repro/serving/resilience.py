"""Serving resilience: breaker, fallback chain, locks, degraded dispatch.

The failure-domain machinery :class:`~repro.serving.CamSearchServer`
mixes in (see ``docs/robustness.md``):

* :class:`_CircuitBreaker` — closed → open → half-open over the
  primary backend (also reused per tenant by the multi-tenant
  gateway, where it guards admission instead of dispatch).
* :class:`_InterpreterExecutor` — the last-resort fallback level.
* :class:`_WriterPriorityLock` — reader/writer lock where waiting
  writers block new readers (batch dispatch reads, gallery updates
  write; the gateway's replica sets reuse it for update fan-out).
* :class:`_ResilienceMixin` — the degraded dispatch walk: retry with
  exponential backoff per level, breaker gating of the primary, and
  the synchronous finalize-failure rescue.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["_CircuitBreaker", "_InterpreterExecutor",
           "_WriterPriorityLock", "_ResilienceMixin"]


class _CircuitBreaker:
    """Closed → open → half-open circuit breaker over the primary backend.

    ``threshold`` consecutive primary failures trip the breaker
    **open**; while open, batches go straight to the degraded chain.
    After ``cooldown`` seconds the next batch runs as a **half-open**
    probe against the primary: success closes the breaker, failure
    re-opens it (and restarts the cooldown).  ``threshold=0`` disables
    the breaker entirely (every batch tries the primary).
    """

    def __init__(self, threshold: int, cooldown_s: float):
        self.threshold = int(threshold)
        self.cooldown = float(cooldown_s)
        self.state = "closed"
        self.consecutive = 0
        self.trips = 0
        self.probes = 0
        self.recoveries = 0
        self._opened_at = 0.0
        self._lock = threading.Lock()

    @property
    def enabled(self) -> bool:
        return self.threshold > 0

    def allow_primary(self) -> bool:
        if not self.enabled:
            return True
        with self._lock:
            if self.state == "closed":
                return True
            if time.perf_counter() - self._opened_at >= self.cooldown:
                self.state = "half-open"
                self.probes += 1
                return True
            return False

    def record_failure(self) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.consecutive += 1
            if self.state == "half-open" or \
                    self.consecutive >= self.threshold:
                if self.state != "open":
                    self.trips += 1
                self.state = "open"
                self._opened_at = time.perf_counter()

    def record_success(self) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.consecutive = 0
            if self.state != "closed":
                self.state = "closed"
                self.recoveries += 1

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"state": self.state, "threshold": self.threshold,
                    "consecutive_failures": self.consecutive,
                    "trips": self.trips, "probes": self.probes,
                    "recoveries": self.recoveries,
                    "cooldown_ms": 1e3 * self.cooldown}


class _InterpreterExecutor:
    """Last-resort fallback level: the IR interpreter.

    Synthesises a fused module for the plan's spec
    (:func:`~repro.core.engine.module_for_spec`) and executes it with
    :func:`~repro.core.executor.execute_module`, chunked to the traced
    query count.  Synchronous (``dispatch`` computes eagerly) and slow,
    but it has no jit/pallas/device dependency at all — when every
    compiled level is failing, correctness-over-latency is the only
    remaining contract.  Fault models corrupt the stored operands here
    exactly like the compiled levels, so the degraded results match.
    """

    backend = "interpreter"

    def __init__(self, spec):
        from ..core.engine import RangeSpec, module_for_spec
        self.spec = spec
        self.is_range = isinstance(spec, RangeSpec)
        self._module = module_for_spec(spec)

    def dispatch(self, *inputs, faults=None):
        from ..core.executor import execute_module
        spec = self.spec
        rows = np.asarray(inputs[spec.query_arg], np.float32)
        if self.is_range:
            stored = tuple(np.asarray(inputs[i], np.float32)
                           for i in spec.pattern_args)
        else:
            stored = (np.asarray(inputs[spec.pattern_arg], np.float32),)
            if spec.care_arg is not None:
                stored += (np.asarray(inputs[spec.care_arg], np.float32),)
        if faults is not None and not faults.is_null:
            stored = tuple(np.asarray(s, np.float32)
                           for s in faults.corrupt_stored(stored, spec))
        m = spec.m
        outs = []
        for s in range(0, rows.shape[0], m):
            chunk = rows[s:s + m]
            valid = chunk.shape[0]
            if valid < m:        # pad the ragged tail to the traced shape
                chunk = np.concatenate(
                    [chunk, np.zeros((m - valid, chunk.shape[1]),
                                     chunk.dtype)])
            res = execute_module(self._module, chunk, *stored)
            outs.append((tuple(np.asarray(r) for r in res), valid))
        return outs

    def finalize(self, pending):
        if self.is_range:
            return np.concatenate([r[0][:v] for r, v in pending], axis=0)
        return (np.concatenate([r[0][:v] for r, v in pending], axis=0),
                np.concatenate([r[1][:v] for r, v in pending], axis=0))


class _WriterPriorityLock:
    """A reader/writer lock where waiting writers block new readers.

    The batcher takes the read side around every batch dispatch (many
    batches may overlap the completion pipeline, but dispatch itself is
    the only point that reads the gallery); ``update_gallery`` takes
    the write side.  Writer priority matters under load: a steady
    request stream keeps the read side continuously busy, and a plain
    RW lock would starve the update forever.
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writers_waiting = 0
        self._writing = False

    def acquire_read(self) -> None:
        with self._cond:
            while self._writing or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if not self._readers:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writing or self._readers:
                    self._cond.wait()
            finally:
                self._writers_waiting -= 1
            self._writing = True

    def release_write(self) -> None:
        with self._cond:
            self._writing = False
            self._cond.notify_all()


class _ResilienceMixin:
    """Degraded dispatch for :class:`~repro.serving.CamSearchServer`.

    Expects the host class to provide ``plan``, ``_stats``
    (:class:`~.telemetry.ServerStats`), ``_breaker``, ``_faults``,
    ``_fault_injector``, ``_max_retries``, ``_backoff_s``,
    ``_fallbacks``, ``_lock``, ``_gallery_lock`` and ``_inputs_for``.
    """

    def _build_fallbacks(self) -> List[Tuple[str, Any]]:
        """Degraded chain below the primary plan, most- to least-capable:
        single-device (for sharded primaries) → jnp (for pallas) → jnp
        unpacked (for packed) → IR interpreter.  Every level is an
        ordinary plan-cache citizen compiled for the same spec/batch."""
        from ..core.engine import CompositePlan, get_plan, module_for_spec
        spec = self.plan.spec
        mod = module_for_spec(spec)
        chain: List[Tuple[str, Any]] = []

        def add(name: str, **kw) -> None:
            try:
                p = get_plan(mod, batch=self.plan.batch, **kw)
            except Exception:       # level not buildable here: skip it
                return
            if p is not None and p is not self.plan and \
                    all(p is not e for _, e in chain):
                chain.append((name, p))

        if isinstance(self.plan, CompositePlan):
            # composite primaries degrade to the *exact* flat search
            # first — module_for_spec resolved the flat equivalent above
            add("jnp-flat", backend="jnp", pack=self.plan.packed,
                shards=self.plan.shards)
        if self.plan.shards > 1:
            add("jnp-single", backend="jnp", pack=self.plan.packed)
        if self.plan.backend == "pallas":
            add("jnp", backend="jnp", pack=self.plan.packed)
        if self.plan.packed:
            add("jnp-unpacked", backend="jnp", pack=False)
        chain.append(("interpreter", _InterpreterExecutor(spec)))
        return chain

    def _levels(self) -> List[Tuple[str, Any]]:
        with self._lock:
            if self._fallbacks is None:
                self._fallbacks = self._build_fallbacks()
            fallbacks = self._fallbacks
        return [("primary", self.plan)] + fallbacks

    def _dispatch_resilient(self, rows: np.ndarray) -> Tuple[Any, Any]:
        """Dispatch with retry, breaker, and degraded fallback.

        Walks the level chain (skipping the primary while the breaker
        is open), giving each level ``max_retries`` extra attempts with
        exponential backoff.  Returns ``(executor, pending)`` from the
        first level that accepts the dispatch; raises the last error
        only when *every* level (including the interpreter) failed.
        """
        levels = self._levels()
        start = 0
        if not self._breaker.allow_primary():
            start = 1
            self._stats.bump(breaker_skips=1)
        last: Optional[BaseException] = None
        for li in range(start, len(levels)):
            name, ex = levels[li]
            primary = li == 0
            for attempt in range(self._max_retries + 1):
                try:
                    if self._fault_injector is not None:
                        self._fault_injector(name)
                    pending = ex.dispatch(*self._inputs_for(ex.spec, rows),
                                          faults=self._faults)
                except BaseException as e:      # noqa: BLE001 — retried
                    last = e
                    if primary:
                        self._breaker.record_failure()
                    if attempt < self._max_retries:
                        # one bump: a reader never sees the error
                        # without its retry (or vice versa)
                        self._stats.bump(backend_errors=1, retries=1)
                        if self._backoff_s:
                            time.sleep(self._backoff_s * (2 ** attempt))
                    else:
                        self._stats.bump(backend_errors=1)
                    continue
                if primary:
                    self._breaker.record_success()
                else:
                    self._stats.bump(degraded_batches=1)
                return ex, pending
        raise last if last is not None else RuntimeError("no dispatch level")

    def _rescue(self, batch, rows: np.ndarray, failed: Any):
        """Synchronous finalize-failure recovery in the completion
        thread: re-run the batch through the levels below the one that
        failed (under the gallery read lock, so the retry still sees
        one gallery version)."""
        levels = self._levels()
        idx = next((i for i, (_, ex) in enumerate(levels)
                    if ex is failed), -1)
        self._gallery_lock.acquire_read()
        try:
            for name, ex in levels[idx + 1:]:
                try:
                    if self._fault_injector is not None:
                        self._fault_injector(name)
                    pending = ex.dispatch(
                        *self._inputs_for(ex.spec, rows),
                        faults=self._faults)
                    out = ex.finalize(pending)
                except BaseException:       # noqa: BLE001 — next level
                    self._stats.bump(backend_errors=1)
                    continue
                self._stats.bump(degraded_batches=1)
                return out
        finally:
            self._gallery_lock.release_read()
        return None
