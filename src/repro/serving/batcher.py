"""Serving batcher: coalescing loop + async completion pipeline.

:class:`_BatcherMixin` owns the two server threads — the batcher
(drain pending requests into plan-sized micro-batches, dispatch) and
the completer (finalize async device results, scatter rows back to
requests) — plus the failure paths that settle a request.  Mixed into
:class:`~repro.serving.CamSearchServer`; expects the host class to
provide ``plan``, ``gallery``, ``care``, ``is_range``, ``max_batch``,
``max_wait``, ``_queue``, ``_completions``, ``_gallery_lock``,
``_stats``, ``_breaker``, ``_completer_alive``, ``_running`` and the
resilience mixin's ``_dispatch_resilient`` / ``_rescue``.
"""

from __future__ import annotations

import queue
import time
from typing import Any, List, Sequence, Tuple

import numpy as np

from ..obs.trace import trace_begin, trace_span, tracer
from .telemetry import SearchRequest

__all__ = ["_BatcherMixin"]


class _BatcherMixin:
    """Batching/completion thread bodies for the search server."""

    def _drain(self, first: SearchRequest) -> List[SearchRequest]:
        """Coalesce pending requests after ``first`` into one batch:
        up to ``max_batch`` rows, lingering at most ``max_wait``."""
        fill = trace_begin("batch.fill", "serving")
        batch = [first]
        rows = first.queries.shape[0]
        deadline = time.perf_counter() + self.max_wait
        while rows < self.max_batch:
            remaining = deadline - time.perf_counter()
            try:
                req = self._queue.get(
                    timeout=max(remaining, 0) if remaining > 0 else None,
                    block=remaining > 0)
            except queue.Empty:
                break
            if req is None:                 # shutdown sentinel
                self._queue.put(None)       # leave it for the main loop
                break
            batch.append(req)
            rows += req.queries.shape[0]
        if fill is not None:
            fill.end({"rows": int(rows), "requests": len(batch)})
        return batch

    def _loop(self) -> None:
        while True:
            req = self._queue.get()
            if req is None:
                if self._running:
                    continue                # stray sentinel from a drain
                break
            batch = self._drain(req)
            self._execute_batch(batch)
        # drain anything left after shutdown so no client blocks forever
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if req is not None:
                self._fail(req, RuntimeError("server stopped"))

    def _inputs_for(self, spec, rows: np.ndarray) -> List[Any]:
        """Module-argument list for one executor's spec (fallback levels
        may order arguments differently from the primary plan)."""
        if self.is_range:
            n_args = max(spec.query_arg, *spec.pattern_args) + 1
            inputs: List[Any] = [None] * n_args
            inputs[spec.query_arg] = rows
            for pos, g in zip(spec.pattern_args, self.gallery):
                inputs[pos] = g
        else:
            n_args = max(spec.query_arg, spec.pattern_arg,
                         -1 if spec.care_arg is None
                         else spec.care_arg) + 1
            inputs = [None] * n_args
            inputs[spec.query_arg] = rows
            inputs[spec.pattern_arg] = self.gallery
            if spec.care_arg is not None:
                inputs[spec.care_arg] = self.care
        return inputs

    def _execute_batch(self, batch: Sequence[SearchRequest]) -> None:
        """Dispatch one coalesced batch; the device result (async jax
        arrays) goes to the completion thread, so the batcher is free to
        coalesce and dispatch the next batch immediately."""
        # expire dead-on-arrival requests first: a missed deadline costs
        # a TimeoutError, never the rest of the batch's slot
        now = time.perf_counter()
        live = []
        for r in batch:
            if r.deadline is not None and now > r.deadline:
                self._fail_timeout(r)
            else:
                live.append(r)
        if not live:
            return
        batch = live
        bid = next(self._batch_ids)
        # reader side of the gallery lock: the whole read-gallery +
        # dispatch sequence sees exactly one gallery version, and a
        # waiting update_gallery writer gets in before the *next* batch
        self._gallery_lock.acquire_read()
        try:
            with trace_span("batch.dispatch", "serving",
                            args=None if not tracer.enabled else
                            {"batch": bid, "requests": len(batch)}):
                rows = np.concatenate([r.queries for r in batch], axis=0)
                executor, pending = self._dispatch_resilient(rows)
            err = None
        except BaseException as e:          # noqa: BLE001 — fanned out
            err = e
        finally:
            self._gallery_lock.release_read()
        if err is not None:
            # failed OUTSIDE the lock: _fail settles the request, which
            # fires done-callbacks synchronously — a gateway callback
            # takes its replica-set routing lock, whose write side
            # (fan_out) may in turn be waiting on OUR gallery write
            # lock.  Settling under the read lock closes that cycle
            # into a deadlock.
            for r in batch:
                self._fail(r, err)
            return
        now = time.perf_counter()
        for r in batch:
            r.result.dispatched_at = now
            if r._tspan is not None:
                # closes the queue-wait window: submit -> this dispatch
                r._tspan.lap("request.queue_wait", {"batch": bid})
        self._stats.bump(batches=1, batched_rows=rows.shape[0])
        self._put_completion((batch, executor, pending, rows, bid))

    def _put_completion(self, item: Tuple[Any, ...]) -> None:
        """Backpressured hand-off that cannot hang shutdown: the put
        polls so a dead completion thread fails the batch instead of
        blocking the batcher (and therefore ``stop()``) forever."""
        while True:
            try:
                self._completions.put(item, timeout=0.05)
                return
            except queue.Full:
                if not self._completer_alive:
                    for r in item[0]:
                        self._fail(r, RuntimeError(
                            "completion thread is not running"))
                    return

    def _completion_loop(self) -> None:
        self._completer_alive = True
        try:
            while True:
                item = self._completions.get()
                if item is None:
                    break
                self._complete_one(item)
        finally:
            self._completer_alive = False

    def _complete_one(self, item: Tuple[Any, ...]) -> None:
        batch, executor, pending, rows_arr, bid = item
        rows = rows_arr.shape[0]
        try:
            with trace_span("batch.finalize", "serving",
                            args=None if not tracer.enabled else
                            {"batch": bid, "rows": rows}):
                out = executor.finalize(pending)
        except BaseException as e:          # noqa: BLE001 — rescued
            if executor is self.plan:
                self._breaker.record_failure()
            self._stats.bump(backend_errors=1)
            out = self._rescue(batch, rows_arr, executor)
            if out is None:
                for r in batch:
                    self._fail(r, e)
                return
        if self.is_range:
            matches = np.asarray(out).reshape(rows, -1)
            values = indices = None
        else:
            values, indices = out
            # finalize shapes outputs for the *compiled module* (which
            # may have been traced with 1-D or stacked queries); the
            # scatter below is strictly row-major
            values = np.asarray(values).reshape(rows, -1)
            indices = np.asarray(indices).reshape(rows, -1)
        now = time.perf_counter()
        off = 0
        for r in batch:
            m = r.queries.shape[0]
            if r.deadline is not None and now > r.deadline:
                # result arrived, but past the budget: a miss, not a
                # late delivery the client already gave up on
                off += m
                self._fail_timeout(r)
                continue
            if self.is_range:
                r.result.matches = matches[off:off + m]
            else:
                r.result.values = values[off:off + m]
                r.result.indices = indices[off:off + m]
            r.result.completed_at = now
            off += m
            # one bump per delivered request: a snapshot can never see
            # the request counted without its rows and latency sample
            self._stats.bump(_latency_s=r.result.latency_s,
                             _queue_s=r.result.queue_wait_s,
                             _service_s=r.result.service_s,
                             requests=1, queries=m)
            if r._tspan is not None:
                # dispatch -> delivery window, then the whole request
                r._tspan.lap("request.service", {"batch": bid})
                r._tspan.end()
            r._settle()

    def _fail(self, req: SearchRequest, err: BaseException) -> None:
        req.result.error = err
        req.result.completed_at = time.perf_counter()
        self._stats.bump(errors=1)
        if req._tspan is not None:
            req._tspan.end({"error": type(err).__name__})
        req._settle()

    def _fail_timeout(self, req: SearchRequest) -> None:
        req.result.error = TimeoutError(
            f"request {req.rid} missed its deadline")
        req.result.completed_at = time.perf_counter()
        self._stats.bump(deadline_misses=1)
        if req._tspan is not None:
            req._tspan.end({"error": "TimeoutError"})
        req._settle()
