"""Serving layer for CAM similarity search.

Continuous-batching front end over the search-plan engine: concurrent
KNN / HDC query requests are coalesced into plan-sized micro-batches
against one cached (optionally multi-device-sharded)
:class:`~repro.core.engine.SearchPlan`.  On top of the single-gallery
:class:`CamSearchServer` sits the multi-tenant
:class:`CamServingGateway`: named tenants, per-tenant admission
control (rate limits, priorities, load shedding), gallery replicas
load-balanced across device groups with transparent failover, and
digest-checked replica healing.  See ``docs/serving.md``.
"""

from .gateway import (CamServingGateway, GatewayRequest, GatewayResult)
from .replica import Replica, ReplicaSet
from .server import CamSearchServer, SearchRequest, SearchResult
from .telemetry import ServerStats
from .tenant import AdmissionConfig, AdmissionError, TenantUnavailable

__all__ = ["CamSearchServer", "SearchRequest", "SearchResult",
           "ServerStats", "CamServingGateway", "GatewayRequest",
           "GatewayResult", "Replica", "ReplicaSet", "AdmissionConfig",
           "AdmissionError", "TenantUnavailable"]
