"""Serving layer for CAM similarity search.

Continuous-batching front end over the search-plan engine: concurrent
KNN / HDC query requests are coalesced into plan-sized micro-batches
against one cached (optionally multi-device-sharded)
:class:`~repro.core.engine.SearchPlan`.  See ``docs/serving.md``.
"""

from .server import CamSearchServer, SearchRequest, SearchResult

__all__ = ["CamSearchServer", "SearchRequest", "SearchResult"]
