"""Serving telemetry: consistent stats and request/result containers.

``ServerStats`` is the one place serving counters live.  Everything a
counter group mutates together is applied in **one** lock acquisition
(:meth:`ServerStats.bump`), and every read (:meth:`ServerStats.view`)
copies the whole group under the same lock — so ``snapshot()`` /
``health()`` can never observe half of a related update (e.g. a
completed request whose latency sample has not landed yet, or a
backend error whose retry counter is still behind).  The historical
failure mode was exactly that: each ``stats[k] += 1`` took its own
lock acquisition, so concurrent readers saw mid-mutation states.

``SearchRequest`` doubles as a one-shot future: ``wait()`` blocks,
``add_done_callback`` runs a function the moment the request settles
(already-settled requests run it immediately in the caller's thread).
The multi-tenant gateway rides the callbacks to fail requests over to
another replica without parking a thread per in-flight request.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["ServerStats", "SearchResult", "SearchRequest"]


class ServerStats:
    """A named group of counters with atomic multi-key updates.

    ``bump(a=1, b=rows)`` applies every delta (and an optional latency
    sample) in one critical section; ``view()`` returns a copy of all
    counters plus the bounded latency window taken in one critical
    section.  Unknown counter names raise — a typo must not mint a new
    counter silently.
    """

    def __init__(self, *names: str, window: int = 4096):
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {n: 0 for n in names}
        # bounded: a long-lived server must not grow per-request state
        self._latencies: "deque[float]" = deque(maxlen=window)
        # end-to-end latency split: time parked before dispatch vs time
        # being served (dispatch -> delivery) — one blended number can't
        # distinguish an overloaded batcher from a slow kernel
        self._queue_waits: "deque[float]" = deque(maxlen=window)
        self._services: "deque[float]" = deque(maxlen=window)

    def bump(self, _latency_s: Optional[float] = None,
             _queue_s: Optional[float] = None,
             _service_s: Optional[float] = None, **deltas: int) -> None:
        with self._lock:
            for k, v in deltas.items():
                if k not in self._counts:
                    raise KeyError(f"unknown stats counter {k!r}")
                self._counts[k] += v
            if _latency_s is not None:
                self._latencies.append(_latency_s)
            if _queue_s is not None:
                self._queue_waits.append(_queue_s)
            if _service_s is not None:
                self._services.append(_service_s)

    def view(self) -> Tuple[Dict[str, int], List[float]]:
        """One consistent copy: every counter and the latency window,
        read in a single critical section."""
        with self._lock:
            return dict(self._counts), list(self._latencies)

    def view_windows(self) -> Tuple[Dict[str, int], List[float],
                                    List[float], List[float]]:
        """Like :meth:`view` plus the queue-wait and service windows,
        all copied in the same critical section."""
        with self._lock:
            return (dict(self._counts), list(self._latencies),
                    list(self._queue_waits), list(self._services))

    @staticmethod
    def percentiles(latencies: List[float],
                    prefix: str = "") -> Dict[str, float]:
        """``{"p50_ms", "p95_ms"}`` (optionally prefixed) over a
        latency-seconds window (empty window -> empty dict)."""
        if not latencies:
            return {}
        lat = sorted(latencies)
        return {f"{prefix}p50_ms": 1e3 * lat[len(lat) // 2],
                f"{prefix}p95_ms": 1e3 * lat[min(len(lat) - 1,
                                                 int(len(lat) * 0.95))]}


@dataclass
class SearchResult:
    """Per-request outcome: top-k values/indices (best-match plans) or
    the boolean match rows (range plans), row-aligned with the
    submitted queries, plus queueing/batching latency telemetry."""

    rid: int
    values: Optional[np.ndarray] = None
    indices: Optional[np.ndarray] = None
    #: range-plan requests: (rows, n) boolean match matrix
    matches: Optional[np.ndarray] = None
    error: Optional[BaseException] = None
    submitted_at: float = 0.0
    #: when the batcher dispatched this request's batch to the device
    #: (0.0 for requests that failed before dispatch)
    dispatched_at: float = 0.0
    completed_at: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.completed_at - self.submitted_at

    @property
    def queue_wait_s(self) -> float:
        """Submit -> dispatch: time parked in the queue / batch fill
        (the whole latency when the request never dispatched)."""
        if not self.dispatched_at:
            return self.latency_s
        return self.dispatched_at - self.submitted_at

    @property
    def service_s(self) -> float:
        """Dispatch -> delivery: device execution + finalize + scatter
        (0.0 when the request never dispatched)."""
        if not self.dispatched_at:
            return 0.0
        return self.completed_at - self.dispatched_at


@dataclass
class SearchRequest:
    """One in-flight query block (``queries``: ``(rows, dim)``).

    ``deadline`` (absolute ``time.perf_counter()`` seconds, or ``None``)
    is the server-side budget: an expired request is failed with a
    ``TimeoutError`` instead of dispatched (or instead of delivered, if
    the result arrives late) — its batch never waits for it.
    """

    rid: int
    queries: np.ndarray
    result: SearchResult
    deadline: Optional[float] = None
    #: cross-thread trace handle (``repro.obs.trace_begin``); ``None``
    #: when tracing is disabled
    _tspan: Any = None
    _done: threading.Event = field(default_factory=threading.Event)
    _cb_lock: threading.Lock = field(default_factory=threading.Lock)
    _callbacks: List[Callable[["SearchRequest"], Any]] = \
        field(default_factory=list)

    def wait(self, timeout: Optional[float] = None) -> SearchResult:
        if not self._done.wait(timeout):
            raise TimeoutError(f"search request {self.rid} timed out")
        return self.result

    def add_done_callback(
            self, fn: Callable[["SearchRequest"], Any]) -> None:
        """Run ``fn(request)`` once the request settles (result or
        error).  Registered after settling, it runs immediately in the
        caller's thread; otherwise in the thread that settles the
        request.  Callback exceptions are swallowed — a broken observer
        must not kill the completion pipeline."""
        with self._cb_lock:
            if not self._done.is_set():
                self._callbacks.append(fn)
                return
        try:
            fn(self)
        except Exception:                       # noqa: BLE001 — observer
            pass

    def _settle(self) -> None:
        """Mark done and drain callbacks (exactly once per callback;
        callbacks run outside the registration lock)."""
        with self._cb_lock:
            self._done.set()
            cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            try:
                fn(self)
            except Exception:                   # noqa: BLE001 — observer
                pass
