"""Forest-to-CAM compiler: tree ensembles as aCAM interval galleries.

Encoding (see ``docs/forest.md`` for the full walk-through):

* a branch node tests ``x[f] <= thr`` — the *left* child tightens the
  row's upper bound (``hi[f] = min(hi[f], thr)``), the *right* child
  tightens the lower bound to the **successor float**
  (``lo[f] = nextafter(thr)``): with float32 queries, ``x > thr`` and
  ``x >= nextafter(thr)`` select exactly the same values, so the
  closed-interval aCAM contract ``lo <= x <= hi`` reproduces the tree
  traversal bit-for-bit;
* features a path never tests stay at the full-range wildcard interval
  ``[-inf, +inf]`` — an aCAM cell that can never mismatch;
* every sample therefore matches exactly one leaf row per tree, and the
  class vote is a boolean-matrix x one-hot matmul.

The ensemble representation is plain numpy arrays (:class:`TreeArrays`
— sklearn's ``tree_`` layout without the sklearn dependency); the
optional :func:`from_sklearn` adapter converts a fitted
``RandomForestClassifier`` when sklearn is installed.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["TreeArrays", "ForestIntervals", "CamForestClassifier",
           "tree_to_intervals", "forest_to_intervals", "random_forest",
           "from_sklearn", "traverse_matches", "vote"]


@dataclass
class TreeArrays:
    """One fitted decision tree as plain arrays (sklearn ``tree_`` layout).

    ``feature``/``threshold`` describe branch nodes (``x[feature] <=
    threshold`` goes left); ``left``/``right`` hold child node ids with
    ``-1`` marking a leaf; ``leaf_class`` holds the predicted class at
    leaf nodes (ignored elsewhere).
    """

    feature: np.ndarray        # (nodes,) int32
    threshold: np.ndarray      # (nodes,) float32
    left: np.ndarray           # (nodes,) int32, -1 = leaf
    right: np.ndarray          # (nodes,) int32, -1 = leaf
    leaf_class: np.ndarray     # (nodes,) int32

    def __post_init__(self):
        self.feature = np.asarray(self.feature, np.int32)
        self.threshold = np.asarray(self.threshold, np.float32)
        self.left = np.asarray(self.left, np.int32)
        self.right = np.asarray(self.right, np.int32)
        self.leaf_class = np.asarray(self.leaf_class, np.int32)

    @property
    def n_leaves(self) -> int:
        return int((self.left < 0).sum())


@dataclass
class ForestIntervals:
    """A flattened forest: one aCAM interval row per root-to-leaf path."""

    lo: np.ndarray             # (L, D) float32, -inf = wildcard bound
    hi: np.ndarray             # (L, D) float32, +inf = wildcard bound
    leaf_class: np.ndarray     # (L,) int32
    tree_id: np.ndarray        # (L,) int32
    n_trees: int
    n_classes: int

    @property
    def n_rows(self) -> int:
        return self.lo.shape[0]

    @property
    def wildcard_frac(self) -> float:
        """Fraction of cells storing the full-range wildcard interval."""
        wild = np.isinf(self.lo) & np.isinf(self.hi)
        return float(wild.mean())


def tree_to_intervals(tree: TreeArrays, dim: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flatten one tree into ``(lo, hi, leaf_class)`` interval rows.

    Iterative root-to-leaf walk; each leaf emits the conjunction of the
    threshold tests on its path as one closed interval per feature.
    """
    los, his, cls = [], [], []
    init_lo = np.full(dim, -np.inf, np.float32)
    init_hi = np.full(dim, np.inf, np.float32)
    stack = [(0, init_lo, init_hi)]
    while stack:
        node, lo, hi = stack.pop()
        if tree.left[node] < 0:            # leaf
            los.append(lo)
            his.append(hi)
            cls.append(tree.leaf_class[node])
            continue
        f = int(tree.feature[node])
        thr = np.float32(tree.threshold[node])
        # left: x[f] <= thr
        llo, lhi = lo.copy(), hi.copy()
        lhi[f] = min(lhi[f], thr)
        stack.append((int(tree.left[node]), llo, lhi))
        # right: x[f] > thr  ==  x[f] >= nextafter(thr) in float32
        rlo, rhi = lo.copy(), hi.copy()
        rlo[f] = max(rlo[f], np.nextafter(thr, np.float32(np.inf)))
        stack.append((int(tree.right[node]), rlo, rhi))
    return (np.stack(los), np.stack(his),
            np.asarray(cls, np.int32))


def forest_to_intervals(trees: Sequence[TreeArrays], dim: int,
                        n_classes: Optional[int] = None) -> ForestIntervals:
    """Flatten a whole ensemble into one interval gallery.

    Rows are emitted in tree order, so ``tree_id`` is monotone — the
    sharded engine's concatenation order keeps whole trees contiguous
    across devices (cosmetic: votes are order-invariant anyway).
    """
    los, his, cls, tid = [], [], [], []
    for t, tree in enumerate(trees):
        lo, hi, c = tree_to_intervals(tree, dim)
        los.append(lo)
        his.append(hi)
        cls.append(c)
        tid.append(np.full(c.shape[0], t, np.int32))
    cls_all = np.concatenate(cls)
    if n_classes is None:
        n_classes = int(cls_all.max()) + 1 if cls_all.size else 1
    return ForestIntervals(
        lo=np.concatenate(los), hi=np.concatenate(his),
        leaf_class=cls_all, tree_id=np.concatenate(tid),
        n_trees=len(trees), n_classes=int(n_classes))


def random_forest(rng: np.random.Generator, *, n_trees: int, dim: int,
                  depth: int, n_classes: int,
                  feature_frac: float = 1.0) -> List[TreeArrays]:
    """A synthetic ensemble of random full binary trees.

    Used by the example / benchmark / tests so the forest path needs no
    training dependency: split features are drawn from a per-tree
    subset (``feature_frac < 1`` guarantees untested features, i.e.
    wildcard interval cells), thresholds from N(0, 1), leaf classes
    uniformly.  Structurally identical to a fitted forest as far as
    the compiler is concerned.
    """
    trees = []
    n_feat = max(1, int(round(feature_frac * dim)))
    for _ in range(n_trees):
        feats = rng.choice(dim, size=n_feat, replace=False)
        n_branch = 2 ** depth - 1
        n_nodes = 2 ** (depth + 1) - 1
        feature = np.full(n_nodes, -1, np.int32)
        threshold = np.zeros(n_nodes, np.float32)
        left = np.full(n_nodes, -1, np.int32)
        right = np.full(n_nodes, -1, np.int32)
        leaf_class = np.zeros(n_nodes, np.int32)
        feature[:n_branch] = rng.choice(feats, size=n_branch)
        threshold[:n_branch] = rng.standard_normal(n_branch).astype(np.float32)
        left[:n_branch] = 2 * np.arange(n_branch, dtype=np.int32) + 1
        right[:n_branch] = 2 * np.arange(n_branch, dtype=np.int32) + 2
        leaf_class[n_branch:] = rng.integers(0, n_classes,
                                             n_nodes - n_branch)
        trees.append(TreeArrays(feature, threshold, left, right, leaf_class))
    return trees


def from_sklearn(model: Any) -> List[TreeArrays]:
    """Convert a fitted sklearn forest/tree to :class:`TreeArrays`.

    Accepts a ``RandomForestClassifier``-like ensemble (anything with
    ``estimators_``) or a single fitted ``DecisionTreeClassifier``.
    Thresholds are cast to float32 — the CAM stores float32 cells, so
    the compiled forest's contract is "the float32 rounding of the
    fitted tree", bit-identical between the engine and this package's
    traversal oracle (sklearn's own float64-threshold ``predict`` can
    disagree on values that fall inside the rounding gap).  Aggregation
    also differs by design: the CAM votes the *majority leaf class*
    (one match line per branch, Pedretti et al.), whereas sklearn
    averages per-tree class probabilities — expect high but not exact
    agreement with ``model.predict``.
    """
    try:
        from sklearn.tree import DecisionTreeClassifier  # noqa: F401
    except ImportError as e:                         # pragma: no cover
        raise ImportError(
            "from_sklearn needs scikit-learn installed; build TreeArrays "
            "directly for a dependency-free forest") from e
    estimators = getattr(model, "estimators_", None) or [model]
    trees = []
    for est in estimators:
        t = est.tree_
        leaf = t.children_left < 0
        value = t.value[:, 0, :]
        trees.append(TreeArrays(
            feature=np.where(leaf, -1, t.feature).astype(np.int32),
            threshold=np.where(leaf, 0.0, t.threshold).astype(np.float32),
            left=t.children_left.astype(np.int32),
            right=t.children_right.astype(np.int32),
            leaf_class=np.argmax(value, axis=1).astype(np.int32)))
    return trees


def traverse_matches(trees: Sequence[TreeArrays], intervals: ForestIntervals,
                     x: np.ndarray) -> np.ndarray:
    """(M, L) boolean match matrix by *tree traversal* (the oracle).

    Walks every tree per sample (``x[f] <= thr`` goes left, float32
    compares) and flags the reached leaf's interval row.  Must equal
    the engine's aCAM interval match bit-for-bit.
    """
    x = np.asarray(x, np.float32)
    m = x.shape[0]
    match = np.zeros((m, intervals.n_rows), bool)
    row0 = 0
    for t, tree in enumerate(trees):
        # leaf order must mirror tree_to_intervals' stack walk
        leaf_rows = _leaf_row_index(tree)
        for i in range(m):
            node = 0
            while tree.left[node] >= 0:
                f = int(tree.feature[node])
                node = int(tree.left[node]
                           if x[i, f] <= tree.threshold[node]
                           else tree.right[node])
            match[i, row0 + leaf_rows[node]] = True
        row0 += tree.n_leaves
    return match


def _leaf_row_index(tree: TreeArrays) -> dict:
    """leaf node id -> emitted row offset (tree_to_intervals order)."""
    order = {}
    stack = [0]
    while stack:
        node = stack.pop()
        if tree.left[node] < 0:
            order[node] = len(order)
            continue
        stack.append(int(tree.left[node]))
        stack.append(int(tree.right[node]))
    return order


def vote(matches: np.ndarray, leaf_class: np.ndarray,
         n_classes: int) -> np.ndarray:
    """(M,) majority-vote predictions from a boolean match matrix.

    One vote per matched row (= one per tree); ties break toward the
    lowest class id (``argmax`` returns the first maximum).
    """
    onehot = np.zeros((leaf_class.shape[0], n_classes), np.int32)
    onehot[np.arange(leaf_class.shape[0]), leaf_class] = 1
    counts = np.asarray(matches, np.int32) @ onehot
    return np.argmax(counts, axis=1).astype(np.int32)


class CamForestClassifier:
    """Compile a tree ensemble onto an analog CAM and run inference.

    Pipeline: flatten the ensemble to interval rows
    (:func:`forest_to_intervals`), build a ``cim.range_search``
    (interval mode) program, tile it to subarray granularity with the
    standard ``CompulsoryPartition`` pass, lower through ``cim-to-cam``
    / ``cam-map`` with ``CamType.ACAM`` (MappingPlans + camsim cost
    report), and execute matches through the engine's
    :class:`~repro.core.engine.RangePlan` — micro-batched, plan-cached,
    optionally sharded over a device mesh.
    """

    def __init__(self, trees: Sequence[TreeArrays], dim: int,
                 n_classes: Optional[int] = None):
        self.trees = list(trees)
        self.dim = int(dim)
        self.intervals = forest_to_intervals(self.trees, self.dim, n_classes)
        self.program = None
        self.plan = None
        self._lo = self._hi = None

    # ------------------------------------------------------------------
    def compile(self, arch=None, *, batch_hint: int = 64,
                backend: str = "jnp", shards: Optional[int] = None,
                unroll_limit: int = 64) -> "CamForestClassifier":
        """Lower the forest onto ``arch`` (must be an ACAM) and build
        the engine plan.  Returns ``self`` for chaining."""
        import jax.numpy as jnp

        from ..core.arch import ArchSpec, CamType
        from ..core.cim_dialect import (make_acquire, make_execute,
                                        make_range_search, make_release,
                                        make_yield)
        from ..core.engine import get_plan
        from ..core.ir import Builder, Module, PassManager, TensorType
        from ..core.passes import CamMap, CimToCam, CompulsoryPartition

        if arch is None:
            arch = ArchSpec(cam_type=CamType.ACAM)
        n = self.intervals.n_rows
        m = max(1, int(batch_hint))
        mod = Module("forest_inference",
                     [TensorType((m, self.dim)),
                      TensorType((n, self.dim)), TensorType((n, self.dim))],
                     arg_names=["x", "lo", "hi"])
        b = Builder(mod.body)
        dev = make_acquire(b)
        exe = make_execute(b, dev.result, list(mod.arguments),
                           [TensorType((m, n), "i1")])
        blk = exe.region().block()
        rs = make_range_search(
            blk, mod.arguments[0], lo=mod.arguments[1], hi=mod.arguments[2],
            extra_attrs={"value_bits": arch.bits_per_cell})
        make_yield(blk, rs.results)
        make_release(b, dev.result)
        b.ret(exe.results)

        ctx = {"arch": arch}
        pm = PassManager()
        pm.add(CompulsoryPartition(unroll_limit=unroll_limit))
        partitioned = pm.run(mod, ctx)
        pm2 = PassManager()
        pm2.add(CimToCam(cam_type=arch.cam_type))
        cam = pm2.run(partitioned.clone(), ctx)
        pm3 = PassManager(verify_each=False)   # mapped IR is loop-structured
        pm3.add(CamMap())
        mapped = pm3.run(cam, ctx)

        self.arch = arch
        self.stages = {"cim_partitioned": partitioned, "cam": cam,
                       "cam_mapped": mapped}
        self.mapping_plans = ctx.get("plans", [])
        self.plan = get_plan(partitioned, backend=backend, shards=shards)
        if self.plan is None:                  # pragma: no cover
            raise RuntimeError("forest program did not yield a RangePlan")
        # jax arrays: hit the plan's pattern memo (and device layout for
        # sharded plans) on every predict
        self._lo = jnp.asarray(self.intervals.lo)
        self._hi = jnp.asarray(self.intervals.hi)
        return self

    # ------------------------------------------------------------------
    def _require_compiled(self):
        if self.plan is None:
            raise RuntimeError("call compile() first")

    def matches(self, x: np.ndarray) -> np.ndarray:
        """(M, L) boolean branch-match matrix via the engine RangePlan."""
        self._require_compiled()
        x = np.asarray(x, np.float32)
        return np.asarray(self.plan.execute(x, self._lo, self._hi))

    def predict(self, x: np.ndarray) -> np.ndarray:
        """(M,) class predictions through the compiled aCAM path."""
        return vote(self.matches(x), self.intervals.leaf_class,
                    self.intervals.n_classes)

    def predict_interpreted(self, x: np.ndarray) -> np.ndarray:
        """Predictions via the IR interpreter (semantic oracle)."""
        from ..core.executor import execute_module

        self._require_compiled()
        x = np.asarray(x, np.float32)
        match = execute_module(self.stages["cim_partitioned"], x,
                               self.intervals.lo, self.intervals.hi)[0]
        return vote(np.asarray(match), self.intervals.leaf_class,
                    self.intervals.n_classes)

    def predict_reference(self, x: np.ndarray) -> np.ndarray:
        """Predictions via plain per-sample tree traversal (no CAM)."""
        m = traverse_matches(self.trees, self.intervals,
                             np.asarray(x, np.float32))
        return vote(m, self.intervals.leaf_class, self.intervals.n_classes)

    # ------------------------------------------------------------------
    def cost_report(self):
        """camsim latency/energy report for the aCAM forest mapping."""
        from ..camsim import CostModel

        self._require_compiled()
        return CostModel(self.arch).report(self.mapping_plans)

    def summary(self) -> dict:
        iv = self.intervals
        out = {"trees": iv.n_trees, "rows": iv.n_rows, "dim": self.dim,
               "classes": iv.n_classes,
               "wildcard_frac": round(iv.wildcard_frac, 4)}
        if self.plan is not None:
            out.update(backend=self.plan.backend, shards=self.plan.shards,
                       batch=self.plan.batch,
                       grid=(self.plan.spec.grid_rows,
                             self.plan.spec.grid_cols))
            rep = self.cost_report()
            out.update(latency_us=round(rep.latency_us, 3),
                       energy_uj=round(rep.energy_uj, 3))
        return out
