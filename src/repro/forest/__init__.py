"""Decision-forest-to-CAM compilation (the flagship non-KNN workload).

Each root-to-leaf path of a decision tree is a conjunction of
per-feature threshold tests — exactly one analog-CAM row of
``[lo, hi]`` intervals (Pedretti et al., *Tree-based machine learning
performed in-memory with memristive analog CAM*).  A whole forest
flattens into one interval gallery; inference is a single aCAM range
search (one match line per branch) followed by a majority class vote.
See ``docs/forest.md``.
"""

from .forest import (CamForestClassifier, ForestIntervals, TreeArrays,
                     forest_to_intervals, from_sklearn, random_forest,
                     traverse_matches, tree_to_intervals, vote)

__all__ = ["CamForestClassifier", "ForestIntervals", "TreeArrays",
           "forest_to_intervals", "from_sklearn", "random_forest",
           "traverse_matches", "tree_to_intervals", "vote"]
