"""Device-fault injection and hardening for CAM plans.

``FaultModel`` (:mod:`repro.faults.model`) is the seeded, deterministic
fault generator every plan accepts at dispatch time
(``plan.execute(..., faults=model)``); ``HardenedPlan``
(:mod:`repro.faults.harden`) wraps a plan with replication,
checksum-readback self-healing, and aCAM guard bands.  See
``docs/robustness.md``.
"""

from .harden import (HardenedPlan, HealReport, detect_faulty_rows,
                     row_checksums)
from .model import FaultModel

__all__ = ["FaultModel", "HardenedPlan", "HealReport", "row_checksums",
           "detect_faulty_rows"]
