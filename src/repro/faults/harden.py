"""Hardening passes: replication, self-healing remap, guard bands.

Fault *injection* (:mod:`repro.faults.model`) answers "what breaks";
this module answers "how do we keep serving anyway", with the three
mechanisms the memristive-CAM literature actually deploys:

* **Redundant-row replication** — each logical row is stored ``R``
  times (plus ``spares`` empty rows); physical search runs over the
  replicated gallery through the *unmodified* engine (any backend /
  packing / sharding — the replica tournament rides the existing
  cross-shard tournament), and a majority/median vote de-duplicates
  physical candidates back to logical results at finalize.
* **Faulty-row remap (self-healing)** — :meth:`HardenedPlan.heal`
  compares a simulated device *readback* of the stored gallery against
  per-row checksums of the clean content and rewrites rows that
  mismatch onto spare rows using the engine's existing
  :meth:`~repro.core.engine.SearchPlan.update_rows` machinery.  Rows
  that stay faulty after the configured passes (stuck cells at every
  spare, or spares exhausted) are reported unrepairable and their
  physical slots excluded from the vote.
* **aCAM sensing guard-bands** — interval plans widen each finite
  ``(lo, hi)`` bound by a margin (typically
  :meth:`FaultModel.suggest_guard`, a few noise sigmas plus drift), so
  conductance noise stops flipping marginal matches; the price is a
  higher false-match rate, which the forest/HDC vote absorbs.

A ``HardenedPlan`` with ``replicas=1, spares=0, guard=0`` is
**bit-identical** to the raw plan — the vote over one replica is the
identity — which the test suite pins.
"""

import zlib
from dataclasses import dataclass, replace
from typing import Any, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.engine import RangeSpec, get_plan, module_for_spec
from ..core.envcfg import env_int

__all__ = ["HardenedPlan", "HealReport", "row_checksums",
           "detect_faulty_rows"]

#: losing-candidate index sentinel (same as ``kref.pad_candidates``)
_PAD_IDX = 2 ** 30


def row_checksums(arrs: Tuple[np.ndarray, ...]) -> np.ndarray:
    """Per-row CRC32 over all stored components.

    The digest primitive shared by :class:`HardenedPlan` (physical-row
    readback checks) and the serving replica layer (replica-divergence
    detection): row ``p``'s checksum covers row ``p`` of *every*
    component — gallery and care mask, or interval ``(lo, hi)`` — so
    two stored copies agree iff their checksum vectors agree.
    """
    n = arrs[0].shape[0]
    return np.array([
        zlib.crc32(b"".join(np.ascontiguousarray(a[p]).tobytes()
                            for a in arrs))
        for p in range(n)], np.uint32)


def detect_faulty_rows(readback: Tuple[Any, ...],
                       clean: Tuple[np.ndarray, ...],
                       tolerance: float = 0.0) -> np.ndarray:
    """Row mask of a simulated device readback diverging from the
    clean stored content.

    Digital cells (``tolerance <= 0``) compare exactly via
    :func:`row_checksums`; analog cells use a per-cell absolute
    tolerance — typically :meth:`FaultModel.suggest_guard`, a few
    noise sigmas plus drift — since Gaussian read noise perturbs every
    cell and only outliers (stuck cells, flipped bounds, excessive
    drift) indicate a row worth rewriting.  Handles ``inf`` bounds
    (``inf == inf`` matches; ``inf - finite`` is an outlier).
    """
    clean = tuple(np.asarray(c, np.float32) for c in clean)
    if tolerance <= 0.0:
        crc = row_checksums(tuple(np.asarray(a, np.float32)
                                  for a in readback))
        return crc != row_checksums(clean)
    bad = np.zeros(clean[0].shape[0], bool)
    for rb, cl in zip(readback, clean):
        rb = np.asarray(rb, np.float32)
        same = rb == cl                         # matching cells/infs
        with np.errstate(invalid="ignore"):     # inf - inf -> nan
            diff = np.where(same, 0.0, np.abs(rb - cl))
        bad |= ~(np.nan_to_num(diff, nan=np.inf) <= tolerance).all(axis=1)
    return bad


@dataclass
class HealReport:
    """Outcome of one :meth:`HardenedPlan.heal` run."""

    detected: int          # distinct faulty physical rows found (all passes)
    remapped: int          # rows rewritten onto spares (all passes)
    unrepairable: int      # live rows still faulty when healing stopped
    passes: int            # detection passes run
    spares_free: int       # spare slots still available afterwards


def _heal_passes_default() -> int:
    return env_int("REPRO_FAULT_HEAL_PASSES", 3, min_value=1)


class HardenedPlan:
    """A fault-hardened wrapper around one compiled plan.

    Compiles a *physical* plan for the replicated gallery (``n_phys =
    replicas * n + spares`` rows, top-``replicas * k + spares``
    candidates for the search family) via
    :func:`~repro.core.engine.module_for_spec`, keeps the clean stored
    content plus per-row checksums on the host, and maps physical
    results back to logical rows with a majority/median vote.  The
    physical plan is an ordinary plan-cache citizen: backend, packing
    and sharding are inherited from the wrapped plan (or overridden),
    and fault injection happens through the same ``faults=`` dispatch
    hook as everywhere else.

    Physical layout: replica ``r`` of logical row ``j`` lives at
    physical row ``r * n + j``; spares occupy the tail.  ``logical_of``
    maps physical -> logical with ``-1`` for dead rows and unused
    spares (dead rows stay allocated — their fault draws are
    position-keyed — but never contribute to results).
    """

    def __init__(self, plan, *, replicas: int = 1, spares: int = 0,
                 guard: float = 0.0, backend: Optional[str] = None,
                 pack: Optional[bool] = None, shards: Optional[int] = None):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if spares < 0:
            raise ValueError(f"spares must be >= 0, got {spares}")
        if guard < 0:
            raise ValueError(f"guard must be >= 0, got {guard}")
        spec = plan.spec
        self.spec = spec
        self.replicas = int(replicas)
        self.spares = int(spares)
        self.guard = float(guard)
        self.is_range = isinstance(spec, RangeSpec)
        if not self.is_range and guard:
            raise ValueError("guard bands only apply to aCAM interval plans")
        if self.is_range and guard and spec.mode != "interval":
            raise ValueError("guard bands only apply to aCAM interval plans")
        self.n = spec.n
        self.n_phys = self.replicas * spec.n + self.spares
        if self.is_range:
            phys_spec = replace(spec, n=self.n_phys)
        else:
            phys_spec = replace(spec, n=self.n_phys,
                                k=self.replicas * spec.k + self.spares)
        self.plan = get_plan(
            module_for_spec(phys_spec),
            backend=plan.backend if backend is None else backend,
            pack=plan.packed if pack is None else pack,
            shards=(plan.shards if plan.shards > 1 else None)
            if shards is None else shards)
        assert self.plan is not None
        self.phys_spec = self.plan.spec
        #: physical -> logical row map; -1 = dead row or unused spare
        self.logical_of = np.concatenate(
            [np.tile(np.arange(self.n, dtype=np.int32), self.replicas),
             np.full(self.spares, -1, np.int32)])
        self._free = list(range(self.replicas * self.n, self.n_phys))
        self._stored: Optional[Tuple[Any, ...]] = None   # jnp phys operands
        self._clean: Optional[Tuple[np.ndarray, ...]] = None
        self._logical: Optional[Tuple[np.ndarray, ...]] = None
        self._crc: Optional[np.ndarray] = None
        self.heals = 0
        self.rows_remapped = 0
        self.unrepairable = 0

    # -- stored content ----------------------------------------------------

    def prepare(self, *stored) -> None:
        """Store the logical content: ``(gallery[, care])`` for the
        search family, ``(patterns,)`` / ``(lo, hi)`` for range.  Guard
        bands are applied to finite interval bounds *before*
        replication, so every replica (and every healed rewrite)
        carries the widened intervals."""
        stored = tuple(np.asarray(s, np.float32) for s in stored)
        if self.is_range and self.spec.mode == "interval" and self.guard:
            lo, hi = stored
            stored = (np.where(np.isfinite(lo), lo - self.guard, lo),
                      np.where(np.isfinite(hi), hi + self.guard, hi))
        self._logical = stored
        phys = []
        for comp, arr in enumerate(stored):
            tail = self._spare_fill(comp, arr)
            phys.append(np.concatenate([np.tile(arr, (self.replicas, 1)),
                                        tail]).astype(np.float32))
        self._clean = tuple(phys)
        self._stored = tuple(jnp.asarray(a) for a in phys)
        self._crc = self._checksums(self._clean)

    def _spare_fill(self, comp: int, arr: np.ndarray) -> np.ndarray:
        """Placeholder content for spare rows.

        Interval spares are the empty interval ``(+inf, -inf)`` (never
        match); everything else is zeros except ternary care masks
        (all-compare, so a spare never degenerates into an
        all-wildcard row with distance zero).
        """
        shape = (self.spares, arr.shape[1])
        if self.is_range and self.spec.mode == "interval":
            return np.full(shape, np.inf if comp == 0 else -np.inf,
                           np.float32)
        if not self.is_range and comp == 1:      # care mask
            return np.ones(shape, np.float32)
        return np.zeros(shape, np.float32)

    @staticmethod
    def _checksums(arrs: Tuple[np.ndarray, ...]) -> np.ndarray:
        """Per-physical-row CRC32 over all stored components."""
        return row_checksums(arrs)

    def _logical_rows(self, logical_idx: np.ndarray
                      ) -> Tuple[np.ndarray, ...]:
        return tuple(a[logical_idx] for a in self._logical)

    # -- execution ---------------------------------------------------------

    def execute(self, queries, faults=None):
        """Run the hardened search: physical plan + majority vote.

        Returns logical-domain results in the wrapped plan's output
        convention — ``(values, indices)`` with logical row indices for
        the search family, an ``(M, n)`` logical match matrix for
        range.  ``faults`` corrupts the *physical* gallery (each
        replica draws independent position-keyed faults — that is the
        whole point of replication).
        """
        if self._stored is None:
            raise RuntimeError("call prepare(*stored) before execute")
        out = self.plan.execute(queries, *self._stored, faults=faults)
        if self.is_range:
            return self._finalize_range(np.asarray(out))
        v, i = (np.asarray(x) for x in out)
        return self._finalize_search(v, i)

    def _finalize_search(self, v: np.ndarray, i: np.ndarray):
        """Median-vote de-duplication of physical top-k candidates.

        Groups candidates by logical row, aggregates each group's
        value as the median over its surviving replicas (a clean
        majority outvotes a corrupt minority), re-ranks, and pads back
        to logical ``k`` with the engine's losing sentinels.  With one
        replica and no spares this reproduces the raw plan's output
        bit-exactly (median of one value is that value; the sort key
        matches the engine's value-then-lower-index tie-break).
        """
        spec = self.spec
        lead, kp = v.shape[:-1], v.shape[-1]
        v2 = v.reshape(-1, kp)
        i2 = i.reshape(-1, kp)
        lose = -np.inf if spec.largest else np.inf
        out_v = np.full((v2.shape[0], spec.k), lose, np.float32)
        out_i = np.full((v2.shape[0], spec.k), _PAD_IDX, np.int32)
        for r in range(v2.shape[0]):
            groups = {}
            for val, pi in zip(v2[r], i2[r]):
                if pi >= self.n_phys:
                    continue                    # padded losing slot
                lg = int(self.logical_of[pi])
                if lg < 0:
                    continue                    # dead row / unused spare
                groups.setdefault(lg, []).append(val)
            agg = sorted(
                ((float(np.median(vs)), lg) for lg, vs in groups.items()),
                key=(lambda t: (-t[0], t[1])) if spec.largest
                else (lambda t: (t[0], t[1])))
            for j, (val, lg) in enumerate(agg[:spec.k]):
                out_v[r, j] = val
                out_i[r, j] = lg
        return (out_v.reshape(lead + (spec.k,)),
                out_i.reshape(lead + (spec.k,)))

    def _finalize_range(self, match: np.ndarray) -> np.ndarray:
        """Strict-majority vote over each logical row's live replicas.

        A logical row matches iff more than half of its live physical
        copies match (use odd ``replicas`` — an even split loses).
        Rows with zero live copies never match.
        """
        lead = match.shape[:-1]
        m2 = match.reshape(-1, self.n_phys)
        onehot = np.zeros((self.n_phys, self.n), np.int32)
        live = self.logical_of >= 0
        onehot[np.nonzero(live)[0], self.logical_of[live]] = 1
        votes = m2.astype(np.int32) @ onehot
        quorum = onehot.sum(axis=0)[None, :]
        return (2 * votes > quorum).reshape(lead + (self.n,))

    # -- self-healing ------------------------------------------------------

    def heal(self, model, *, max_passes: Optional[int] = None,
             tolerance: Optional[float] = None) -> HealReport:
        """Detect faulty rows by checksum readback and remap to spares.

        ``model`` simulates the device readback
        (``corrupt_stored`` of the physical arrays).  Digital cells
        compare exactly (CRC32 of the readback row vs the stored
        checksum); analog cells use a tolerance —
        ``model.suggest_guard(z=4)`` by default — since Gaussian read
        noise perturbs *every* cell and only outliers (stuck cells,
        flipped bounds, excessive drift) indicate a row worth
        rewriting.  Each pass rewrites every detected row onto a free
        spare via the engine's ``update_rows``; the next pass checks
        the new positions (a spare can be faulty too — fault draws are
        position-keyed).  Healing never bumps the model's write epoch;
        callers model a scrub by passing ``model.rewritten()``.
        """
        if self._stored is None:
            raise RuntimeError("call prepare(*stored) before heal")
        if model is None or model.is_null:
            return HealReport(0, 0, 0, 0, len(self._free))
        if max_passes is None:
            max_passes = _heal_passes_default()
        if tolerance is None:
            tolerance = model.suggest_guard(z=4.0)
        detected = remapped = 0
        passes = 0
        # each physical position counts as one detection event, even if
        # it stays bad across passes (spares exhausted)
        seen_bad = np.zeros(self.n_phys, bool)
        for passes in range(1, max_passes + 1):
            bad = self._detect(model, tolerance)
            detected += int((bad & ~seen_bad).sum())
            seen_bad |= bad
            targets = np.nonzero(bad)[0]
            if targets.size == 0 or not self._free:
                break
            moves_from, moves_to = [], []
            for p in targets:
                if not self._free:
                    break
                moves_from.append(int(p))
                moves_to.append(self._free.pop(0))
            self._remap(np.array(moves_from, np.int64),
                        np.array(moves_to, np.int64))
            remapped += len(moves_to)
        self.heals += 1
        self.rows_remapped += remapped
        final_bad = self._detect(model, tolerance)
        detected += int((final_bad & ~seen_bad).sum())
        unrepairable = int(final_bad.sum())
        self.unrepairable = unrepairable
        return HealReport(detected=detected, remapped=remapped,
                          unrepairable=unrepairable, passes=passes,
                          spares_free=len(self._free))

    def _detect(self, model, tolerance: float) -> np.ndarray:
        """Faulty-live-row mask from a simulated readback."""
        readback = model.corrupt_stored(self._clean, self.phys_spec)
        bad = detect_faulty_rows(readback, self._clean, tolerance)
        return bad & (self.logical_of >= 0)

    def _remap(self, frm: np.ndarray, to: np.ndarray) -> None:
        """Rewrite the logical content of faulty rows onto spares.

        Goes through the plan's incremental ``update_rows`` (only the
        touched row tiles re-prepare) except for ternary plans, whose
        care cells ``update_rows`` cannot rewrite — those rebuild both
        physical operands host-side and take a full re-prepare on the
        next dispatch.
        """
        logical = self.logical_of[frm].astype(np.int64)
        rows = self._logical_rows(logical)
        ternary = not self.is_range and len(self._stored) > 1
        if ternary:
            for comp, blk in enumerate(rows):
                self._clean[comp][to] = blk
            self._stored = tuple(jnp.asarray(a) for a in self._clean)
        else:
            if len(self._stored) > 1:
                upd = self.plan.update_rows(self._stored, to, rows)
                self._stored = tuple(upd)
            else:
                upd = self.plan.update_rows(self._stored[0], to, rows[0])
                self._stored = (upd,)
            for comp, blk in enumerate(rows):
                self._clean[comp][to] = blk
        self._crc[to] = self._checksums(tuple(a[to] for a in self._clean))
        self.logical_of[to] = logical
        self.logical_of[frm] = -1

    # -- telemetry ---------------------------------------------------------

    def snapshot(self) -> dict:
        live = self.logical_of >= 0
        copies = np.bincount(self.logical_of[live], minlength=self.n) \
            if self._stored is not None else np.zeros(self.n, int)
        return {
            "replicas": self.replicas, "spares": self.spares,
            "guard": self.guard, "n": self.n, "n_phys": self.n_phys,
            "spares_free": len(self._free), "heals": self.heals,
            "rows_remapped": self.rows_remapped,
            "unrepairable": self.unrepairable,
            "min_live_copies": int(copies.min()) if copies.size else 0,
        }
