"""Deterministic CAM device-fault models.

The memristive CAM literature this repo compiles for (aCAM: arxiv
1907.08177; tree-in-CAM: arxiv 2103.08986) is explicit that stored
patterns are *analog device state*, not bits in DRAM: cells get stuck,
writes flip bits, conductances sit on a Gaussian around their target
and drift over time.  :class:`FaultModel` expresses those effects as a
**pure, seeded transformation of the stored operands** — the engine
corrupts the source gallery host-side before its (jitted,
fault-agnostic) prepare, so every backend and layout (jnp / sharded /
pallas, packed uint32 lanes and float slabs, both plan families)
executes the *same* faulted cells while oracles keep the clean ones.

Determinism contract:

* **stuck cells** are keyed on ``seed`` alone — permanent: the same
  physical cell is stuck across write epochs and time steps.
* **bit flips** and **analog noise** are keyed on ``(seed, epoch)`` —
  transient write-time effects: bumping ``epoch`` (a rewrite / scrub)
  redraws them.
* **drift** direction is keyed on ``seed``; its magnitude is
  ``drift * t`` — deterministic aging, reset by a rewrite in the
  hardening layer's remap path.

Corruption happens in the *source metric domain* (bipolar ±1 cells for
dot/cos, {0, 1} cells for hamming, raw floats for euclidean, ``(lo,
hi)`` bounds for aCAM intervals), so the packed and unpacked encodings
of a faulted gallery are bit-identical — a flip lands in the uint32
lane and the float slab alike.  Care masks (ternary wildcard config)
pass through clean: faults target the stored pattern conductances.
"""

from dataclasses import dataclass, replace
from typing import Any, Dict, Tuple

import numpy as np

__all__ = ["FaultModel"]

#: SeedSequence spawn keys — distinct per effect so draws never alias
_TAG_STUCK, _TAG_FLIP, _TAG_NOISE, _TAG_DRIFT = 1, 2, 3, 4


@dataclass(frozen=True)
class FaultModel:
    """Seeded, fully deterministic CAM fault model.

    Frozen and hashable on purpose: the engine keys its prepared-
    pattern memo on ``(sources, fault model)``, so two dispatches with
    the same model reuse one corrupted layout, and the clean entry
    (``faults=None``) is never polluted.
    """

    seed: int = 0
    #: per-cell probability of a *permanent* stuck cell (split evenly
    #: between stuck-at-0 and stuck-at-1)
    p_stuck: float = 0.0
    #: per-cell probability of a *transient* write-time bit flip
    #: (redrawn each write ``epoch``); on analog cells a flip swaps the
    #: cell to its complementary extreme
    p_flip: float = 0.0
    #: std-dev of per-cell Gaussian conductance noise on analog cells /
    #: interval bounds (redrawn each write ``epoch``)
    sigma: float = 0.0
    #: per-time-step deterministic conductance drift magnitude; each
    #: cell drifts in a fixed (seeded) direction by ``drift * t``
    drift: float = 0.0
    #: elapsed time steps since the last write (drives drift)
    t: int = 0
    #: write epoch — bump on rewrite/scrub to redraw transient effects
    epoch: int = 0
    #: analog value a stuck-at-1 cell reads back as
    stuck_hi: float = 1.0

    def __post_init__(self):
        if self.seed < 0 or self.t < 0 or self.epoch < 0:
            raise ValueError("seed, t and epoch must be non-negative")
        for name in ("p_stuck", "p_flip"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.sigma < 0 or self.drift < 0:
            raise ValueError("sigma and drift must be non-negative")

    # -- identity ----------------------------------------------------------

    @property
    def is_null(self) -> bool:
        """True when the model cannot corrupt anything — the engine
        normalises null models to ``None`` so ``FaultModel(p_stuck=0)``
        is *bit-identical* to running with no fault model at all."""
        return (self.p_stuck == 0.0 and self.p_flip == 0.0
                and self.sigma == 0.0 and (self.drift == 0.0 or self.t == 0))

    def rewritten(self) -> "FaultModel":
        """The model after a gallery rewrite: transient flips/noise are
        redrawn (new epoch) and drift restarts from the fresh write."""
        return replace(self, epoch=self.epoch + 1, t=0)

    def aged(self, steps: int) -> "FaultModel":
        """The model ``steps`` time steps later (drift accumulates)."""
        return replace(self, t=self.t + int(steps))

    def suggest_guard(self, z: float = 2.0) -> float:
        """aCAM sensing guard-band: widen interval bounds by ``z``
        noise std-devs plus the accumulated drift, trading false-match
        rate for miss rate (see docs/robustness.md)."""
        return float(z * self.sigma + self.drift * self.t)

    # -- deterministic draws -----------------------------------------------

    def _rng(self, tag: int, *extra: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, tag, *extra]))

    def stuck_masks(self, shape: Tuple[int, ...]):
        """Permanent stuck-cell masks ``(stuck0, stuck1)`` for a stored
        operand of ``shape`` — keyed on seed + shape only, invariant
        across epochs and time steps."""
        u = self._rng(_TAG_STUCK, *shape).random(shape)
        return u < self.p_stuck / 2.0, \
            (u >= self.p_stuck / 2.0) & (u < self.p_stuck)

    def flip_mask(self, shape: Tuple[int, ...]):
        """Transient write-time bit-flip mask — redrawn per epoch."""
        rng = self._rng(_TAG_FLIP, self.epoch, *shape)
        return rng.random(shape) < self.p_flip

    def noise(self, shape: Tuple[int, ...], comp: int = 0) -> np.ndarray:
        """Per-cell Gaussian conductance noise — redrawn per epoch;
        ``comp`` separates the draws for multi-component operands
        (interval ``lo`` vs ``hi``)."""
        rng = self._rng(_TAG_NOISE, self.epoch, comp, *shape)
        return (self.sigma * rng.standard_normal(shape)).astype(np.float32)

    def drift_shift(self, shape: Tuple[int, ...], comp: int = 0) -> np.ndarray:
        """Deterministic drift offset ``±drift * t`` with a per-cell
        fixed (seeded) direction."""
        rng = self._rng(_TAG_DRIFT, comp, *shape)
        sign = np.where(rng.random(shape) < 0.5, -1.0, 1.0)
        return (sign * (self.drift * self.t)).astype(np.float32)

    # -- domain corruptions ------------------------------------------------

    def corrupt_bits(self, x: np.ndarray, *, bipolar: bool) -> np.ndarray:
        """Corrupt binary cells.

        ``bipolar`` selects the ±1 alphabet (dot/cos galleries, where
        the CAM stores the sign bit via ``x > 0``); otherwise {0, 1}
        (hamming).  Flips first, then stuck cells (a stuck cell wins
        over any write).
        """
        x = np.asarray(x)
        bits = (x > 0) if bipolar else (x != 0)
        bits = bits ^ self.flip_mask(bits.shape)
        s0, s1 = self.stuck_masks(bits.shape)
        bits = (bits | s1) & ~s0
        if bipolar:
            return np.where(bits, 1.0, -1.0).astype(np.float32)
        return bits.astype(np.float32)

    def corrupt_analog(self, x: np.ndarray) -> np.ndarray:
        """Corrupt analog cells (euclidean galleries): Gaussian noise +
        drift, flips swing the cell to its complementary extreme, stuck
        cells read 0 / ``stuck_hi``."""
        x = np.asarray(x, np.float32)
        y = x + self.noise(x.shape) + self.drift_shift(x.shape)
        flip = self.flip_mask(x.shape)
        y = np.where(flip, np.float32(self.stuck_hi) - y, y)
        s0, s1 = self.stuck_masks(x.shape)
        y = np.where(s0, np.float32(0.0), y)
        y = np.where(s1, np.float32(self.stuck_hi), y)
        return y.astype(np.float32)

    def corrupt_interval(self, lo: np.ndarray, hi: np.ndarray):
        """Corrupt aCAM interval bounds.

        Noise and drift move each bound independently (widening *or*
        narrowing the acceptance band); ±inf wildcard bounds are
        unaffected by additive noise by IEEE arithmetic.  A flipped
        cell swaps its bounds (an inverted programming pulse); a
        stuck-at-1 cell always conducts (wildcard ``(-inf, +inf)``), a
        stuck-at-0 cell never matches (empty ``(+inf, -inf)``).
        """
        lo = np.asarray(lo, np.float32)
        hi = np.asarray(hi, np.float32)
        shape = lo.shape
        lo2 = lo + self.noise(shape, 0) + self.drift_shift(shape, 0)
        hi2 = hi + self.noise(shape, 1) + self.drift_shift(shape, 1)
        flip = self.flip_mask(shape)
        lo2, hi2 = (np.where(flip, hi2, lo2).astype(np.float32),
                    np.where(flip, lo2, hi2).astype(np.float32))
        s0, s1 = self.stuck_masks(shape)
        inf = np.float32(np.inf)
        lo2 = np.where(s1, -inf, np.where(s0, inf, lo2))
        hi2 = np.where(s1, inf, np.where(s0, -inf, hi2))
        return lo2.astype(np.float32), hi2.astype(np.float32)

    # -- engine entry point ------------------------------------------------

    def corrupt_stored(self, srcs: Tuple[Any, ...], spec) -> Tuple[Any, ...]:
        """Corrupt a plan's stored operands according to its spec.

        ``srcs`` is the stored-operand tuple exactly as the plan sees
        it — ``(gallery,)`` / ``(gallery, care)`` for similarity,
        ``(patterns,)`` / ``(lo, hi)`` for range — and the same
        structure comes back with the pattern cells faulted.  Dispatch
        is duck-typed on the spec (``mode`` marks a range spec) so this
        module never imports the engine.
        """
        if getattr(spec, "mode", None) == "interval":
            return self.corrupt_interval(srcs[0], srcs[1])
        metric = spec.metric
        pat = np.asarray(srcs[0])
        if metric in ("dot", "cos"):
            out = self.corrupt_bits(pat, bipolar=True)
        elif metric == "hamming":
            out = self.corrupt_bits(pat, bipolar=False)
        else:
            out = self.corrupt_analog(pat)
        return (out,) + tuple(srcs[1:])

    # -- telemetry ---------------------------------------------------------

    def cell_fault_counts(self, shape: Tuple[int, ...]) -> Dict[str, int]:
        """Realised fault counts for a stored operand of ``shape`` —
        surfaced by the serving ``health()`` endpoint."""
        s0, s1 = self.stuck_masks(shape)
        return {"stuck0": int(s0.sum()), "stuck1": int(s1.sum()),
                "flips": int(self.flip_mask(shape).sum())}
