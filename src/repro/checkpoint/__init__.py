"""Fault-tolerant sharded checkpointing.

Design (1000+ node posture, DESIGN.md §6):

* **step-numbered directories** ``ckpt_dir/step_000123/`` written by every
  host for its local shards (``host_<i>.npz``) plus one ``manifest.json``
  (tree structure, global shapes, logical sharding axes, step, mesh shape);
* **atomic commit**: writes go to ``step_X.tmp`` and are ``os.rename``d
  only after all arrays + manifest are fsynced — a crash mid-write never
  corrupts the latest checkpoint;
* **async save**: ``AsyncCheckpointer`` snapshots device arrays to host
  memory synchronously (cheap) and does file I/O on a worker thread so the
  train loop is not blocked; ``wait()`` joins before the next save.
* **elastic restore**: arrays are saved with *global* content (per-shard
  addressable data is gathered per host); restore re-shards to whatever
  mesh/sharding the new job passes — checkpoints store logical, not
  physical, layout.
"""

from .checkpointer import (AsyncCheckpointer, latest_step, restore_pytree,
                           save_pytree)

__all__ = ["AsyncCheckpointer", "save_pytree", "restore_pytree", "latest_step"]
