"""Atomic, async, elastic checkpoint I/O (see package docstring)."""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_pytree", "restore_pytree", "latest_step", "AsyncCheckpointer"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_names(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out


def _treedef_spec(tree: Any) -> Any:
    """JSON-able structure descriptor (nested dicts/lists with leaf=None)."""
    if isinstance(tree, dict):
        return {k: _treedef_spec(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {"__seq__": type(tree).__name__,
                "items": [_treedef_spec(v) for v in tree]}
    if hasattr(tree, "_fields"):  # NamedTuple
        return {"__namedtuple__": type(tree).__name__,
                "fields": {k: _treedef_spec(getattr(tree, k))
                           for k in tree._fields}}
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := _STEP_RE.match(d)) and
             os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def save_pytree(tree: Any, ckpt_dir: str, step: int,
                extra_metadata: Optional[Dict[str, Any]] = None) -> str:
    """Atomic save.  Returns the committed directory path."""
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + f".tmp.{jax.process_index()}"
    os.makedirs(tmp, exist_ok=True)

    named = _flatten_with_names(tree)
    arrays: Dict[str, np.ndarray] = {}
    manifest_arrays = {}
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        true_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or true_dtype in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
            # npz cannot round-trip ml_dtypes: store the raw bits and
            # record the logical dtype in the manifest
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        arrays[name] = arr
        manifest_arrays[name] = {"shape": list(arr.shape),
                                 "dtype": true_dtype}
    path = os.path.join(tmp, f"host_{jax.process_index()}.npz")
    with open(path, "wb") as f:
        np.savez(f, **{k.replace("/", "|"): v for k, v in arrays.items()})
        f.flush()
        os.fsync(f.fileno())

    manifest = {"step": step, "arrays": manifest_arrays,
                "process_count": jax.process_count(),
                "structure": "flat-names",
                **(extra_metadata or {})}
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())

    # atomic commit (process 0 renames; single-host in this container)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_pytree(template: Any, ckpt_dir: str, step: Optional[int] = None,
                   shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree of NamedShardings (same structure) — the
    elastic-restore path: saved global arrays are placed onto the *new*
    mesh regardless of the writer's topology.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    data: Dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(d)):
        if fn.startswith("host_") and fn.endswith(".npz"):
            with np.load(os.path.join(d, fn)) as z:
                for k in z.files:
                    data[k.replace("|", "/")] = z[k]

    named = _flatten_with_names(template)
    shard_list = (None,) * len(named)
    if shardings is not None:
        shard_list = [s for _, s in _flatten_with_names(shardings)]

    leaves = []
    meta = manifest.get("arrays", {})
    for (name, leaf), sh in zip(named, shard_list):
        if name not in data:
            raise KeyError(f"checkpoint missing array {name!r}")
        arr = data[name]
        true_dtype = meta.get(name, {}).get("dtype", str(arr.dtype))
        if str(arr.dtype) != true_dtype and arr.dtype.kind == "u":
            import ml_dtypes
            arr = arr.view(np.dtype(true_dtype))
        want = tuple(np.asarray(leaf).shape) if not hasattr(leaf, "shape") \
            else tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs {want}")
        dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Non-blocking saver: device->host snapshot now, file I/O on a thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, tree: Any, step: int,
             extra_metadata: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_pytree(host_tree, self.ckpt_dir, step, extra_metadata)
                self._gc()
            except BaseException as e:   # surfaced at next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(int(m.group(1)) for d in os.listdir(self.ckpt_dir)
                       if (m := _STEP_RE.match(d)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:09d}"),
                          ignore_errors=True)
