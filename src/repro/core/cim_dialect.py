"""The extended ``cim`` abstraction (paper §III-D1).

The programming model (from CINM [16]) is three functions:

* ``cim.acquire  -> !cim.device``  — allocate an accelerator, returns handle
* ``cim.execute (handle) { region } -> results`` — ops to run on the device
* ``cim.release (handle)``

C4CAM extends ``cim`` with the analyses/ops needed for CAM devices:

* compute ops mirroring torch (``cim.matmul`` etc.) inside execute regions,
* the fused ``cim.similarity`` op produced by Algorithm 1,
* partitioning ops: ``cim.search_tile`` (per-subarray distance block),
  ``cim.topk_tile`` and ``cim.merge_partial`` (horizontal = accumulate
  partial distances across column tiles, vertical = tournament-merge
  candidate lists across row tiles) — Fig. 5d.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from .ir import Block, Builder, IRError, Module, Operation, Region, TensorType, Value

__all__ = [
    "DEVICE_TYPE", "make_acquire", "make_execute", "make_release",
    "make_yield", "make_similarity", "execute_blocks", "CIM_COMPUTE_OPS",
]

#: pseudo-type for device handles (shape (), dtype tag)
DEVICE_TYPE = TensorType((), "!cim.device")

#: torch op -> cim op name (ops the accelerator abstraction understands)
CIM_COMPUTE_OPS: Dict[str, str] = {
    "torch.transpose": "cim.transpose",
    "torch.matmul": "cim.matmul",
    "torch.mm": "cim.matmul",
    "torch.sub": "cim.sub",
    "torch.add": "cim.add",
    "torch.mul": "cim.mul",
    "torch.div": "cim.div",
    "torch.norm": "cim.norm",
    "torch.topk": "cim.topk",
    "torch.neg": "cim.neg",
    "torch.abs": "cim.abs",
    "torch.unsqueeze": "cim.unsqueeze",
    "torch.squeeze": "cim.squeeze",
}

#: pure shape-metadata ops — transparent to Algorithm 1's opSize gate
SHAPE_OPS = {"cim.unsqueeze", "cim.squeeze"}


def make_acquire(builder: Builder) -> Operation:
    return builder.create("cim.acquire", [], [DEVICE_TYPE])


def make_release(builder: Builder, handle: Value) -> Operation:
    return builder.create("cim.release", [handle])


def make_yield(block: Block, values: Sequence[Value]) -> Operation:
    op = Operation("cim.yield", values)
    block.append(op)
    return op


def make_execute(builder: Builder, handle: Value, operands: Sequence[Value],
                 result_types: Sequence[TensorType]) -> Operation:
    """Creates ``cim.execute`` with an empty single-block region.

    The region's ops reference outer SSA values directly (MLIR
    ``isolated_from_above = false`` semantics).
    """
    region = Region([Block()])
    return builder.create("cim.execute", [handle, *operands], result_types,
                          regions=[region])


def make_similarity(block: Block, queries: Value, patterns: Value, *,
                    metric: str, k: int, largest: bool,
                    care: Optional[Value] = None,
                    extra_attrs: Optional[Dict[str, Any]] = None) -> Operation:
    """``cim.similarity``: fused distance + top-k (paper Fig. 5c).

    queries ``(M, D)``, patterns ``(N, D)`` -> values/indices ``(M, k)``.

    ``care`` (TCAM ternary search, hamming only): a per-pattern
    ``(N, D)`` wildcard mask as a third operand — non-zero cells are
    compared, zero cells are "don't care" and never mismatch.  This is
    the TCAM cell's third state surfaced at the ``cim`` level; the
    search-plan engine lowers it to a bit-packed
    ``popcount((q ^ p) & care)`` match.
    """
    m = queries.type.shape[0] if queries.type.rank == 2 else 1
    attrs = {"metric": metric, "k": k, "largest": largest}
    if care is not None:
        if metric != "hamming":
            raise IRError("care masks (ternary TCAM search) require "
                          f"metric='hamming', got {metric!r}")
        attrs["ternary"] = True
    if extra_attrs:
        attrs.update(extra_attrs)
    operands = [queries, patterns] if care is None else \
        [queries, patterns, care]
    op = Operation("cim.similarity", operands,
                   [TensorType((m, k), queries.type.dtype),
                    TensorType((m, k), "i32")], attrs)
    block.append(op)
    return op


def execute_blocks(module: Module) -> List[Operation]:
    """All ``cim.execute`` ops in program order."""
    return [op for op in module.body.operations if op.name == "cim.execute"]
