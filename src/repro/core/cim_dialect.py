"""The extended ``cim`` abstraction (paper §III-D1).

The programming model (from CINM [16]) is three functions:

* ``cim.acquire  -> !cim.device``  — allocate an accelerator, returns handle
* ``cim.execute (handle) { region } -> results`` — ops to run on the device
* ``cim.release (handle)``

C4CAM extends ``cim`` with the analyses/ops needed for CAM devices:

* compute ops mirroring torch (``cim.matmul`` etc.) inside execute regions,
* the fused ``cim.similarity`` op produced by Algorithm 1,
* partitioning ops: ``cim.search_tile`` (per-subarray distance block),
  ``cim.topk_tile`` and ``cim.merge_partial`` (horizontal = accumulate
  partial distances across column tiles, vertical = tournament-merge
  candidate lists across row tiles) — Fig. 5d.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from .arch import Metric
from .ir import Block, Builder, IRError, Module, Operation, Region, TensorType, Value

__all__ = [
    "DEVICE_TYPE", "make_acquire", "make_execute", "make_release",
    "make_yield", "make_similarity", "make_range_search", "execute_blocks",
    "CIM_COMPUTE_OPS",
]

#: pseudo-type for device handles (shape (), dtype tag)
DEVICE_TYPE = TensorType((), "!cim.device")

#: torch op -> cim op name (ops the accelerator abstraction understands)
CIM_COMPUTE_OPS: Dict[str, str] = {
    "torch.transpose": "cim.transpose",
    "torch.matmul": "cim.matmul",
    "torch.mm": "cim.matmul",
    "torch.sub": "cim.sub",
    "torch.add": "cim.add",
    "torch.mul": "cim.mul",
    "torch.div": "cim.div",
    "torch.norm": "cim.norm",
    "torch.topk": "cim.topk",
    "torch.neg": "cim.neg",
    "torch.abs": "cim.abs",
    "torch.unsqueeze": "cim.unsqueeze",
    "torch.squeeze": "cim.squeeze",
}

#: pure shape-metadata ops — transparent to Algorithm 1's opSize gate
SHAPE_OPS = {"cim.unsqueeze", "cim.squeeze"}


def make_acquire(builder: Builder) -> Operation:
    return builder.create("cim.acquire", [], [DEVICE_TYPE])


def make_release(builder: Builder, handle: Value) -> Operation:
    return builder.create("cim.release", [handle])


def make_yield(block: Block, values: Sequence[Value]) -> Operation:
    op = Operation("cim.yield", values)
    block.append(op)
    return op


def make_execute(builder: Builder, handle: Value, operands: Sequence[Value],
                 result_types: Sequence[TensorType]) -> Operation:
    """Creates ``cim.execute`` with an empty single-block region.

    The region's ops reference outer SSA values directly (MLIR
    ``isolated_from_above = false`` semantics).
    """
    region = Region([Block()])
    return builder.create("cim.execute", [handle, *operands], result_types,
                          regions=[region])


def make_similarity(block: Block, queries: Value, patterns: Value, *,
                    metric: str, k: int, largest: bool,
                    care: Optional[Value] = None,
                    extra_attrs: Optional[Dict[str, Any]] = None) -> Operation:
    """``cim.similarity``: fused distance + top-k (paper Fig. 5c).

    queries ``(M, D)``, patterns ``(N, D)`` -> values/indices ``(M, k)``.

    ``care`` (TCAM ternary search, hamming only): a per-pattern
    ``(N, D)`` wildcard mask as a third operand — non-zero cells are
    compared, zero cells are "don't care" and never mismatch.  This is
    the TCAM cell's third state surfaced at the ``cim`` level; the
    search-plan engine lowers it to a bit-packed
    ``popcount((q ^ p) & care)`` match.
    """
    m = queries.type.shape[0] if queries.type.rank == 2 else 1
    attrs = {"metric": Metric.validate(metric), "k": k, "largest": largest}
    if care is not None:
        if metric != "hamming":
            raise IRError("care masks (ternary TCAM search) require "
                          f"metric='hamming', got {metric!r}")
        attrs["ternary"] = True
    if extra_attrs:
        attrs.update(extra_attrs)
    operands = [queries, patterns] if care is None else \
        [queries, patterns, care]
    op = Operation("cim.similarity", operands,
                   [TensorType((m, k), queries.type.dtype),
                    TensorType((m, k), "i32")], attrs)
    block.append(op)
    return op


def make_range_search(block: Block, queries: Value, *,
                      patterns: Optional[Value] = None,
                      lo: Optional[Value] = None, hi: Optional[Value] = None,
                      metric: Optional[str] = None,
                      threshold: Optional[float] = None, below: bool = True,
                      extra_attrs: Optional[Dict[str, Any]] = None
                      ) -> Operation:
    """``cim.range_search``: boolean match search (paper §II ``TH`` mode).

    Two forms, both returning one ``(M, N)`` ``i1`` match matrix:

    * **threshold** — ``patterns`` + ``metric`` + ``threshold``: row
      ``j`` matches query ``i`` iff its distance/similarity is at/below
      the threshold (``below=True``, the TH discharge contract of
      :func:`repro.kernels.ref.cam_range`) or at/above it
      (``below=False`` — "at least this similar" for dot/cos).
    * **interval** (analog CAM) — ``lo`` + ``hi``, each ``(N, D)``: row
      ``j`` matches iff ``lo[j, d] <= q[i, d] <= hi[j, d]`` for every
      dimension; a wildcard dimension stores the full-range interval.
      This is the aCAM cell contract
      (:func:`repro.kernels.ref.acam_match`) that maps decision-forest
      branches onto CAM rows.
    """
    m = queries.type.shape[0] if queries.type.rank == 2 else 1
    attrs: Dict[str, Any] = {}
    if lo is not None or hi is not None:
        if lo is None or hi is None or patterns is not None or \
                metric is not None or threshold is not None:
            raise IRError("interval range search takes exactly lo + hi "
                          "(no patterns/metric/threshold)")
        if lo.type.shape != hi.type.shape:
            raise IRError(f"lo/hi shape mismatch: {lo.type.shape} vs "
                          f"{hi.type.shape}")
        n = lo.type.shape[-2]
        attrs.update(mode="interval")
        operands = [queries, lo, hi]
    else:
        if patterns is None or metric is None or threshold is None:
            raise IRError("threshold range search needs patterns + metric "
                          "+ threshold")
        n = patterns.type.shape[-2]
        attrs.update(mode="threshold", metric=Metric.validate(metric),
                     threshold=float(threshold), below=bool(below))
        operands = [queries, patterns]
    if extra_attrs:
        attrs.update(extra_attrs)
    op = Operation("cim.range_search", operands,
                   [TensorType((m, n), "i1")], attrs)
    block.append(op)
    return op


def execute_blocks(module: Module) -> List[Operation]:
    """All ``cim.execute`` ops in program order."""
    return [op for op in module.body.operations if op.name == "cim.execute"]
