"""Search-plan execution engine: compiled, cached execution of ``cim`` IR.

The functional executor (:mod:`repro.core.executor`) interprets the
partitioned ``cim`` IR op-by-op — every ``cim.search_tile`` /
``cim.merge_partial`` / ``cim.topk_tile`` dispatches eagerly, and the
vectorized fallback rebuilds its search closure on every call.  That is
fine for pinning semantics, but it makes DSE sweeps (Fig. 8, Table II)
pay Python-loop and retrace costs at every design point.

This module compiles a partitioned similarity program **once** into a
:class:`SearchPlan`:

* ``extract_plan_spec`` structurally analyses the ``cim_partitioned``
  module (either the explicit Fig.-5d tile ops or the loop-structured
  ``cim.tiled_similarity`` form) and distils it to a
  :class:`SimilaritySpec` — metric, k, tile geometry, grid, operand
  wiring and output shapes.  Anything that is not a pure similarity
  program yields ``None`` and execution falls back to the interpreter.
* ``get_plan`` keys a **process-wide plan cache** on
  ``(spec, backend, micro-batch)``: recompiling the same program — or a
  different program with identical structure, which is exactly what a
  DSE sweep over optimization targets produces — returns the *same*
  ``SearchPlan`` object and reuses its jitted executable instead of
  re-tracing.
* The plan's executable replaces the per-tile Python loops with a
  ``jax.lax.scan`` over row tiles (vertical tournament merge carried
  through the scan) around an inner scan over column tiles (horizontal
  partial-distance accumulation).  Peak intermediate is one
  ``(batch, tile_rows)`` distance block — never the dense ``(M, N)``
  matrix.
* Queries are **micro-batched**: M is chunked into plan-sized batches
  streamed through the jitted executable, so million-query workloads
  reuse one trace and bounded memory.  Pattern encoding/padding is
  hoisted out of the per-chunk path (and memoised per input array), so
  repeated executions against the same stored patterns skip it entirely.

Numerical contract: the plan performs the *same* arithmetic in the same
order as the interpreted tile ops — bit-identical results for the
integer metrics (hamming / dot), float-tolerance for eucl / cos — as
pinned by ``repro.kernels.ref``.
"""

from __future__ import annotations

import os
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ref as kref
from .ir import Module

__all__ = [
    "SimilaritySpec", "SearchPlan", "extract_plan_spec", "get_plan",
    "plan_cache_stats", "clear_plan_cache",
]


# ---------------------------------------------------------------------------
# Metric / encoding helpers (physical CAM domain <-> logical metric domain)
# ---------------------------------------------------------------------------


def _metric_values(metric: str, largest: bool):
    """How the physical CAM search relates to the logical metric."""
    if metric in ("dot", "cos"):
        # bipolar: argmax dot == argmin hamming; report dot values
        return "hamming", (lambda h, dim: dim - 2.0 * h), (not largest)
    if metric == "eucl":
        return "eucl", (lambda d, dim: d), largest
    if metric == "hamming":
        return "hamming", (lambda h, dim: h), largest
    raise ValueError(metric)


def _encode(x: jax.Array, metric: str) -> jax.Array:
    if metric in ("dot", "cos", "hamming"):
        return (x > 0).astype(jnp.float32) if metric != "hamming" else x
    return x


def _as_2d(q: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    if q.ndim == 1:
        return q[None, :], ()
    if q.ndim == 2:
        return q, (q.shape[0],)
    lead = q.shape[:-1]
    return q.reshape((-1, q.shape[-1])), lead


# ---------------------------------------------------------------------------
# Plan spec: everything a compiled search needs, hashable for the cache
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimilaritySpec:
    """Structural summary of a partitioned similarity program.

    Two modules with equal specs compile to interchangeable executables;
    the spec (plus backend and micro-batch size) *is* the plan-cache key.
    """

    metric: str
    k: int
    largest: bool              # logical polarity (metric domain)
    tile_rows: int             # R: pattern rows per subarray
    dims_per_tile: int         # logical values per column tile
    grid_rows: int
    grid_cols: int
    m: int                     # traced query count (batch hint only)
    n: int                     # pattern rows
    dim: int                   # logical feature dimension
    query_arg: int             # positions in module.arguments
    pattern_arg: int
    out_v_shape: Tuple[int, ...]
    out_i_shape: Tuple[int, ...]


_SIM_OPS = {"cim.similarity", "cim.tiled_similarity"}
_TILE_OPS = {"cim.search_tile", "cim.merge_partial", "cim.topk_tile",
             "cim.reshape_result"}


def extract_plan_spec(module: Module) -> Optional[SimilaritySpec]:
    """Return the spec if ``module`` is a pure similarity program.

    Accepted shape: ``cim.acquire`` / one ``cim.execute`` whose region is a
    single fused (or partitioned) similarity / ``cim.release`` /
    ``func.return`` of the execute's two results.  Host ops, multiple
    similarities, or operands that are not module arguments all return
    ``None`` (the interpreter remains the general path).
    """
    args = module.arguments
    arg_pos = {id(a): i for i, a in enumerate(args)}
    execute = None
    ret = None
    for op in module.body.operations:
        if op.name in ("cim.acquire", "cim.release"):
            continue
        if op.name == "cim.execute":
            if execute is not None:
                return None
            execute = op
            continue
        if op.name == "func.return":
            ret = op
            continue
        return None
    if execute is None or ret is None or len(execute.results) != 2:
        return None
    if [id(v) for v in ret.operands] != [id(r) for r in execute.results]:
        return None

    body = execute.body_ops()
    names = {op.name for op in body} - {"cim.yield"}
    if names and names <= _SIM_OPS and len(body) == 2:
        sim = body[0]
        yld = body[1]
        if yld.name != "cim.yield" or \
                [id(v) for v in yld.operands] != [id(r) for r in sim.results]:
            return None
        q, p = sim.operands
        if id(q) not in arg_pos or id(p) not in arg_pos:
            return None
        a = sim.attributes
        n, dim = p.type.shape[-2], p.type.shape[-1]
        tr = int(a.get("tile_rows", 0)) or n
        dpt = int(a.get("dims_per_tile", 0)) or dim
        gr = int(a.get("grid_rows", 0)) or -(-n // tr)
        gc = int(a.get("grid_cols", 0)) or -(-dim // dpt)
        m = 1
        for d in q.type.shape[:-1]:
            m *= d
        return SimilaritySpec(
            metric=a["metric"], k=int(a["k"]), largest=bool(a["largest"]),
            tile_rows=tr, dims_per_tile=dpt, grid_rows=gr, grid_cols=gc,
            m=m, n=n, dim=dim,
            query_arg=arg_pos[id(q)], pattern_arg=arg_pos[id(p)],
            out_v_shape=tuple(sim.results[0].type.shape),
            out_i_shape=tuple(sim.results[1].type.shape))

    if names and names <= _TILE_OPS:
        return _spec_from_unrolled(body, arg_pos)
    return None


def _spec_from_unrolled(body, arg_pos) -> Optional[SimilaritySpec]:
    """Reconstruct the spec from explicit Fig.-5d tile ops."""
    searches = [op for op in body if op.name == "cim.search_tile"]
    topks = [op for op in body if op.name == "cim.topk_tile"]
    reshapes = [op for op in body if op.name == "cim.reshape_result"]
    yields = [op for op in body if op.name == "cim.yield"]
    if not searches or not topks or len(reshapes) != 1 or len(yields) != 1:
        return None
    fin, yld = reshapes[0], yields[0]
    if [id(v) for v in yld.operands] != [id(r) for r in fin.results]:
        return None
    first = searches[0]
    q, p = first.operands
    if id(q) not in arg_pos or id(p) not in arg_pos:
        return None
    for st in searches:
        if [id(v) for v in st.operands] != [id(q), id(p)]:
            return None
    sa = first.attributes
    metric = sa["metric"]
    phys_largest = bool(sa.get("phys_largest", False))
    largest = (not phys_largest) if metric in ("dot", "cos") else phys_largest
    gr = 1 + max(int(op.attributes["row_tile"]) for op in searches)
    gc = 1 + max(int(op.attributes["col_tile"]) for op in searches)
    if len(searches) != gr * gc or len(topks) != gr:
        return None
    n, dim = p.type.shape[-2], p.type.shape[-1]
    fa = fin.attributes
    return SimilaritySpec(
        metric=metric, k=int(fa["k"]), largest=largest,
        tile_rows=int(sa["tile_rows"]), dims_per_tile=int(sa["dims_per_tile"]),
        grid_rows=gr, grid_cols=gc, m=int(fa["m"]), n=n, dim=dim,
        query_arg=arg_pos[id(q)], pattern_arg=arg_pos[id(p)],
        out_v_shape=tuple(fin.results[0].type.shape),
        out_i_shape=tuple(fin.results[1].type.shape))


# ---------------------------------------------------------------------------
# Compiled executables
# ---------------------------------------------------------------------------

def _pick_batch(m: int) -> int:
    """Micro-batch size: next power of two, clamped to the chunk cap."""
    cap = int(os.environ.get("REPRO_ENGINE_MAX_CHUNK", "1024"))
    b = 8
    while b < min(max(m, 1), cap):
        b *= 2
    return b


def _build_scan_executable(spec: SimilaritySpec, batch: int):
    """(prepare_patterns, chunk_fn) for the jnp (reference-tiled) backend.

    ``chunk_fn`` mirrors ``kernels.ref.cam_topk_tiled`` exactly — same
    partial-sum order, same stable top-k and tournament merges — but as a
    ``lax.scan`` over the (row_tile, col_tile) grid, so the jaxpr stays
    small at any grid size and XLA pipelines the tiles.
    """
    metric, k = spec.metric, spec.k
    phys_metric, to_logical, phys_largest = _metric_values(metric, spec.largest)
    tr, dpt, gr, gc = (spec.tile_rows, spec.dims_per_tile,
                       spec.grid_rows, spec.grid_cols)
    n, dim = spec.n, spec.dim
    kk = min(k, tr)
    lose = -jnp.inf if phys_largest else jnp.inf

    def prepare(p):
        pe = _encode(jnp.asarray(p), metric).astype(jnp.float32)
        pe = jnp.pad(pe, ((0, gr * tr - n), (0, gc * dpt - dim)))
        # (gr, gc, tr, dpt): one leaf per (row_tile, col_tile) subarray
        return pe.reshape(gr, tr, gc, dpt).transpose(0, 2, 1, 3)

    def chunk_fn(q, pt):
        qe = _encode(q, metric).astype(jnp.float32)
        qp = jnp.pad(qe, ((0, 0), (0, gc * dpt - dim)))
        qt = qp.reshape(batch, gc, dpt).transpose(1, 0, 2)   # (gc, B, dpt)

        def tile_topk(pr, roff):
            """Per-row-tile candidate list (pr: (gc, tr, dpt))."""

            def col_step(acc, qc_pc):
                qc, pc = qc_pc          # horizontal merge, oracle arithmetic
                return acc + kref.distances(qc, pc, phys_metric), None

            dist, _ = jax.lax.scan(
                col_step, jnp.zeros((batch, tr), jnp.float32), (qt, pr))
            gidx = roff + jnp.arange(tr, dtype=jnp.int32)
            dist = jnp.where(gidx[None, :] < n, dist, lose)  # ragged rows
            key = dist if phys_largest else -dist
            _, idx = jax.lax.top_k(key, kk)
            v = jnp.take_along_axis(dist, idx, axis=-1)
            i = idx.astype(jnp.int32) + roff
            return kref.pad_candidates(v, i, k, phys_largest)

        def row_step(carry, xs):
            cv, ci = carry                                   # vertical merge
            v, i = tile_topk(*xs)
            return kref.merge_topk(cv, ci, v, i, k=k,
                                   largest=phys_largest), None

        # tile 0 seeds the tournament (its padded-slot indices are real
        # column positions, which the interpreter also reports), remaining
        # row tiles stream through the scan.
        roffs = jnp.arange(gr, dtype=jnp.int32) * tr
        init = tile_topk(pt[0], roffs[0])
        (v, i), _ = jax.lax.scan(row_step, init, (pt[1:], roffs[1:]))
        return to_logical(v, float(dim)), i

    return jax.jit(prepare), jax.jit(chunk_fn)


def _build_pallas_executable(spec: SimilaritySpec, batch: int):
    """(prepare_patterns, chunk_fn) driving the fused Pallas kernel.

    Pattern encoding and block padding run once per stored array (hoisted
    behind the plan cache) instead of on every ``cam_topk`` call.
    """
    from ..kernels import ops as kops

    metric, k = spec.metric, spec.k
    phys_metric, to_logical, phys_largest = _metric_values(metric, spec.largest)
    n, dim = spec.n, spec.dim
    k_eff = min(k, n)
    bn = max(8, min(spec.tile_rows, n))
    bd = min(spec.dims_per_tile, dim)
    bm = min(128, max(8, batch))

    def prepare(p):
        pe = _encode(jnp.asarray(p), metric).astype(jnp.float32)
        return kops.pad_to_blocks(pe, bn, bd)

    def chunk_fn(q, pp):
        qe = _encode(q, metric).astype(jnp.float32)
        qp = kops.pad_to_blocks(qe, bm, bd)
        v, i = kops.cam_topk_prepadded(
            qp, pp, metric=phys_metric, k=k_eff, largest=phys_largest,
            n_valid=n, block_m=bm, block_n=bn, block_d=bd)
        v, i = kref.pad_candidates(v[:batch], i[:batch], k, phys_largest)
        return to_logical(v, float(dim)), i

    return jax.jit(prepare), jax.jit(chunk_fn)


# ---------------------------------------------------------------------------
# SearchPlan
# ---------------------------------------------------------------------------


@dataclass
class SearchPlan:
    """A compiled, reusable executable for one similarity-program shape."""

    spec: SimilaritySpec
    backend: str
    batch: int
    _prepare: Callable = field(repr=False)
    _chunk_fn: Callable = field(repr=False)
    executions: int = 0
    chunks_run: int = 0
    _pattern_cache: "OrderedDict[Tuple[int, Tuple[int, ...], str], Tuple[Any, Any]]" = \
        field(default_factory=OrderedDict, repr=False)
    # plans are shared process-wide (the plan cache hands the same object
    # to every caller), so the memo needs its own lock
    _pattern_lock: threading.Lock = field(default_factory=threading.Lock,
                                          repr=False)

    _PATTERN_CACHE_SLOTS = 4

    def _prepared_patterns(self, p_src):
        """Encode + lay out the stored patterns, memoised per input array.

        Only *immutable* inputs (``jax.Array``) are memoised — a numpy
        gallery can be mutated in place under an unchanged id/shape/dtype,
        which would silently serve stale prepared patterns.  Mutable
        inputs are re-prepared on every call (the pre-engine behaviour);
        callers wanting the memo pass the gallery as a jax array.  The
        key keeps a strong reference to the source so its id cannot be
        recycled while the entry lives.
        """
        if not isinstance(p_src, jax.Array):
            return self._prepare(jnp.asarray(p_src))
        key = (id(p_src), tuple(p_src.shape), str(p_src.dtype))
        with self._pattern_lock:
            hit = self._pattern_cache.get(key)
            if hit is not None:
                self._pattern_cache.move_to_end(key)
                return hit[1]
        prepared = self._prepare(p_src)
        with self._pattern_lock:
            self._pattern_cache[key] = (p_src, prepared)
            while len(self._pattern_cache) > self._PATTERN_CACHE_SLOTS:
                self._pattern_cache.popitem(last=False)
        return prepared

    def execute(self, *inputs):
        """Run the plan; accepts exactly the compiled module's arguments."""
        self.executions += 1
        spec = self.spec
        q_src = inputs[spec.query_arg]
        p_src = inputs[spec.pattern_arg]
        q2, lead = _as_2d(jnp.asarray(q_src))
        m = q2.shape[0]
        pp = self._prepared_patterns(p_src)

        b = self.batch
        vs, is_ = [], []
        for s in range(0, m, b):
            chunk = q2[s:s + b]
            valid = chunk.shape[0]
            if valid < b:
                chunk = jnp.pad(chunk, ((0, b - valid), (0, 0)))
            v, i = self._chunk_fn(chunk, pp)
            self.chunks_run += 1
            vs.append(v[:valid])
            is_.append(i[:valid])
        v = vs[0] if len(vs) == 1 else jnp.concatenate(vs, axis=0)
        i = is_[0] if len(is_) == 1 else jnp.concatenate(is_, axis=0)

        k = spec.k
        if m * k == _size(spec.out_v_shape):
            v = v.reshape(spec.out_v_shape)
            i = i.reshape(spec.out_i_shape)
        else:   # runtime M differs from the traced shape: mirror _as_2d
            v = v.reshape(lead + (k,))
            i = i.reshape(lead + (k,))
        return (v, i)


def _size(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


# ---------------------------------------------------------------------------
# Process-wide plan cache
# ---------------------------------------------------------------------------

_PLAN_CACHE: "OrderedDict[Tuple[SimilaritySpec, str, int], SearchPlan]" = \
    OrderedDict()
#: LRU bound — a DSE sweep over many distinct geometries must not pin
#: every plan (and its memoised galleries) forever
_MAX_PLANS = 64
_CACHE_LOCK = threading.Lock()
_STATS = {"hits": 0, "misses": 0}


def get_plan(module: Module, *, backend: str = "jnp",
             batch: Optional[int] = None) -> Optional[SearchPlan]:
    """Plan for a partitioned module, from the cache when possible.

    Returns ``None`` when the module is not a pure similarity program
    (callers then fall back to the IR interpreter).
    """
    try:
        spec = extract_plan_spec(module)
    except Exception:       # malformed/exotic IR: the interpreter handles it
        spec = None
    if spec is None:
        return None
    if backend not in ("jnp", "pallas"):
        return None
    b = batch or _pick_batch(spec.m)
    key = (spec, backend, b)
    with _CACHE_LOCK:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            _STATS["hits"] += 1
            _PLAN_CACHE.move_to_end(key)
            return plan
        _STATS["misses"] += 1
    if backend == "pallas":
        prepare, chunk_fn = _build_pallas_executable(spec, b)
    else:
        prepare, chunk_fn = _build_scan_executable(spec, b)
    plan = SearchPlan(spec=spec, backend=backend, batch=b,
                      _prepare=prepare, _chunk_fn=chunk_fn)
    with _CACHE_LOCK:
        # lost-race double insert is harmless but keep one canonical plan
        plan = _PLAN_CACHE.setdefault(key, plan)
        _PLAN_CACHE.move_to_end(key)
        while len(_PLAN_CACHE) > _MAX_PLANS:
            _PLAN_CACHE.popitem(last=False)
    return plan


def plan_cache_stats() -> Dict[str, int]:
    """Process-wide cache counters (hits / misses / live plans)."""
    with _CACHE_LOCK:
        return {"hits": _STATS["hits"], "misses": _STATS["misses"],
                "plans": len(_PLAN_CACHE)}


def clear_plan_cache() -> None:
    with _CACHE_LOCK:
        _PLAN_CACHE.clear()
        _STATS["hits"] = _STATS["misses"] = 0
