"""Search-plan execution engine: compiled, cached execution of ``cim`` IR.

The functional executor (:mod:`repro.core.executor`) interprets the
partitioned ``cim`` IR op-by-op — every ``cim.search_tile`` /
``cim.merge_partial`` / ``cim.topk_tile`` dispatches eagerly, and the
vectorized fallback rebuilds its search closure on every call.  That is
fine for pinning semantics, but it makes DSE sweeps (Fig. 8, Table II)
pay Python-loop and retrace costs at every design point.

This module compiles a partitioned similarity program **once** into a
:class:`SearchPlan`:

* ``extract_plan_spec`` structurally analyses the ``cim_partitioned``
  module (either the explicit Fig.-5d tile ops or the loop-structured
  ``cim.tiled_similarity`` form) and distils it to a
  :class:`SimilaritySpec` — metric, k, tile geometry, grid, operand
  wiring and output shapes.  Anything that is not a pure similarity
  program yields ``None`` and execution falls back to the interpreter.
* ``get_plan`` keys a **process-wide plan cache** on
  ``(spec, backend, micro-batch)``: recompiling the same program — or a
  different program with identical structure, which is exactly what a
  DSE sweep over optimization targets produces — returns the *same*
  ``SearchPlan`` object and reuses its jitted executable instead of
  re-tracing.
* The plan's executable replaces the per-tile Python loops with a
  ``jax.lax.scan`` over row tiles (vertical tournament merge carried
  through the scan) around an inner scan over column tiles (horizontal
  partial-distance accumulation).  Peak intermediate is one
  ``(batch, tile_rows)`` distance block — never the dense ``(M, N)``
  matrix.
* Queries are **micro-batched**: M is chunked into plan-sized batches
  streamed through the jitted executable, so million-query workloads
  reuse one trace and bounded memory.  Pattern encoding/padding is
  hoisted out of the per-chunk path (and memoised per input array), so
  repeated executions against the same stored patterns skip it entirely.

Numerical contract: the plan performs the *same* arithmetic in the same
order as the interpreted tile ops — bit-identical results for the
integer metrics (hamming / dot), float-tolerance for eucl / cos — as
pinned by ``repro.kernels.ref``.

Bit-packed fast path (binary / ternary search)
----------------------------------------------
Binary and bipolar metrics (hamming, dot, cos) physically search *bits*:
the float encoding spends 32 bytes of traffic per byte of information.
``get_plan(..., pack=...)`` (auto-on for those metrics) packs the
gallery and each query chunk into uint32 lanes (``kernels.packing``) and
runs the identical tile tournament over ``popcount(q ^ p)`` — or
``popcount((q ^ p) & care)`` for TCAM wildcard (ternary) programs, whose
per-pattern care mask arrives as a third module argument.  Counts are
the same integers the float path produces, so results stay bit-identical
while the resident gallery shrinks 32x; column tiling happens in lane
units (``ceil(dims_per_tile / 32)`` lanes per tile).  The packing choice
joins the plan-cache key, as does the operand dtype recorded in the
spec.

Range plans (second plan family)
--------------------------------
Pure *range* programs — ``cim.range_search`` / ``cim.tiled_range_search``,
the paper's TH threshold mode and the analog-CAM interval match behind
decision-forest inference — compile into a :class:`RangePlan` living in
the same process-wide cache (its frozen :class:`RangeSpec` can never
collide with a :class:`SimilaritySpec` key).  The executable shares the
tile geometry, query micro-batching, pattern memoisation, packed
popcount path and sharded ``shard_map`` machinery; the difference is
the epilogue: no cross-tile tournament — every stored row owns a match
line, so row tiles (and shards) *concatenate* their boolean match
slices in ascending row order.  See the range section of
``docs/engine.md`` and ``docs/forest.md``.

Gallery mutation (online-learning workloads)
--------------------------------------------
Stored patterns are immutable *inputs* to a plan, but serving workloads
whose galleries change under live traffic — HDC retraining rewrites
class vectors, one-shot learners add exemplars — cannot afford a full
re-prepare (re-encode + re-pack + re-layout of every row) per touched
row.  :meth:`SearchPlan.update_rows` / :meth:`RangePlan.update_rows`
apply a row-granular mutation and rewrite **only the touched row
tiles** of the memoised prepared layout: the updated gallery comes back
as a fresh immutable ``jax.Array`` whose pattern-memo entry was seeded
incrementally (packed lanes repacked per tile, sharded layouts
re-pinned so each tile lands on its owning shard, pallas layouts
row-scattered).  Results after an update are bit-identical to
re-preparing the mutated gallery from scratch — the incremental path
runs the same encode/pack/layout arithmetic on the touched tiles.
``REPRO_ENGINE_UPDATE=off`` disables the incremental rewrite (the
mutation still happens; the next dispatch re-prepares in full).

Sharded execution (multi-device)
--------------------------------
``get_plan(..., shards=S)`` compiles the same program against a 1-D
``("data",)`` device mesh (`repro.launch.mesh.make_data_mesh`): the
gallery's pattern rows are sharded across devices at row-tile
granularity via ``shard_map`` — the *bank* level of the paper's §III-B
hierarchy, one level above the row-tile (subarray) scan each device
already runs — and the per-device candidate lists meet in a cross-device
top-k tournament merge with exactly :func:`kref.merge_topk` semantics
(ascending shard order == ascending global row order, so ties still
break toward the lower index).  Results are bit-identical to the
single-device plan for integer metrics.  The shard count is part of the
plan-cache key; requests beyond the host's device count clamp.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec

from ..kernels import packing as kpack
from ..kernels import ref as kref
from ..launch.mesh import make_data_mesh
from .envcfg import env_flag, env_int
from .ir import Module

__all__ = [
    "SimilaritySpec", "RangeSpec", "SearchPlan", "RangePlan",
    "PendingSearch", "extract_plan_spec", "extract_range_spec",
    "get_plan", "merge_shard_candidates", "module_for_spec",
    "plan_cache_stats", "clear_plan_cache",
]


# ---------------------------------------------------------------------------
# Metric / encoding helpers (physical CAM domain <-> logical metric domain)
# ---------------------------------------------------------------------------


def _metric_values(metric: str, largest: bool):
    """How the physical CAM search relates to the logical metric."""
    if metric in ("dot", "cos"):
        # bipolar: argmax dot == argmin hamming; report dot values
        return "hamming", (lambda h, dim: dim - 2.0 * h), (not largest)
    if metric == "eucl":
        return "eucl", (lambda d, dim: d), largest
    if metric == "hamming":
        return "hamming", (lambda h, dim: h), largest
    raise ValueError(metric)


def _encode(x: jax.Array, metric: str) -> jax.Array:
    if metric in ("dot", "cos", "hamming"):
        return (x > 0).astype(jnp.float32) if metric != "hamming" else x
    return x


def _bits(x: jax.Array, metric: str) -> jax.Array:
    """Cell bits for the packed path (bool array, unpacked).

    ``dot``/``cos`` binarise exactly like :func:`_encode` (``x > 0``),
    so the packed path sees the same cells as the float path for *any*
    real input.  ``hamming`` inputs are {0, 1} by the kernel contract
    (see ``kernels/ref.py``); the bit is ``x != 0``, which coincides
    with the unpacked mismatch count on contract-conforming data —
    packed hamming plans *enforce* the contract at dispatch time
    (:func:`_check_binary_cells`) because collapsing a richer alphabet
    to bits would silently change results.
    """
    return (x > 0) if metric in ("dot", "cos") else (x != 0)


def _check_binary_cells(x, what: str) -> None:
    """Packed-hamming contract guard: values must be {0, 1} / booleans.

    The unpacked path computes a true elementwise mismatch count for
    *any* alphabet; the packed path only sees bits.  Rather than let
    bipolar or multi-bit data (e.g. {-1, +1}, value_bits > 1 cells)
    silently collapse to all-match, reject it here — one host-side pass
    over data the pack step reads anyway (galleries only on a memo
    miss).  ``pack=False`` keeps the general float path for such data.
    """
    a = np.asarray(x)
    if a.dtype == np.bool_:
        return
    if not bool(((a == 0) | (a == 1)).all()):
        raise ValueError(
            f"packed hamming search requires binary {{0, 1}} {what} "
            f"(got values outside the CAM cell contract); pass "
            f"pack=False to run the float path on non-binary data")


#: metrics with a bit-packed physical search (binary cells, integer counts)
_PACKABLE_METRICS = ("hamming", "dot", "cos")


def _resolve_pack(spec: "SimilaritySpec", pack: Optional[bool]) -> bool:
    """Effective packing choice for a plan.

    ``None`` (auto) packs every packable metric — the physical search is
    bit-identical either way, and the packed gallery is 32x smaller —
    unless ``REPRO_ENGINE_PACK`` is ``off``/``0``.  An explicit
    ``pack=True`` on an analog metric is a hard error: euclidean
    distances have no binary cell encoding.
    """
    packable = spec.metric in _PACKABLE_METRICS
    if pack is None:
        return packable and env_flag("REPRO_ENGINE_PACK", True)
    if pack and not packable:
        raise ValueError(
            f"packed execution requires a binary/bipolar metric "
            f"(hamming/dot/cos), got {spec.metric!r}")
    return bool(pack)


def _update_enabled() -> bool:
    """``REPRO_ENGINE_UPDATE`` kill switch for the incremental update
    path: ``off``/``0`` makes ``update_rows`` still apply the mutation
    but skip the memo rewrite — the next dispatch re-prepares in full
    (the pre-update behaviour, kept reachable for triage)."""
    return env_flag("REPRO_ENGINE_UPDATE", True)


def _normalize_faults(faults):
    """Validate/normalise a dispatch-time fault model.

    The engine duck-types the model (``is_null`` /
    ``corrupt_stored(srcs, spec)``, hashable) so ``repro.core`` never
    imports ``repro.faults``.  Null models normalise to ``None`` —
    that guarantees ``FaultModel(p_stuck=0)`` takes *exactly* the clean
    code path (same memo key, same prepared layout, bit-identical
    results).  The model is deliberately **not** part of the plan-cache
    key: faults corrupt the stored sources host-side before the jitted
    prepare, so the executables never retrace across fault epochs.
    """
    if faults is None:
        return None
    if not hasattr(faults, "is_null") or not hasattr(faults, "corrupt_stored"):
        raise TypeError(
            f"faults must be a repro.faults.FaultModel-like object, "
            f"got {type(faults).__name__}")
    return None if faults.is_null else faults


#: source-gallery mutation for update_rows.  The donating variant
#: reuses the old gallery's buffer (an in-place scatter — the 80 MB
#: copy of a large float gallery is otherwise the dominant update
#: cost); callers opt in only when nothing else references the array.
_scatter_rows = jax.jit(lambda g, i, r: g.at[i].set(r))
_scatter_rows_donated = jax.jit(lambda g, i, r: g.at[i].set(r),
                                donate_argnums=0)


def _tile_rows_block(arr: jax.Array, tiles: jax.Array, tr: int,
                     n: int) -> jax.Array:
    """Gather whole row tiles out of a stored operand (jit-traceable).

    Returns the ``(len(tiles) * tr, dim)`` row block covering the given
    row tiles, with slots at/beyond row ``n`` zeroed — exactly the
    content a full prepare lays out for those tiles (it zero-pads
    ragged rows *after* encoding, but every cell encoding maps 0 -> 0,
    so zeroing the raw rows first is equivalent).
    """
    tiles = jnp.asarray(tiles, jnp.int32)
    row_ids = (tiles[:, None] * tr
               + jnp.arange(tr, dtype=jnp.int32)).reshape(-1)
    valid = row_ids < n
    block = jnp.asarray(arr)[jnp.minimum(row_ids, n - 1)]
    return jnp.where(valid[:, None], block, 0)


def _as_2d(q: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    if q.ndim == 1:
        return q[None, :], ()
    if q.ndim == 2:
        return q, (q.shape[0],)
    lead = q.shape[:-1]
    return q.reshape((-1, q.shape[-1])), lead


# ---------------------------------------------------------------------------
# Plan spec: everything a compiled search needs, hashable for the cache
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimilaritySpec:
    """Structural summary of a partitioned similarity program.

    Two modules with equal specs compile to interchangeable executables;
    the spec (plus backend and micro-batch size) *is* the plan-cache key.
    """

    metric: str
    k: int
    largest: bool              # logical polarity (metric domain)
    tile_rows: int             # R: pattern rows per subarray
    dims_per_tile: int         # logical values per column tile
    grid_rows: int
    grid_cols: int
    m: int                     # traced query count (batch hint only)
    n: int                     # pattern rows
    dim: int                   # logical feature dimension
    query_arg: int             # positions in module.arguments
    pattern_arg: int
    out_v_shape: Tuple[int, ...]
    out_i_shape: Tuple[int, ...]
    #: TCAM ternary search: module-argument position of the per-pattern
    #: care mask ((N, D), non-zero = compared cell, 0 = wildcard)
    care_arg: Optional[int] = None
    #: IR dtypes of the (query, pattern[, care]) operands.  Part of the
    #: plan key: with packed uint32 operands in play, two programs with
    #: identical geometry but different operand dtypes must not share an
    #: executable.
    in_dtypes: Tuple[str, ...] = ("f32", "f32")


@dataclass(frozen=True)
class RangeSpec:
    """Structural summary of a partitioned range-search program.

    The second plan family: boolean match search (paper TH mode /
    analog-CAM interval match) instead of top-k.  Shares the plan
    cache, tile geometry, micro-batching, pattern memoisation, packing
    and sharding machinery with :class:`SimilaritySpec` plans; being a
    distinct (frozen, hashable) type, its cache keys can never collide
    with a similarity plan's.
    """

    #: "threshold" (distance vs tau) or "interval" (aCAM lo/hi cells)
    mode: str
    #: logical metric for threshold mode; the sentinel "interval" for
    #: interval mode (not packable, encoding is a passthrough)
    metric: str
    threshold: float           # static: part of the plan key
    below: bool                # True: match iff value <= tau; False: >=
    tile_rows: int
    dims_per_tile: int
    grid_rows: int
    grid_cols: int
    m: int                     # traced query count (batch hint only)
    n: int                     # stored rows
    dim: int
    query_arg: int
    #: module-argument positions of the stored operands — (patterns,)
    #: for threshold mode, (lo, hi) for interval mode
    pattern_args: Tuple[int, ...]
    out_shape: Tuple[int, ...]
    in_dtypes: Tuple[str, ...] = ("f32", "f32")


_SIM_OPS = {"cim.similarity", "cim.tiled_similarity"}
_TILE_OPS = {"cim.search_tile", "cim.merge_partial", "cim.topk_tile",
             "cim.reshape_result"}
_RANGE_OPS = {"cim.range_search", "cim.tiled_range_search"}


def extract_plan_spec(module: Module) -> Optional[SimilaritySpec]:
    """Return the spec if ``module`` is a pure similarity program.

    Accepted shape: ``cim.acquire`` / one ``cim.execute`` whose region is a
    single fused (or partitioned) similarity / ``cim.release`` /
    ``func.return`` of the execute's two results.  Host ops, multiple
    similarities, or operands that are not module arguments all return
    ``None`` (the interpreter remains the general path).
    """
    args = module.arguments
    arg_pos = {id(a): i for i, a in enumerate(args)}
    execute = None
    ret = None
    for op in module.body.operations:
        if op.name in ("cim.acquire", "cim.release"):
            continue
        if op.name == "cim.execute":
            if execute is not None:
                return None
            execute = op
            continue
        if op.name == "func.return":
            ret = op
            continue
        return None
    if execute is None or ret is None or len(execute.results) != 2:
        return None
    if [id(v) for v in ret.operands] != [id(r) for r in execute.results]:
        return None

    body = execute.body_ops()
    names = {op.name for op in body} - {"cim.yield"}
    if names and names <= _SIM_OPS and len(body) == 2:
        sim = body[0]
        yld = body[1]
        if yld.name != "cim.yield" or \
                [id(v) for v in yld.operands] != [id(r) for r in sim.results]:
            return None
        if len(sim.operands) not in (2, 3):
            return None
        q, p = sim.operands[0], sim.operands[1]
        care = sim.operands[2] if len(sim.operands) == 3 else None
        if any(id(v) not in arg_pos for v in sim.operands):
            return None
        a = sim.attributes
        if care is not None and a["metric"] != "hamming":
            return None     # TCAM wildcards only exist for hamming search
        n, dim = p.type.shape[-2], p.type.shape[-1]
        tr = int(a.get("tile_rows", 0)) or n
        dpt = int(a.get("dims_per_tile", 0)) or dim
        gr = int(a.get("grid_rows", 0)) or -(-n // tr)
        gc = int(a.get("grid_cols", 0)) or -(-dim // dpt)
        m = 1
        for d in q.type.shape[:-1]:
            m *= d
        return SimilaritySpec(
            metric=a["metric"], k=int(a["k"]), largest=bool(a["largest"]),
            tile_rows=tr, dims_per_tile=dpt, grid_rows=gr, grid_cols=gc,
            m=m, n=n, dim=dim,
            query_arg=arg_pos[id(q)], pattern_arg=arg_pos[id(p)],
            out_v_shape=tuple(sim.results[0].type.shape),
            out_i_shape=tuple(sim.results[1].type.shape),
            care_arg=None if care is None else arg_pos[id(care)],
            in_dtypes=tuple(v.type.dtype for v in sim.operands))

    if names and names <= _TILE_OPS:
        return _spec_from_unrolled(body, arg_pos)
    return None


def _spec_from_unrolled(body, arg_pos) -> Optional[SimilaritySpec]:
    """Reconstruct the spec from explicit Fig.-5d tile ops."""
    searches = [op for op in body if op.name == "cim.search_tile"]
    topks = [op for op in body if op.name == "cim.topk_tile"]
    reshapes = [op for op in body if op.name == "cim.reshape_result"]
    yields = [op for op in body if op.name == "cim.yield"]
    if not searches or not topks or len(reshapes) != 1 or len(yields) != 1:
        return None
    fin, yld = reshapes[0], yields[0]
    if [id(v) for v in yld.operands] != [id(r) for r in fin.results]:
        return None
    first = searches[0]
    q, p = first.operands
    if id(q) not in arg_pos or id(p) not in arg_pos:
        return None
    for st in searches:
        if [id(v) for v in st.operands] != [id(q), id(p)]:
            return None
    sa = first.attributes
    metric = sa["metric"]
    phys_largest = bool(sa.get("phys_largest", False))
    largest = (not phys_largest) if metric in ("dot", "cos") else phys_largest
    gr = 1 + max(int(op.attributes["row_tile"]) for op in searches)
    gc = 1 + max(int(op.attributes["col_tile"]) for op in searches)
    if len(searches) != gr * gc or len(topks) != gr:
        return None
    n, dim = p.type.shape[-2], p.type.shape[-1]
    fa = fin.attributes
    return SimilaritySpec(
        metric=metric, k=int(fa["k"]), largest=largest,
        tile_rows=int(sa["tile_rows"]), dims_per_tile=int(sa["dims_per_tile"]),
        grid_rows=gr, grid_cols=gc, m=int(fa["m"]), n=n, dim=dim,
        query_arg=arg_pos[id(q)], pattern_arg=arg_pos[id(p)],
        out_v_shape=tuple(fin.results[0].type.shape),
        out_i_shape=tuple(fin.results[1].type.shape),
        in_dtypes=(q.type.dtype, p.type.dtype))


def extract_range_spec(module: Module) -> Optional[RangeSpec]:
    """Return the spec if ``module`` is a pure range-search program.

    Accepted shape mirrors :func:`extract_plan_spec` with a single
    ``cim.range_search`` / ``cim.tiled_range_search`` (one ``i1``
    result) in the execute body, operands fed straight from module
    arguments.  Anything else returns ``None`` — the interpreter stays
    the general path.
    """
    args = module.arguments
    arg_pos = {id(a): i for i, a in enumerate(args)}
    execute = None
    ret = None
    for op in module.body.operations:
        if op.name in ("cim.acquire", "cim.release"):
            continue
        if op.name == "cim.execute":
            if execute is not None:
                return None
            execute = op
            continue
        if op.name == "func.return":
            ret = op
            continue
        return None
    if execute is None or ret is None or len(execute.results) != 1:
        return None
    if [id(v) for v in ret.operands] != [id(r) for r in execute.results]:
        return None

    body = execute.body_ops()
    if len(body) != 2:
        return None
    rs, yld = body
    if rs.name not in _RANGE_OPS or yld.name != "cim.yield":
        return None
    if [id(v) for v in yld.operands] != [id(r) for r in rs.results]:
        return None
    if any(id(v) not in arg_pos for v in rs.operands):
        return None
    a = rs.attributes
    mode = a.get("mode", "threshold")
    if mode == "interval":
        if len(rs.operands) != 3:
            return None
        metric = "interval"
    else:
        if len(rs.operands) != 2 or "metric" not in a:
            return None
        metric = a["metric"]
    q = rs.operands[0]
    stored = rs.operands[1]
    n, dim = stored.type.shape[-2], stored.type.shape[-1]
    tr = int(a.get("tile_rows", 0)) or n
    dpt = int(a.get("dims_per_tile", 0)) or dim
    gr = int(a.get("grid_rows", 0)) or -(-n // tr)
    gc = int(a.get("grid_cols", 0)) or -(-dim // dpt)
    m = 1
    for d in q.type.shape[:-1]:
        m *= d
    return RangeSpec(
        mode=mode, metric=metric,
        threshold=float(a.get("threshold", 0.0)),
        below=bool(a.get("below", True)),
        tile_rows=tr, dims_per_tile=dpt, grid_rows=gr, grid_cols=gc,
        m=m, n=n, dim=dim,
        query_arg=arg_pos[id(q)],
        pattern_args=tuple(arg_pos[id(v)] for v in rs.operands[1:]),
        out_shape=tuple(rs.results[0].type.shape),
        in_dtypes=tuple(v.type.dtype for v in rs.operands))


def module_for_spec(spec, m: Optional[int] = None) -> Module:
    """Synthesise a ``cim`` module whose extracted spec matches ``spec``.

    Round-trips a plan spec back to IR: a single fused similarity /
    range-search op with the spec's tile geometry injected as op
    attributes (``extract_plan_spec`` / ``extract_range_spec`` read
    ``tile_rows`` / ``dims_per_tile`` off the fused op, so the
    partition pass need not run).  Module arguments are in canonical
    order — query, stored operand(s)[, care] — which is also the
    argument order of every partitioned module in this repo.

    This is what lets the hardening layer compile a *physical* plan
    (replicated/spare rows — a different ``n``) for an existing
    logical spec, and the serving layer rebuild an interpreter-
    executable module for its degraded fallback chain, without keeping
    the original module object around.
    """
    from .cim_dialect import (make_acquire, make_execute, make_range_search,
                              make_release, make_similarity, make_yield)
    from .ir import Builder, TensorType

    m = spec.m if m is None else int(m)
    n, dim = spec.n, spec.dim
    geom = {"tile_rows": spec.tile_rows, "dims_per_tile": spec.dims_per_tile}
    is_range = isinstance(spec, RangeSpec)
    interval = is_range and spec.mode == "interval"
    n_stored = 3 if (interval or getattr(spec, "care_arg", None) is not None) \
        else 2
    arg_types = [TensorType((m, dim))] + \
        [TensorType((n, dim)) for _ in range(n_stored - 1)]
    mod = Module("spec_synth", arg_types)
    b = Builder(mod.body)
    dev = make_acquire(b)
    if is_range:
        out_types = [TensorType((m, n), "i1")]
    else:
        out_types = [TensorType((m, spec.k)), TensorType((m, spec.k), "i32")]
    exe = make_execute(b, dev.result, list(mod.arguments), out_types)
    blk = exe.region().block()
    if interval:
        q_a, lo_a, hi_a = mod.arguments
        op = make_range_search(blk, q_a, lo=lo_a, hi=hi_a, extra_attrs=geom)
    elif is_range:
        q_a, p_a = mod.arguments
        op = make_range_search(blk, q_a, patterns=p_a, metric=spec.metric,
                               threshold=spec.threshold, below=spec.below,
                               extra_attrs=geom)
    else:
        q_a, p_a = mod.arguments[0], mod.arguments[1]
        care_a = mod.arguments[2] if n_stored == 3 else None
        op = make_similarity(blk, q_a, p_a, metric=spec.metric, k=spec.k,
                             largest=spec.largest, care=care_a,
                             extra_attrs=geom)
    make_yield(blk, op.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    return mod


# ---------------------------------------------------------------------------
# Compiled executables
# ---------------------------------------------------------------------------

def _pick_batch(m: int) -> int:
    """Micro-batch size: next power of two, clamped to the chunk cap.

    The clamp is applied *after* rounding up — a non-power-of-two cap
    (say 1000) must still bound the batch, not let the round-up jump
    over it to 1024.
    """
    cap = env_int("REPRO_ENGINE_MAX_CHUNK", 1024, min_value=1)
    b = 8
    while b < min(max(m, 1), cap):
        b *= 2
    return min(b, cap)


def _col_dist_fn(spec: SimilaritySpec, packed: bool) -> Callable:
    """Per-column-tile partial distance: ``f(qc, pr) -> (B, tr) float32``.

    ``pr`` is the tuple of per-tile pattern leaves — ``(patterns,)`` or
    ``(patterns, care)`` for ternary.  Unpacked leaves are float slabs
    fed to the oracle arithmetic; packed leaves are uint32 lanes fed to
    XOR+popcount.  Both produce the *same integers* for the integer
    metrics (exact in float32), so the tournament downstream is
    bit-identical whichever representation runs.
    """
    phys_metric, _, _ = _metric_values(spec.metric, spec.largest)
    ternary = spec.care_arg is not None
    if packed:
        def f(qc, pr):
            return kref.packed_distances(qc, pr[0],
                                         pr[1] if ternary else None)
        return f
    if ternary:
        return lambda qc, pr: kref.ternary_distances(qc, pr[0], pr[1])
    return lambda qc, pr: kref.distances(qc, pr[0], phys_metric)


def _tile_tournament(spec: SimilaritySpec, batch: int, col_dist: Callable):
    """The row-tile tournament shared by the single-device and sharded
    executables: ``scan(qt, pt, roffs)`` runs the column-tile partial-sum
    scan + per-tile top-k + vertical ``merge_topk`` tournament over the
    row tiles in ``pt`` (physical domain), with global row offsets
    ``roffs``.  ``pt`` is a tuple of pattern leaves (see
    :func:`_col_dist_fn`), each ``(gr, gc, tr, lanes-or-dpt)``.  One
    definition keeps every execution path bit-identical by construction.
    """
    k = spec.k
    _, _, phys_largest = _metric_values(spec.metric, spec.largest)
    tr = spec.tile_rows
    n = spec.n
    kk = min(k, tr)
    lose = -jnp.inf if phys_largest else jnp.inf
    # rows beyond the unsharded physical extent exist only on shard-
    # padding tiles; their candidates become pad_candidates sentinels
    # (a no-op for the single-device grid, which never exceeds it)
    n_phys = spec.grid_rows * tr

    def tile_topk(qt, pr, roff):
        """Per-row-tile candidate list (pr leaves: (gc, tr, ...))."""

        def col_step(acc, xs):
            qc = xs[0]                  # horizontal merge, oracle arithmetic
            return acc + col_dist(qc, xs[1:]), None

        dist, _ = jax.lax.scan(
            col_step, jnp.zeros((batch, tr), jnp.float32), (qt, *pr))
        gidx = roff + jnp.arange(tr, dtype=jnp.int32)
        dist = jnp.where(gidx[None, :] < n, dist, lose)      # ragged rows
        key = dist if phys_largest else -dist
        _, idx = jax.lax.top_k(key, kk)
        v = jnp.take_along_axis(dist, idx, axis=-1)
        i = idx.astype(jnp.int32) + roff
        i = jnp.where(i < n_phys, i, 2 ** 30)
        return kref.pad_candidates(v, i, k, phys_largest)

    def scan(qt, pt, roffs):
        def row_step(carry, xs):
            cv, ci = carry                                   # vertical merge
            tiles, roff = xs
            v, i = tile_topk(qt, tiles, roff)
            return kref.merge_topk(cv, ci, v, i, k=k,
                                   largest=phys_largest), None

        # tile 0 seeds the tournament (its padded-slot indices are real
        # column positions, which the interpreter also reports), remaining
        # row tiles stream through the scan.
        init = tile_topk(qt, tuple(x[0] for x in pt), roffs[0])
        (v, i), _ = jax.lax.scan(
            row_step, init, (tuple(x[1:] for x in pt), roffs[1:]))
        return v, i

    return scan


def _layout_queries(q, spec: SimilaritySpec, batch: int,
                    packed: bool = False):
    """Encode + pad + split a query chunk into per-column-tile slabs.

    Packed: each column tile's ``dims_per_tile`` cells pack into their
    own ``ceil(dpt/32)`` uint32 lanes — tiling in **lane units** — so a
    tile's partial count covers exactly the same logical dims as the
    float slab it replaces (tail bits of a tile's last lane are zero in
    queries, patterns, and care masks alike).
    """
    gc, dpt, dim = spec.grid_cols, spec.dims_per_tile, spec.dim
    if packed:
        qb = _bits(q, spec.metric)
        qp = jnp.pad(qb, ((0, 0), (0, gc * dpt - dim)))
        return kpack.pack_bits(qp.reshape(batch, gc, dpt)).transpose(1, 0, 2)
    qe = _encode(q, spec.metric).astype(jnp.float32)
    qp = jnp.pad(qe, ((0, 0), (0, gc * dpt - dim)))
    return qp.reshape(batch, gc, dpt).transpose(1, 0, 2)     # (gc, B, dpt)


def _lay_patterns(p, care, spec: SimilaritySpec, gr_total: int,
                  packed: bool) -> Tuple[jax.Array, ...]:
    """Gallery (+ care mask) laid out as per-subarray tiles.

    Returns the tuple of pattern leaves the tournament scans over:
    ``(patterns,)`` or ``(patterns, care)``, each
    ``(gr_total, gc, tile_rows, dpt-or-lanes)``.  ``gr_total`` exceeds
    ``spec.grid_rows`` only for sharded plans (shard-padding tiles).
    """
    tr, dpt, gc = spec.tile_rows, spec.dims_per_tile, spec.grid_cols
    n, dim = spec.n, spec.dim
    pad = ((0, gr_total * tr - n), (0, gc * dpt - dim))

    def lay(x):
        return x.reshape(gr_total, tr, gc, dpt).transpose(0, 2, 1, 3)

    if packed:
        pe = jnp.pad(_bits(jnp.asarray(p), spec.metric), pad)
        leaves = [kpack.pack_bits(lay(pe))]
        if care is not None:
            ce = jnp.pad(jnp.asarray(care) != 0, pad)
            leaves.append(kpack.pack_bits(lay(ce)))
        return tuple(leaves)
    pe = jnp.pad(_encode(jnp.asarray(p), spec.metric).astype(jnp.float32),
                 pad)
    leaves = [lay(pe)]
    if care is not None:
        ce = jnp.pad((jnp.asarray(care) != 0).astype(jnp.float32), pad)
        leaves.append(lay(ce))
    return tuple(leaves)


def _tile_row_update(spec, packed: bool, placement=None):
    """Row-update closure for the tile-layout executables (jnp + sharded).

    ``update(prepared, srcs, idx)`` re-lays only the row tiles touched
    by ``idx`` — running the *same* encode/pack/layout code a full
    prepare runs, on a ``len(tiles)``-tile slice — and scatters them
    into the prepared leaves.  ``srcs`` are the **post-mutation** stored
    operands, ``(gallery,)`` / ``(gallery, care)`` / ``(lo, hi)``.
    ``placement`` (sharded plans) re-pins each updated leaf to the mesh
    so every rewritten tile lands back on its owning shard.
    """
    def relay(prepared, srcs, tiles):
        # tiles has static length under jit; the jit cache retraces per
        # touched-tile count, which a retraining loop repeats constantly
        nt = tiles.shape[0]
        tspec = replace(spec, n=nt * spec.tile_rows)
        blocks = [_tile_rows_block(s, tiles, spec.tile_rows, spec.n)
                  for s in srcs]
        if isinstance(spec, SimilaritySpec):
            fresh = _lay_patterns(blocks[0],
                                  blocks[1] if len(blocks) > 1 else None,
                                  tspec, nt, packed)
        else:
            fresh = _lay_range_patterns(blocks, tspec, nt, packed)
        return tuple(leaf.at[tiles].set(f.astype(leaf.dtype))
                     for leaf, f in zip(prepared, fresh))

    # the donating variant scatters the fresh tiles into the old
    # prepared leaves' buffers in place (the caller just invalidated
    # the old layout — see update_rows(donate=True))
    relay_jit = jax.jit(relay)
    relay_don = jax.jit(relay, donate_argnums=0)

    def update(prepared, srcs, idx, donate=False):
        tiles = np.unique(np.asarray(idx, np.int64) // spec.tile_rows)
        fn = relay_don if donate else relay_jit
        out = fn(tuple(prepared), tuple(srcs), jnp.asarray(tiles, jnp.int32))
        if placement is not None:
            out = tuple(jax.device_put(x, placement) for x in out)
        return out

    return update


def _row_scatter_update(spec, packed: bool, interval: bool = False):
    """Row-update closure for the pallas executables, whose prepared
    layout is the block-padded 2-D operand itself: encode/pack just the
    touched rows and scatter them (padding lanes/columns stay zero)."""
    def relay(prepared, srcs, j):
        out = []
        for leaf, s in zip(prepared, srcs):
            rows = jnp.asarray(s)[j]
            if packed:
                enc = kpack.pack_bits(_bits(rows, spec.metric))
            elif interval:
                enc = rows.astype(jnp.float32)
            else:
                enc = _encode(rows, spec.metric).astype(jnp.float32)
            enc = jnp.pad(enc, ((0, 0), (0, leaf.shape[1] - enc.shape[1])))
            out.append(leaf.at[j].set(enc.astype(leaf.dtype)))
        return tuple(out)

    relay_jit = jax.jit(relay)
    relay_don = jax.jit(relay, donate_argnums=0)

    def update(prepared, srcs, idx, donate=False):
        fn = relay_don if donate else relay_jit
        return fn(tuple(prepared), tuple(srcs),
                  jnp.asarray(np.asarray(idx, np.int64)))

    return update


def _build_scan_executable(spec: SimilaritySpec, batch: int,
                           packed: bool = False):
    """(prepare_patterns, chunk_fn, row_update) for the jnp
    (reference-tiled) backend.

    ``chunk_fn`` mirrors ``kernels.ref.cam_topk_tiled`` exactly — same
    partial-sum order, same stable top-k and tournament merges — but as a
    ``lax.scan`` over the (row_tile, col_tile) grid, so the jaxpr stays
    small at any grid size and XLA pipelines the tiles.  With
    ``packed=True`` the same scan runs over uint32 lane tiles
    (XOR+popcount partial counts) — identical integers, 1/32nd the
    resident gallery.
    """
    _, to_logical, _ = _metric_values(spec.metric, spec.largest)
    gr, dim = spec.grid_rows, spec.dim
    scan = _tile_tournament(spec, batch, _col_dist_fn(spec, packed))

    def prepare(p, care=None):
        return _lay_patterns(p, care, spec, gr, packed)

    def chunk_fn(q, pt):
        qt = _layout_queries(q, spec, batch, packed)
        roffs = jnp.arange(gr, dtype=jnp.int32) * spec.tile_rows
        v, i = scan(qt, pt, roffs)
        return to_logical(v, float(dim)), i

    return jax.jit(prepare), jax.jit(chunk_fn), _tile_row_update(spec, packed)


def _build_sharded_executable(spec: SimilaritySpec, batch: int, shards: int,
                              packed: bool = False):
    """(prepare_patterns, chunk_fn, row_update) sharding gallery rows
    over a device mesh.

    Device ``d`` holds row tiles ``[d*tps, (d+1)*tps)`` of the padded
    gallery (``tps = ceil(grid_rows / shards)``) and runs the *same*
    row-tile scan as the single-device executable over its shard — the
    bank level of the paper's hierarchy.  ``chunk_fn`` returns the
    per-device candidate lists still *sharded* ``(shards, batch, k)``;
    the cross-device tournament happens in :func:`merge_shard_candidates`
    at result-materialisation time.

    The per-device program deliberately contains **no collective**: an
    ``all_gather`` at the tail of each chunk would make every device's
    stream rendezvous with the slowest shard before its next chunk could
    start, serialising the pipeline exactly where the serving layer
    needs overlap.  Collective-free shard programs let each device run
    chunk after chunk back-to-back; the merge is O(shards·k) per query
    and runs off-stream.

    Padding tiles introduced by uneven division live *beyond* the
    single-device physical row count ``grid_rows * tile_rows``; their
    candidates are rewritten to the ``pad_candidates`` sentinels
    (losing value, index ``2**30``) so a sharded plan emits bit-identical
    output to the unsharded one even when ``n < k`` leaves losing slots
    visible.
    """
    _, to_logical, _ = _metric_values(spec.metric, spec.largest)
    tr, gr = spec.tile_rows, spec.grid_rows
    dim = spec.dim
    mesh = make_data_mesh(shards)
    tps = -(-gr // shards)          # row tiles per shard
    gr_pad = shards * tps
    scan = _tile_tournament(spec, batch, _col_dist_fn(spec, packed))

    def prepare(p, care=None):
        pt = _lay_patterns(p, care, spec, gr_pad, packed)
        # lay the row-tile axis out over the mesh once, behind the plan
        # cache — chunk execution never re-shards the gallery
        sh = NamedSharding(mesh, PartitionSpec("data"))
        return tuple(jax.device_put(x, sh) for x in pt)

    def local_scan(qt, pt):
        """One device's shard of the row-tile tournament (no collectives)."""
        d = jax.lax.axis_index("data")
        roffs = (d * tps + jnp.arange(tps, dtype=jnp.int32)) * tr
        v, i = scan(qt, pt, roffs)
        # logical-domain conversion is elementwise and strictly monotone,
        # so the host-side merge can run directly on logical values with
        # the logical polarity and still match the physical tournament
        return to_logical(v, float(dim))[None], i[None]   # (1, B, k)

    def chunk_fn(q, pt):
        qt = _layout_queries(q, spec, batch, packed)
        # PartitionSpec("data") applies prefix-wise to every pattern leaf
        return shard_map(
            local_scan, mesh=mesh,
            in_specs=(PartitionSpec(), PartitionSpec("data")),
            out_specs=(PartitionSpec("data"), PartitionSpec("data")),
            check_rep=False)(qt, pt)                          # (S, B, k)

    sh = NamedSharding(mesh, PartitionSpec("data"))
    return prepare, jax.jit(chunk_fn), _tile_row_update(spec, packed,
                                                        placement=sh)


def merge_shard_candidates(values: Any, indices: Any, *, k: int,
                           largest: bool) -> Tuple[Any, Any]:
    """Cross-shard top-k tournament, host-side.

    Takes the ``(shards, batch, k)`` per-device candidate lists a sharded
    ``chunk_fn`` emits and reduces them to ``(batch, k)``.  Semantically
    identical to folding :func:`kref.merge_topk` over shards in ascending
    order: concatenation in shard order is concatenation in ascending
    global-row order, and a *stable* argsort on the (negated, for
    ``largest``) values breaks ties toward the lower global index exactly
    like ``lax.top_k`` does in the on-device merges.  No arithmetic
    happens here — only selection on already-computed values — so
    integer-metric results stay bit-identical to the single-device plan.
    """
    av = np.asarray(values)
    ai = np.asarray(indices)
    s, b, kk = av.shape
    vv = np.transpose(av, (1, 0, 2)).reshape(b, s * kk)
    ii = np.transpose(ai, (1, 0, 2)).reshape(b, s * kk)
    key = -vv if largest else vv
    sel = np.argsort(key, axis=-1, kind="stable")[:, :k]
    return (np.take_along_axis(vv, sel, axis=-1),
            np.take_along_axis(ii, sel, axis=-1))


def _build_pallas_executable(spec: SimilaritySpec, batch: int,
                             packed: bool = False):
    """(prepare_patterns, chunk_fn, row_update) driving the fused
    Pallas kernels.

    Pattern encoding and block padding run once per stored array (hoisted
    behind the plan cache) instead of on every ``cam_topk`` call.  With
    ``packed=True`` the packed XOR+popcount kernel runs over uint32
    lanes (lane-blocked grid) instead of the float MXU decomposition —
    candidates are bit-identical either way.
    """
    from ..kernels import ops as kops

    metric, k = spec.metric, spec.k
    phys_metric, to_logical, phys_largest = _metric_values(metric, spec.largest)
    n, dim = spec.n, spec.dim
    ternary = spec.care_arg is not None
    k_eff = min(k, n)
    bn = max(8, min(spec.tile_rows, n))
    bd = min(spec.dims_per_tile, dim)
    bm = min(128, max(8, batch))
    bl = max(1, min(kpack.lanes(bd), kpack.lanes(dim)))  # lane-unit tiling

    def prepare(p, care=None):
        if packed:
            pp = kops.pad_to_blocks(
                kpack.pack_bits(_bits(jnp.asarray(p), metric)), bn, bl)
            if care is None:
                return (pp,)
            cp = kops.pad_to_blocks(
                kpack.pack_bits(jnp.asarray(care) != 0), bn, bl)
            return (pp, cp)
        pe = _encode(jnp.asarray(p), metric).astype(jnp.float32)
        return (kops.pad_to_blocks(pe, bn, bd),)

    def chunk_fn(q, pp):
        if packed:
            qp = kops.pad_to_blocks(
                kpack.pack_bits(_bits(q, metric)), bm, bl)
            v, i = kops.cam_topk_packed_prepadded(
                qp, pp[0], pp[1] if ternary else None, k=k_eff,
                largest=phys_largest, n_valid=n, block_m=bm, block_n=bn,
                block_l=bl)
        else:
            qe = _encode(q, metric).astype(jnp.float32)
            qp = kops.pad_to_blocks(qe, bm, bd)
            v, i = kops.cam_topk_prepadded(
                qp, pp[0], metric=phys_metric, k=k_eff,
                largest=phys_largest, n_valid=n, block_m=bm, block_n=bn,
                block_d=bd)
        v, i = kref.pad_candidates(v[:batch], i[:batch], k, phys_largest)
        return to_logical(v, float(dim)), i

    return jax.jit(prepare), jax.jit(chunk_fn), _row_scatter_update(spec,
                                                                    packed)


# ---------------------------------------------------------------------------
# Range-search executables (boolean match: TH threshold / aCAM interval)
# ---------------------------------------------------------------------------


def _range_col_fn(spec: RangeSpec, packed: bool) -> Callable:
    """Per-column-tile partial value for a range program.

    Threshold mode accumulates the same physical distances the search
    path uses (packed popcounts included); interval mode accumulates
    aCAM *violation counts* — ``(q < lo) | (q > hi)`` per cell, summed.
    Both are additive over column tiles, so the scan reproduces the
    dense oracle exactly (integer counts) or in identical float order
    (eucl, mirroring :func:`kref.tiled_distances`).
    """
    if spec.mode == "interval":
        # the pinned oracle IS the per-tile function: violation counts
        # are additive over dimension tiles by construction
        return lambda qc, pr: kref.acam_violations(qc, pr[0], pr[1])
    phys_metric, _, _ = _metric_values(spec.metric, True)
    if packed:
        return lambda qc, pr: kref.packed_distances(qc, pr[0])
    return lambda qc, pr: kref.distances(qc, pr[0], phys_metric)


def _range_tile_scan(spec: RangeSpec, batch: int, col_fn: Callable):
    """Row-tile scan for range programs: ``scan(qt, pt)`` accumulates
    each row tile's physical value over the column tiles and returns
    the stacked ``(n_tiles, batch, tile_rows)`` value blocks.  No
    tournament — every stored row keeps its own match line."""
    tr = spec.tile_rows

    def tile_value(qt, pr):
        def col_step(acc, xs):
            return acc + col_fn(xs[0], xs[1:]), None

        dist, _ = jax.lax.scan(
            col_step, jnp.zeros((batch, tr), jnp.float32), (qt, *pr))
        return dist

    def scan(qt, pt):
        def row_step(carry, xs):
            return carry, tile_value(qt, xs)

        _, dists = jax.lax.scan(row_step, None, pt)
        return dists                                    # (gr, B, tr)

    return scan


def _range_compare(spec: RangeSpec):
    """Value block -> boolean match block, in the logical metric domain."""
    if spec.mode == "interval":
        return lambda d: d == 0
    _, to_logical, _ = _metric_values(spec.metric, True)
    tau, below, dim = spec.threshold, spec.below, float(spec.dim)
    if below:
        return lambda d: to_logical(d, dim) <= tau
    return lambda d: to_logical(d, dim) >= tau


def _lay_range_patterns(pats, spec: RangeSpec, gr_total: int,
                        packed: bool) -> Tuple[jax.Array, ...]:
    """Stored operands laid out as per-subarray tiles.

    ``(patterns,)`` or ``(lo, hi)``, each ``(gr_total, gc, tr, X)``.
    Zero padding is interval-safe: padded dims carry ``q = lo = hi =
    0`` (never a violation) and padded rows land beyond ``spec.n``,
    where finalize slices them off.
    """
    leaves = []
    for p in pats:
        leaves.extend(_lay_patterns(p, None, spec, gr_total, packed))
    return tuple(leaves)


def _build_range_scan_executable(spec: RangeSpec, batch: int,
                                 packed: bool = False):
    """(prepare, chunk_fn, row_update) for the jnp range path: chunk_fn
    returns the ``(batch, grid_rows * tile_rows)`` boolean match block."""
    gr = spec.grid_rows
    scan = _range_tile_scan(spec, batch, _range_col_fn(spec, packed))
    compare = _range_compare(spec)

    def prepare(*pats):
        return _lay_range_patterns(pats, spec, gr, packed)

    def chunk_fn(q, pt):
        qt = _layout_queries(q, spec, batch, packed)
        d = scan(qt, pt)                                 # (gr, B, tr)
        hit = compare(d)
        return hit.transpose(1, 0, 2).reshape(batch, -1)

    return jax.jit(prepare), jax.jit(chunk_fn), _tile_row_update(spec, packed)


def _build_range_sharded_executable(spec: RangeSpec, batch: int, shards: int,
                                    packed: bool = False):
    """(prepare, chunk_fn, row_update) sharding stored rows over a
    device mesh.

    Same bank-level row split as the sharded search executable, but the
    per-device outputs are boolean match slices that simply
    *concatenate* in shard order (== ascending global row order) at
    finalize — range search has no cross-shard tournament, so the
    per-device program is trivially collective-free.
    """
    tr, gr = spec.tile_rows, spec.grid_rows
    mesh = make_data_mesh(shards)
    tps = -(-gr // shards)
    gr_pad = shards * tps
    scan = _range_tile_scan(spec, batch, _range_col_fn(spec, packed))
    compare = _range_compare(spec)

    def prepare(*pats):
        pt = _lay_range_patterns(pats, spec, gr_pad, packed)
        sh = NamedSharding(mesh, PartitionSpec("data"))
        return tuple(jax.device_put(x, sh) for x in pt)

    def local_scan(qt, pt):
        d = scan(qt, pt)                                 # (tps, B, tr)
        hit = compare(d)
        return hit.transpose(1, 0, 2).reshape(batch, tps * tr)[None]

    def chunk_fn(q, pt):
        qt = _layout_queries(q, spec, batch, packed)
        return shard_map(
            local_scan, mesh=mesh,
            in_specs=(PartitionSpec(), PartitionSpec("data")),
            out_specs=PartitionSpec("data"),
            check_rep=False)(qt, pt)                     # (S, B, tps*tr)

    sh = NamedSharding(mesh, PartitionSpec("data"))
    return prepare, jax.jit(chunk_fn), _tile_row_update(spec, packed,
                                                        placement=sh)


def _build_range_pallas_executable(spec: RangeSpec, batch: int):
    """(prepare, chunk_fn, row_update) driving the fused aCAM /
    threshold kernels.

    The match threshold (or the ``violations == 0`` test) happens at
    block-extraction time inside the kernel — only an int8 matrix
    leaves it.  Unpacked operands only (the packed popcount path lives
    in the jnp executable).
    """
    from ..kernels import ops as kops

    n, dim = spec.n, spec.dim
    bn = max(8, min(spec.tile_rows, n))
    bd = min(spec.dims_per_tile, dim)
    bm = min(128, max(8, batch))
    interval = spec.mode == "interval"
    if not interval:
        phys_metric, _, _ = _metric_values(spec.metric, True)
        to_logical = "bipolar" if spec.metric in ("dot", "cos") \
            else "identity"

    def prepare(*pats):
        if interval:
            return tuple(
                kops.pad_to_blocks(jnp.asarray(p).astype(jnp.float32),
                                   bn, bd)
                for p in pats)
        pe = _encode(jnp.asarray(pats[0]), spec.metric).astype(jnp.float32)
        return (kops.pad_to_blocks(pe, bn, bd),)

    def chunk_fn(q, pp):
        if interval:
            qp = kops.pad_to_blocks(q.astype(jnp.float32), bm, bd)
            hit = kops.acam_match_prepadded(
                qp, pp[0], pp[1], n_valid=n, block_m=bm, block_n=bn,
                block_d=bd)
        else:
            qe = _encode(q, spec.metric).astype(jnp.float32)
            qp = kops.pad_to_blocks(qe, bm, bd)
            hit = kops.cam_range_match_prepadded(
                qp, pp[0], metric=phys_metric, threshold=spec.threshold,
                below=spec.below, to_logical=to_logical, dim=dim,
                n_valid=n, block_m=bm, block_n=bn, block_d=bd)
        return hit[:batch] != 0

    return jax.jit(prepare), jax.jit(chunk_fn), _row_scatter_update(
        spec, packed=False, interval=interval)


# ---------------------------------------------------------------------------
# SearchPlan
# ---------------------------------------------------------------------------


@dataclass
class PendingSearch:
    """An async-dispatched search: chunk results not yet materialised.

    ``chunks`` holds ``(values, indices, valid_rows)`` per micro-batch —
    jax arrays still computing on-device.  :meth:`SearchPlan.finalize`
    turns a pending search into final ``(values, indices)``.
    """

    plan: "SearchPlan"
    m: int
    lead: Tuple[int, ...]
    chunks: list


def _src_ident(x) -> Tuple:
    """Memo identity of one stored-operand source array."""
    return (id(x), tuple(x.shape), str(x.dtype))


def _memo_insert(plan, srcs: Tuple[Any, ...], prepared,
                 faults=None) -> None:
    """Insert a prepared layout into the plan's pattern memo (LRU).

    The entry keeps strong references to the sources so their ids
    cannot be recycled while it lives — same contract as the miss path
    of :func:`_memoised_prepare`.  ``faults`` joins the key: a faulted
    layout must never shadow the clean one (or another model's).
    """
    with plan._pattern_lock:
        plan._pattern_cache[
            tuple(_src_ident(s) for s in srcs) + (faults,)] = \
            (srcs, prepared)
        slots = plan._pattern_cache_slots()
        while len(plan._pattern_cache) > slots:
            plan._pattern_cache.popitem(last=False)
            plan.pattern_evictions += 1


def _memoised_prepare(plan, srcs: Tuple[Any, ...], run: Callable[[], Any],
                      check: Callable[[], None], faults=None):
    """Per-plan pattern-prep memoisation shared by both plan families.

    ``srcs`` are the stored-operand sources the prepared layout derives
    from — ``(gallery,)``, ``(gallery, care)`` or ``(lo, hi)``; all must
    be immutable ``jax.Array`` values to be memoised (a numpy array can
    be mutated in place under an unchanged id/shape/dtype).  Mutable
    inputs re-prepare on every call and still count as telemetry misses
    — a numpy-gallery workload reading hits=0/misses=0 would look fully
    cached while re-packing the gallery on every search.  The cache
    entry keeps strong references to the sources so their ids cannot be
    recycled while it lives.  ``check`` runs only when actually
    preparing (memo hits skip it).

    ``faults`` (a normalised fault model or ``None``) is part of the
    memo key — the model is frozen/hashable, so repeated dispatches
    with the same model hit the same corrupted layout while the clean
    entry (``None``) stays untouched.
    """
    if not all(isinstance(s, jax.Array) for s in srcs):
        with plan._pattern_lock:
            plan.pattern_misses += 1
        check()
        return run()
    key = tuple(_src_ident(s) for s in srcs) + (faults,)
    with plan._pattern_lock:
        hit = plan._pattern_cache.get(key)
        if hit is not None:
            plan.pattern_hits += 1
            plan._pattern_cache.move_to_end(key)
            return hit[-1]
    check()
    prepared = run()
    with plan._pattern_lock:
        plan.pattern_misses += 1
    _memo_insert(plan, srcs, prepared, faults)
    return prepared


@dataclass
class SearchPlan:
    """A compiled, reusable executable for one similarity-program shape."""

    spec: SimilaritySpec
    backend: str
    batch: int
    _prepare: Callable = field(repr=False)
    _chunk_fn: Callable = field(repr=False)
    shards: int = 1
    #: bit-packed execution (uint32 lanes, XOR+popcount physical search)
    packed: bool = False
    #: backend-specific incremental row-update closure (see update_rows)
    _row_update: Optional[Callable] = field(default=None, repr=False)
    executions: int = 0
    chunks_run: int = 0
    pattern_hits: int = 0
    pattern_misses: int = 0
    pattern_evictions: int = 0
    #: update_rows telemetry: calls, total rows rewritten, and calls
    #: that could not take the incremental path (memo miss / kill
    #: switch / mutable sources) and fell back to full re-prepare
    row_updates: int = 0
    rows_updated: int = 0
    row_update_fallbacks: int = 0
    _pattern_cache: "OrderedDict[Tuple, Tuple[Any, ...]]" = \
        field(default_factory=OrderedDict, repr=False)
    # plans are shared process-wide (the plan cache hands the same object
    # to every caller), so the memo needs its own lock
    _pattern_lock: threading.Lock = field(default_factory=threading.Lock,
                                          repr=False)
    # executions / chunks_run are bumped from every serving worker thread
    # driving the shared plan; unguarded += would drop counts
    _stats_lock: threading.Lock = field(default_factory=threading.Lock,
                                        repr=False)

    @staticmethod
    def _pattern_cache_slots() -> int:
        """LRU bound on memoised prepared galleries (per plan).

        Small on purpose: a prepared gallery is the dominant resident
        cost of a plan (float galleries especially), and a serving
        process typically cycles between a handful of live galleries.
        ``REPRO_ENGINE_PATTERN_SLOTS`` tunes it; evictions are counted
        and surfaced via :func:`plan_cache_stats`.
        """
        return env_int("REPRO_ENGINE_PATTERN_SLOTS", 4, min_value=1)

    def _prepared_patterns(self, p_src, care_src=None, faults=None):
        """Encode + lay out the stored patterns, memoised per input array.

        Only *immutable* inputs (``jax.Array``) are memoised — a numpy
        gallery can be mutated in place under an unchanged id/shape/dtype,
        which would silently serve stale prepared patterns.  Mutable
        inputs are re-prepared on every call (the pre-engine behaviour);
        callers wanting the memo pass the gallery as a jax array.
        Ternary plans key on the (gallery, care-mask) pair — both must
        be jax arrays to memoise.

        ``faults`` (already normalised) corrupts the stored sources
        host-side *before* the jitted prepare — the executable itself
        is fault-agnostic, so injecting faults never retraces.
        """
        def check():
            # guarded before (not inside) the jitted prepare, and only
            # when actually preparing — memo hits skip it: packing
            # collapses non-binary alphabets silently, see the guard
            if self.packed and self.spec.metric == "hamming":
                _check_binary_cells(p_src, "patterns")

        srcs = (p_src,) if care_src is None else (p_src, care_src)

        def run():
            if faults is not None:
                use = faults.corrupt_stored(
                    tuple(np.asarray(s) for s in srcs), self.spec)
                return self._prepare(jnp.asarray(use[0]),
                                     *(jnp.asarray(u) for u in use[1:]))
            return self._prepare(p_src if isinstance(p_src, jax.Array)
                                 else jnp.asarray(p_src), care_src)

        return _memoised_prepare(self, srcs, run, check, faults)

    def dispatch(self, *inputs, faults=None) -> "PendingSearch":
        """Enqueue the plan's chunks without waiting for device results.

        Returns a :class:`PendingSearch` whose chunk arrays are
        async-dispatched jax values; pass it to :meth:`finalize` to
        materialise ``(values, indices)``.  The split lets a serving
        loop dispatch the next micro-batch while the device still runs
        the previous one.

        Thread-safe: the serving layer drives one shared plan from many
        worker threads.  The jitted executables are pure, the pattern
        memo has its own lock, and the stats counters are guarded here.

        ``faults`` injects a device-fault model (see ``repro.faults``):
        the stored patterns are corrupted host-side before the prepare,
        the queries and executables stay clean.  A null model is
        normalised away, so ``faults=FaultModel(p_stuck=0)`` is
        bit-identical to ``faults=None``.
        """
        faults = _normalize_faults(faults)
        with self._stats_lock:
            self.executions += 1
        spec = self.spec
        q_src = inputs[spec.query_arg]
        p_src = inputs[spec.pattern_arg]
        care_src = None if spec.care_arg is None else inputs[spec.care_arg]
        q2, lead = _as_2d(jnp.asarray(q_src))
        m = q2.shape[0]
        # host-resident queries are validated for free (they are about to
        # be transferred anyway; the serving layer always passes numpy
        # rows).  Device-resident jax queries skip the per-dispatch check
        # — np.asarray on them would block mid-dispatch and defeat the
        # async dispatch/finalize pipelining; the memo-miss gallery guard
        # still catches the realistic failure (one encoding pipeline
        # feeding both operands a non-binary alphabet).
        if self.packed and spec.metric == "hamming" and \
                not isinstance(q_src, jax.Array):
            _check_binary_cells(q_src, "queries")
        pp = self._prepared_patterns(p_src, care_src, faults)

        b = self.batch
        chunks = []
        for s in range(0, m, b):
            chunk = q2[s:s + b]
            valid = chunk.shape[0]
            if valid < b:
                chunk = jnp.pad(chunk, ((0, b - valid), (0, 0)))
            v, i = self._chunk_fn(chunk, pp)
            with self._stats_lock:
                self.chunks_run += 1
            chunks.append((v, i, valid))
        return PendingSearch(plan=self, m=m, lead=lead, chunks=chunks)

    def finalize(self, pending: "PendingSearch"):
        """Materialise a dispatched search: cross-shard merge (sharded
        plans), ragged-tail slicing, chunk concatenation, output shaping."""
        spec = self.spec
        xp = np if self.shards > 1 else jnp
        vs, is_ = [], []
        for v, i, valid in pending.chunks:
            if self.shards > 1:
                v, i = merge_shard_candidates(v, i, k=spec.k,
                                              largest=spec.largest)
            vs.append(v[:valid])
            is_.append(i[:valid])
        if not vs:      # zero queries: well-shaped empty result
            vs = [xp.zeros((0, spec.k), xp.float32)]
            is_ = [xp.zeros((0, spec.k), xp.int32)]
        v = vs[0] if len(vs) == 1 else xp.concatenate(vs, axis=0)
        i = is_[0] if len(is_) == 1 else xp.concatenate(is_, axis=0)

        m, lead, k = pending.m, pending.lead, spec.k
        if m * k == _size(spec.out_v_shape):
            v = v.reshape(spec.out_v_shape)
            i = i.reshape(spec.out_i_shape)
        else:   # runtime M differs from the traced shape: mirror _as_2d
            v = v.reshape(lead + (k,))
            i = i.reshape(lead + (k,))
        return (v, i)

    def execute(self, *inputs, faults=None):
        """Run the plan; accepts exactly the compiled module's arguments.

        Always returns jax arrays, regardless of shard count (the
        sharded finalize merges on host; converting back keeps the
        public output type shard-invariant).  Serving loops that want
        the host arrays directly use dispatch/finalize themselves.
        ``faults`` is forwarded to :meth:`dispatch`.
        """
        v, i = self.finalize(self.dispatch(*inputs, faults=faults))
        if self.shards > 1:
            v, i = jnp.asarray(v), jnp.asarray(i)
        return v, i

    # -- gallery mutation --------------------------------------------------

    def _validate_update(self, idx: np.ndarray, *new_rows) -> None:
        spec = self.spec
        if idx.ndim != 1:
            raise ValueError(f"indices must be 1-D, got shape {idx.shape}")
        if idx.size == 0:
            return
        if idx.min() < 0 or idx.max() >= spec.n:
            raise ValueError(
                f"row indices out of range for an n={spec.n} gallery")
        if np.unique(idx).size != idx.size:
            # jax scatter with duplicate indices picks an unspecified
            # winner; reject instead of silently choosing one
            raise ValueError("duplicate row indices in update_rows")
        for nr in new_rows:
            if tuple(np.shape(nr)) != (idx.size, spec.dim):
                raise ValueError(
                    f"new rows shape {np.shape(nr)} != "
                    f"({idx.size}, {spec.dim})")

    def _seed_updated_memo(self, old_srcs: Tuple[Any, ...],
                           new_srcs: Tuple[Any, ...], idx: np.ndarray,
                           donate: bool = False) -> None:
        """Derive the mutated sources' prepared layout from the old one.

        Incremental only when the old layout is memoised (immutable
        jax-array sources that have been prepared and not evicted) and
        the update path is enabled; otherwise a counted fallback — the
        next dispatch re-prepares the new sources in full, which is
        always correct, just not incremental.

        ``donate`` (the caller just invalidated the old gallery):
        the stale memo entry is popped and its prepared leaves' buffers
        are reused in place for the fresh-tile scatter — no full-leaf
        copy per update.
        """
        with self._stats_lock:
            self.row_updates += 1
            self.rows_updated += int(idx.size)
        if self._row_update is None or not _update_enabled() or \
                not all(isinstance(s, jax.Array) for s in old_srcs):
            with self._stats_lock:
                self.row_update_fallbacks += 1
            return
        # only the clean (faults=None) entry is rewritten incrementally;
        # faulted layouts re-prepare in full on the next faulted
        # dispatch — fault masks are position-keyed, so a row moving
        # through update_rows must re-draw its cell faults anyway
        key = tuple(_src_ident(s) for s in old_srcs) + (None,)
        with self._pattern_lock:
            if donate:       # the old layout must not outlive its buffers
                hit = self._pattern_cache.pop(key, None)
            else:
                hit = self._pattern_cache.get(key)
        if hit is None:
            with self._stats_lock:
                self.row_update_fallbacks += 1
            return
        prepared = self._row_update(hit[-1], new_srcs, idx, donate)
        _memo_insert(self, new_srcs, prepared)

    def update_rows(self, gallery, indices, new_rows, care=None, *,
                    donate: bool = False):
        """Row-granular gallery mutation with incremental re-preparation.

        Returns the updated gallery as a fresh immutable ``jax.Array``
        whose prepared layout was derived from ``gallery``'s memoised
        layout by rewriting only the row tiles ``indices`` touch —
        encode/pack/layout runs on those tiles alone (sharded plans
        re-pin the leaves so each tile lands on its owning shard), so an
        online-learning workload touching 1% of a large gallery skips
        ~99% of the re-prepare work.  Results are bit-identical to a
        full re-prepare of the mutated gallery.

        ``care`` must be the plan's care mask for ternary programs (the
        memo keys on the (gallery, care) pair; the mask itself is
        immutable).  If ``gallery``'s layout is not memoised — numpy
        source, never dispatched, or evicted — the mutation still
        happens and the next dispatch re-prepares in full (counted in
        ``row_update_fallbacks``).

        ``donate=True`` reuses ``gallery``'s device buffer for the
        mutation (in-place scatter instead of a full-gallery copy —
        the copy otherwise dominates large-gallery updates).  Only pass
        it when nothing else will read ``gallery`` afterwards: the old
        array is invalidated, exactly like jit donation.
        """
        spec = self.spec
        if (care is None) != (spec.care_arg is None):
            raise ValueError("care mask must be passed iff the plan's "
                             "program is ternary")
        idx = np.atleast_1d(np.asarray(indices, np.int64))
        self._validate_update(idx, new_rows)
        g = gallery if isinstance(gallery, jax.Array) else jnp.asarray(gallery)
        if idx.size == 0:
            return g
        if self.packed and spec.metric == "hamming":
            _check_binary_cells(new_rows, "updated rows")
        scatter = _scatter_rows_donated if donate else _scatter_rows
        new_g = scatter(g, jnp.asarray(idx),
                        jnp.asarray(new_rows).astype(g.dtype))
        old_srcs = (g,) if care is None else (g, care)
        new_srcs = (new_g,) if care is None else (new_g, care)
        self._seed_updated_memo(old_srcs, new_srcs, idx, donate)
        return new_g


@dataclass
class RangePlan(SearchPlan):
    """A compiled, reusable executable for one range-search program.

    Same plan-cache citizenship, micro-batching, pattern memoisation,
    packing and sharding as :class:`SearchPlan`; the result is a single
    ``(M, N)`` boolean match matrix instead of ``(values, indices)``.
    ``spec`` is a :class:`RangeSpec`.
    """

    def _prepared_patterns(self, *pats, faults=None):
        def check():
            if self.packed and self.spec.metric == "hamming":
                _check_binary_cells(pats[0], "patterns")

        def run():
            if faults is not None:
                use = faults.corrupt_stored(
                    tuple(np.asarray(p) for p in pats), self.spec)
                return self._prepare(*(jnp.asarray(u) for u in use))
            return self._prepare(*(p if isinstance(p, jax.Array)
                                   else jnp.asarray(p) for p in pats))

        return _memoised_prepare(self, tuple(pats), run, check, faults)

    def dispatch(self, *inputs, faults=None) -> "PendingSearch":
        """Enqueue the plan's chunks; ``chunks`` hold ``(match, valid)``
        pairs of async boolean blocks.  Same thread-safety contract and
        ``faults`` semantics as the search plan (the serving layer
        drives one shared plan)."""
        faults = _normalize_faults(faults)
        with self._stats_lock:
            self.executions += 1
        spec = self.spec
        q_src = inputs[spec.query_arg]
        pats = tuple(inputs[i] for i in spec.pattern_args)
        q2, lead = _as_2d(jnp.asarray(q_src))
        m = q2.shape[0]
        if self.packed and spec.metric == "hamming" and \
                not isinstance(q_src, jax.Array):
            _check_binary_cells(q_src, "queries")
        pp = self._prepared_patterns(*pats, faults=faults)

        b = self.batch
        chunks = []
        for s in range(0, m, b):
            chunk = q2[s:s + b]
            valid = chunk.shape[0]
            if valid < b:
                chunk = jnp.pad(chunk, ((0, b - valid), (0, 0)))
            hit = self._chunk_fn(chunk, pp)
            with self._stats_lock:
                self.chunks_run += 1
            chunks.append((hit, valid))
        return PendingSearch(plan=self, m=m, lead=lead, chunks=chunks)

    def finalize(self, pending: "PendingSearch"):
        """Materialise a dispatched range search into the boolean match
        matrix: concatenate per-shard slices (shard order == ascending
        global row order — no tournament), drop padded rows/chunks,
        shape for the compiled module."""
        spec = self.spec
        xp = np if self.shards > 1 else jnp
        outs = []
        for hit, valid in pending.chunks:
            if self.shards > 1:
                h = np.asarray(hit)                       # (S, B, cols)
                h = np.transpose(h, (1, 0, 2)).reshape(h.shape[1], -1)
            else:
                h = hit
            outs.append(h[:valid, :spec.n])
        if not outs:    # zero queries: well-shaped empty result
            outs = [xp.zeros((0, spec.n), bool)]
        match = outs[0] if len(outs) == 1 else xp.concatenate(outs, axis=0)
        m, lead = pending.m, pending.lead
        if m * spec.n == _size(spec.out_shape):
            return match.reshape(spec.out_shape)
        return match.reshape(lead + (spec.n,))

    def execute(self, *inputs, faults=None):
        """Run the plan; returns the ``(M, N)`` boolean match matrix (a
        jax array regardless of shard count, like the search plan)."""
        match = self.finalize(self.dispatch(*inputs, faults=faults))
        return jnp.asarray(match) if self.shards > 1 else match

    def update_rows(self, stored, indices, new_rows, care=None, *,
                    donate: bool = False):
        """Row-granular mutation of a range plan's stored operands.

        ``stored`` is the current stored content — the pattern array
        for threshold mode, the ``(lo, hi)`` pair for interval mode —
        and ``new_rows`` matches that structure with ``(len(indices),
        dim)`` row blocks.  Returns the updated operand(s) in the same
        structure (jax arrays), memo-seeded incrementally exactly like
        :meth:`SearchPlan.update_rows` (including the ``donate``
        buffer-reuse contract).
        """
        if care is not None:
            raise ValueError("range plans have no care operand")
        spec = self.spec
        multi = len(spec.pattern_args) == 2
        olds = tuple(stored) if multi else (stored,)
        news = tuple(new_rows) if multi else (new_rows,)
        if len(olds) != len(spec.pattern_args) or len(news) != len(olds):
            raise ValueError(
                f"expected {len(spec.pattern_args)} stored operand(s) "
                f"and matching new-row block(s)")
        idx = np.atleast_1d(np.asarray(indices, np.int64))
        self._validate_update(idx, *news)
        gj = tuple(o if isinstance(o, jax.Array) else jnp.asarray(o)
                   for o in olds)
        if idx.size == 0:
            return gj if multi else gj[0]
        if self.packed and spec.metric == "hamming":
            _check_binary_cells(news[0], "updated rows")
        j = jnp.asarray(idx)
        scatter = _scatter_rows_donated if donate else _scatter_rows
        upd = tuple(scatter(g, j, jnp.asarray(nr).astype(g.dtype))
                    for g, nr in zip(gj, news))
        self._seed_updated_memo(gj, upd, idx, donate)
        return upd if multi else upd[0]


def _size(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


# ---------------------------------------------------------------------------
# Process-wide plan cache
# ---------------------------------------------------------------------------

_PLAN_CACHE: "OrderedDict[Tuple[SimilaritySpec, str, int, int, bool], SearchPlan]" = \
    OrderedDict()
#: LRU bound — a DSE sweep over many distinct geometries must not pin
#: every plan (and its memoised galleries) forever
_MAX_PLANS = 64
_CACHE_LOCK = threading.Lock()
#: pattern_* entries retain the pattern-memo counters of plans evicted
#: from the LRU, keeping plan_cache_stats() monotonic across evictions
_STATS = {"hits": 0, "misses": 0,
          "pattern_hits": 0, "pattern_misses": 0, "pattern_evictions": 0}


def _retire_plan(plan: SearchPlan) -> None:
    """Fold an evicted plan's pattern counters into the retained stats.

    Caller holds ``_CACHE_LOCK``; lock order ``_CACHE_LOCK`` ->
    ``_pattern_lock`` is safe (no path acquires them in reverse).
    """
    with plan._pattern_lock:
        _STATS["pattern_hits"] += plan.pattern_hits
        _STATS["pattern_misses"] += plan.pattern_misses
        _STATS["pattern_evictions"] += plan.pattern_evictions
        plan.pattern_hits = plan.pattern_misses = plan.pattern_evictions = 0


def _normalize_shards(shards: Optional[int]) -> int:
    """Effective shard count: ``None``/<=1 means unsharded; requests are
    clamped to the host's device count (a plan asking for 8-way sharding
    on a 1-device host degrades to the single-device executable)."""
    if shards is None or shards <= 1:
        return 1
    return max(1, min(int(shards), jax.device_count()))


def get_plan(module: Module, *, backend: str = "jnp",
             batch: Optional[int] = None,
             shards: Optional[int] = None,
             pack: Optional[bool] = None) -> Optional[SearchPlan]:
    """Plan for a partitioned module, from the cache when possible.

    ``shards > 1`` selects the multi-device executable: gallery rows
    sharded over a ``("data",)`` mesh, cross-device ``merge_topk``
    tournament (see ``_build_sharded_executable``).  The effective shard
    count is part of the plan-cache key.

    ``pack`` selects bit-packed execution (uint32 lanes, XOR+popcount):
    ``None`` auto-packs binary/bipolar metrics (hamming / dot / cos) —
    bit-identical results at 1/32nd the gallery footprint — ``False``
    forces the float path, ``True`` on an analog metric raises.  The
    effective packing joins the plan-cache key: a packed and an unpacked
    plan for the same geometry are different executables and must never
    collide (their prepared operands have different dtypes).

    Returns ``None`` when the module is not a pure similarity program
    (callers then fall back to the IR interpreter).
    """
    try:
        spec = extract_plan_spec(module)
        if spec is None:
            spec = extract_range_spec(module)
    except Exception:       # malformed/exotic IR: the interpreter handles it
        spec = None
    if spec is None:
        return None
    if backend not in ("jnp", "pallas"):
        return None
    if shards is not None and shards > 1 and backend != "jnp":
        # checked on the *requested* count, before device clamping, so
        # the refusal does not depend on how many devices this host has
        raise ValueError(
            f"sharded plans require the 'jnp' backend, got {backend!r}")
    is_range = isinstance(spec, RangeSpec)
    packed = _resolve_pack(spec, pack)
    if is_range and backend == "pallas" and packed:
        # the fused range kernels take float cells; the packed popcount
        # range path lives in the jnp executable
        if pack:
            raise ValueError(
                "packed range search requires the 'jnp' backend")
        packed = False
    if getattr(spec, "care_arg", None) is not None and not packed \
            and backend == "pallas":
        raise ValueError(
            "ternary (care-masked) search on the pallas backend requires "
            "packed execution; pass pack=True (and unset "
            "REPRO_ENGINE_PACK=off if the kill switch disabled auto-pack)")
    s = _normalize_shards(shards)
    b = batch or _pick_batch(spec.m)
    key = (spec, backend, b, s, packed)
    with _CACHE_LOCK:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            _STATS["hits"] += 1
            _PLAN_CACHE.move_to_end(key)
            return plan
        _STATS["misses"] += 1
    if is_range:
        if s > 1:
            prepare, chunk_fn, row_update = _build_range_sharded_executable(
                spec, b, s, packed=packed)
        elif backend == "pallas":
            prepare, chunk_fn, row_update = _build_range_pallas_executable(
                spec, b)
        else:
            prepare, chunk_fn, row_update = _build_range_scan_executable(
                spec, b, packed=packed)
        plan = RangePlan(spec=spec, backend=backend, batch=b, shards=s,
                         packed=packed, _prepare=prepare, _chunk_fn=chunk_fn,
                         _row_update=row_update)
    else:
        if s > 1:
            prepare, chunk_fn, row_update = _build_sharded_executable(
                spec, b, s, packed=packed)
        elif backend == "pallas":
            prepare, chunk_fn, row_update = _build_pallas_executable(
                spec, b, packed=packed)
        else:
            prepare, chunk_fn, row_update = _build_scan_executable(
                spec, b, packed=packed)
        plan = SearchPlan(spec=spec, backend=backend, batch=b, shards=s,
                          packed=packed, _prepare=prepare, _chunk_fn=chunk_fn,
                          _row_update=row_update)
    with _CACHE_LOCK:
        # lost-race double insert is harmless but keep one canonical plan
        plan = _PLAN_CACHE.setdefault(key, plan)
        _PLAN_CACHE.move_to_end(key)
        while len(_PLAN_CACHE) > _MAX_PLANS:
            _, evicted = _PLAN_CACHE.popitem(last=False)
            _retire_plan(evicted)
    return plan


def plan_cache_stats() -> Dict[str, int]:
    """Process-wide cache counters.

    Plan cache (hits / misses / live plans) plus the pattern-prep memo
    counters (each plan's memoised prepared-gallery LRU — see
    ``SearchPlan._prepared_patterns``): ``pattern_hits`` /
    ``pattern_misses`` / ``pattern_evictions``, summed over the live
    plans plus the retained totals of plans the 64-slot LRU evicted —
    monotonic until :func:`clear_plan_cache` resets everything.
    """
    # the whole aggregation holds _CACHE_LOCK so a concurrent eviction
    # cannot move a plan's counters into _STATS between the snapshot and
    # the live sum (which would transiently undercount); the established
    # lock order _CACHE_LOCK -> _pattern_lock makes the nesting safe
    with _CACHE_LOCK:
        out = {"hits": _STATS["hits"], "misses": _STATS["misses"],
               "plans": len(_PLAN_CACHE)}
        ph = _STATS["pattern_hits"]
        pm = _STATS["pattern_misses"]
        pe = _STATS["pattern_evictions"]
        for p in _PLAN_CACHE.values():
            with p._pattern_lock:
                ph += p.pattern_hits
                pm += p.pattern_misses
                pe += p.pattern_evictions
    out.update(pattern_hits=ph, pattern_misses=pm, pattern_evictions=pe)
    return out


def clear_plan_cache() -> None:
    with _CACHE_LOCK:
        _PLAN_CACHE.clear()
        for k in _STATS:
            _STATS[k] = 0
