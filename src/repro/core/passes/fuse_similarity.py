"""Execute-block fusion + Algorithm 1 (SimilarityMatching) from the paper.

``FuseExecuteBlocks`` merges maximal dataflow-connected runs of
``cim.acquire / cim.execute / cim.release`` triples into a single execute
block (the paper's ``cim-fuse-ops`` analysis: blocks whose ops cannot be
lowered individually are fused so patterns can be recovered).

``SimilarityMatching`` then inspects each execute block's op list exactly as
Algorithm 1 does: a fast size gate (4 ops for dot-product / Euclidean
patterns, 6-8 for cosine — sizes include the ``cim.yield`` terminator and
binary-div expansion) followed by DFG matching, rewriting matched bodies to
one fused ``cim.similarity`` op.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from ..cim_dialect import make_yield
from ..ir import Builder, Module, Operation, Pass, Region, Block, TensorType, Value


def _producer_in(block_ops: List[Operation], v: Value) -> Optional[Operation]:
    for op in block_ops:
        if v in op.results:
            return op
    return None


class FuseExecuteBlocks(Pass):
    name = "cim-fuse-ops"

    def run(self, module: Module, ctx: Dict[str, Any]) -> Module:
        ops = module.ops()
        new = Module(module.name, [a.type for a in module.arguments])
        vmap: Dict[Value, Value] = {}
        for old_a, new_a in zip(module.arguments, new.arguments):
            new_a.name = old_a.name
            vmap[old_a] = new_a
        b = Builder(new.body)

        # group program-order runs of (acquire, execute, release)
        runs: List[List[Operation]] = []
        i = 0
        current: List[Operation] = []
        tail: List[Operation] = []
        while i < len(ops):
            op = ops[i]
            if (op.name == "cim.acquire" and i + 2 < len(ops)
                    and ops[i + 1].name == "cim.execute"
                    and ops[i + 2].name == "cim.release"):
                current.append(ops[i + 1])
                i += 3
                continue
            if current:
                runs.append(current)
                current = []
            tail.append(op)
            i += 1
        if current:
            runs.append(current)

        if len(runs) != 1 or tail and any(t.name != "func.return" for t in tail):
            # conservative: only fuse the single-run straight-line case the
            # paper targets; otherwise emit the input unchanged.
            return module

        executes = runs[0]
        # inline all execute bodies into one region
        inner_map: Dict[Value, Value] = dict(vmap)
        body = Block()
        yielded: List[Value] = []
        for exe in executes:
            region_ops = exe.body_ops()
            ys: List[Value] = []
            for rop in region_ops:
                if rop.name == "cim.yield":
                    ys = [inner_map.get(v, v) for v in rop.operands]
                    continue
                cloned = rop.clone(inner_map)
                body.append(cloned)
            # outer results of this execute alias its yielded values
            for outer_r, y in zip(exe.results, ys):
                inner_map[outer_r] = y
            yielded = ys

        handle_op = Operation("cim.acquire", [], [executes[0].operands[0].type])
        new.body.append(handle_op)
        # operands of the fused execute = outer values used inside
        defined = {id(v) for op in body.operations for v in op.results}
        free: List[Value] = []
        for op in body.operations:
            for v in op.operands:
                if id(v) not in defined and v not in free:
                    free.append(v)
        make_yield(body, yielded)
        result_types = [v.type for v in yielded]
        exe = Operation("cim.execute", [handle_op.result, *free], result_types,
                        regions=[Region([body])])
        new.body.append(exe)
        new.body.append(Operation("cim.release", [handle_op.result]))
        # map original return values
        ret_vals = []
        for v in module.return_values():
            mapped = inner_map.get(v, vmap.get(v, v))
            if mapped in yielded:
                ret_vals.append(exe.results[yielded.index(mapped)])
            else:
                ret_vals.append(mapped)
        b.ret(ret_vals)
        return new


# ---------------------------------------------------------------------------
# Algorithm 1: SimilarityMatching
# ---------------------------------------------------------------------------


def _match_similarity(body_ops: List[Operation]) -> Optional[Dict[str, Any]]:
    """Implements Algorithm 1's ``similarDFG`` via structural backward match.

    Returns a dict with keys: queries, patterns, k, largest, metric,
    result_types — or None if no pattern matches.
    """
    from ..cim_dialect import SHAPE_OPS
    yield_op = body_ops[-1]
    if yield_op.name != "cim.yield":
        return None
    compute = body_ops[:-1]
    # Algorithm 1's opSize gate counts compute ops; unsqueeze/squeeze are
    # shape metadata and transparent to the DFG match
    n_ops = 1 + sum(1 for op in compute if op.name not in SHAPE_OPS)
    topks = [op for op in compute if op.name == "cim.topk"]
    if len(topks) != 1:
        return None
    topk = topks[0]
    # fused block must only expose the topk results
    produced = {id(r) for op in compute for r in op.results}
    for y in yield_op.operands:
        if id(y) in produced and y not in topk.results:
            return None

    src = _producer_in(compute, topk.operands[0])
    if src is None:
        return None
    k = int(topk.attributes["k"])
    largest = bool(topk.attributes.get("largest", True))
    rts = [r.type for r in topk.results]

    # -- DotProdSimPattern: transpose -> matmul -> topk  (opSize gate == 4)
    if src.name == "cim.matmul" and n_ops == 4:
        tr = _producer_in(compute, src.operands[1])
        if tr is not None and tr.name == "cim.transpose":
            return dict(queries=src.operands[0], patterns=tr.operands[0],
                        k=k, largest=largest, metric="dot", result_types=rts,
                        pattern="DotProdSimPattern")
    # -- EuclNormPattern: sub -> norm -> topk  (opSize gate == 4)
    if src.name == "cim.norm" and n_ops == 4:
        sub = _producer_in(compute, src.operands[0])
        if sub is not None and sub.name == "cim.sub":
            def peel_shape_ops(v: Value) -> Value:
                p = _producer_in(compute, v)
                while p is not None and p.name in ("cim.unsqueeze",
                                                   "cim.squeeze"):
                    v = p.operands[0]
                    p = _producer_in(compute, v)
                return v
            a, bb = (peel_shape_ops(o) for o in sub.operands)
            # queries = the broadcast (M, 1, D)/unsqueezed side; patterns =
            # the (N, D) stored side
            qry, pat = (a, bb) if a.type.rank <= bb.type.rank else (bb, a)
            if a.type.rank == bb.type.rank:
                # un-broadcast case: left operand is the query by convention
                qry, pat = a, bb
            return dict(queries=qry, patterns=pat, k=k, largest=largest,
                        metric="eucl", result_types=rts,
                        pattern="EuclNormPattern")
    # -- CosSimPattern: norm, norm, transpose, matmul, div(s) -> topk.
    # The paper's gate is opSize == 6 with a ternary div; our frontend
    # expands it to two binary divs + a transpose of the norm, so the
    # equivalent gate is 6..9 (documented deviation).
    if src.name == "cim.div" and 6 <= n_ops <= 9:
        # peel one or two div levels (binary-div expansion of the paper's
        # ternary div(v4, v2, v1))
        node = src
        divisors: List[Value] = []
        for _ in range(2):
            divisors.append(node.operands[1])
            nxt = _producer_in(compute, node.operands[0])
            if nxt is None:
                return None
            if nxt.name != "cim.div":
                break
            node = nxt
        mm = nxt
        if mm.name != "cim.matmul":
            return None
        tr = _producer_in(compute, mm.operands[1])
        if tr is None or tr.name != "cim.transpose":
            return None
        norms = [op for op in compute if op.name == "cim.norm"]
        if len(norms) < 1:
            return None
        return dict(queries=mm.operands[0], patterns=tr.operands[0],
                    k=k, largest=largest, metric="cos", result_types=rts,
                    pattern="CosSimPattern")
    return None


class SimilarityMatching(Pass):
    """Rewrites matched execute-block bodies to ``cim.similarity``."""

    name = "cim-similarity-match"

    def run(self, module: Module, ctx: Dict[str, Any]) -> Module:
        for exe in module.ops():
            if exe.name != "cim.execute":
                continue
            body_ops = exe.body_ops()
            m = _match_similarity(body_ops)
            if m is None:
                continue
            blk = exe.region().block()
            old_yield = body_ops[-1]
            topk_results = []
            for op in body_ops[:-1]:
                if op.name == "cim.topk":
                    topk_results = op.results
            blk.operations = []
            # how many bits of CAM storage one element needs: binary /
            # bipolar data (HDC) is 1 bit; analog-quantized features default
            # to 8 bits.  Overridable per compilation (paper's binary vs
            # multi-bit implementations).
            value_bits = ctx.get("value_bits") or {
                "f32": 8, "f64": 8, "bf16": 8, "f16": 8,
                "i8": 8, "ui8": 1, "i1": 1}.get(m["queries"].type.dtype, 8)
            sim = Operation("cim.similarity", [m["queries"], m["patterns"]],
                            m["result_types"],
                            {"metric": m["metric"], "k": m["k"],
                             "largest": m["largest"],
                             "pattern": m["pattern"],
                             "value_bits": value_bits})
            blk.append(sim)
            make_yield(blk, sim.results)
            # rewire: execute results keep identity; nothing outside changes
            ctx.setdefault("matched_patterns", []).append(m["pattern"])
        return module
