"""C4CAM transformation passes (paper §III-D).

Pipeline order (see `repro.core.compiler`):

1. ``TorchToCim``      — torch ops -> per-op acquire/execute/release blocks
2. ``FuseExecuteBlocks`` + ``SimilarityMatching`` — Algorithm 1
3. ``CompulsoryPartition`` — tile to subarray granularity, merge_partial
4. ``CimToCam``        — device allocation + write/search/read lowering
5. ``CamMap``          — nested scf.parallel hierarchy mapping + MappingPlan
"""

from .torch_to_cim import TorchToCim
from .fuse_similarity import FuseExecuteBlocks, SimilarityMatching
from .partition import CompulsoryPartition
from .cim_to_cam import CimToCam
from .cam_map import CamMap, MappingPlan

__all__ = ["TorchToCim", "FuseExecuteBlocks", "SimilarityMatching",
           "CompulsoryPartition", "CimToCam", "CamMap", "MappingPlan"]
